"""Serving benchmark: continuous batching under Poisson arrivals,
dense vs 8:16(+16:256 outlier) compressed weights.

Generates an open-loop synthetic workload (exponential interarrival gaps),
replays it through the ServingEngine for both weight formats, and reports
throughput (generated tok/s) plus p50/p99 of time-to-first-token, per-token
latency, and end-to-end request latency.

CPU smoke:   python benchmarks/serving_bench.py --smoke
Full-ish:    python benchmarks/serving_bench.py --requests 64 --rate 4 \
                 --slots 16 --gen 32
"""
from __future__ import annotations

import argparse
import dataclasses
import sys

import jax

sys.path.insert(0, "src")

from repro import configs                                      # noqa: E402
from repro.core import SparsifyConfig                          # noqa: E402
from repro.models import get_model                             # noqa: E402
from repro.models.sparse_serving import sparsify_for_serving   # noqa: E402
from repro.runtime.metrics import format_summary, summarize    # noqa: E402
from repro.serving import ServingEngine, poisson_trace, replay  # noqa: E402


def bench_cfg(args):
    cfg = configs.get_smoke(args.arch)
    if args.smoke:
        cfg = dataclasses.replace(cfg, n_layers=2, d_model=128, d_ff=256,
                                  vocab=512, remat=False)
    return cfg


def run_one(name: str, cfg, params, trace, args) -> dict:
    engine = ServingEngine(cfg, params, n_slots=args.slots,
                           max_len=args.max_len, max_queue=args.max_queue,
                           max_prefill_per_step=args.max_prefill_per_step)
    # Warm every shape the replay will hit outside the timed window: the
    # engine pads prefill batches to a fixed size per power-of-two bucket,
    # so one request per distinct bucket covers all prefill compiles, and
    # any request covers the (fixed-shape) decode/sampler compiles.
    from repro.serving.engine import _bucket
    warm_buckets = {}
    for t in trace:
        warm_buckets.setdefault(_bucket(len(t.prompt)), t)
    for t in warm_buckets.values():
        engine.submit(t.prompt, t.sampling())
    engine.run()
    engine.finished.clear()

    res = replay(engine, trace, time_scale=args.time_scale)
    summary = summarize([r.metrics for r in res["finished"]], res["wall_s"])
    summary["rejected"] = res["rejected"]
    print(format_summary(name, summary))
    if res["rejected"]:
        print(f"{'':>10}{res['rejected']} rejected by admission control")
    return summary


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama-paper")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny model + short workload (CI / CPU)")
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--rate", type=float, default=2.0,
                    help="Poisson arrival rate, requests/s")
    ap.add_argument("--prompt-min", type=int, default=8)
    ap.add_argument("--prompt-max", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--slots", type=int, default=8)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--max-queue", type=int, default=64)
    ap.add_argument("--max-prefill-per-step", type=int, default=2)
    ap.add_argument("--time-scale", type=float, default=1.0)
    ap.add_argument("--weight-pattern", default="8:16")
    ap.add_argument("--outlier-pattern", default="16:256")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)
    if args.smoke:
        args.requests = min(args.requests, 10)
        args.rate = max(args.rate, 5.0)
        args.gen = min(args.gen, 8)
        args.slots = min(args.slots, 4)
        args.max_len = min(args.max_len, 64)

    cfg = bench_cfg(args)
    zoo = get_model(cfg)
    params = zoo.init(jax.random.PRNGKey(args.seed))

    trace = poisson_trace(n_requests=args.requests, rate_per_s=args.rate,
                          vocab=cfg.vocab,
                          prompt_len=(args.prompt_min, args.prompt_max),
                          max_new_tokens=args.gen, seed=args.seed)
    print(f"model {cfg.name} ({cfg.family}), {args.requests} requests @ "
          f"{args.rate}/s Poisson, prompts {args.prompt_min}-{args.prompt_max}, "
          f"gen {args.gen}, {args.slots} slots")

    results = {"dense": run_one("dense", cfg, params, trace, args)}

    scfg = SparsifyConfig(weight_pattern=args.weight_pattern,
                          outlier_pattern=args.outlier_pattern,
                          scorer="magnitude", use_smoothquant=False)
    sparams, report = sparsify_for_serving(params, scfg)
    print(f"  sparse deploy: {report['n_layers_sparsified']} matrices, "
          f"{report['ratio']:.3f}x bytes")
    results["sparse"] = run_one("sparse", cfg, sparams, trace, args)

    d, s = results["dense"], results["sparse"]
    if d["tok_per_s"] > 0:
        print(f"sparse/dense throughput: {s['tok_per_s']/d['tok_per_s']:.2f}x")
    return results


if __name__ == "__main__":
    main()
