"""Serving benchmark: continuous batching under Poisson arrivals,
dense vs 8:16(+16:256 outlier) compressed weights, slot vs paged KV.

The scenarios:

1. Poisson open-loop workload (exponential interarrival gaps) replayed
   through the ServingEngine for each (weights, kv_layout) combination;
   reports throughput (generated tok/s) plus p50/p99 of time-to-first-
   token, per-token latency, and end-to-end request latency.
2. Shared-system-prompt burst under an EQUAL KV-memory budget: every
   request is one long shared prefix plus a short unique tail.  The slot
   layout must reserve max_len per request, capping concurrency at
   budget/max_len; the paged layout allocates blocks on demand and
   stores the shared prefix KV once (prefix cache), so it admits more
   concurrent requests and skips most prefill work (lower TTFT).
3. Long-prompt chunked-prefill stress at an EQUAL KV budget: short
   decode-heavy requests are mid-stream when very long prompts land.
   One-shot prefill stalls every decoder for the whole prompt (one giant
   inter-token gap); with ``--token-budget`` the prompt advances in
   chunks beside the decode batch.  Reports the pooled inter-token
   latency p99 (the decode-tail stall) and prefill chunk counts for both
   modes.
4. Mixed-family co-hosting: an xLSTM (ssm) engine and a dense engine
   share one host and wall clock, each replaying its own Poisson trace;
   the summary's ``families`` breakdown reports per-family tok/s and
   ttft/itl percentiles over the shared window.
5. Speculative decoding: the 8:16(+outlier) compressed model drafts
   --spec-k tokens per request per step for its DENSE COUNTERPART — the
   densified realization of the same compressed weights
   (``densify_params``), so the pair agrees the way a trained model and
   its above-threshold compression do without needing trained weights —
   on the paged layout, vs the non-speculative baseline and the
   model-free n-gram proposer on the same trace.  Records acceptance
   rate, accepted tokens/step, tok/s speedup, and a token-identity
   cross-check of every greedy stream against the baseline.
6. Equal-HBM KV dtype: bf16 vs int8 (+per-position scales) arenas sized
   to the same byte budget.  int8 admits ~2*hd/(hd+4) more slots, so
   under an oversubscribing burst it runs more requests concurrently;
   greedy replays record the quantized arena's token agreement rate
   against the bf16 reference.
7. Multi-replica fleet: N replicas behind the prefix-aware router vs
   round-robin vs ONE replica-sized engine holding the fleet's total KV
   (equal total KV budget), on a bursty multi-tenant trace with
   heavy-tailed lengths.  Reports host and critical-path tok/s (max
   per-replica busy time — what disjoint mesh slices would see),
   per-replica stats, routing-decision counters, prefix hit rate per
   policy, and a 1-vs-N token-identity cross-check.  See
   ``fleet_scenario`` for the baseline framing.

Every run also lands in a machine-readable ``BENCH_serving.json``
(--out) so the perf trajectory is tracked across PRs, with a top-level
``summary`` block (aggregate tok/s, worst-case ITL percentiles, and a
one-line digest per scenario) for trajectory diffs that don't have to
walk the per-scenario trees.  Summaries record the engine placement
(device count, mesh shape) and per-device tok/s; ``--mesh 1x8`` runs the
mesh-native tensor-parallel engine so single- vs multi-device results
compare on the same schema.

CPU smoke:   python benchmarks/serving_bench.py --smoke
Full-ish:    python benchmarks/serving_bench.py --requests 64 --rate 4 \
                 --slots 16 --gen 32
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import sys
import time

import jax

sys.path.insert(0, "src")

from repro import configs                                      # noqa: E402
from repro.core import SparsifyConfig                          # noqa: E402
from repro.models import get_model                             # noqa: E402
from repro.models.sparse_serving import (densify_params,       # noqa: E402
                                         sparsify_for_serving)
from repro.runtime.metrics import format_summary, summarize    # noqa: E402
from repro.serving import (QueueFull, ServingEngine,           # noqa: E402
                           SpeculativeConfig, TraceRequest,
                           long_prompt_trace, poisson_trace, replay)


def bench_cfg(args):
    cfg = configs.get_smoke(args.arch)
    if args.smoke:
        cfg = dataclasses.replace(cfg, n_layers=2, d_model=128, d_ff=256,
                                  vocab=512, remat=False)
    return cfg


# one trace buffer + counter registry shared by every engine the bench
# builds (each engine gets its own ServingTracer / process-id pair), so a
# --trace-out run lands the whole dense/sparse x slot/paged grid in a
# single Perfetto file.  None when tracing is off: engines run NULL_TRACER.
_OBS = {"buffer": None, "registry": None}


def _make_tracer(args, name: str):
    if not getattr(args, "trace_out", None):
        return None
    from repro.serving import ServingTracer
    from repro.runtime.telemetry import MetricsRegistry, TraceBuffer
    if _OBS["buffer"] is None:
        _OBS["buffer"] = TraceBuffer()
        _OBS["registry"] = MetricsRegistry()
    return ServingTracer(buffer=_OBS["buffer"], registry=_OBS["registry"],
                         name=name)


def _build_engine(cfg, params, args, kv_layout, *, n_slots=None,
                  max_len=None, n_blocks=None, token_budget=None,
                  prefix_caching=True, trace_name="", draft=None,
                  kv_dtype=None):
    from repro.launch.mesh import make_serving_mesh
    return ServingEngine(
        cfg, params, n_slots=n_slots or args.slots,
        max_len=max_len or args.max_len, max_queue=args.max_queue,
        token_budget=token_budget or args.token_budget,
        max_prefill_per_step=args.max_prefill_per_step,
        kv_layout=kv_layout, kv_dtype=kv_dtype or args.kv_dtype,
        block_size=args.block_size, n_blocks=n_blocks,
        prefix_caching=prefix_caching, mesh=make_serving_mesh(args.mesh),
        draft=draft, tracer=_make_tracer(args, trace_name or kv_layout))


def _warm_and_replay(engine, trace, time_scale) -> dict:
    """Replay untimed (compiles every prefill/decode shape the trace
    hits), then replay timed.  The paged engine is warmed twice: the
    first pass fills the prefix cache, the second compiles the
    suffix-prefill shapes that cache hits route through — the timed pass
    then measures prefix-cache steady state."""
    warm_passes = 2 if engine.kv_layout == "paged" else 1
    for _ in range(warm_passes):
        for t in trace:
            while True:                # drain when the queue fills up
                try:
                    engine.submit(t.prompt, t.sampling())
                    break
                except QueueFull:
                    engine.step()
        engine.run()
    engine.finished.clear()
    engine.reset_stats()               # measure only the timed window

    res = replay(engine, trace, time_scale=time_scale)
    summary = summarize([r.metrics for r in res["finished"]], res["wall_s"])
    summary["rejected"] = res["rejected"]
    summary.update(engine.stats())
    # engine.stats() carries placement (device count + mesh shape); add the
    # per-device rate so single- vs multi-device runs compare directly
    summary["tok_per_s_per_device"] = (
        summary["tok_per_s"] / max(summary["placement"]["devices"], 1))
    return summary


def run_one(name: str, cfg, params, trace, args, kv_layout) -> dict:
    engine = _build_engine(cfg, params, args, kv_layout, trace_name=name)
    summary = _warm_and_replay(engine, trace, args.time_scale)
    print(format_summary(name, summary))
    if summary["rejected"]:
        print(f"{'':>10}{summary['rejected']} rejected by admission control")
    return summary


def shared_prefix_scenario(cfg, params, args) -> dict:
    """Long shared system prompt + unique tails, arriving as one burst,
    slot vs paged under the same KV-memory budget (in cache tokens)."""
    import numpy as np
    rng = np.random.default_rng(args.seed + 1)
    sys_prompt = rng.integers(0, cfg.vocab, size=args.sys_len).tolist()
    n = args.shared_requests
    trace = []
    for i in range(n):
        tail = rng.integers(0, cfg.vocab, size=args.tail_len).tolist()
        trace.append(TraceRequest(arrival_s=0.001 * i,
                                  prompt=sys_prompt + tail,
                                  max_new_tokens=args.gen, seed=i))
    max_len = args.sys_len + args.tail_len + args.gen
    budget_tokens = args.kv_budget_tokens or args.slots * args.max_len
    slot_slots = max(budget_tokens // max_len, 1)
    paged_blocks = budget_tokens // args.block_size
    paged_rows = min(n, args.slots * 4)

    out = {"kv_budget_tokens": budget_tokens, "n_requests": n,
           "sys_len": args.sys_len, "tail_len": args.tail_len,
           "gen": args.gen}
    for layout, kw in (("slot", dict(n_slots=slot_slots, max_len=max_len)),
                       ("paged", dict(n_slots=paged_rows, max_len=max_len,
                                      n_blocks=paged_blocks))):
        engine = _build_engine(cfg, params, args, layout,
                               trace_name=f"sys/{layout}", **kw)
        summary = _warm_and_replay(engine, trace, args.time_scale)
        print(format_summary(f"sys/{layout}", summary))
        out[layout] = summary

    s, p = out["slot"], out["paged"]
    hits = p.get("pool", {}).get("prefix_cache", {}).get("hit_tokens", 0)
    print(f"shared-prefix @ {budget_tokens}-token KV budget: "
          f"max concurrent slot={s['max_running']} vs paged={p['max_running']}; "
          f"prefix-cache hit tokens={hits}; "
          f"ttft p50 slot={s['ttft']['p50']*1e3:.0f}ms vs "
          f"paged={p['ttft']['p50']*1e3:.0f}ms")
    return out


def kv_dtype_scenario(cfg, params, args) -> dict:
    """Equal-HBM-budget KV dtype comparison: bf16 vs int8 arenas sized to
    the SAME byte budget.  An int8 token costs ``hd + 4`` bytes per KV
    head (values + one f32 scale) against bf16's ``2*hd``, so the same
    bytes admit ``2*hd/(hd+4)`` more slots (~1.88x at hd=64).  A burst of
    more requests than either engine can hold measures admitted
    concurrency directly; greedy replays of the same trace measure
    per-token agreement of the quantized arena against the bf16
    reference."""
    import numpy as np
    rng = np.random.default_rng(args.seed + 9)
    hd = cfg.head_dim
    bf16_slots = args.kv_dtype_slots
    int8_slots = (bf16_slots * 2 * hd) // (hd + 4)
    n = int8_slots + 4                 # oversubscribe both engines
    plen = max(args.prompt_min, 4)
    trace = [TraceRequest(arrival_s=0.0005 * i,
                          prompt=rng.integers(0, cfg.vocab,
                                              size=plen).tolist(),
                          max_new_tokens=args.gen, seed=i)
             for i in range(n)]

    out = {"head_dim": hd, "bf16_slots": bf16_slots,
           "int8_slots": int8_slots, "n_requests": n, "gen": args.gen}
    toks = {}
    for dtype, slots in (("bf16", bf16_slots), ("int8", int8_slots)):
        engine = _build_engine(cfg, params, args, "slot", n_slots=slots,
                               kv_dtype=dtype, trace_name=f"kv/{dtype}")
        for t in trace:                # warm: compile every shape
            while True:
                try:
                    engine.submit(t.prompt, t.sampling())
                    break
                except QueueFull:
                    engine.step()
        engine.run()
        engine.finished.clear()
        engine.reset_stats()
        res = replay(engine, trace, time_scale=args.time_scale)
        summary = summarize([r.metrics for r in res["finished"]],
                            res["wall_s"])
        summary["rejected"] = res["rejected"]
        summary.update(engine.stats())
        toks[dtype] = {r.request_id: list(r.tokens)
                       for r in res["finished"]}
        print(format_summary(f"kv/{dtype}", summary))
        out[dtype] = summary

    # greedy agreement: positionwise match rate of int8 streams vs the
    # bf16 reference streams for the same requests
    matched = total = 0
    for rid, ref in toks["bf16"].items():
        got = toks["int8"].get(rid, [])
        total += len(ref)
        matched += sum(a == b for a, b in zip(ref, got))
    b16, i8 = out["bf16"], out["int8"]
    out["greedy_agreement"] = matched / total if total else 1.0
    out["concurrency_ratio"] = (i8["max_running"]
                                / max(b16["max_running"], 1))
    out["bf16_arena_bytes"] = b16["pool"]["arena_bytes"]
    out["int8_arena_bytes"] = i8["pool"]["arena_bytes"]
    print(f"kv-dtype @ equal HBM: int8 admits {i8['max_running']} vs "
          f"bf16 {b16['max_running']} concurrent "
          f"({out['concurrency_ratio']:.2f}x) at "
          f"{out['int8_arena_bytes']}/{out['bf16_arena_bytes']} arena "
          f"bytes; greedy agreement {out['greedy_agreement']:.3f}")
    return out


def long_prompt_scenario(cfg, params, args) -> dict:
    """Short decode-heavy requests mid-stream when long prompts land:
    one-shot prefill vs token-budget chunked prefill at an EQUAL KV
    budget (same arena, rows, and requests; only the step policy moves).
    The metric that matters is the pooled inter-token-latency p99 — the
    worst stall a decoding request observes."""
    max_len = args.long_len + args.gen
    trace = long_prompt_trace(
        n_short=args.long_short_requests, short_len=args.tail_len,
        gen_short=args.gen * 2, n_long=args.long_requests,
        long_len=args.long_len, gen_long=args.gen,
        vocab=cfg.vocab, seed=args.seed + 2)
    budget = args.token_budget or max(args.long_len // 4, 32)
    out = {"token_budget": budget, "long_len": args.long_len,
           "n_short": args.long_short_requests,
           "n_long": args.long_requests}
    # one-shot = a budget no prompt exceeds: the whole prompt lands in one
    # chunk, reproducing the pre-chunking schedule under identical memory.
    # Prefix caching is off so the measured pass repeats the warmed-up
    # prefill work instead of hitting KV the warm passes left behind —
    # the scenario measures prefill *scheduling*, not caching.
    for mode, tb in (("oneshot", 2 * max_len), ("chunked", budget)):
        engine = _build_engine(cfg, params, args, "paged",
                               n_slots=args.slots, max_len=max_len,
                               n_blocks=2 * max_len // args.block_size,
                               token_budget=tb, prefix_caching=False,
                               trace_name=f"long/{mode}")
        summary = _warm_and_replay(engine, trace, args.time_scale)
        print(format_summary(f"long/{mode}", summary))
        out[mode] = summary
    o, c = out["oneshot"], out["chunked"]
    print(f"long-prompt @ budget {budget} tok/step: itl p99 "
          f"oneshot={o['itl']['p99']*1e3:.1f}ms vs "
          f"chunked={c['itl']['p99']*1e3:.1f}ms; chunks max "
          f"{o['prefill_chunks']['max']} vs {c['prefill_chunks']['max']}")
    return out


def mixed_family_scenario(args) -> dict:
    """Co-hosted mixed-family serving: an xLSTM (ssm) engine and a dense
    transformer engine share one host and one wall clock, each replaying
    its own Poisson trace — O(1)-state recurrent serving and KV-pool
    serving contending for the same cores.  The pooled summary's
    ``families`` breakdown (runtime/metrics.py) reports each family's
    tok/s and latency tails over the SHARED window, which is the number
    that matters when deciding whether families can be co-scheduled or
    need separate hosts."""
    pairs = []
    for arch in ("xlstm-350m", args.arch):
        cfg = configs.get_smoke(arch)
        if args.smoke:
            cfg = dataclasses.replace(cfg, n_layers=2, remat=False)
        zoo = get_model(cfg)
        params = zoo.init(jax.random.PRNGKey(args.seed))
        trace = poisson_trace(
            n_requests=max(args.requests // 2, 2), rate_per_s=args.rate,
            vocab=cfg.vocab, prompt_len=(args.prompt_min, args.prompt_max),
            max_new_tokens=args.gen, seed=args.seed + len(pairs))
        engine = _build_engine(cfg, params, args, "slot",
                               trace_name=f"mixed/{cfg.family}")
        pairs.append((cfg.family, engine, trace))

    for _, engine, trace in pairs:              # warm: compile every shape
        for t in trace:
            engine.submit(t.prompt, t.sampling())
        engine.run()
        engine.finished.clear()
        engine.reset_stats()

    pending = sorted(((t.arrival_s, j, t, engine)
                      for _, engine, trace in pairs
                      for j, t in enumerate(trace)),
                     key=lambda e: e[0])
    t0 = time.monotonic()
    rejected, i = 0, 0
    while i < len(pending) or any(e.has_work for _, e, _ in pairs):
        now = time.monotonic() - t0
        while (i < len(pending)
               and pending[i][0] * args.time_scale <= now):
            _, _, tr, engine = pending[i]
            i += 1
            try:
                engine.submit(tr.prompt, tr.sampling())
            except QueueFull:
                rejected += 1
        stepped = False
        for _, engine, _ in pairs:
            if engine.has_work:
                engine.step()
                stepped = True
        if not stepped and i < len(pending):
            next_due = pending[i][0] * args.time_scale
            time.sleep(min(max(next_due - (time.monotonic() - t0), 0.0),
                           0.05))
    wall_s = time.monotonic() - t0

    metrics = [r.metrics for _, engine, _ in pairs for r in engine.finished]
    summary = summarize(metrics, wall_s)
    summary["rejected"] = rejected
    print(format_summary("mixed", summary))
    for fam, sub in summary.get("families", {}).items():
        print(f"{'':>10}{fam}: {sub['n_requests']} req, "
              f"{sub['tok_per_s']:.1f} tok/s, "
              f"ttft p50 {sub['ttft']['p50']*1e3:.0f}ms, "
              f"itl p99 {sub['itl']['p99']*1e3:.1f}ms")
    return summary


def speculative_scenario(cfg, args) -> dict:
    """The 8:16 model drafts for its dense counterpart.

    The pair is constructed so the agreement the paper measures on trained
    models (the compressed model crosses the Performance Threshold, so its
    tokens track the dense parent's) holds deterministically at smoke
    scale: the draft is the 8:16+outlier compression of a fresh init and
    the TARGET is ``densify_params`` of those same containers — the dense
    realization of the compressed weights, served through the dense matmul
    path while the draft runs the sparse kernels.  Three engines replay
    the same greedy trace on the paged layout: no draft (baseline), the
    sparse draft, and the model-free n-gram proposer.  Every stream must
    be token-identical to the baseline (greedy speculative decoding is
    exact); the sparse draft should accept nearly everything.
    """
    scfg = SparsifyConfig(weight_pattern=args.weight_pattern,
                          outlier_pattern=args.outlier_pattern,
                          scorer="magnitude", use_smoothquant=False)
    sparse, _ = sparsify_for_serving(
        get_model(cfg).init(jax.random.PRNGKey(args.seed)), scfg)
    target = densify_params(sparse)
    trace = poisson_trace(n_requests=args.requests, rate_per_s=args.rate,
                          vocab=cfg.vocab,
                          prompt_len=(args.prompt_min, args.prompt_max),
                          max_new_tokens=args.gen, seed=args.seed + 4)
    variants = (
        ("baseline", None),
        ("sparse_draft", SpeculativeConfig(k=args.spec_k, method="model",
                                           params=sparse, cfg=cfg)),
        ("ngram_draft", SpeculativeConfig(k=args.spec_k, method="ngram")),
    )
    out = {"spec_k": args.spec_k, "layout": "paged"}
    base_streams = None
    for name, draft in variants:
        engine = _build_engine(cfg, target, args, "paged", draft=draft,
                               trace_name=f"spec/{name}")
        summary = _warm_and_replay(engine, trace, args.time_scale)
        # greedy speculative streams must match the baseline exactly; the
        # i-th submitted request of the timed window is the one with the
        # i-th-smallest request id, in every engine
        streams = [r.tokens for r in
                   sorted(engine.finished, key=lambda r: r.request_id)]
        if base_streams is None:
            base_streams = streams
        else:
            summary["token_identical"] = streams == base_streams
        out[name] = summary
        line = format_summary(f"spec/{name}", summary)
        sp = summary.get("speculative")
        if sp:
            line += (f" | {sp['accepted_per_step']:.2f} acc tok/step "
                     f"(k={sp['k']})")
        print(line)
    base_tps = out["baseline"]["tok_per_s"]
    for name in ("sparse_draft", "ngram_draft"):
        s = out[name]
        s["speedup_vs_baseline"] = (s["tok_per_s"] / base_tps
                                    if base_tps > 0 else float("nan"))
    sd = out["sparse_draft"]
    print(f"speculative @ k={args.spec_k}: sparse-draft acceptance "
          f"{sd['speculative']['acceptance_rate']:.2f}, "
          f"{sd['speculative']['accepted_per_step']:.2f} accepted tok/step, "
          f"{sd['speedup_vs_baseline']:.2f}x tok/s vs baseline, "
          f"token_identical={sd['token_identical']}")
    return out


def prefill_curve_scenario(cfg, params, args) -> dict:
    """SLOW scenario (opt-in via --prefill-curve): very-long-prompt
    prefill time vs prompt length, chunked through the RETIRED
    gather-based path vs the in-place attend-over-pool path.

    The gathered baseline reconstructs PR 4's ``forward_with_prefix``
    schedule locally: every C-token chunk ships a gathered
    [L, 1, cursor, KV, hd] prefix copy into the step, so prefilling P
    tokens moves O(P^2/C) prefix bytes (and retraces once per cursor).
    The in-place path is ``transformer.unified_step`` over a slot view:
    the arena rides donated and the cursor is data, so per-chunk bytes
    are constant.  Each point records both wall time (same chunks, same
    prompt, 1 row, warm — compile excluded) and the ACCEPTANCE metric,
    ``step_bytes``: compiled bytes-accessed of the first vs last chunk —
    gathered grows with the cursor, in-place stays flat.  Wall times on a
    CPU smoke model are flop-bound (the masked in-place attention still
    computes over the whole arena row), so the bytes curve, not the
    milliseconds, is where the asymptote shows at small scale; on real
    HBM-bound serving shapes the bytes ARE the milliseconds.
    """
    import numpy as np
    import jax.numpy as jnp
    from repro.models import transformer as tfm
    from repro.models.layers import linear, rms_norm
    from repro.serving import SlotPoolView

    C = args.curve_chunk
    lengths = [int(x) for x in args.curve_lens.split(",")]
    if any(P % C or P < C for P in lengths):
        raise ValueError(f"--curve-lens {lengths} must be multiples of "
                         f"--curve-chunk {C}")
    L, KV, hd = cfg.n_layers, cfg.n_kv_heads, cfg.hd
    rng = np.random.default_rng(args.seed + 3)

    def gathered_chunk(params, tokens, pk, pv):
        # the retired gather-based chunk primitive, kept ONLY as this
        # benchmark's baseline
        B, S = tokens.shape
        P = pk.shape[2]
        x = jnp.take(params["embed"], tokens, axis=0)
        positions = jnp.broadcast_to(P + jnp.arange(S)[None], (B, S))

        def body(h, xs):
            lp, pkl, pvl = xs
            h, kv = tfm.block_forward(lp, h, positions, cfg,
                                      prior_kv=(pkl, pvl))
            return h, kv
        x, (k, v) = jax.lax.scan(body, x, (params["layers"], pk, pv))
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        head = params["embed"] if cfg.tie_embeddings else params["lm_head"]
        return linear(head, x), (k, v)

    gathered_fn = jax.jit(gathered_chunk)
    inplace_fn = jax.jit(
        lambda p, k, v, cur, t: tfm.unified_step(
            p, SlotPoolView(k, v, None, cur, jnp.full((1,), C, jnp.int32)),
            {"tokens": t}, cfg),
        donate_argnums=(1, 2))

    def run_inplace(toks, P):
        k = jnp.zeros((L, 1, P, KV, hd), cfg.dtype)
        v = jnp.zeros((L, 1, P, KV, hd), cfg.dtype)
        for c in range(0, P, C):
            cur = jnp.asarray([c], jnp.int32)
            logits, (k, v) = inplace_fn(params, k, v, cur, toks[:, c:c + C])
        return logits

    def run_gathered(toks, P):
        pk = jnp.zeros((L, 1, 0, KV, hd), cfg.dtype)
        pv = jnp.zeros((L, 1, 0, KV, hd), cfg.dtype)
        for c in range(0, P, C):
            logits, (k, v) = gathered_fn(params, toks[:, c:c + C], pk, pv)
            pk = jnp.concatenate([pk, k], axis=2)
            pv = jnp.concatenate([pv, v], axis=2)
        return logits

    from repro.launch.hlo_analysis import cost_summary

    def step_bytes(P):
        """Compiled bytes-accessed of the FIRST vs LAST chunk step — the
        acceptance metric: the gathered step's bytes grow with the cursor
        (its prefix operand is [L, 1, cursor, KV, hd]); the in-place
        step's do not (the cursor is data, the arena operand is fixed)."""
        toks_c = jnp.zeros((1, C), jnp.int32)
        last = max(P - C, 0)
        out = {}
        for name, cur in (("first", 0), ("last", last)):
            pk = jnp.zeros((L, 1, cur, KV, hd), cfg.dtype)
            g = gathered_fn.lower(params, toks_c, pk, pk).compile()
            k = jnp.zeros((L, 1, P, KV, hd), cfg.dtype)
            v = jnp.zeros((L, 1, P, KV, hd), cfg.dtype)
            i = inplace_fn.lower(params, k, v, jnp.asarray([cur], jnp.int32),
                                 toks_c).compile()
            out[f"gathered_{name}"] = cost_summary(g)["bytes_accessed"]
            out[f"in_place_{name}"] = cost_summary(i)["bytes_accessed"]
        return out

    curve = []
    for P in lengths:
        toks = jnp.asarray(rng.integers(0, cfg.vocab, size=(1, P)), jnp.int32)
        point = {"prompt_len": P, "chunk": C}
        for name, fn in (("in_place", run_inplace), ("gathered", run_gathered)):
            fn(toks, P).block_until_ready()          # warm: compile excluded
            t0 = time.perf_counter()
            for _ in range(args.curve_reps):
                fn(toks, P).block_until_ready()
            point[f"{name}_s"] = (time.perf_counter() - t0) / args.curve_reps
        point["speedup"] = point["gathered_s"] / max(point["in_place_s"], 1e-12)
        point["step_bytes"] = sb = step_bytes(P)
        g_growth = sb["gathered_last"] / max(sb["gathered_first"], 1.0)
        i_growth = sb["in_place_last"] / max(sb["in_place_first"], 1.0)
        print(f"prefill-curve P={P:5d} chunk={C}: in-place "
              f"{point['in_place_s']*1e3:8.1f}ms vs gathered "
              f"{point['gathered_s']*1e3:8.1f}ms ({point['speedup']:.2f}x); "
              f"step-bytes first->last chunk: gathered {g_growth:.2f}x vs "
              f"in-place {i_growth:.2f}x")
        curve.append(point)
    return {"chunk": C, "points": curve}


def _make_router_tracer(args, name: str):
    if not getattr(args, "trace_out", None):
        return None
    from repro.runtime.telemetry import MetricsRegistry, TraceBuffer
    from repro.serving import RouterTracer
    if _OBS["buffer"] is None:
        _OBS["buffer"] = TraceBuffer()
        _OBS["registry"] = MetricsRegistry()
    return RouterTracer(buffer=_OBS["buffer"], registry=_OBS["registry"],
                        name=name)


def _adopt_compiled(src, dst) -> None:
    """Alias ``src``'s jitted step functions into every replica of
    ``dst`` (identically-configured ReplicaSets trace identical shapes,
    and the functions close over constants only) so the second fleet
    reuses the first's compile cache instead of re-paying every (B, S)
    variant."""
    a0 = src.replicas[0].adapter
    for e in dst.replicas:
        for fn in ("_step_fn", "_decode_fn", "_encode_fn"):
            if hasattr(a0, fn):
                setattr(e.adapter, fn, getattr(a0, fn))
        e._step_fn = e.adapter._step_fn
        e._decode_fn = e.adapter._decode_fn


def _fleet_warm_and_replay(target, trace, time_scale, *, reps=2):
    """Warm with two full replays (arrival-paced, so warm passes compile
    the same chunk shapes the measured pass hits), then measure
    ``reps`` replays and keep the best critical path — a straggler jit
    variant that only a particular arrival interleaving reaches lands in
    the first measured pass, not the reported one.  Returns (summary,
    COLD token streams by request id): streams are captured from the
    first (cold-cache) pass, where 1-vs-N identity is exact — warmed
    runs reuse prefix-cache KV whose float rounding depends on how the
    warming pass happened to chunk it, which can flip a greedy near-tie
    when comparing DIFFERENTLY-SHAPED targets (within one target the
    cache holds exactly the KV that engine wrote, so its streams stay
    self-consistent)."""
    cold = None
    for i in range(2):
        res = replay(target, trace, time_scale=time_scale)
        if i == 0:
            cold = {r.request_id: list(r.tokens)
                    for r in res["finished"]}
        target.clear_finished()
    best = None
    for _ in range(reps):
        target.reset_stats()
        res = replay(target, trace, time_scale=time_scale)
        done = list(res["finished"])
        gen_tokens = sum(len(r.tokens) for r in done)
        metrics = [r.metrics for r in done]
        st = target.stats()
        crit = st.get("critical_path_s") or res["wall_s"]
        if best is None or crit < best[-1]:
            best = (res, gen_tokens, metrics, st, crit)
        target.clear_finished()
    res, gen, metrics, st, crit = best
    streams = cold
    summary = summarize(metrics, res["wall_s"])
    summary["rejected"] = res["rejected"]
    summary.update(st)
    summary["critical_path_s"] = crit
    summary["tok_per_s_critical_path"] = (gen / crit if crit > 0
                                          else float("nan"))
    return summary, streams


def fleet_scenario(cfg, params, args) -> dict:
    """Multi-replica fleet: N engine replicas behind a prefix-aware
    router, vs round-robin routing, vs a single replica-sized engine.

    Workload: heavy-tailed lognormal prompt/output lengths, bursty
    Poisson arrivals, and a tenant mix where each tenant's requests share
    a system prompt (``fleet_trace`` — deterministic in its seed and
    identical regardless of replica count).

    Baseline framing — read before comparing numbers.  The baseline is
    ONE replica-sized engine (same slots, same fused-decode width) given
    the fleet's ENTIRE KV budget (replicas x blocks-per-replica): equal
    total KV bytes, 1/N the decode lanes.  That is the horizontal
    scale-out question — the compressed engine already saturates a
    single mesh slice, so extra throughput must come from more replicas,
    not a wider batch.  All three targets are driven as ReplicaSets (the
    baseline is a 1-replica set) so busy time is accounted identically.

    Throughput is reported two ways.  ``tok_per_s`` is host wall time —
    honest for THIS process, where every replica steps on the same
    in-process loop (and on a 1-core CI runner they also share the
    core, so host numbers cannot show scale-out).  The headline
    ``tok_per_s_critical_path`` divides by the fleet's makespan — max
    per-replica busy time plus routing/rebalance time, each replica's
    jitted steps timed for real — which is the wall time an N-slice
    deployment sees, since replicas run concurrently on disjoint mesh
    slices (``make_replica_meshes``).  The CI gate holds the
    prefix-routed fleet to >= 1.5x the baseline on that metric, and to
    a prefix-cache hit rate >= round-robin's: prefix routing partitions
    tenants across replicas so N small caches behave like one big
    cache, while round-robin interleaves every tenant through every
    replica and LRU-thrashes all of them.
    """
    from repro.serving import ReplicaSet, fleet_trace
    R, S, NB = args.replicas, args.fleet_slots, args.fleet_blocks
    max_len = args.fleet_sys_len + args.fleet_prompt_max + args.fleet_gen_max
    trace = fleet_trace(
        n_requests=args.fleet_requests, n_tenants=args.fleet_tenants,
        vocab=cfg.vocab, sys_len=args.fleet_sys_len,
        rate_per_s=args.fleet_rate, burst_mean=4.0,
        prompt_median=8, prompt_sigma=0.6, prompt_max=args.fleet_prompt_max,
        gen_median=6, gen_sigma=1.1, gen_max=args.fleet_gen_max,
        seed=args.seed + 11)
    total_prompt = sum(len(t.prompt) for t in trace)
    kw = dict(kv_layout="paged", kv_dtype=args.kv_dtype,
              block_size=args.block_size, max_len=max_len,
              prefix_caching=True, max_queue=args.max_queue,
              token_budget=args.token_budget or 64)

    def build(n_replicas, routing, blocks, name):
        tracers = None
        if getattr(args, "trace_out", None):
            tracers = [_make_tracer(args, f"fleet/{name}/r{i}")
                       for i in range(n_replicas)]
        return ReplicaSet(cfg, params, n_replicas=n_replicas,
                          routing=routing, n_slots=S, n_blocks=blocks,
                          steal_threshold=args.fleet_steal_threshold,
                          tracers=tracers,
                          router_tracer=_make_router_tracer(
                              args, f"fleet/{name}/router"), **kw)

    out = {"n_replicas": R, "n_requests": args.fleet_requests,
           "n_tenants": args.fleet_tenants, "sys_len": args.fleet_sys_len,
           "prompt_max": args.fleet_prompt_max,
           "gen_max": args.fleet_gen_max, "prompt_tokens": total_prompt,
           "slots_per_replica": S, "blocks_per_replica": NB,
           "equal_total_kv_blocks": R * NB,
           "block_size": args.block_size}
    streams = {}
    prev = None
    for name, n_rep, routing, blocks in (
            ("single", 1, "round_robin", R * NB),
            ("round_robin", R, "round_robin", NB),
            ("prefix", R, "prefix", NB)):
        target = build(n_rep, routing, blocks, name)
        if n_rep == R and prev is not None:
            _adopt_compiled(prev, target)      # same shapes: reuse compiles
        summary, streams[name] = _fleet_warm_and_replay(
            target, trace, args.fleet_time_scale)
        pc = summary.get("prefix_cache", {})
        summary["prefix_hit_rate"] = (pc.get("hit_tokens", 0)
                                      / max(total_prompt, 1))
        out[name] = summary
        if n_rep == R:
            prev = target
        print(format_summary(f"fleet/{name}", summary)
              + f" | crit {summary['tok_per_s_critical_path']:.0f} tok/s"
              + f" | prefix-hit {summary['prefix_hit_rate']:.3f}")

    base = out["single"]["tok_per_s_critical_path"]
    for name in ("round_robin", "prefix"):
        out[name]["speedup_vs_baseline"] = (
            out[name]["tok_per_s_critical_path"] / base if base > 0
            else float("nan"))
        out[name]["token_identical"] = streams[name] == streams["single"]
    out["token_identical"] = all(out[n]["token_identical"]
                                 for n in ("round_robin", "prefix"))
    print(f"fleet @ {R} replicas, equal total KV ({R * NB} blocks): "
          f"critical-path speedup prefix="
          f"{out['prefix']['speedup_vs_baseline']:.2f}x "
          f"round_robin={out['round_robin']['speedup_vs_baseline']:.2f}x; "
          f"prefix-hit prefix={out['prefix']['prefix_hit_rate']:.3f} "
          f"round_robin={out['round_robin']['prefix_hit_rate']:.3f}; "
          f"steals={out['prefix']['n_steals']} "
          f"token-identical={out['token_identical']}")
    return out


def _digest(name: str, s: dict | None) -> dict | None:
    """One scenario's machine-comparable one-liner for the summary block.
    NaNs become None so the summary stays strict-JSON diffable."""
    if not s or "tok_per_s" not in s:
        return None
    import math

    def num(x):
        return None if x is None or (isinstance(x, float)
                                     and math.isnan(x)) else x
    d = {"tok_per_s": num(s.get("tok_per_s")),
         "itl_p50": num(s.get("itl", {}).get("p50")),
         "itl_p99": num(s.get("itl", {}).get("p99")),
         "line": format_summary(name, s)}
    sp = s.get("speculative")
    if sp:
        d["spec_acceptance_rate"] = num(sp.get("acceptance_rate"))
        d["spec_accepted_per_step"] = num(sp.get("accepted_per_step"))
    if "speedup_vs_baseline" in s:
        d["speedup_vs_baseline"] = num(s["speedup_vs_baseline"])
    if "token_identical" in s:
        d["token_identical"] = s["token_identical"]
    return d


def summary_block(sections: dict) -> dict:
    """Top-level ``summary`` for BENCH_serving.json: per-scenario digests
    plus aggregate tok/s and worst-case ITL tails, so the bench trajectory
    diffs across PRs without walking every per-scenario tree."""
    scenarios = {}
    for name, s in sections.items():
        d = _digest(name, s)
        if d is not None:
            scenarios[name] = d
    rates = [d["tok_per_s"] for d in scenarios.values()
             if d["tok_per_s"] is not None]
    p50s = [d["itl_p50"] for d in scenarios.values()
            if d["itl_p50"] is not None]
    p99s = [d["itl_p99"] for d in scenarios.values()
            if d["itl_p99"] is not None]
    return {
        "n_scenarios": len(scenarios),
        "tok_per_s_mean": sum(rates) / len(rates) if rates else None,
        "tok_per_s_max": max(rates, default=None),
        "itl_p50_worst": max(p50s, default=None),
        "itl_p99_worst": max(p99s, default=None),
        "scenarios": scenarios,
    }


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama-paper")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny model + short workload (CI / CPU)")
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--rate", type=float, default=2.0,
                    help="Poisson arrival rate, requests/s")
    ap.add_argument("--prompt-min", type=int, default=8)
    ap.add_argument("--prompt-max", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--slots", type=int, default=8)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--max-queue", type=int, default=64)
    ap.add_argument("--token-budget", type=int, default=None,
                    help="prefill tokens per engine step (chunked prefill); "
                         "default: engine default (effectively un-chunked)")
    ap.add_argument("--max-prefill-per-step", type=int, default=None,
                    help="DEPRECATED request-count spelling of --token-budget")
    ap.add_argument("--time-scale", type=float, default=1.0)
    ap.add_argument("--kv-layout", default="both",
                    choices=("slot", "paged", "both"))
    ap.add_argument("--kv-dtype", default="bf16", choices=("bf16", "int8"),
                    help="KV arena storage dtype for the main grid engines "
                         "(the kv-dtype scenario always runs both)")
    ap.add_argument("--block-size", type=int, default=16)
    ap.add_argument("--mesh", default=None,
                    help="serving mesh 'DATAxMODEL' (e.g. '1x8') — "
                         "mesh-native tensor-parallel engine; default: "
                         "single device")
    ap.add_argument("--weight-pattern", default="8:16")
    ap.add_argument("--outlier-pattern", default="16:256")
    ap.add_argument("--seed", type=int, default=0)
    # shared-system-prompt scenario
    ap.add_argument("--no-shared-prefix", action="store_true",
                    help="skip the shared-system-prompt scenario")
    ap.add_argument("--shared-requests", type=int, default=16)
    ap.add_argument("--sys-len", type=int, default=96)
    ap.add_argument("--tail-len", type=int, default=16)
    ap.add_argument("--kv-budget-tokens", type=int, default=None,
                    help="KV budget for the shared-prefix comparison "
                         "(default: slots * max_len)")
    # equal-HBM-budget KV dtype scenario
    ap.add_argument("--no-kv-dtype", action="store_true",
                    help="skip the equal-HBM bf16-vs-int8 KV scenario")
    ap.add_argument("--kv-dtype-slots", type=int, default=8,
                    help="bf16 slot count of the equal-HBM comparison; the "
                         "int8 engine gets the same bytes' worth of slots")
    # long-prompt chunked-prefill scenario
    ap.add_argument("--no-long-prompt", action="store_true",
                    help="skip the long-prompt chunked-prefill scenario")
    # mixed-family co-hosting scenario
    ap.add_argument("--no-mixed-family", action="store_true",
                    help="skip the mixed-family (xlstm + dense) scenario")
    # speculative-decoding scenario
    ap.add_argument("--no-speculative", action="store_true",
                    help="skip the speculative-decoding scenario")
    ap.add_argument("--spec-k", type=int, default=4,
                    help="initial draft length for the speculative "
                         "scenario")
    ap.add_argument("--long-requests", type=int, default=2)
    ap.add_argument("--long-short-requests", type=int, default=6)
    ap.add_argument("--long-len", type=int, default=256,
                    help="long-prompt length for the chunked scenario")
    # multi-replica fleet scenario
    ap.add_argument("--no-fleet", action="store_true",
                    help="skip the multi-replica fleet scenario")
    ap.add_argument("--replicas", type=int, default=4,
                    help="fleet scenario replica count")
    ap.add_argument("--fleet-requests", type=int, default=32)
    ap.add_argument("--fleet-tenants", type=int, default=8,
                    help="tenants (distinct shared system prompts)")
    ap.add_argument("--fleet-slots", type=int, default=4,
                    help="decode slots per replica (the single baseline "
                         "gets the same)")
    ap.add_argument("--fleet-blocks", type=int, default=32,
                    help="KV blocks per replica; the single baseline "
                         "gets replicas * this (equal total KV)")
    ap.add_argument("--fleet-sys-len", type=int, default=32)
    ap.add_argument("--fleet-prompt-max", type=int, default=24)
    ap.add_argument("--fleet-gen-max", type=int, default=48)
    ap.add_argument("--fleet-rate", type=float, default=50.0,
                    help="fleet trace Poisson burst-epoch rate, /s")
    ap.add_argument("--fleet-steal-threshold", type=int, default=2)
    ap.add_argument("--fleet-time-scale", type=float, default=0.002,
                    help="arrival time compression for the fleet replays "
                         "(bursty near-saturation is the scenario)")
    # very-long-prompt prefill curve (slow; opt-in)
    ap.add_argument("--prefill-curve", action="store_true",
                    help="SLOW: record prefill-time-vs-prompt-length "
                         "curves (retired gathered path vs in-place "
                         "attend-over-pool) into the results file")
    ap.add_argument("--curve-lens", default="128,256,512,1024",
                    help="comma-separated prompt lengths for "
                         "--prefill-curve")
    ap.add_argument("--curve-chunk", type=int, default=64,
                    help="chunk size for --prefill-curve")
    ap.add_argument("--curve-reps", type=int, default=3,
                    help="timed repetitions per --prefill-curve point")
    ap.add_argument("--out", default="BENCH_serving.json",
                    help="machine-readable results file ('' to skip)")
    ap.add_argument("--trace-out", default=None,
                    help="write a Chrome/Perfetto trace_event JSON of all "
                         "engines here (load in ui.perfetto.dev); a "
                         "Prometheus counter snapshot lands next to it")
    args = ap.parse_args(argv)
    if args.smoke:
        args.requests = min(args.requests, 10)
        args.rate = max(args.rate, 5.0)
        args.gen = min(args.gen, 8)
        args.slots = min(args.slots, 4)
        args.max_len = min(args.max_len, 64)
        args.block_size = min(args.block_size, 8)
        args.shared_requests = min(args.shared_requests, 10)
        args.sys_len = min(args.sys_len, 40)
        args.tail_len = min(args.tail_len, 8)
        args.long_len = min(args.long_len, 128)
        args.long_requests = min(args.long_requests, 1)
        args.long_short_requests = min(args.long_short_requests, 4)
        args.replicas = min(args.replicas, 4)
        args.fleet_requests = min(args.fleet_requests, 32)
        args.fleet_sys_len = min(args.fleet_sys_len, 32)
        args.fleet_gen_max = min(args.fleet_gen_max, 48)
        args.curve_lens = "64,128"
        args.curve_chunk = min(args.curve_chunk, 16)
        args.curve_reps = 1

    cfg = bench_cfg(args)
    zoo = get_model(cfg)
    params = zoo.init(jax.random.PRNGKey(args.seed))

    trace = poisson_trace(n_requests=args.requests, rate_per_s=args.rate,
                          vocab=cfg.vocab,
                          prompt_len=(args.prompt_min, args.prompt_max),
                          max_new_tokens=args.gen, seed=args.seed)
    layouts = (("slot", "paged") if args.kv_layout == "both"
               else (args.kv_layout,))
    print(f"model {cfg.name} ({cfg.family}), {args.requests} requests @ "
          f"{args.rate}/s Poisson, prompts {args.prompt_min}-{args.prompt_max}, "
          f"gen {args.gen}, {args.slots} slots, layouts {layouts}")

    results = {}
    for layout in layouts:
        results[f"dense/{layout}"] = run_one(f"dense/{layout}", cfg, params,
                                             trace, args, layout)

    scfg = SparsifyConfig(weight_pattern=args.weight_pattern,
                          outlier_pattern=args.outlier_pattern,
                          scorer="magnitude", use_smoothquant=False)
    sparams, report = sparsify_for_serving(params, scfg)
    print(f"  sparse deploy: {report['n_layers_sparsified']} matrices, "
          f"{report['ratio']:.3f}x bytes")
    for layout in layouts:
        results[f"sparse/{layout}"] = run_one(f"sparse/{layout}", cfg,
                                              sparams, trace, args, layout)

    d = results.get("dense/slot") or results.get(f"dense/{layouts[0]}")
    s = results.get("sparse/slot") or results.get(f"sparse/{layouts[0]}")
    if d and s and d["tok_per_s"] > 0:
        print(f"sparse/dense throughput: {s['tok_per_s']/d['tok_per_s']:.2f}x")

    shared = None
    if not args.no_shared_prefix:
        shared = shared_prefix_scenario(cfg, params, args)

    kv_dtype = None
    if not args.no_kv_dtype:
        kv_dtype = kv_dtype_scenario(cfg, params, args)

    long_prompt = None
    if not args.no_long_prompt:
        long_prompt = long_prompt_scenario(cfg, params, args)

    mixed_family = None
    if not args.no_mixed_family:
        mixed_family = mixed_family_scenario(args)

    speculative = None
    if not args.no_speculative:
        speculative = speculative_scenario(cfg, args)

    fleet = None
    if not args.no_fleet:
        fleet = fleet_scenario(cfg, params, args)

    prefill_curve = None
    if args.prefill_curve:
        prefill_curve = prefill_curve_scenario(cfg, params, args)

    if args.out:
        payload = {
            "meta": {"model": cfg.name, "family": cfg.family,
                     "smoke": args.smoke, "requests": args.requests,
                     "rate_per_s": args.rate, "gen": args.gen,
                     "slots": args.slots, "max_len": args.max_len,
                     "block_size": args.block_size,
                     "kv_dtype": args.kv_dtype,
                     "token_budget": args.token_budget,
                     "weight_pattern": args.weight_pattern,
                     "outlier_pattern": args.outlier_pattern,
                     "seed": args.seed, "timestamp": time.time(),
                     "backend": jax.default_backend(),
                     "visible_devices": jax.device_count(),
                     "mesh": args.mesh},
            "poisson": results,
            "shared_prefix": shared,
            "kv_dtype": kv_dtype,
            "long_prompt": long_prompt,
            "mixed_family": mixed_family,
            "speculative": speculative,
            "fleet": fleet,
            "prefill_curve": prefill_curve,
        }
        sections = dict(results)
        if shared:
            sections["shared_prefix/slot"] = shared.get("slot")
            sections["shared_prefix/paged"] = shared.get("paged")
        if kv_dtype:
            sections["kv_dtype/bf16"] = kv_dtype.get("bf16")
            sections["kv_dtype/int8"] = kv_dtype.get("int8")
        if long_prompt:
            sections["long_prompt/oneshot"] = long_prompt.get("oneshot")
            sections["long_prompt/chunked"] = long_prompt.get("chunked")
        if mixed_family:
            sections["mixed_family"] = mixed_family
        if speculative:
            for v in ("baseline", "sparse_draft", "ngram_draft"):
                sections[f"speculative/{v}"] = speculative.get(v)
        if fleet:
            for v in ("single", "round_robin", "prefix"):
                sections[f"fleet/{v}"] = fleet.get(v)
        payload["summary"] = summary_block(sections)
        if _OBS["registry"] is not None:
            payload["counters"] = _OBS["registry"].snapshot()
        with open(args.out, "w") as f:
            json.dump(payload, f, indent=2, sort_keys=True)
        print(f"wrote {args.out}")

    if args.trace_out and _OBS["buffer"] is not None:
        _OBS["buffer"].write(args.trace_out)
        counters = args.trace_out + ".counters.txt"
        with open(counters, "w") as f:
            f.write(_OBS["registry"].prometheus_text())
        print(f"wrote {args.trace_out} (load in ui.perfetto.dev) "
              f"and {counters}")
    return results


if __name__ == "__main__":
    main()
