"""Roofline analysis from the dry-run JSONs (assignment deliverable g).

Per (arch x shape) on the single-pod 16x16 mesh (256 chips):

  compute    = HLO_flops_per_dev / 197e12
  memory     = HLO_bytes_per_dev / 819e9
  collective = collective_bytes_per_dev / 50e9

HLO terms use the depth-probe extrapolation when available (lax.scan bodies
are cost-counted once — DESIGN.md §6); the scan-path numbers are kept as a
lower bound.  MODEL_FLOPS = 6*N*D (train) / 2*N_active*D (inference);
ratio = MODEL_FLOPS / HLO_flops measures how much compiled compute is useful.

The per-cell roofline fraction reported in EXPERIMENTS.md §Perf:
  ideal  = max(MODEL_FLOPS_per_dev/peak, min_bytes_per_dev/bw)
  actual = max(compute, memory, collective)
  fraction = ideal / actual
with min_bytes = weight (+KV for decode) traffic lower bound (x6 params for
train: fwd read, bwd read, grad write, opt m/v read+write at bf16).
"""
from __future__ import annotations

import json
import pathlib

from repro.configs import SHAPES, get

PEAK = 197e12
HBM = 819e9
ICI = 50e9
CHIPS = 256

RESULTS = pathlib.Path(__file__).resolve().parent.parent / "results"


def model_flops(cfg, shape) -> float:
    """Global useful FLOPs for one step."""
    n_act = cfg.active_param_count()
    if shape.kind == "train":
        return 6.0 * n_act * shape.tokens
    if shape.kind == "prefill":
        return 2.0 * n_act * shape.tokens
    return 2.0 * n_act * shape.global_batch          # decode: 1 token/seq


def min_bytes(cfg, shape) -> float:
    """Global HBM-traffic lower bound for one step (bf16 weights)."""
    pbytes = cfg.param_count() * 2.0
    if shape.kind == "train":
        return 6.0 * pbytes
    if shape.kind == "prefill":
        return pbytes + shape.tokens * cfg.d_model * 2
    kv = (2 * cfg.n_layers * cfg.n_kv_heads * cfg.hd * shape.seq_len
          * shape.global_batch * 2.0) if cfg.family not in ("ssm",) else 0.0
    if cfg.family == "hybrid":
        n_attn = cfg.n_layers // max(cfg.attn_every, 1)
        kv = 2 * n_attn * cfg.n_kv_heads * cfg.hd * shape.seq_len \
            * shape.global_batch * 2.0
    return pbytes + kv


def load_cell(arch: str, shape_name: str, mesh="pod16x16") -> dict | None:
    fn = RESULTS / "dryrun" / f"{arch}__{shape_name}__{mesh}.json"
    if not fn.exists():
        return None
    return json.loads(fn.read_text())


def analyse_cell(arch: str, shape_name: str) -> dict | None:
    rec = load_cell(arch, shape_name)
    if rec is None:
        return None
    if rec.get("status") == "skipped":
        return {"arch": arch, "shape": shape_name, "status": "skipped",
                "reason": rec.get("reason", "")}
    if rec.get("status") != "ok":
        return {"arch": arch, "shape": shape_name, "status": "error",
                "reason": rec.get("error", "")}
    cfg = get(arch)
    shape = SHAPES[shape_name]

    probe = rec.get("probe")
    src = "probe" if probe else "scan"
    flops_dev = (probe or rec["full"])["flops"]
    bytes_dev = (probe or rec["full"])["bytes_accessed"]
    coll_dev = (probe["collective_bytes"] if probe
                else rec["full"]["collective_bytes"].get("total", 0.0))

    compute = flops_dev / PEAK
    memory = bytes_dev / HBM
    collective = coll_dev / ICI
    terms = {"compute": compute, "memory": memory, "collective": collective}
    dominant = max(terms, key=terms.get)

    mf = model_flops(cfg, shape)
    mb = min_bytes(cfg, shape)
    ideal = max(mf / CHIPS / PEAK, mb / CHIPS / HBM)
    actual = max(terms.values())
    mem_stats = rec["full"]["memory"]
    return {
        "arch": arch, "shape": shape_name, "status": "ok", "src": src,
        "compute_s": compute, "memory_s": memory, "collective_s": collective,
        "dominant": dominant,
        "model_flops": mf, "hlo_flops_global": flops_dev * CHIPS,
        "useful_ratio": mf / max(flops_dev * CHIPS, 1.0),
        "ideal_s": ideal, "fraction": ideal / max(actual, 1e-30),
        "args_gib": mem_stats.get("argument_size_in_bytes", 0) / 2**30,
        "temp_gib": mem_stats.get("temp_size_in_bytes", 0) / 2**30,
    }


def all_cells():
    from repro.configs import ASSIGNED
    rows = []
    for arch in ASSIGNED:
        for shape_name in SHAPES:
            r = analyse_cell(arch, shape_name)
            if r is not None:
                rows.append(r)
    return rows


def sparse_comparison(arch: str, shape_name: str) -> dict | None:
    """§Perf cell A: dense vs sparse(-quant) deploy roofline terms."""
    out = {}
    for tag, mesh in (("dense", "pod16x16"), ("sparse", "pod16x16_sparse"),
                      ("sparse+int8", "pod16x16_sparseq")):
        rec = load_cell(arch, shape_name, mesh)
        if rec is None or rec.get("status") != "ok":
            continue
        # use the scan-path ("full") numbers for ALL variants so the
        # comparison is apples-to-apples (sparse cells ship without probes)
        flops = rec["full"]["flops"]
        bytes_ = rec["full"]["bytes_accessed"]
        coll = rec["full"]["collective_bytes"].get("total", 0.0)
        out[tag] = {
            "compute_s": flops / PEAK, "memory_s": bytes_ / HBM,
            "collective_s": coll / ICI,
            "args_gib": rec["full"]["memory"].get("argument_size_in_bytes", 0) / 2**30,
        }
    return out or None


def run():
    from .common import emit
    rows = all_cells()
    for r in rows:
        if r["status"] != "ok":
            emit(f"roofline/{r['arch']}/{r['shape']}", 0.0,
                 f"status={r['status']}")
            continue
        emit(f"roofline/{r['arch']}/{r['shape']}",
             max(r["compute_s"], r["memory_s"], r["collective_s"]) * 1e6,
             f"dom={r['dominant']};comp_s={r['compute_s']:.3e};"
             f"mem_s={r['memory_s']:.3e};coll_s={r['collective_s']:.3e};"
             f"useful={r['useful_ratio']:.2f};frac={r['fraction']:.2f};"
             f"src={r['src']}")
    # also write a markdown table for EXPERIMENTS.md
    out = RESULTS / "roofline_table.md"
    lines = ["| arch | shape | compute s | memory s | collective s | dominant "
             "| MODEL/HLO flops | roofline frac | temp GiB/dev |",
             "|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        if r["status"] != "ok":
            lines.append(f"| {r['arch']} | {r['shape']} | — | — | — | "
                         f"{r['status']}: {r['reason'][:60]} | — | — | — |")
            continue
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.3e} | "
            f"{r['memory_s']:.3e} | {r['collective_s']:.3e} | {r['dominant']} |"
            f" {r['useful_ratio']:.2f} | {r['fraction']:.2f} | "
            f"{r['temp_gib']:.1f} |")
    out.write_text("\n".join(lines) + "\n")
    print(f"# wrote {out}")

    # paper-technique serving comparison (where sparse cells exist)
    for arch in ("qwen3-8b", "qwen2-vl-72b", "internlm2-1.8b", "gemma-7b"):
        for shape_name in ("decode_32k", "prefill_32k"):
            cmp = sparse_comparison(arch, shape_name)
            if cmp and len(cmp) > 1:
                d = cmp.get("dense")
                for tag, r in cmp.items():
                    speed = (d["memory_s"] / r["memory_s"]
                             if d and r["memory_s"] else float("nan"))
                    emit(f"sparse_deploy/{arch}/{shape_name}/{tag}",
                         r["memory_s"] * 1e6,
                         f"mem_s={r['memory_s']:.3e};comp_s={r['compute_s']:.3e};"
                         f"args_gib={r['args_gib']:.2f};mem_term_speedup={speed:.2f}")
    return rows


if __name__ == "__main__":
    run()
