"""Generate the §Dry-run evidence table (results/dryrun_table.md):
per (arch x shape): status on both meshes, per-device argument/temp bytes,
collective counts — the 'does it actually lower, compile, and shard' proof.
"""
from __future__ import annotations

import json
import pathlib

from repro.configs import ASSIGNED, SHAPES

RESULTS = pathlib.Path(__file__).resolve().parent.parent / "results"


def _fmt(rec):
    if rec is None:
        return "missing"
    if rec.get("status") == "skipped":
        return "skip"
    if rec.get("status") != "ok":
        return "ERROR"
    m = rec["full"]["memory"]
    cc = rec["full"]["collective_counts"]
    ncoll = sum(cc.values())
    return (f"ok a={m.get('argument_size_in_bytes',0)/2**30:.2f}G "
            f"t={m.get('temp_size_in_bytes',0)/2**30:.1f}G c{ncoll}")


def run(out_name="dryrun_table.md"):
    lines = ["| arch | shape | 16x16 (256 chips) | 2x16x16 (512 chips) |",
             "|---|---|---|---|"]
    n_ok = n_skip = n_bad = 0
    for arch in ASSIGNED:
        for shape in SHAPES:
            recs = {}
            for tag in ("pod16x16", "pod2x16x16"):
                fn = RESULTS / "dryrun" / f"{arch}__{shape}__{tag}.json"
                recs[tag] = json.loads(fn.read_text()) if fn.exists() else None
            s1, s2 = _fmt(recs["pod16x16"]), _fmt(recs["pod2x16x16"])
            for s in (s1, s2):
                if s.startswith("ok"):
                    n_ok += 1
                elif s == "skip":
                    n_skip += 1
                else:
                    n_bad += 1
            lines.append(f"| {arch} | {shape} | {s1} | {s2} |")
    lines.append("")
    lines.append(f"cells: {n_ok} compiled ok, {n_skip} skipped by design, "
                 f"{n_bad} missing/error (of {len(ASSIGNED)*len(SHAPES)*2})")
    out = RESULTS / out_name
    out.write_text("\n".join(lines) + "\n")
    print("\n".join(lines[-3:]))
    print(f"# wrote {out}")


if __name__ == "__main__":
    run()
