"""Shared benchmark machinery: one trained small LM, cached on disk.

The paper evaluates pruning on pretrained LLaMA/Mistral checkpoints (not
available offline), so every table is reproduced as orderings/deltas on a
small llama-family model trained in-repo on the structured synthetic corpus
(two seeds play the roles of the paper's WikiText-2 / C4 calibration sets).
"""
from __future__ import annotations

import dataclasses
import pathlib
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.data.pipeline import SyntheticLM
from repro.eval.harness import (collect_activation_stats, eval_ppl,
                                sparsify_model, train_small_lm)

CACHE = pathlib.Path(__file__).resolve().parent.parent / "results" / "bench_model"

BENCH_CFG = dataclasses.replace(
    configs.get_smoke("llama-paper"),
    name="bench-llama", n_layers=2, d_model=256, n_heads=4, n_kv_heads=4,
    head_dim=64, d_ff=512, vocab=512, remat=False)

# two calibration corpora of the SAME language (paper: WikiText-2 vs C4):
# identical bigram structure (seed), disjoint sampling streams.
DATA_WIKI = SyntheticLM(vocab=BENCH_CFG.vocab, seq_len=128, batch=16, seed=0,
                        branching=24, stream_seed=0)
DATA_C4 = SyntheticLM(vocab=BENCH_CFG.vocab, seq_len=128, batch=16, seed=0,
                      branching=24, stream_seed=7)


def _leaf_names(tree):
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    return ["/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
            for path, _ in flat]


def get_trained(steps: int = 400):
    """Train (or load cached) benchmark model. Returns (cfg, params)."""
    from repro.models import get_model
    zoo = get_model(BENCH_CFG)
    fn = CACHE.with_suffix(".npz")
    template = zoo.init(jax.random.PRNGKey(0))
    if fn.exists():
        flat, tdef = jax.tree_util.tree_flatten(template)
        names = _leaf_names(template)
        with np.load(fn) as z:
            if set(names) <= set(z.files):
                # npz holds f32; cast back to each leaf's true dtype
                leaves = [jnp.asarray(z[n]).astype(t.dtype)
                          for n, t in zip(names, flat)]
                return BENCH_CFG, jax.tree_util.tree_unflatten(tdef, leaves)
    t0 = time.time()
    params, losses = train_small_lm(BENCH_CFG, DATA_WIKI, steps=steps, lr=3e-3)
    print(f"# trained bench model: {losses[0]:.3f} -> {losses[-1]:.3f} "
          f"in {time.time()-t0:.0f}s")
    fn.parent.mkdir(parents=True, exist_ok=True)
    flat, _ = jax.tree_util.tree_flatten(params)
    np.savez(fn, **{n: np.asarray(l, np.float32)
                    for n, l in zip(_leaf_names(params), flat)})
    return BENCH_CFG, params


def stats_for(cfg, params, data, n_batches: int = 4):
    return collect_activation_stats(cfg, params, data.calibration(n_batches))


def ppl(cfg, params, data=DATA_WIKI, n_batches: int = 4):
    return eval_ppl(cfg, params, data, n_batches=n_batches)


def emit(name: str, us_per_call: float, derived: str):
    """The benchmark output contract: name,us_per_call,derived CSV."""
    print(f"{name},{us_per_call:.1f},{derived}")
