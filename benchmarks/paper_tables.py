"""Paper-table reproductions (Tables 1, 4, 5, 6/2/3/8, 7) on the bench LM.

Every function prints `name,us_per_call,derived` rows (us_per_call = wall
time of the sparsify+eval for that row) and returns a dict for EXPERIMENTS.md.
"""
from __future__ import annotations

import dataclasses
import time

import numpy as np

from repro.core import Pattern, SparsifyConfig
from repro.eval.harness import sparsify_model, eval_ppl
from .common import (BENCH_CFG, DATA_C4, DATA_WIKI, emit, get_trained, ppl,
                     stats_for)


def _run(cfg, params, stats, data, **kw):
    t0 = time.time()
    sp = sparsify_model(cfg, params, stats, SparsifyConfig(**kw))
    p = eval_ppl(cfg, sp, data, n_batches=4)
    return p, (time.time() - t0) * 1e6


def table1_patterns():
    """Pattern flexibility: configurations/bits (exact) + PPL RIA / RIA+VC."""
    cfg, params = get_trained()
    stats = stats_for(cfg, params, DATA_WIKI)
    dense = ppl(cfg, params)
    out = {"dense_ppl": dense}
    for pat in ("2:4", "4:8", "8:16", "16:32"):
        p = Pattern(*[int(v) for v in pat.split(":")])
        ppl_ria, us1 = _run(cfg, params, stats, DATA_WIKI, weight_pattern=pat,
                            outlier_pattern=None, scorer="ria",
                            use_variance_correction=False)
        ppl_vc, us2 = _run(cfg, params, stats, DATA_WIKI, weight_pattern=pat,
                           outlier_pattern=None, scorer="ria",
                           use_variance_correction=True)
        out[pat] = dict(configurations=p.configurations,
                        bits=p.paper_bits_per_element(),
                        ppl_ria=ppl_ria, ppl_ria_vc=ppl_vc)
        emit(f"table1/{pat}", us1,
             f"cfgs={p.configurations};bits={p.paper_bits_per_element():.4f};"
             f"ppl_ria={ppl_ria:.3f};ppl_ria_vc={ppl_vc:.3f}")
    return out


def table4_ablation():
    """RIA / +VC / +SQ / +EBFT ablation at 2:4 on both calibration sets."""
    cfg, params = get_trained()
    rows = {}
    for dname, data in (("wikitext2", DATA_WIKI), ("c4", DATA_C4)):
        stats = stats_for(cfg, params, data)
        grid = {
            "dense": None,
            "magnitude": dict(scorer="magnitude", use_smoothquant=False,
                              use_variance_correction=False),
            "ria": dict(scorer="ria", use_smoothquant=False,
                        use_variance_correction=False),
            "ria_vc": dict(scorer="ria", use_smoothquant=False,
                           use_variance_correction=True),
            "ria_sq": dict(scorer="ria", use_smoothquant=True,
                           use_variance_correction=False),
            "ria_sq_vc": dict(scorer="ria", use_smoothquant=True,
                              use_variance_correction=True),
        }
        for mname, kw in grid.items():
            if kw is None:
                p, us = eval_ppl(cfg, params, data, n_batches=4), 0.0
            else:
                p, us = _run(cfg, params, stats, data, weight_pattern="2:4",
                             outlier_pattern=None, **kw)
            rows[f"{dname}/{mname}"] = p
            emit(f"table4/{dname}/{mname}", us, f"ppl={p:.3f}")
    return rows


def table4_ebft():
    """EBFT rows of Table 4 (blockwise fine-tune of the 2:4 model)."""
    from .ebft_bench import run_ebft_row
    cfg, params = get_trained()
    rows = {}
    for mname, kw in (("ria_ebft", dict(scorer="ria", use_smoothquant=False,
                                        use_variance_correction=False)),
                      ("ria_sq_vc_ebft", dict(scorer="ria", use_smoothquant=True,
                                              use_variance_correction=True))):
        p, us = run_ebft_row(cfg, params, DATA_WIKI, weight_pattern="2:4", **kw)
        rows[mname] = p
        emit(f"table4/wikitext2/{mname}", us, f"ppl={p:.3f}")
    return rows


def table5_magnitude_outliers():
    """Magnitude pruning +- structured 4:256 outliers, two model widths."""
    _, params = get_trained()
    rows = {}
    for tag, cfg_mod in (("base", {}),
                         ("wide", dict(d_model=384, n_heads=6, n_kv_heads=6))):
        cfg = dataclasses.replace(BENCH_CFG, **cfg_mod)
        if tag == "wide":
            # train the wider sibling briefly (role of LLaMA-13B vs 7B)
            from repro.eval.harness import train_small_lm
            params_w, _ = train_small_lm(cfg, DATA_WIKI, steps=250, lr=3e-3)
            p_use = params_w
        else:
            p_use = params
        stats = stats_for(cfg, p_use, DATA_WIKI)
        for op in (None, "4:256"):
            p, us = _run(cfg, p_use, stats, DATA_WIKI, weight_pattern="2:4",
                         outlier_pattern=op, scorer="magnitude",
                         use_smoothquant=False, use_variance_correction=False)
            rows[f"{tag}/{op}"] = p
            emit(f"table5/{tag}/outliers={op}", us, f"ppl={p:.3f}")
    return rows


def table6_grid():
    """{2:4, 8:16} x outliers {-, 4:256, 8:256, 16:256}, RIA+SQ(+VC)."""
    cfg, params = get_trained()
    stats = stats_for(cfg, params, DATA_WIKI)
    rows = {}
    for pat in ("2:4", "8:16"):
        for op in (None, "4:256", "8:256", "16:256"):
            for vc in (False, True):
                p, us = _run(cfg, params, stats, DATA_WIKI, weight_pattern=pat,
                             outlier_pattern=op, scorer="ria",
                             use_smoothquant=True, use_variance_correction=vc)
                key = f"{pat}/out={op}/vc={int(vc)}"
                rows[key] = p
                emit(f"table6/{key}", us, f"ppl={p:.3f}")
    return rows


def table7_struct_vs_unstruct():
    """Structured vs unstructured salient weights at matched budget."""
    cfg, params = get_trained()
    stats = stats_for(cfg, params, DATA_WIKI)
    rows = {}
    for op in ("4:256", "8:256", "16:256"):
        for unstruct in (False, True):
            p, us = _run(cfg, params, stats, DATA_WIKI, weight_pattern="8:16",
                         outlier_pattern=op, scorer="ria", use_smoothquant=True,
                         use_variance_correction=True,
                         unstructured_outliers=unstruct)
            key = f"{op}/{'unstructured' if unstruct else 'structured'}"
            rows[key] = p
            emit(f"table7/{key}", us, f"ppl={p:.3f}")
    return rows
