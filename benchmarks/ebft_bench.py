"""EBFT benchmark helper: blockwise fine-tune a sparsified bench model."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core import (EBFTConfig, SparsifyConfig, ebft_block, sparsify_tree)
from repro.core.ebft import make_block_masks
from repro.eval.harness import collect_activation_stats, eval_ppl
from repro.models import transformer as tfm
from repro.models.layers import rms_norm


def _block_fn(cfg):
    def fn(lp, x):
        pos = jnp.broadcast_to(jnp.arange(x.shape[1])[None], x.shape[:2])
        y, _ = tfm.block_forward(lp, x, pos, cfg)
        return y
    return fn


def run_ebft_row(cfg, params, data, weight_pattern="2:4", outlier_pattern=None,
                 steps: int = 40, **scfg_kw):
    """Sparsify then EBFT every block against its dense teacher.

    Returns (ppl_after, wall_us)."""
    t0 = time.time()
    stats = collect_activation_stats(cfg, params, data.calibration(2))
    scfg = SparsifyConfig(weight_pattern=weight_pattern,
                          outlier_pattern=outlier_pattern, **scfg_kw)
    sparse_params, records = sparsify_tree(params, stats, scfg)

    # calibration block inputs: embeddings of a calibration batch
    calib = data.calibration(1)[0]
    toks = jnp.asarray(calib["tokens"][:8])
    x_dense = jnp.take(params["embed"], toks, axis=0)
    x_sparse = jnp.take(sparse_params["embed"], toks, axis=0)

    block_fn = _block_fn(cfg)
    ecfg = EBFTConfig(steps=steps, lr=2e-4, batch_size=4)
    new_layers = {k: list() for k in sparse_params["layers"]}
    for i in range(cfg.n_layers):
        lp_dense = jax.tree.map(lambda p: p[i], params["layers"])
        lp_sparse = jax.tree.map(lambda p: p[i], sparse_params["layers"])
        mask_by_path = {}
        for name, sl in records.items():
            leaf = name.split("/")[-1]
            if leaf in lp_sparse:
                mask_by_path[leaf] = jax.tree.map(lambda m: m[i],
                                                  sl.nonsalient_kept_mask)
        masks = make_block_masks(lp_sparse, mask_by_path)
        tuned, _losses = ebft_block(block_fn, lp_sparse, lp_dense, masks,
                                    x_sparse, ecfg)
        for k in new_layers:
            new_layers[k].append(tuned[k])
        # propagate calibration activations through the DENSE block (EBFT
        # uses the dense model's intermediate inputs as teacher inputs)
        x_dense = block_fn(lp_dense, x_dense)
        x_sparse = block_fn(tuned, x_sparse)

    tuned_params = dict(sparse_params)
    tuned_params["layers"] = {k: jnp.stack(v) for k, v in new_layers.items()}
    p = eval_ppl(cfg, tuned_params, data, n_batches=4)
    return p, (time.time() - t0) * 1e6
