"""Benchmark entry point — one function per paper table + kernel micro +
roofline.  Prints ``name,us_per_call,derived`` CSV rows.

  PYTHONPATH=src python -m benchmarks.run [--fast] [--only tableN|kernels|roofline]
"""
from __future__ import annotations

import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="table1|table4|table4_ebft|table5|table6|table7|"
                         "kernels|roofline")
    ap.add_argument("--fast", action="store_true",
                    help="skip the slow EBFT rows")
    args = ap.parse_args()

    from . import kernels_micro, roofline
    from . import paper_tables as pt

    jobs = {
        "table1": pt.table1_patterns,
        "table4": pt.table4_ablation,
        "table4_ebft": pt.table4_ebft,
        "table5": pt.table5_magnitude_outliers,
        "table6": pt.table6_grid,
        "table7": pt.table7_struct_vs_unstruct,
        "kernels": kernels_micro.run,
        "roofline": roofline.run,
    }
    if args.fast:
        jobs.pop("table4_ebft")
    if args.only:
        jobs = {args.only: jobs[args.only]}

    print("name,us_per_call,derived")
    t0 = time.time()
    for name, fn in jobs.items():
        t1 = time.time()
        try:
            fn()
        except Exception as e:  # noqa: BLE001 — report per-table failures
            print(f"{name},0.0,ERROR={type(e).__name__}:{e}", file=sys.stderr)
            raise
        print(f"# {name} done in {time.time()-t1:.0f}s", file=sys.stderr)
    print(f"# all benchmarks in {time.time()-t0:.0f}s", file=sys.stderr)


if __name__ == "__main__":
    main()
