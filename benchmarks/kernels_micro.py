"""Kernel micro-benchmarks.

On this CPU container the Pallas kernels run in interpret mode (correctness
path, not representative of TPU time), so each row reports BOTH:
  - us_per_call of the jitted XLA-CPU *reference* path (what we can measure),
  - modeled v5e time from the kernel's byte/flop budget (what the roofline
    predicts): t = max(bytes/819e9, flops/197e12).
The derived column carries the modeled dense-vs-sparse speedup — the paper's
bandwidth argument, quantified per shape.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ActStats, SparsifyConfig, sparsify_linear
from repro.kernels import ops
from .common import emit

V5E_BW = 819e9
V5E_FLOPS = 197e12


def _time(fn, *args, reps=5):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else \
        jax.block_until_ready(fn(*args))
    t0 = time.time()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.time() - t0) / reps * 1e6


def _modeled_us(b, out, kdim, sparse: bool, batch_bytes=2):
    flops = 2 * b * out * kdim
    w_bytes = out * kdim * (1.4375 if sparse else 2.0)
    io_bytes = (b * kdim + b * out) * batch_bytes
    t = max(w_bytes + io_bytes, 0) / V5E_BW
    t = max(t, flops / V5E_FLOPS)
    return t * 1e6


def run():
    shapes = [(16, 2048, 2048), (16, 4096, 4096), (128, 4096, 4096),
              (16, 4096, 14336)]
    for b, out, kdim in shapes:
        key = jax.random.PRNGKey(0)
        w = jax.random.normal(key, (out, kdim), jnp.float32)
        x = jax.random.normal(jax.random.PRNGKey(1), (b, kdim))
        st = ActStats.init(kdim).update(x)
        sl = sparsify_linear(w, st, SparsifyConfig())

        dense_fn = jax.jit(lambda x, w: x @ w.T)
        us_dense = _time(dense_fn, x, w)
        sparse_fn = jax.jit(lambda x: ops.sparse_linear_apply(
            x, sl.nm, sl.outliers, backend="reference"))
        us_sparse = _time(sparse_fn, x)

        m_dense = _modeled_us(b, out, kdim, sparse=False)
        m_sparse = _modeled_us(b, out, kdim, sparse=True)
        emit(f"kernel/nm_fused/{b}x{out}x{kdim}", us_sparse,
             f"cpu_dense_us={us_dense:.1f};v5e_model_dense_us={m_dense:.2f};"
             f"v5e_model_sparse_us={m_sparse:.2f};"
             f"modeled_speedup={m_dense/m_sparse:.2f}")


if __name__ == "__main__":
    run()
