"""Quickstart: the paper's pipeline on one weight matrix in ~40 lines.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.core import (ActStats, SparsifyConfig, sparsify_linear,
                        dense_effective_weight, Pattern)
from repro.kernels import ops

key = jax.random.PRNGKey(0)

# A linear layer W[out, in] and some calibration activations with outliers
# in the first 16 input channels (the setting the paper targets).
W = jax.random.normal(key, (1024, 2048), jnp.float32) * 0.02
x_calib = jax.random.normal(jax.random.PRNGKey(1), (512, 2048))
x_calib = x_calib.at[:, :16].mul(20.0)
stats = ActStats.init(2048).update(x_calib)

# --- the 4-stage pipeline (stages 1-3; EBFT is stage 4, see sparsify_e2e) ---
cfg = SparsifyConfig(
    weight_pattern="8:16",      # paper's headline pattern
    outlier_pattern="16:256",   # SSP-for-SW: structured salient weights
    scorer="ria",               # importance metric
    use_smoothquant=True,       # stage 1: equalized scoring view
    use_variance_correction=True)  # stage 3
sl = sparsify_linear(W, stats, cfg)

print(f"pattern           : {cfg.weight_pattern} "
      f"({Pattern(8,16).configurations} configurations/block, "
      f"{Pattern(8,16).paper_bits_per_element()} bits/elem metadata)")
print(f"N:M invariant     : every 16-block keeps exactly 8 -> "
      f"{bool((sl.nm_mask.reshape(-1,16).sum(-1) == 8).all())}")
print(f"salient fraction  : {float(sl.salient_mask.mean()):.4f} "
      f"(16/256 = {16/256:.4f})")

# --- deployment: y = x @ (W_nm + outliers)^T via the fused sparse kernel ---
x = jax.random.normal(jax.random.PRNGKey(2), (8, 2048))
y_kernel = ops.sparse_linear_apply(x, sl.nm, sl.outliers, backend="pallas")
y_dense = x @ dense_effective_weight(W, sl, cfg).T
print(f"fused kernel error: {float(jnp.abs(y_kernel - y_dense).max()):.2e}")

# --- what did compression buy? ---
dense_bytes = W.size * 2                                    # bf16 deploy
comp_bytes = (sl.nm.values.size * 2 + sl.nm.packed_metadata().size * 4
              + sl.outliers.values.size * 2 + sl.outliers.indices.size)
print(f"deployed bytes    : {dense_bytes/2**20:.2f} MiB -> "
      f"{comp_bytes/2**20:.2f} MiB ({dense_bytes/comp_bytes:.2f}x)")

# --- quality: relative output error vs the dense layer ---
err = jnp.linalg.norm(y_dense - x @ W.T) / jnp.linalg.norm(x @ W.T)
print(f"rel. output error : {float(err):.4f} (50% of weights removed)")
