"""End-to-end driver (assignment deliverable b): train a dense LM for a few
hundred steps, sparsify it with the paper's full pipeline (RIA+SQ+VC), recover
with EBFT, and report the perplexity ladder at every stage.

Default config is CPU-sized (~8M params); pass --d-model 768 --layers 12
for a ~100M-param run on real hardware (same code path).

    PYTHONPATH=src python examples/sparsify_e2e.py --steps 300
"""
import argparse
import dataclasses
import time

from repro import configs
from repro.core import SparsifyConfig
from repro.data.pipeline import SyntheticLM
from repro.eval.harness import (collect_activation_stats, eval_ppl,
                                sparsify_model, train_small_lm)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--d-model", type=int, default=256)
    ap.add_argument("--d-ff", type=int, default=512)
    ap.add_argument("--vocab", type=int, default=512)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--pattern", default="8:16")
    ap.add_argument("--outliers", default="16:256")
    ap.add_argument("--ebft-steps", type=int, default=40)
    args = ap.parse_args()

    cfg = dataclasses.replace(
        configs.get_smoke("llama-paper"), name="e2e",
        n_layers=args.layers, d_model=args.d_model, d_ff=args.d_ff,
        vocab=args.vocab,
        n_heads=max(4, args.d_model // 64), n_kv_heads=max(4, args.d_model // 64),
        head_dim=64 if args.d_model >= 256 else args.d_model // 4, remat=False)
    n_params = cfg.param_count()
    print(f"== 1. train dense LM ({n_params/1e6:.1f}M params, "
          f"{args.steps} steps) ==")
    data = SyntheticLM(vocab=cfg.vocab, seq_len=args.seq, batch=args.batch)
    t0 = time.time()
    params, losses = train_small_lm(cfg, data, steps=args.steps, lr=3e-3,
                                    log_every=50)
    print(f"   loss {losses[0]:.3f} -> {losses[-1]:.3f} "
          f"({time.time()-t0:.0f}s)")
    ppl_dense = eval_ppl(cfg, params, data)
    print(f"   dense PPL {ppl_dense:.3f}")

    print(f"== 2. calibrate (activation statistics) ==")
    stats = collect_activation_stats(cfg, params, data.calibration(4))

    print(f"== 3. sparsify {args.pattern} + outliers {args.outliers} "
          f"(RIA+SQ+VC) ==")
    ladder = {}
    for tag, kw in (
        ("magnitude", dict(scorer="magnitude", use_smoothquant=False,
                           use_variance_correction=False)),
        ("RIA", dict(scorer="ria", use_smoothquant=False,
                     use_variance_correction=False)),
        ("RIA+SQ+VC", dict(scorer="ria", use_smoothquant=True,
                           use_variance_correction=True)),
    ):
        scfg = SparsifyConfig(weight_pattern=args.pattern,
                              outlier_pattern=args.outliers, **kw)
        sp = sparsify_model(cfg, params, stats, scfg)
        ladder[tag] = eval_ppl(cfg, sp, data)
        print(f"   {tag:12s} PPL {ladder[tag]:.3f}")

    print(f"== 4. EBFT recovery ({args.ebft_steps} steps/block) ==")
    import sys
    sys.path.insert(0, str(__import__("pathlib").Path(__file__).parent.parent))
    from benchmarks.ebft_bench import run_ebft_row
    ppl_ebft, us = run_ebft_row(cfg, params, data,
                                weight_pattern=args.pattern,
                                outlier_pattern=args.outliers,
                                steps=args.ebft_steps, scorer="ria",
                                use_smoothquant=True,
                                use_variance_correction=True)
    print(f"   RIA+SQ+VC+EBFT PPL {ppl_ebft:.3f} ({us/1e6:.0f}s)")

    print("== summary (PPL, lower is better) ==")
    print(f"   dense          {ppl_dense:8.3f}")
    for k, v in ladder.items():
        print(f"   {k:14s} {v:8.3f}")
    print(f"   RIA+SQ+VC+EBFT {ppl_ebft:8.3f}")
    assert ppl_ebft <= ladder["magnitude"], "pipeline should beat magnitude"


if __name__ == "__main__":
    main()
