"""Batched serving with compressed 8:16 weights (paper deployment story).

Loads a model, swaps every projection for its compressed SparseWeight form,
and serves a batch of prompts through prefill + decode — demonstrating that
the whole zoo serves sparse through the same `linear()` dispatch.

    PYTHONPATH=src python examples/serve_sparse.py --arch internlm2-1.8b
    (any assigned arch id works; smoke-sized variants keep it CPU-friendly)
"""
import argparse

from repro.launch.serve import main as serve_main


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2-1.8b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--dense", action="store_true",
                    help="serve dense weights instead (for comparison)")
    args = ap.parse_args()

    argv = ["--arch", args.arch, "--smoke-arch",
            "--batch", str(args.batch), "--prompt-len", str(args.prompt_len),
            "--gen", str(args.gen)]
    if not args.dense:
        argv.append("--sparse")
    serve_main(argv)


if __name__ == "__main__":
    main()
