"""Qwen3-8B [hf:Qwen/Qwen3-8B].

36L, d_model 4096, 32 heads (GQA kv=8, head_dim 128), d_ff 12288,
vocab 151936, per-head q/k RMSNorm (qk_norm).
"""
from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="qwen3-8b", family="dense",
    n_layers=36, d_model=4096, n_heads=32, n_kv_heads=8, head_dim=128,
    d_ff=12288, vocab=151936,
    qk_norm=True, rope_theta=1e6,
    source="hf:Qwen/Qwen3-8B",
))


def smoke() -> ModelConfig:
    return register(ModelConfig(
        name="qwen3-8b-smoke", family="dense",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab=256, qk_norm=True, remat=False,
    ))
