"""Gemma-7B [arXiv:2403.08295; hf].

28L, d_model 3072, 16 heads with head_dim 256 (q-dim 4096 != d_model),
MHA (kv=16; the 2b sibling uses MQA), GeGLU d_ff 24576, vocab 256000,
tied embeddings.
"""
from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="gemma-7b", family="dense",
    n_layers=28, d_model=3072, n_heads=16, n_kv_heads=16, head_dim=256,
    d_ff=24576, vocab=256000,
    act="gelu", glu=True, tie_embeddings=True,
    source="arXiv:2403.08295; hf:google/gemma-7b",
))


def smoke() -> ModelConfig:
    return register(ModelConfig(
        name="gemma-7b-smoke", family="dense",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=32,
        d_ff=128, vocab=256, act="gelu", glu=True, tie_embeddings=True,
        remat=False,
    ))
