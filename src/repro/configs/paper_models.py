"""The paper's own evaluation models (Tables 2-8): LLaMA-2 7B/13B, LLaMA-3 8B,
Mistral 7B [arXiv:2307.09288, 2407.21783, 2310.06825; hf]."""
from .base import ModelConfig, register

LLAMA2_7B = register(ModelConfig(
    name="llama2-7b", family="dense",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=32, head_dim=128,
    d_ff=11008, vocab=32000,
    source="arXiv:2307.09288",
))

LLAMA2_13B = register(ModelConfig(
    name="llama2-13b", family="dense",
    n_layers=40, d_model=5120, n_heads=40, n_kv_heads=40, head_dim=128,
    d_ff=13824, vocab=32000,
    source="arXiv:2307.09288",
))

LLAMA3_8B = register(ModelConfig(
    name="llama3-8b", family="dense",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, head_dim=128,
    d_ff=14336, vocab=128256, rope_theta=5e5,
    source="arXiv:2407.21783",
))

MISTRAL_7B = register(ModelConfig(
    name="mistral-7b", family="dense",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, head_dim=128,
    d_ff=14336, vocab=32000, window=4096,
    source="arXiv:2310.06825",
))


def smoke() -> ModelConfig:
    """A tiny llama-family model used by paper-table benchmarks: small enough
    to train on CPU, big enough to show the pruning-method orderings."""
    return register(ModelConfig(
        name="llama-paper-smoke", family="dense",
        n_layers=4, d_model=256, n_heads=4, n_kv_heads=4, head_dim=64,
        d_ff=512, vocab=512, remat=False,
    ))
