"""Model/shape configuration system + architecture registry.

Each assigned architecture gets one module in this package defining
``CONFIG`` (exact published hyper-parameters) and ``smoke()`` (a reduced
same-family config for CPU tests).  ``repro.configs.get(name)`` resolves ids.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_ff_expert: int
    shared_expert: bool = False          # llama4-style always-on expert
    router_dtype: str = "float32"


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                          # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int | None = None          # default d_model // n_heads
    act: str = "silu"                    # silu | gelu | sq_relu
    glu: bool = True                     # gated MLP (SwiGLU/GeGLU) vs plain
    qk_norm: bool = False                # qwen3
    rope_theta: float = 10000.0
    window: int | None = None            # sliding-window attention (mixtral)
    mrope_sections: tuple[int, ...] | None = None   # qwen2-vl M-RoPE
    moe: MoEConfig | None = None
    # ssm / hybrid:
    ssm_state: int = 0                   # mamba2 state dim
    ssm_head_dim: int = 64
    slstm_every: int = 0                 # xlstm: sLSTM at layers i % k == k-1
    attn_every: int = 0                  # zamba2: shared attn after every k
    # enc-dec:
    enc_layers: int = 0                  # whisper encoder depth
    # numerics / perf knobs:
    dtype: Any = jnp.bfloat16
    remat: bool = True
    tie_embeddings: bool = False
    norm_eps: float = 1e-6
    source: str = ""                     # citation tag

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    def param_count(self) -> int:
        """Analytic total parameter count (used for MODEL_FLOPS)."""
        d, hd = self.d_model, self.hd
        emb = self.vocab * d * (1 if self.tie_embeddings else 2)
        attn = d * (self.n_heads * hd) + 2 * d * (self.n_kv_heads * hd) \
            + (self.n_heads * hd) * d
        if self.family == "ssm":        # xlstm block (see models/xlstm.py)
            di = 2 * d
            blk = d * 2 * di + 3 * di * di // 4 + di * d + 2 * di  # up,qkv/gates,down
            return emb + self.n_layers * blk
        if self.family == "hybrid":     # mamba2 blocks + shared attn block
            di = 2 * d
            mamba = d * (2 * di + 2 * self.ssm_state + di // self.ssm_head_dim) \
                + di * d
            ff = 3 * d * self.d_ff
            n_attn = self.n_layers // max(self.attn_every, 1)
            return emb + self.n_layers * mamba + (attn + ff)  # shared => once
        ff_mult = 3 if self.glu else 2
        if self.moe is not None:
            ff = self.moe.num_experts * ff_mult * d * self.moe.d_ff_expert
            if self.moe.shared_expert:
                ff += ff_mult * d * self.moe.d_ff_expert
            ff += self.moe.num_experts * d  # router
        else:
            ff = ff_mult * d * self.d_ff
        layers = self.n_layers * (attn + ff)
        if self.family == "encdec":
            # encoder blocks + decoder cross-attention
            layers += self.enc_layers * (attn + ff_mult * d * self.d_ff)
            layers += self.n_layers * attn  # cross-attn
        return emb + layers

    def active_param_count(self) -> int:
        """Params touched per token (MoE: only routed experts)."""
        if self.moe is None:
            return self.param_count()
        d = self.d_model
        ff_mult = 3 if self.glu else 2
        dense_ff_like = self.moe.top_k * ff_mult * d * self.moe.d_ff_expert
        if self.moe.shared_expert:
            dense_ff_like += ff_mult * d * self.moe.d_ff_expert
        attn = d * (self.n_heads * self.hd) + 2 * d * (self.n_kv_heads * self.hd) \
            + (self.n_heads * self.hd) * d
        emb = self.vocab * d * (1 if self.tie_embeddings else 2)
        return emb + self.n_layers * (attn + dense_ff_like + self.moe.num_experts * d)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str                            # train_4k | prefill_32k | decode_32k | long_500k
    seq_len: int
    global_batch: int
    kind: str                            # train | prefill | decode

    @property
    def tokens(self) -> int:
        return self.seq_len * self.global_batch


SHAPES = {
    "train_4k":    ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k":  ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k":   ShapeConfig("long_500k", 524288, 1, "decode"),
}

# Archs with recurrent/hybrid state run long_500k; pure full-attention skip it
# (DESIGN.md §5).
SUBQUADRATIC_FAMILIES = ("ssm", "hybrid")


def shape_applicable(cfg: ModelConfig, shape: ShapeConfig) -> bool:
    if shape.name == "long_500k":
        return cfg.family in SUBQUADRATIC_FAMILIES
    return True


_REGISTRY: dict[str, "ModelConfig"] = {}


def register(cfg: ModelConfig) -> ModelConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get(name: str) -> ModelConfig:
    if name not in _REGISTRY:       # registry may be partially populated
        _load_all()
    return _REGISTRY[name]


def all_archs() -> list[str]:
    _load_all()
    return sorted(_REGISTRY)


ASSIGNED = [
    "qwen2-vl-72b", "xlstm-350m", "gemma-7b", "qwen3-8b", "internlm2-1.8b",
    "nemotron-4-340b", "mixtral-8x7b", "llama4-maverick-400b-a17b",
    "whisper-medium", "zamba2-2.7b",
]

PAPER_ARCHS = ["llama2-7b", "llama2-13b", "llama3-8b", "mistral-7b"]


def _load_all():
    from . import (qwen2_vl_72b, xlstm_350m, gemma_7b, qwen3_8b,          # noqa
                   internlm2_1_8b, nemotron4_340b, mixtral_8x7b,
                   llama4_maverick, whisper_medium, zamba2_2_7b, paper_models)
