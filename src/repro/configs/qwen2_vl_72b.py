"""Qwen2-VL-72B backbone [arXiv:2409.12191; hf].

80L, d_model 8192, 64 heads (GQA kv=8, head_dim 128), d_ff 29568,
vocab 152064.  M-RoPE with sections (16, 24, 24) frequency pairs for
(temporal, height, width) position ids.  The vision patch frontend is a stub
per the assignment: input_specs feeds precomputed patch/text embeddings plus
the 3-row M-RoPE position ids.
"""
from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="qwen2-vl-72b", family="vlm",
    n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8, head_dim=128,
    d_ff=29568, vocab=152064,
    act="silu", glu=True, rope_theta=1e6, mrope_sections=(16, 24, 24),
    source="arXiv:2409.12191; hf:Qwen/Qwen2-VL-72B",
))


def smoke() -> ModelConfig:
    return register(ModelConfig(
        name="qwen2-vl-72b-smoke", family="vlm",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab=256,
        act="silu", glu=True, rope_theta=1e6, mrope_sections=(2, 3, 3),
        remat=False,
    ))
