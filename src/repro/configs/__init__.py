"""Architecture registry: repro.configs.get("qwen3-8b") etc."""

from .base import (ModelConfig, MoEConfig, ShapeConfig, SHAPES, ASSIGNED,
                   PAPER_ARCHS, get, all_archs, register, shape_applicable)


def get_smoke(name: str) -> ModelConfig:
    """Resolve the reduced smoke config for an assigned arch id."""
    import importlib
    mod_by_arch = {
        "qwen2-vl-72b": "qwen2_vl_72b",
        "xlstm-350m": "xlstm_350m",
        "gemma-7b": "gemma_7b",
        "qwen3-8b": "qwen3_8b",
        "internlm2-1.8b": "internlm2_1_8b",
        "nemotron-4-340b": "nemotron4_340b",
        "mixtral-8x7b": "mixtral_8x7b",
        "llama4-maverick-400b-a17b": "llama4_maverick",
        "whisper-medium": "whisper_medium",
        "zamba2-2.7b": "zamba2_2_7b",
        "llama-paper": "paper_models",
    }
    mod = importlib.import_module(f".{mod_by_arch[name]}", __package__)
    return mod.smoke()
