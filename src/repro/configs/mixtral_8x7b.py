"""Mixtral-8x7B [arXiv:2401.04088; hf].

32L, d_model 4096, 32 heads (GQA kv=8), vocab 32000; MoE FFN with 8 experts,
top-2 routing, expert d_ff 14336; sliding-window attention (4096).
"""
from .base import ModelConfig, MoEConfig, register

CONFIG = register(ModelConfig(
    name="mixtral-8x7b", family="moe",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, head_dim=128,
    d_ff=14336, vocab=32000,
    window=4096,
    moe=MoEConfig(num_experts=8, top_k=2, d_ff_expert=14336),
    source="arXiv:2401.04088; hf:mistralai/Mixtral-8x7B-v0.1",
))


def smoke() -> ModelConfig:
    return register(ModelConfig(
        name="mixtral-8x7b-smoke", family="moe",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab=256, window=16,
        moe=MoEConfig(num_experts=4, top_k=2, d_ff_expert=128),
        remat=False,
    ))
