"""Llama-4-Maverick-400B-A17B [hf:meta-llama/Llama-4-Scout-17B-16E family;
unverified].

48L, d_model 5120, 40 heads (GQA kv=8, head_dim 128), vocab 202048; MoE with
128 experts, top-1 routing + one always-on shared expert, expert d_ff 8192.
(Upstream Maverick interleaves dense/MoE layers; we model all layers as MoE
with shared expert — active-params accounting uses top-1 + shared.)
"""
from .base import ModelConfig, MoEConfig, register

CONFIG = register(ModelConfig(
    name="llama4-maverick-400b-a17b", family="moe",
    n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8, head_dim=128,
    d_ff=8192, vocab=202048,
    rope_theta=5e5,
    moe=MoEConfig(num_experts=128, top_k=1, d_ff_expert=8192,
                  shared_expert=True),
    source="hf:meta-llama/Llama-4-Scout-17B-16E",
))


def smoke() -> ModelConfig:
    return register(ModelConfig(
        name="llama4-maverick-400b-a17b-smoke", family="moe",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab=256,
        moe=MoEConfig(num_experts=8, top_k=1, d_ff_expert=128,
                      shared_expert=True),
        remat=False,
    ))
