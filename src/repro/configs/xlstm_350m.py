"""xLSTM-350M [arXiv:2405.04517; unverified].

24 blocks, d_model 1024, 4 heads, vocab 50304 (GPT-NeoX tokenizer rounding).
d_ff=0 per the assignment — xLSTM blocks carry their own 2x up/down
projections instead of a separate MLP.  sLSTM blocks interleaved every 8th
layer (xLSTM[7:1]); the rest are mLSTM (matrix memory, chunkwise-parallel).
"""
from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="xlstm-350m", family="ssm",
    n_layers=24, d_model=1024, n_heads=4, n_kv_heads=4,
    d_ff=0, vocab=50304,
    slstm_every=8,
    source="arXiv:2405.04517",
))


def smoke() -> ModelConfig:
    return register(ModelConfig(
        name="xlstm-350m-smoke", family="ssm",
        n_layers=4, d_model=64, n_heads=2, n_kv_heads=2,
        d_ff=0, vocab=256, slstm_every=2, remat=False,
    ))
