"""Whisper-medium backbone [arXiv:2212.04356; unverified].

24 encoder + 24 decoder layers, d_model 1024, 16 heads (MHA), GELU d_ff 4096,
vocab 51865.  Conv/log-mel frontend is a STUB per the assignment:
input_specs feeds precomputed frame embeddings [B, S, d].
"""
from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="whisper-medium", family="encdec",
    n_layers=24, enc_layers=24, d_model=1024, n_heads=16, n_kv_heads=16,
    head_dim=64, d_ff=4096, vocab=51865,
    act="gelu", glu=False,
    source="arXiv:2212.04356",
))


def smoke() -> ModelConfig:
    return register(ModelConfig(
        name="whisper-medium-smoke", family="encdec",
        n_layers=2, enc_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
        head_dim=16, d_ff=128, vocab=256, act="gelu", glu=False, remat=False,
    ))
