"""Zamba2-2.7B [arXiv:2411.15242; hf].

54 Mamba2 blocks (d_model 2560, ssm_state 64, ssm head_dim 64) with ONE
shared full-attention+MLP block (32 heads, head_dim 80, d_ff 10240) applied
after every 6th Mamba2 block — weights shared across applications, each
application keeping its own KV cache.
"""
from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="zamba2-2.7b", family="hybrid",
    n_layers=54, d_model=2560, n_heads=32, n_kv_heads=32, head_dim=80,
    d_ff=10240, vocab=32000,
    ssm_state=64, ssm_head_dim=64, attn_every=6,
    source="arXiv:2411.15242; hf:Zyphra/Zamba2-2.7B",
))


def smoke() -> ModelConfig:
    return register(ModelConfig(
        name="zamba2-2.7b-smoke", family="hybrid",
        n_layers=4, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
        d_ff=128, vocab=256,
        ssm_state=16, ssm_head_dim=16, attn_every=2, remat=False,
    ))
