"""InternLM2-1.8B [arXiv:2403.17297; hf].

24L, d_model 2048, 16 heads (GQA kv=8, head_dim 128), d_ff 8192, vocab 92544.
"""
from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="internlm2-1.8b", family="dense",
    n_layers=24, d_model=2048, n_heads=16, n_kv_heads=8, head_dim=128,
    d_ff=8192, vocab=92544,
    source="arXiv:2403.17297; hf:internlm/internlm2-1_8b",
))


def smoke() -> ModelConfig:
    return register(ModelConfig(
        name="internlm2-1.8b-smoke", family="dense",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab=256, remat=False,
    ))
