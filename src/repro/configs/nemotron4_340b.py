"""Nemotron-4-340B [arXiv:2402.16819 (Nemotron-4 15B report family); unverified].

96L, d_model 18432, 96 heads (GQA kv=8, head_dim 192), squared-ReLU
(non-gated) d_ff 73728, vocab 256000.
"""
from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="nemotron-4-340b", family="dense",
    n_layers=96, d_model=18432, n_heads=96, n_kv_heads=8, head_dim=192,
    d_ff=73728, vocab=256000,
    act="sq_relu", glu=False,
    source="arXiv:2402.16819",
))


def smoke() -> ModelConfig:
    return register(ModelConfig(
        name="nemotron-4-340b-smoke", family="dense",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=256, vocab=256, act="sq_relu", glu=False, remat=False,
    ))
