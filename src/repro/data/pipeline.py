"""Deterministic sharded token pipeline + calibration sets.

Sources:
  SyntheticLM   — a fixed-seed Zipf-ish Markov token stream with enough
                  structure (bigram dependencies) that perplexity orderings
                  between pruning methods are meaningful on CPU (the paper's
                  WikiText-2/C4 stand-in for this offline container; two
                  different seeds play the role of the two calibration sets).
  TextFile      — newline documents with a whitespace/byte vocab (offline
                  friendly, used if the user points us at a corpus).

Determinism/resume: batches are a pure function of (seed, step, host) —
``batch_at(step)`` — so restart-from-checkpoint replays the exact stream
(fault_tolerance relies on this), and each host reads only its shard.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class SyntheticLM:
    vocab: int
    seq_len: int
    batch: int                      # per-host batch
    seed: int = 0                   # structure seed (the "language")
    branching: int = 12             # bigram fan-out; lower = more learnable
    stream_seed: int | None = None  # sampling seed; two corpora of the SAME
                                    # language = same seed, diff stream_seed

    def __post_init__(self):
        if self.stream_seed is None:
            self.stream_seed = self.seed
        rng = np.random.default_rng(self.seed)
        # Markov transition table: each token can be followed by `branching`
        # candidates with Zipf weights.
        self._next = rng.integers(0, self.vocab,
                                  size=(self.vocab, self.branching))
        w = 1.0 / np.arange(1, self.branching + 1)
        self._w = w / w.sum()

    def batch_at(self, step: int, host: int = 0) -> dict:
        rng = np.random.default_rng(
            (self.stream_seed * 1_000_003 + step) * 131 + host)
        toks = np.empty((self.batch, self.seq_len + 1), np.int32)
        toks[:, 0] = rng.integers(0, self.vocab, size=self.batch)
        for t in range(self.seq_len):
            choice = rng.choice(self.branching, size=self.batch, p=self._w)
            toks[:, t + 1] = self._next[toks[:, t], choice]
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    def calibration(self, n_batches: int, start_step: int = 10_000) -> list[dict]:
        """A held-out slice used as the pruning calibration set."""
        return [self.batch_at(start_step + i) for i in range(n_batches)]


@dataclasses.dataclass
class TextFile:
    path: str
    seq_len: int
    batch: int
    seed: int = 0

    def __post_init__(self):
        raw = open(self.path, "rb").read()
        self._data = np.frombuffer(raw, np.uint8).astype(np.int32)
        self.vocab = 256

    def batch_at(self, step: int, host: int = 0) -> dict:
        rng = np.random.default_rng((self.seed + step) * 131 + host)
        starts = rng.integers(0, len(self._data) - self.seq_len - 1,
                              size=self.batch)
        toks = np.stack([self._data[s:s + self.seq_len + 1] for s in starts])
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


def prefetch(source, steps, start: int = 0, host: int = 0, depth: int = 2):
    """Generator with a simple lookahead buffer (threaded IO would slot in
    here on a real cluster; on CPU the synthetic source is cheap)."""
    from collections import deque
    buf = deque()
    for s in range(start, start + steps):
        buf.append(source.batch_at(s, host))
        if len(buf) > depth:
            yield buf.popleft()
    while buf:
        yield buf.popleft()
