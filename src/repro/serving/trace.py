"""Request traces: synthetic workloads (Poisson open-loop, long-prompt
chunked-prefill stress), JSON round-trip, and replay.

A trace is a list of ``TraceRequest`` — arrival offset (seconds from trace
start), prompt, and sampling params.  ``replay`` drives a ServingEngine
against wall-clock arrivals (scaled by ``time_scale``): requests are
submitted once their arrival time passes, the engine steps whenever it has
work, and the loop exits when everything drains.  Used by both the
``--trace`` mode of launch/serve.py and benchmarks/serving_bench.py.
"""
from __future__ import annotations

import dataclasses
import json
import time

import numpy as np

from .request import SamplingParams
from .scheduler import QueueFull


@dataclasses.dataclass(frozen=True)
class TraceRequest:
    arrival_s: float                   # offset from trace start
    prompt: list[int]
    max_new_tokens: int = 16
    temperature: float = 0.0
    top_k: int = 0
    seed: int = 0

    def sampling(self) -> SamplingParams:
        return SamplingParams(max_new_tokens=self.max_new_tokens,
                              temperature=self.temperature,
                              top_k=self.top_k, seed=self.seed)


def poisson_trace(*, n_requests: int, rate_per_s: float, vocab: int,
                  prompt_len: tuple[int, int] = (8, 32),
                  max_new_tokens: int = 16, temperature: float = 0.0,
                  seed: int = 0) -> list[TraceRequest]:
    """Synthetic open-loop workload: exponential interarrival gaps at
    ``rate_per_s``, prompt lengths uniform over [lo, hi], random token ids."""
    rng = np.random.default_rng(seed)
    t = 0.0
    out = []
    for i in range(n_requests):
        t += float(rng.exponential(1.0 / rate_per_s))
        plen = int(rng.integers(prompt_len[0], prompt_len[1] + 1))
        prompt = rng.integers(0, vocab, size=plen).tolist()
        out.append(TraceRequest(arrival_s=t, prompt=prompt,
                                max_new_tokens=max_new_tokens,
                                temperature=temperature, seed=i))
    return out


def long_prompt_trace(*, n_short: int, short_len: int, gen_short: int,
                      n_long: int, long_len: int, gen_long: int,
                      vocab: int, long_after_s: float = 0.05,
                      seed: int = 0) -> list[TraceRequest]:
    """The chunked-prefill stress workload: a burst of short decode-heavy
    requests, then very long prompts landing while everyone is mid-decode.

    Without a token budget each long prompt prefills in one engine step and
    every decoding request observes that step's full latency as one
    inter-token gap; with chunked prefill the prompt advances
    ``token_budget`` tokens per step and decode gaps stay bounded.  The
    benchmark replays this trace one-shot vs chunked and compares the
    decode-tail (pooled inter-token latency p99) at an equal KV budget.
    """
    rng = np.random.default_rng(seed)
    out = [TraceRequest(arrival_s=0.001 * i,
                        prompt=rng.integers(0, vocab, size=short_len).tolist(),
                        max_new_tokens=gen_short, seed=i)
           for i in range(n_short)]
    for j in range(n_long):
        out.append(TraceRequest(
            arrival_s=long_after_s * (j + 1),
            prompt=rng.integers(0, vocab, size=long_len).tolist(),
            max_new_tokens=gen_long, seed=n_short + j))
    return out


def save_trace(path: str, trace: list[TraceRequest]) -> None:
    with open(path, "w") as f:
        json.dump([dataclasses.asdict(t) for t in trace], f)


def load_trace(path: str) -> list[TraceRequest]:
    with open(path) as f:
        return [TraceRequest(**d) for d in json.load(f)]


def replay(engine, trace: list[TraceRequest], *, time_scale: float = 1.0,
           verbose: bool = False) -> dict:
    """Feed ``trace`` into ``engine`` against the wall clock.

    ``time_scale`` compresses (<1) or stretches (>1) arrival gaps.  Requests
    rejected by admission control are recorded, not retried (open-loop
    workload).  Returns {"finished": [...], "rejected": n, "wall_s": s}.
    """
    pending = sorted(trace, key=lambda t: t.arrival_s)
    t0 = time.monotonic()
    rejected = 0
    i = 0
    while i < len(pending) or engine.has_work:
        now = time.monotonic() - t0
        while i < len(pending) and pending[i].arrival_s * time_scale <= now:
            tr = pending[i]
            i += 1
            try:
                engine.submit(tr.prompt, tr.sampling())
            except (QueueFull, ValueError) as e:
                # queue at capacity, or the request can never fit a slot —
                # open-loop workload: count it rejected, keep replaying
                rejected += 1
                if verbose:
                    print(f"rejected request {i - 1}: {e}")
        if engine.has_work:
            engine.step()
        elif i < len(pending):
            # idle until the next arrival is due
            next_due = pending[i].arrival_s * time_scale
            time.sleep(min(max(next_due - (time.monotonic() - t0), 0.0), 0.05))
    wall_s = time.monotonic() - t0
    return {"finished": engine.finished, "rejected": rejected, "wall_s": wall_s}
