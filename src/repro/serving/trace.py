"""Request traces: synthetic workloads (Poisson open-loop, long-prompt
chunked-prefill stress), JSON round-trip, and replay.

A trace is a list of ``TraceRequest`` — arrival offset (seconds from trace
start), prompt, and sampling params.  ``replay`` drives a ServingEngine
against wall-clock arrivals (scaled by ``time_scale``): requests are
submitted once their arrival time passes, the engine steps whenever it has
work, and the loop exits when everything drains.  Used by both the
``--trace`` mode of launch/serve.py and benchmarks/serving_bench.py.
"""
from __future__ import annotations

import dataclasses
import json
import time

import numpy as np

from .request import SamplingParams
from .scheduler import QueueFull


@dataclasses.dataclass(frozen=True)
class TraceRequest:
    arrival_s: float                   # offset from trace start
    prompt: list[int]
    max_new_tokens: int = 16
    temperature: float = 0.0
    top_k: int = 0
    seed: int = 0
    # session/tenant id for fleet routing affinity; -1 = no session.
    # Defaulted so pre-fleet trace JSON still loads.
    session: int = -1

    def sampling(self) -> SamplingParams:
        return SamplingParams(max_new_tokens=self.max_new_tokens,
                              temperature=self.temperature,
                              top_k=self.top_k, seed=self.seed)


def poisson_trace(*, n_requests: int, rate_per_s: float, vocab: int,
                  prompt_len: tuple[int, int] = (8, 32),
                  max_new_tokens: int = 16, temperature: float = 0.0,
                  seed: int = 0) -> list[TraceRequest]:
    """Synthetic open-loop workload: exponential interarrival gaps at
    ``rate_per_s``, prompt lengths uniform over [lo, hi], random token ids."""
    rng = np.random.default_rng(seed)
    t = 0.0
    out = []
    for i in range(n_requests):
        t += float(rng.exponential(1.0 / rate_per_s))
        plen = int(rng.integers(prompt_len[0], prompt_len[1] + 1))
        prompt = rng.integers(0, vocab, size=plen).tolist()
        out.append(TraceRequest(arrival_s=t, prompt=prompt,
                                max_new_tokens=max_new_tokens,
                                temperature=temperature, seed=i))
    return out


def long_prompt_trace(*, n_short: int, short_len: int, gen_short: int,
                      n_long: int, long_len: int, gen_long: int,
                      vocab: int, long_after_s: float = 0.05,
                      seed: int = 0) -> list[TraceRequest]:
    """The chunked-prefill stress workload: a burst of short decode-heavy
    requests, then very long prompts landing while everyone is mid-decode.

    Without a token budget each long prompt prefills in one engine step and
    every decoding request observes that step's full latency as one
    inter-token gap; with chunked prefill the prompt advances
    ``token_budget`` tokens per step and decode gaps stay bounded.  The
    benchmark replays this trace one-shot vs chunked and compares the
    decode-tail (pooled inter-token latency p99) at an equal KV budget.
    """
    rng = np.random.default_rng(seed)
    out = [TraceRequest(arrival_s=0.001 * i,
                        prompt=rng.integers(0, vocab, size=short_len).tolist(),
                        max_new_tokens=gen_short, seed=i)
           for i in range(n_short)]
    for j in range(n_long):
        out.append(TraceRequest(
            arrival_s=long_after_s * (j + 1),
            prompt=rng.integers(0, vocab, size=long_len).tolist(),
            max_new_tokens=gen_long, seed=n_short + j))
    return out


def fleet_trace(*, n_requests: int, n_tenants: int, vocab: int,
                sys_len: int = 32, rate_per_s: float = 20.0,
                burst_mean: float = 4.0,
                prompt_median: int = 16, prompt_sigma: float = 0.8,
                prompt_max: int = 64,
                gen_median: int = 6, gen_sigma: float = 1.0,
                gen_max: int = 48, temperature: float = 0.0,
                seed: int = 0) -> list[TraceRequest]:
    """The fleet-scale workload: shared-system-prompt tenants, heavy
    tails, bursts — the "millions of users" shape, shrunk to a trace.

    * **tenant mix**: each request belongs to one of ``n_tenants``
      sessions and opens with that tenant's fixed ``sys_len``-token
      system prompt followed by a per-request tail — the prefix-cache
      sharing opportunity routing is meant to exploit (and round-robin
      is meant to squander, by spreading every tenant over every
      replica's cache);
    * **heavy-tailed lengths**: prompt-tail and output lengths are
      lognormal (median/sigma, clipped to [1, max]) — a few stragglers
      decode long after the cohort retires, which is exactly where one
      wide engine burns its full fused-decode lane complement on
      near-empty batches;
    * **bursty arrivals**: arrival epochs are Poisson at ``rate_per_s``
      and each epoch lands a geometric burst (mean ``burst_mean``) of
      back-to-back requests — queues actually form, giving
      work-stealing something to level.

    Deterministic in ``seed`` and — by construction — independent of
    who consumes it: every sample is drawn from one generator in one
    fixed order, so 1-replica and N-replica runs (any routing policy)
    replay the identical request stream (pinned by tests/test_fleet.py).
    """
    rng = np.random.default_rng(seed)
    sys_prompts = [rng.integers(0, vocab, size=sys_len).tolist()
                   for _ in range(n_tenants)]

    def _lognormal(median: int, sigma: float, hi: int) -> int:
        x = rng.lognormal(mean=float(np.log(max(median, 1))), sigma=sigma)
        return int(np.clip(round(x), 1, hi))

    out: list[TraceRequest] = []
    t = 0.0
    while len(out) < n_requests:
        t += float(rng.exponential(1.0 / rate_per_s))
        burst = min(1 + int(rng.geometric(1.0 / burst_mean)),
                    n_requests - len(out))
        for j in range(burst):
            i = len(out)
            tenant = int(rng.integers(n_tenants))
            tail = _lognormal(prompt_median, prompt_sigma, prompt_max)
            prompt = sys_prompts[tenant] \
                + rng.integers(0, vocab, size=tail).tolist()
            out.append(TraceRequest(
                arrival_s=t + 1e-4 * j, prompt=prompt,
                max_new_tokens=_lognormal(gen_median, gen_sigma, gen_max),
                temperature=temperature, seed=i, session=tenant))
    return out


def save_trace(path: str, trace: list[TraceRequest]) -> None:
    with open(path, "w") as f:
        json.dump([dataclasses.asdict(t) for t in trace], f)


def load_trace(path: str) -> list[TraceRequest]:
    with open(path) as f:
        return [TraceRequest(**d) for d in json.load(f)]


def replay(engine, trace: list[TraceRequest], *, time_scale: float = 1.0,
           verbose: bool = False) -> dict:
    """Feed ``trace`` into ``engine`` against the wall clock.

    ``time_scale`` compresses (<1) or stretches (>1) arrival gaps.  Requests
    rejected by admission control are recorded, not retried (open-loop
    workload).  Returns {"finished": [...], "rejected": n, "wall_s": s}.
    """
    pending = sorted(trace, key=lambda t: t.arrival_s)
    t0 = time.monotonic()
    rejected = 0
    i = 0
    while i < len(pending) or engine.has_work:
        now = time.monotonic() - t0
        while i < len(pending) and pending[i].arrival_s * time_scale <= now:
            tr = pending[i]
            i += 1
            try:
                # fleets take a session id for routing affinity; plain
                # engines don't — feature-detect so one replay drives both
                if tr.session >= 0 and getattr(engine, "accepts_session",
                                               False):
                    engine.submit(tr.prompt, tr.sampling(),
                                  session=tr.session)
                else:
                    engine.submit(tr.prompt, tr.sampling())
            except (QueueFull, ValueError) as e:
                # queue at capacity, or the request can never fit a slot —
                # open-loop workload: count it rejected, keep replaying
                rejected += 1
                if verbose:
                    print(f"rejected request {i - 1}: {e}")
        if engine.has_work:
            engine.step()
        elif i < len(pending):
            # idle until the next arrival is due
            next_due = pending[i].arrival_s * time_scale
            time.sleep(min(max(next_due - (time.monotonic() - t0), 0.0), 0.05))
    wall_s = time.monotonic() - t0
    return {"finished": engine.finished, "rejected": rejected, "wall_s": wall_s}
