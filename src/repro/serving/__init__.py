"""Continuous-batching serving engine (slot-based KV pool, interleaved
prefill/decode scheduling, per-request sampling + streaming callbacks).

  engine = ServingEngine(cfg, params, n_slots=8, max_len=256)
  req = engine.submit(prompt_tokens, SamplingParams(max_new_tokens=16))
  engine.run()            # or engine.step() under an external loop
  req.tokens              # generated ids; req.metrics has ttft/e2e/...

Dense params and SparseWeight compressed params (the paper's 8:16 +
structured-outlier deployment) are served by the same engine.
"""

from .cache_pool import SlotKVPool
from .engine import ServingEngine, SUPPORTED_FAMILIES
from .request import Request, SamplingParams, Status
from .scheduler import QueueFull, RequestQueue
from .trace import (TraceRequest, load_trace, poisson_trace, replay,
                    save_trace)
