"""Continuous-batching serving engine (preallocated KV pools, interleaved
prefill/decode scheduling, per-request sampling + streaming callbacks).

  engine = ServingEngine(cfg, params, n_slots=8, max_len=256)
  req = engine.submit(prompt_tokens, SamplingParams(max_new_tokens=16))
  engine.run()            # or engine.step() under an external loop
  req.tokens              # generated ids; req.metrics has ttft/e2e/...

``kv_layout="slot"`` reserves a contiguous max_len KV region per request;
``kv_layout="paged"`` allocates block_size-token blocks on demand with
prefix sharing and preempt-to-queue under memory pressure (serving/paged/).
``token_budget=`` bounds the prefill tokens any step may spend: prompts
larger than the budget advance chunk-by-chunk across steps beside the
decode batch, so long prompts never stall everyone else's tokens
(``max_prefill_per_step`` is the deprecated request-count spelling).
``mesh=`` makes the engine tensor-parallel through the serving placement
layer (serving/placement.py) — token-identical to the single-device path.

Dense params and SparseWeight compressed params (the paper's 8:16 +
structured-outlier deployment) are served by the same engine.

``draft=SpeculativeConfig(...)`` turns on draft-verify speculative
decoding (serving/speculative.py): a cheap proposer — the 8:16-compressed
model, any second parameter set, or an n-gram prompt-lookup — drafts k
tokens per decoding request per step, and the target scores all k+1
positions in ONE fused verify call through the same step pipeline.
Greedy speculative streams are token-identical to non-speculative ones.

``tracer=ServingTracer()`` turns on the observability substrate
(serving/observe.py): Perfetto trace spans for every request lifecycle and
engine step, a Prometheus-text counter registry, and per-jitted-variant
step-time attribution.  The default NULL_TRACER is a no-op with zero
per-step cost.
"""

from .cache_pool import (CachePoolError, CapacityError, DoubleFree,
                         KVCachePool, SlotKVPool, SlotPoolView)
from .engine import KV_LAYOUTS, ServingEngine, SUPPORTED_FAMILIES
from .families import (EncDecAdapter, FamilyAdapter, HybridAdapter,
                       RecurrentAdapter, TransformerAdapter, build_adapter)
from .fleet import ROUTING_POLICIES, ReplicaSet, RouteDecision, Router
from .observe import (NULL_ROUTER_TRACER, NULL_TRACER, NullRouterTracer,
                      NullTracer, RouterTracer, ServingTracer)
from .paged import OutOfBlocks, PagedKVPool, PagedPoolView
from .placement import ServingPlacement
from .request import Request, SamplingParams, Status
from .state_pool import (EncDecPoolView, EncoderContextPool, HybridPoolView,
                         RecurrentStatePool, RecurrentStateView)
from .scheduler import (CHUNK_QUANTUM, PREEMPT_DECODE_PRESSURE,
                        PREEMPT_PREFILL_PRESSURE, QueueFull, RequestQueue,
                        plan_chunks, resolve_token_budget,
                        spec_verify_reserve, validate_token_budget)
from .speculative import (NGramProposer, SpeculativeConfig, Speculator,
                          verify_bucket)
from .trace import (TraceRequest, fleet_trace, load_trace, long_prompt_trace,
                    poisson_trace, replay, save_trace)
