"""PagedKVPool: the engine-facing facade over the paged-KV machinery.

Satisfies the same cache-pool protocol as ``SlotKVPool``
(``serving/cache_pool.py``): ``n_free`` concurrency units, a
``max_request_tokens`` admission bound, ``k``/``v``/``pos`` device state
the jitted steps consume, and the ``adopt``/``advance_*``/``release``
lifecycle hooks.  The difference is what backs a request: a *row* here is
only scheduling state (a decode-batch lane plus a block table); the KV
bytes live in ``block_size``-token blocks allocated on demand from one
shared arena (``block_pool.py``), found via the per-row table
(``block_table.py``), and shared across requests with identical prefixes
(``prefix_cache.py``).

All KV writes happen INSIDE the jitted step functions: the pool exposes a
``PagedPoolView`` (arena + per-lane block tables + cursors) and
``models/transformer.unified_step`` scatters each chunk/decode token
through the table and attends in place over the blocks
(``paged_attention.py``) — prefill chunks never gather their
already-written prefix, so per-step HBM traffic is independent of the
cursor.

Admission decouples concurrency from reservation: a row costs nothing
until tokens are actually written, so ``n_rows`` can far exceed what
per-row ``max_len`` reservation would allow in the same HBM.  Allocation
is chunk-aware: ``admit(alloc_tokens=...)`` maps only the first prefill
chunk (plus any matched cached prefix) onto blocks, and
``ensure_capacity`` appends blocks as the engine's prefill cursor
advances — so a half-prefilled long prompt holds only the blocks it has
actually filled.  The flip side is that the arena can run dry mid-decode
or mid-prefill; ``prepare_decode``/``ensure_capacity`` raise
``OutOfBlocks`` and the engine preempts a running request back to the
queue instead of failing.

One block is reserved as the *trash block*: inactive decode-batch rows,
prefill bucket padding, and any position past a lane's ``n_new`` route
their writes there, so the fused steps can write unconditionally for
every lane without corrupting blocks that were recycled to another
request.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from ..cache_pool import CachePoolError, CapacityError, DoubleFree
from .block_pool import BlockPool, OutOfBlocks
from .block_table import BlockTable, blocks_needed
from .prefix_cache import PrefixCache


@dataclasses.dataclass(frozen=True)
class PagedPoolView:
    """What ``transformer.attend_over_pool`` sees of a paged pool: the
    block arena plus per-lane block tables and cursors — NOT a gathered
    copy of context.  Constructed inside the engine's traced step
    functions; ``trash`` is the host-known trash-block id (static).

    ``k``/``v`` are [L, n_blocks, block_size, KV, hd] at step level and
    one layer's [n_blocks, block_size, KV, hd] slice inside the per-layer
    scan.  ``block_tables`` [B, nb] maps each lane's sequence position p
    to physical block ``bt[b, p // block_size]`` (padding lanes carry
    all-trash tables).  ``cursor``/``n_new`` as in ``SlotPoolView``.

    ``k_scale``/``v_scale`` ([L, n_blocks, block_size, KV] f32, or None
    for bf16 arenas) are an int8 arena's per-position dequant scales; they
    are addressed through the SAME block tables as the values, so prefix
    sharing, copy-on-write and fork carry them implicitly.
    """
    k: Any
    v: Any
    block_tables: Any
    cursor: Any
    n_new: Any
    trash: int = 0
    k_scale: Any | None = None
    v_scale: Any | None = None

    rows = None                           # duck-type marker: paged layout

    def _write_slots(self, bs, S):
        """Flat (block*block_size + offset) scatter index per (lane, i);
        padding routes to the trash block."""
        nb = self.block_tables.shape[1]
        p = self.cursor[:, None] + jnp.arange(S)[None]        # [B,S]
        bi = p // bs
        blk = jnp.take_along_axis(self.block_tables,
                                  jnp.clip(bi, 0, nb - 1), axis=1)
        slot = blk * bs + p % bs
        valid = (jnp.arange(S)[None] < self.n_new[:, None]) & (bi < nb)
        return jnp.where(valid, slot, self.trash * bs).reshape(-1)

    def write_layer(self, k_l, v_l, fresh_k, fresh_v):
        """Scatter fresh [B, S, KV, hd] KV through the block tables at
        each lane's cursor, in place under donation.  Every padding
        element — batch-pad lanes, positions past a lane's ``n_new``, or
        positions past the table width — routes to the trash block, so
        the compiled scatter depends only on (B, S)."""
        bs = k_l.shape[1]
        B, S = fresh_k.shape[:2]
        slot = self._write_slots(bs, S)
        def scat(arena, vals):
            nblk = arena.shape[0]
            flat = arena.reshape(nblk * bs, *arena.shape[2:])
            flat = flat.at[slot].set(
                vals.reshape(B * S, *vals.shape[2:]).astype(arena.dtype))
            return flat.reshape(arena.shape)
        return scat(k_l, fresh_k), scat(v_l, fresh_v)

    def write_layer_quantized(self, k_l, v_l, ks_l, vs_l, fresh_k, fresh_v):
        """Quantize-on-scatter: int8-quantize fresh KV per position and
        route values + scales through the same table-derived slots (the
        bf16 projections never land in HBM as an arena copy)."""
        from ..cache_pool import quantize_kv
        bs = k_l.shape[1]
        B, S = fresh_k.shape[:2]
        slot = self._write_slots(bs, S)
        def scat(arena, vals):
            nblk = arena.shape[0]
            flat = arena.reshape(nblk * bs, *arena.shape[2:])
            flat = flat.at[slot].set(
                vals.reshape(B * S, *vals.shape[2:]).astype(arena.dtype))
            return flat.reshape(arena.shape)
        qk, sk = quantize_kv(fresh_k)
        qv, sv = quantize_kv(fresh_v)
        return scat(k_l, qk), scat(v_l, qv), scat(ks_l, sk), scat(vs_l, sv)


class PagedKVPool:
    def __init__(self, cfg, n_rows: int, max_len: int, *,
                 block_size: int = 16, n_blocks: int | None = None,
                 prefix_caching: bool = True, placement=None,
                 kv_dtype: str = "bf16"):
        self.block_size = block_size
        self.max_blocks_per_row = blocks_needed(max_len, block_size)
        if n_blocks is None:
            # same HBM as a SlotKVPool(n_rows, max_len) reservation
            n_blocks = n_rows * self.max_blocks_per_row
        self.blocks = BlockPool(cfg, n_blocks + 1, block_size,
                                placement=placement,
                                kv_dtype=kv_dtype)            # +1 trash
        self.kv_dtype = kv_dtype
        self._trash = self.blocks.alloc()                       # permanent
        self.n_blocks = n_blocks                                # usable
        self.n_rows = n_rows
        self.max_len = max_len
        self.prefix_cache = PrefixCache(self.blocks) if prefix_caching \
            else None
        self.tables: list[BlockTable | None] = [None] * n_rows
        self._bt_np = np.full((n_rows, self.max_blocks_per_row),
                              self._trash, np.int32)
        self._bt_jnp = jnp.asarray(self._bt_np)
        self._bt_dirty = False
        self._pos_np = np.zeros((n_rows,), np.int32)
        self._free_rows = list(range(n_rows - 1, -1, -1))
        self.n_preemptions = 0

    # ----------------------------------------------------- protocol attrs
    @property
    def k(self):
        return self.blocks.k

    @property
    def v(self):
        return self.blocks.v

    @property
    def k_scale(self):
        return self.blocks.k_scale

    @property
    def v_scale(self):
        return self.blocks.v_scale

    @property
    def pos(self):
        return jnp.asarray(self._pos_np)

    @property
    def n_slots(self) -> int:
        return self.n_rows

    @property
    def n_free(self) -> int:
        return len(self._free_rows)

    @property
    def max_request_tokens(self) -> int:
        """Longest request (prompt + generation) that can ever complete."""
        return min(self.max_len, self.n_blocks * self.block_size)

    @property
    def trash_block(self) -> int:
        return self._trash

    @property
    def block_tables(self):
        if self._bt_dirty:
            self._bt_jnp = jnp.asarray(self._bt_np)
            self._bt_dirty = False
        return self._bt_jnp

    def lane_tables(self, rows: list[int], n_rows_padded: int) -> np.ndarray:
        """Host per-lane block tables for a chunk group; padding lanes are
        all-trash so their writes and gathers stay harmless."""
        out = np.full((n_rows_padded, self.max_blocks_per_row),
                      self._trash, np.int32)
        for i, row in enumerate(rows):
            out[i] = self._bt_np[row]
        return out

    # -------------------------------------------------------- allocation
    @property
    def free_blocks(self) -> int:
        """Blocks obtainable right now: free-list plus evictable cache."""
        n = self.blocks.n_free
        if self.prefix_cache is not None:
            n += self.prefix_cache.n_evictable
        return n

    def can_admit(self, n_tokens: int, lookahead_blocks: int = 1) -> bool:
        """Block-aware admission: a free row, and enough obtainable blocks
        to hold the whole prompt plus a decode lookahead margin.  A prefix
        hit can only reduce the real need, so this is conservative.  The
        requirement is clamped to the arena size so a request whose prompt
        alone fills the arena (legal: submit() bounds prompt+generation by
        capacity) is not deferred forever by the margin."""
        if not self._free_rows:
            return False
        need = min(blocks_needed(n_tokens, self.block_size)
                   + lookahead_blocks, self.n_blocks)
        return need <= self.free_blocks

    def _alloc_block(self) -> int:
        while True:
            try:
                return self.blocks.alloc()
            except OutOfBlocks:
                if self.prefix_cache is None \
                        or not self.prefix_cache.evict_one():
                    raise

    def _cow(self, block: int) -> int:
        while True:
            try:
                return self.blocks.copy_on_write(block)
            except OutOfBlocks:
                if self.prefix_cache is None \
                        or not self.prefix_cache.evict_one():
                    raise

    # --------------------------------------------------------- admission
    def admit(self, tokens, alloc_tokens: int | None = None) -> tuple[int, int]:
        """Assign a row and map the (leading part of the) prompt onto blocks.

        Matches the longest cached prefix (sharing those blocks
        read-only), allocates fresh blocks for the rest, and returns
        ``(row, n_cached)`` — the prefill only needs to compute
        ``tokens[n_cached:]``.  At least the final prompt token is always
        recomputed so there are logits to sample the first generated
        token from; when that token's block was itself a cache hit, the
        block is first copied copy-on-write so the shared original stays
        immutable.  Raises ``OutOfBlocks`` (engine requeues the request)
        without leaking references.

        ``alloc_tokens`` bounds how many leading tokens get fresh blocks
        NOW (chunk-aware admission: the engine allocates per chunk via
        ``ensure_capacity`` as the prefill cursor advances).  ``None``
        allocates for the whole sequence up front; matched prefix blocks
        are always kept regardless of the bound.
        """
        if not self._free_rows:
            raise CapacityError("admit called with no free rows")
        n = len(tokens)
        if n > self.max_request_tokens:
            raise CapacityError(
                f"prompt of {n} tokens exceeds pool capacity "
                f"{self.max_request_tokens}")
        matched = self.prefix_cache.match(tokens) \
            if self.prefix_cache is not None else []
        bs = self.block_size
        # at least the final prompt token must be recomputed (its logits
        # seed the first generated token), and the cached count is kept on
        # a block boundary so suffix prefills see a handful of distinct
        # (bucket) shapes instead of one per prompt length
        n_cached = min(len(matched) * bs, (n - 1) // bs * bs) if matched \
            else 0
        target = n if alloc_tokens is None else min(n, max(alloc_tokens,
                                                           n_cached))
        table_blocks = list(matched)
        try:
            if matched and n_cached < len(matched) * bs:
                # the recomputed prompt tail lands inside the final matched
                # block -> take a private copy before writing (the shared
                # original may be serving other requests read-only)
                if self.blocks.ref[table_blocks[-1]] > 1:
                    table_blocks[-1] = self._cow(table_blocks[-1])
            for _ in range(blocks_needed(target, bs) - len(table_blocks)):
                table_blocks.append(self._alloc_block())
        except OutOfBlocks:
            for b in table_blocks:
                self.blocks.decref(b)
            raise
        row = self._free_rows.pop()
        self.tables[row] = BlockTable(bs, table_blocks, n_cached)
        self._bt_np[row, :] = self._trash
        self._bt_np[row, :len(table_blocks)] = table_blocks
        self._bt_dirty = True
        self._pos_np[row] = 0            # set for real by advance_prefill
        return row, n_cached

    def ensure_capacity(self, row: int, n_tokens: int) -> None:
        """Grow the row's table until it can hold ``n_tokens`` positions
        (chunk-aware allocation: called before each prefill chunk lands).
        No-op when the table already covers them.  Raises ``OutOfBlocks``
        mid-growth; already-appended blocks stay on the table (they are
        accounted to the row and used by the retried chunk)."""
        if n_tokens > self.max_request_tokens:
            raise CapacityError(
                f"{n_tokens} tokens exceed pool capacity "
                f"{self.max_request_tokens}")
        t = self.tables[row]
        if t is None:
            raise CachePoolError(f"ensure_capacity on free row {row}")
        while t.capacity < n_tokens:
            t.append_block(self._alloc_block())
            self._bt_np[row, t.n_blocks - 1] = t.blocks[-1]
            self._bt_dirty = True

    def prefix_match_length(self, tokens) -> int:
        """Side-effect-free probe: how many leading tokens of ``tokens``
        the prefix cache already covers (0 when caching is off).  See
        ``PrefixCache.match_length`` — no refcounts, no LRU touch, so
        fleet routers can probe every replica per request for free."""
        if self.prefix_cache is None:
            return 0
        return self.prefix_cache.match_length(tokens)

    # -------------------------------------------------------------- data
    def register_prefix(self, row: int, tokens) -> None:
        """Publish the row's full blocks covering ``tokens`` into the
        prefix cache.  ``tokens`` may be any fully-WRITTEN prefix of the
        row's sequence — the whole prompt after its final chunk, or the
        written history at preemption time (cursor resume: a re-admission
        then matches these blocks instead of recomputing them)."""
        if self.prefix_cache is not None:
            self.prefix_cache.insert(tokens, self.tables[row].blocks)

    def prepare_decode(self, rows: list[int],
                       n_tokens: list[int] | None = None) -> None:
        """Ensure every active row can write its next ``n_tokens[i]``
        positions (1 each when omitted — plain decode; a speculative
        verify step writes its k+1 candidate positions in one fused
        step).  Allocates blocks across each row's write range [pos,
        pos + n) (raises ``OutOfBlocks`` — the engine preempts and
        retries) and copies-on-write any shared block inside it: a
        prefix-cache or fork sharer must never see this row's fresh —
        possibly later rejected and rolled back — tokens."""
        bs = self.block_size
        ns = [1] * len(rows) if n_tokens is None else n_tokens
        for row, n in zip(rows, ns):
            pos = int(self._pos_np[row])
            n = max(n, 1)
            if pos + n > self.max_request_tokens:
                raise CapacityError(
                    f"decode write of {n} tokens at position {pos} exceeds "
                    f"pool capacity {self.max_request_tokens}")
            t = self.tables[row]
            for bi in range(pos // bs, (pos + n - 1) // bs + 1):
                if bi >= t.n_blocks:
                    t.append_block(self._alloc_block())
                    self._bt_np[row, bi] = t.blocks[bi]
                    self._bt_dirty = True
                elif self.blocks.ref[t.blocks[bi]] > 1:
                    fresh = self._cow(t.blocks[bi])
                    t.replace_block(bi, fresh)
                    self._bt_np[row, bi] = fresh
                    self._bt_dirty = True

    def fork(self, row: int) -> int:
        """Fork ``row`` copy-on-write into a fresh row: the new row's
        table shares every parent block read-only (incref only — no KV
        bytes move).  The first write either side makes inside a shared
        block goes through ``BlockPool.copy_on_write``
        (``prepare_decode``/``ensure_capacity``/``admit``), so the two
        sequences diverge block-by-block from the fork point — the
        substrate tree/forked draft speculation builds on.  Raises
        ``CapacityError`` when no free row is available (callers treat it
        like admission pressure, not a bug)."""
        t = self.tables[row]
        if t is None:
            raise CachePoolError(f"fork of free row {row}")
        if not self._free_rows:
            raise CapacityError("fork with no free row available")
        for b in t.blocks:
            self.blocks.incref(b)
        new = self._free_rows.pop()
        self.tables[new] = BlockTable(self.block_size, list(t.blocks),
                                      t.n_cached_tokens)
        self._bt_np[new, :] = self._trash
        self._bt_np[new, :t.n_blocks] = t.blocks
        self._bt_dirty = True
        self._pos_np[new] = self._pos_np[row]
        return new

    # --------------------------------------------------------- lifecycle
    def adopt(self, k, v, k_scale=None, v_scale=None) -> None:
        """Take ownership of a step's output arenas (donated in place)."""
        self.blocks.k = k
        self.blocks.v = v
        if k_scale is not None:
            self.blocks.k_scale = k_scale
            self.blocks.v_scale = v_scale

    def advance_prefill(self, rows: list[int], ends: list[int]) -> None:
        for row, end in zip(rows, ends):
            self._pos_np[row] = end

    def advance_decode(self, active_mask) -> None:
        """Positions advance on the host mirror for this step's decode
        rows only.  Rows mid-prefill keep their cursor, free rows keep a
        stale (harmless) value — the batch-wide decode write for every
        non-decoding row lands either in the trash block (free rows:
        their table IS the trash block; mid-prefill rows at an
        unallocated block boundary) or at a position the next chunk
        scatter overwrites before any query can attend to it."""
        active = np.asarray(active_mask)
        self._pos_np = np.where(active, self._pos_np + 1,
                                self._pos_np).astype(np.int32)

    def release(self, row: int) -> None:
        t = self.tables[row]
        if t is None:
            raise DoubleFree(f"release of free row {row}")
        for b in t.blocks:
            self.blocks.decref(b)        # cached blocks survive via cache ref
        self.tables[row] = None
        self._bt_np[row, :] = self._trash
        self._bt_dirty = True
        self._pos_np[row] = 0
        self._free_rows.append(row)

    def stats(self) -> dict:
        occ = self.blocks.occupancy()
        out = {"layout": "paged", "n_blocks": self.n_blocks,
               "block_size": self.block_size,
               "free_blocks": self.blocks.n_free,
               "occupancy": occ,
               "kv_dtype": self.kv_dtype,
               "arena_bytes": occ["arena_bytes"],
               "scale_bytes": occ["scale_bytes"],
               "n_preemptions": self.n_preemptions}
        if self.prefix_cache is not None:
            out["prefix_cache"] = self.prefix_cache.stats()
        return out

    def reset_stats(self) -> None:
        self.n_preemptions = 0
        if self.prefix_cache is not None:
            self.prefix_cache.reset_stats()
