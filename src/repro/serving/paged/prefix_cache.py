"""Hash-chained prefix cache over full KV blocks.

Every FULL block of a prefilled sequence is registered under a chained
key: ``key(block_i) = (key(block_{i-1}), tokens_of_block_i)``, so a block
is only ever matched when the *entire* token prefix leading to it is
identical — the standard vLLM-style automatic prefix-caching scheme.
(Python dict hashing does the hashing; keeping the exact token tuple in
the key means a hash collision can never silently serve wrong KV.)

The cache holds one reference on every registered block, so a block can
outlive the request that computed it and be shared read-only by later
requests with the same prefix (each sharer increfs on match).  Shared
blocks are never written in place: a request that must write into a
matched block — only the final matched block, when the whole prompt was
cached and its last token is recomputed for first-token logits — takes a
private copy first (BlockPool.copy_on_write).

Eviction is LRU over entries whose block nobody but the cache references
(ref == 1); ``evict_one`` is called by the paged pool when the allocator
runs dry.  Evicting a parent block can orphan cached children (their
chain can no longer be matched); orphans are harmless and age out of the
same LRU.
"""
from __future__ import annotations

import collections

from .block_pool import BlockPool


class PrefixCache:
    def __init__(self, pool: BlockPool):
        self._pool = pool
        # key -> block id, in LRU order (oldest first)
        self._entries: collections.OrderedDict = collections.OrderedDict()
        self._key_of_block: dict[int, object] = {}
        # accounting for the benchmark / tests
        self.lookups = 0
        self.hits = 0                   # lookups matching >= 1 block
        self.hit_tokens = 0
        self.inserted_blocks = 0
        self.evicted_blocks = 0
        self.probes = 0                 # side-effect-free match_length calls

    def __len__(self) -> int:
        return len(self._entries)

    # ------------------------------------------------------------- keys
    def _chain_keys(self, tokens) -> list:
        bs = self._pool.block_size
        keys, parent = [], None
        for start in range(0, len(tokens) - len(tokens) % bs, bs):
            parent = (parent, tuple(tokens[start:start + bs]))
            keys.append(parent)
        return keys

    # ------------------------------------------------------------ match
    def match(self, tokens) -> list[int]:
        """Longest chain of cached full blocks prefixing ``tokens``.

        Matched blocks are increfed for the caller (who now co-owns them)
        and touched in the LRU.  Returns the physical block ids in
        sequence order; the caller decides how many cached tokens it can
        actually use (it must recompute at least the last prompt token to
        have logits to sample from).
        """
        self.lookups += 1
        keys = self._chain_keys(tokens)
        matched = []
        for key in keys:
            block = self._entries.get(key)
            if block is None:
                break
            matched.append(block)
        for key in reversed(keys[:len(matched)]):
            self._entries.move_to_end(key)   # parents most-recent last
        for b in matched:
            self._pool.incref(b)
        if matched:
            self.hits += 1
        self.hit_tokens += len(matched) * self._pool.block_size
        return matched

    def match_length(self, tokens) -> int:
        """Longest cached-prefix length of ``tokens``, in TOKENS, with NO
        side effects: no refcounts taken, no LRU touch, no hit/lookup
        accounting.

        This is the router-facing probe behind prefix-aware replica
        routing: a router probes EVERY replica's cache per incoming
        request, and a probe must neither pin blocks (the request may be
        routed elsewhere) nor disturb eviction order (N-1 losing probes
        per request would otherwise refresh entries the winner never
        uses).  ``match`` remains the admission-time lookup that actually
        claims the blocks.  Probes are counted separately (``probes`` in
        ``stats()``) so hit-rate accounting stays admission-only.
        """
        self.probes += 1
        bs = self._pool.block_size
        matched = 0
        parent = None
        for start in range(0, len(tokens) - len(tokens) % bs, bs):
            parent = (parent, tuple(tokens[start:start + bs]))
            if parent not in self._entries:
                break
            matched += 1
        return matched * bs

    # ----------------------------------------------------------- insert
    def insert(self, tokens, blocks: list[int]) -> None:
        """Register the full blocks of a just-prefilled sequence.

        ``blocks`` is the request's block table; only indices covering
        complete ``block_size`` chunks of ``tokens`` are cached.  A key
        that is already cached is left pointing at its existing block
        (content-identical), so duplicates are deduped rather than
        double-registered.
        """
        for i, key in enumerate(self._chain_keys(tokens)):
            if key in self._entries:
                self._entries.move_to_end(key)
                continue
            block = blocks[i]
            if block in self._key_of_block:      # already cached under a
                continue                         # different chain — skip
            self._pool.incref(block)
            self._pool.mark_cached(block)
            self._entries[key] = block
            self._key_of_block[block] = key
            self.inserted_blocks += 1

    # ---------------------------------------------------------- evict
    @property
    def n_evictable(self) -> int:
        # maintained incrementally by the pool at refcount transitions —
        # O(1), this sits on the per-request admission hot path
        return self._pool.n_cached_idle

    def evict_one(self) -> bool:
        """Drop the least-recently-used entry whose block only the cache
        still references, freeing that block.  Returns False when every
        cached block is in use by a live request."""
        for key, block in self._entries.items():          # oldest first
            if self._pool.ref[block] == 1:
                del self._entries[key]
                del self._key_of_block[block]
                self._pool.decref(block)
                self.evicted_blocks += 1
                return True
        return False

    def stats(self) -> dict:
        return {"lookups": self.lookups, "hits": self.hits,
                "hit_rate": self.hits / self.lookups if self.lookups else 0.0,
                "hit_tokens": self.hit_tokens,
                "entries": len(self._entries),
                "inserted_blocks": self.inserted_blocks,
                "evicted_blocks": self.evicted_blocks,
                "probes": self.probes}

    def reset_stats(self) -> None:
        """Zero the counters without touching cached content (so a warmed
        cache can be measured over exactly one benchmark window)."""
        self.lookups = self.hits = self.hit_tokens = 0
        self.inserted_blocks = self.evicted_blocks = self.probes = 0
