"""Ref-counted block allocator over one preallocated paged KV arena.

The arena is a single pair of k/v buffers shaped
``[L, n_blocks, block_size, KV, hd]`` allocated once at engine start —
the paged analogue of SlotKVPool's ``[L, n_slots, max_len, KV, hd]``
reservation, but handed out in ``block_size``-token units with reference
counts so physical blocks can be shared read-only between requests
(prefix caching) and copied on write when a sharer needs to mutate one.

The pool itself is policy-free: it allocates, increfs, decrefs, and
copies blocks.  Who shares what (prefix_cache.py) and who owns which
block when (pool.py / the engine) live above it.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np


class BlockPoolError(RuntimeError):
    """Invariant violation in block accounting (double free, bad ref)."""


class OutOfBlocks(RuntimeError):
    """No free block available; callers evict prefix-cache entries or
    preempt a running request and retry."""


@partial(jax.jit, donate_argnums=(0,))
def _copy_block(arena, dst, src):
    """arena[:, dst] = arena[:, src], in place (donated)."""
    return jax.lax.dynamic_update_index_in_dim(
        arena, jax.lax.dynamic_index_in_dim(arena, src, 1, keepdims=False),
        dst, 1)


class BlockPool:
    def __init__(self, cfg, n_blocks: int, block_size: int, placement=None,
                 kv_dtype: str = "bf16"):
        if n_blocks < 1:
            raise ValueError("need at least one block")
        from ..cache_pool import KV_DTYPES
        from ..placement import ServingPlacement
        pl = placement or ServingPlacement()
        if kv_dtype not in KV_DTYPES:
            raise ValueError(f"kv_dtype must be one of {KV_DTYPES}, "
                             f"not {kv_dtype!r}")
        L, KV, hd = cfg.n_layers, cfg.n_kv_heads, cfg.hd
        shape = (L, n_blocks, block_size, KV, hd)
        arena_dtype = jnp.int8 if kv_dtype == "int8" else cfg.dtype
        # the one shared arena is committed KV-head-sharded on the serving
        # mesh (serving/placement.py); refcounts and the free list below are
        # host-side scheduling state and never shard
        self.k = pl.place_kv(jnp.zeros(shape, arena_dtype))
        self.v = pl.place_kv(jnp.zeros(shape, arena_dtype))
        if kv_dtype == "int8":
            # per-position dequant scales, blocked exactly like the values
            # (blocks on axis 1) so every block operation — alloc, share,
            # copy-on-write — moves scales with their block for free
            sshape = (L, n_blocks, block_size, KV)
            self.k_scale = pl.place_kv_scale(jnp.ones(sshape, jnp.float32))
            self.v_scale = pl.place_kv_scale(jnp.ones(sshape, jnp.float32))
        else:
            self.k_scale = self.v_scale = None
        self.kv_dtype = kv_dtype
        self.n_blocks = n_blocks
        self.block_size = block_size
        self.ref = np.zeros((n_blocks,), np.int32)
        self._free = list(range(n_blocks - 1, -1, -1))   # pop() -> ascending
        # prefix-cache bookkeeping: which blocks the cache has registered,
        # and how many of those only the cache still references (ref == 1,
        # i.e. evictable).  Maintained incrementally at every refcount
        # transition so the admission hot path reads it O(1) instead of
        # scanning the cache.
        self._cached = np.zeros((n_blocks,), bool)
        self.n_cached_idle = 0

    @property
    def n_free(self) -> int:
        return len(self._free)

    def occupancy(self) -> dict:
        """Arena occupancy snapshot for gauges/benchmarks: allocated vs
        free blocks plus how many cache-held blocks are evictable, and the
        full HBM bill (int8 values AND their f32 scales)."""
        from ..cache_pool import arena_nbytes
        scale_bytes = arena_nbytes(self.k_scale, self.v_scale)
        return {"n_blocks": self.n_blocks, "n_free": self.n_free,
                "n_allocated": self.n_blocks - self.n_free,
                "n_cached_idle": self.n_cached_idle,
                "kv_dtype": self.kv_dtype,
                "arena_bytes": arena_nbytes(self.k, self.v) + scale_bytes,
                "scale_bytes": scale_bytes}

    def alloc(self) -> int:
        """Hand out a free block with refcount 1."""
        if not self._free:
            raise OutOfBlocks(f"all {self.n_blocks} KV blocks in use")
        b = self._free.pop()
        self.ref[b] = 1
        return b

    def mark_cached(self, block: int) -> None:
        """Called by the prefix cache when it registers ``block``."""
        if not self._cached[block]:
            self._cached[block] = True
            if self.ref[block] == 1:
                self.n_cached_idle += 1

    def incref(self, block: int) -> None:
        if self.ref[block] <= 0:
            raise BlockPoolError(f"incref on free block {block}")
        if self._cached[block] and self.ref[block] == 1:
            self.n_cached_idle -= 1          # cache-idle -> shared
        self.ref[block] += 1

    def decref(self, block: int) -> bool:
        """Drop one reference; returns True when the block was freed."""
        if self.ref[block] <= 0:
            raise BlockPoolError(f"decref on free block {block} (double free)")
        self.ref[block] -= 1
        r = int(self.ref[block])
        if self._cached[block]:
            if r == 1:
                self.n_cached_idle += 1      # only the cache holds it now
            elif r == 0:
                self.n_cached_idle -= 1      # cache entry evicted
                self._cached[block] = False
        if r == 0:
            self._free.append(block)
            return True
        return False

    def copy_on_write(self, block: int) -> int:
        """Give the caller a private copy of ``block``: allocates a fresh
        block, copies the KV contents on device, and drops one reference
        on the original.  Raises OutOfBlocks when no block is free."""
        dst = self.alloc()
        src_, dst_ = jnp.int32(block), jnp.int32(dst)
        self.k = _copy_block(self.k, dst_, src_)
        self.v = _copy_block(self.v, dst_, src_)
        if self.k_scale is not None:
            self.k_scale = _copy_block(self.k_scale, dst_, src_)
            self.v_scale = _copy_block(self.v_scale, dst_, src_)
        self.decref(block)
        return dst
