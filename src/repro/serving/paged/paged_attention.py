"""Paged decode attention: one query token per row over block-mapped KV.

Two implementations behind one entry point:

- ``reference``: gather the row's blocks into a contiguous
  ``[B, nb*block_size, KV, hd]`` view with ``arena[block_tables]`` and run
  the same masked softmax as ``models/layers.decode_attention``.  Because a
  table maps sequence position ``p`` to gathered index ``p`` exactly, the
  ``< cache_len`` mask carries over unchanged — XLA fuses the gather, so
  this is also the portable CPU/GPU path.
- ``pallas``: a TPU kernel (interpret-mode fallback off-TPU) that never
  materializes the gathered view.  The block table rides in as a
  scalar-prefetch operand, the grid is ``(B, nb)`` with blocks innermost,
  and each step DMAs exactly one physical KV block — the index map reads
  ``block_tables[b, j]`` — accumulating flash-style (running max / sum /
  weighted value in VMEM scratch).  HBM traffic is therefore proportional
  to the tokens a request has actually written, not to a reserved
  ``max_len``, which is the whole point of paging the cache.

Both paths mask with a finite ``-1e30`` (exp underflows to exactly 0.0
against any real row max), so padding blocks — table entries past a short
row point at the shared trash block — contribute exactly nothing and the
result is bit-comparable with the contiguous slot-cache attention.
"""
from __future__ import annotations

import functools
import math
import os

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_NEG_INF = -1e30


def _default_backend() -> str:
    env = os.environ.get("REPRO_PAGED_BACKEND")
    if env:
        return env
    # interpret-mode Pallas is a Python loop over the grid — fine for
    # validation, far too slow to serve from on CPU
    return "pallas" if jax.default_backend() == "tpu" else "reference"


# --------------------------------------------------------------------------
# reference (jnp gather)
# --------------------------------------------------------------------------

def paged_attention_ref(q, k_arena, v_arena, block_tables, cache_len,
                        *, window: int | None = None) -> jax.Array:
    """q [B,1,H,hd]; arenas [n_blocks, bs, KV, hd]; block_tables [B, nb]
    int32; cache_len [B] (tokens visible per row).  Returns [B,1,H,hd]."""
    B, _, H, hd = q.shape
    _, bs, KV, _ = k_arena.shape
    nb = block_tables.shape[1]
    k = k_arena[block_tables].reshape(B, nb * bs, KV, hd)
    v = v_arena[block_tables].reshape(B, nb * bs, KV, hd)
    if KV != H:
        k = jnp.repeat(k, H // KV, axis=2)
        v = jnp.repeat(v, H // KV, axis=2)
    qf = (q.astype(jnp.float32) / math.sqrt(hd)).reshape(B, H, hd)
    scores = jnp.einsum("bhd,bshd->bhs", qf, k.astype(jnp.float32))
    idx = jnp.arange(nb * bs)[None]
    valid = idx < cache_len[:, None]
    if window is not None:
        valid &= idx >= jnp.maximum(cache_len[:, None] - window, 0)
    scores = jnp.where(valid[:, None], scores, _NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhs,bshd->bhd", probs, v.astype(jnp.float32))
    return out.reshape(B, 1, H, hd).astype(q.dtype)


# --------------------------------------------------------------------------
# pallas kernel
# --------------------------------------------------------------------------

def _paged_attn_kernel(bt_ref, len_ref, q_ref, k_ref, v_ref, o_ref,
                       m_ref, l_ref, acc_ref, *, bs, nb, n_rep, window):
    b, j = pl.program_id(0), pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    hd = q_ref.shape[-1]
    qf = q_ref[0].astype(jnp.float32) / math.sqrt(hd)         # [H, hd]
    k = k_ref[0].astype(jnp.float32)                          # [bs, KV, hd]
    v = v_ref[0].astype(jnp.float32)
    if n_rep > 1:
        k = jnp.repeat(k, n_rep, axis=1)                      # [bs, H, hd]
        v = jnp.repeat(v, n_rep, axis=1)
    s = jnp.einsum("hd,shd->hs", qf, k)                       # [H, bs]

    seq_len = len_ref[b]
    idx = j * bs + jax.lax.iota(jnp.int32, bs)                # [bs]
    valid = idx < seq_len
    if window is not None:
        valid &= idx >= jnp.maximum(seq_len - window, 0)
    s = jnp.where(valid[None, :], s, _NEG_INF)

    m_prev, l_prev = m_ref[...], l_ref[...]                   # [H,1]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)                                    # [H, bs]
    m_ref[...] = m_new
    l_ref[...] = l_prev * alpha + jnp.sum(p, axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jnp.einsum("hs,shd->hd", p, v)

    @pl.when(j == nb - 1)
    def _finish():
        denom = jnp.maximum(l_ref[...], 1e-30)
        o_ref[...] = (acc_ref[...] / denom)[None].astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("window", "interpret"))
def paged_attention_pallas(q, k_arena, v_arena, block_tables, cache_len,
                           *, window: int | None = None,
                           interpret: bool = True) -> jax.Array:
    """Same contract as ``paged_attention_ref``; one grid step per
    (row, block), KV blocks DMA'd by table lookup via scalar prefetch."""
    B, _, H, hd = q.shape
    n_blocks, bs, KV, _ = k_arena.shape
    nb = block_tables.shape[1]
    n_rep = H // KV
    q3 = q.reshape(B, H, hd)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,                  # block tables, cache lens
        grid=(B, nb),
        in_specs=[
            pl.BlockSpec((1, H, hd), lambda b, j, bt, ln: (b, 0, 0)),
            pl.BlockSpec((1, bs, KV, hd),
                         lambda b, j, bt, ln: (bt[b, j], 0, 0, 0)),
            pl.BlockSpec((1, bs, KV, hd),
                         lambda b, j, bt, ln: (bt[b, j], 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, H, hd), lambda b, j, bt, ln: (b, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((H, 1), jnp.float32),    # running max
            pltpu.VMEM((H, 1), jnp.float32),    # running sum
            pltpu.VMEM((H, hd), jnp.float32),   # weighted-value accumulator
        ],
    )
    out = pl.pallas_call(
        functools.partial(_paged_attn_kernel, bs=bs, nb=nb, n_rep=n_rep,
                          window=window),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, H, hd), q.dtype),
        interpret=interpret,
    )(block_tables.astype(jnp.int32), cache_len.astype(jnp.int32),
      q3, k_arena, v_arena)
    return out.reshape(B, 1, H, hd)


# --------------------------------------------------------------------------
# dispatch
# --------------------------------------------------------------------------

def paged_attention(q, k_arena, v_arena, block_tables, cache_len, *,
                    window: int | None = None,
                    backend: str | None = None) -> jax.Array:
    backend = backend or _default_backend()
    if backend == "pallas":
        return paged_attention_pallas(
            q, k_arena, v_arena, block_tables, cache_len, window=window,
            interpret=jax.default_backend() != "tpu")
    return paged_attention_ref(q, k_arena, v_arena, block_tables, cache_len,
                               window=window)
