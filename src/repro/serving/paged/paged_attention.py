"""Paged attention over block-mapped KV: decode (q_len == 1) AND chunked
prefill (q_len == S) through one entry point.

``q`` is [B, S, H, hd]; ``cursor`` [B] counts the tokens of each row that
were already visible before this step's S fresh ones.  Query i of row b
sits at absolute position ``cursor[b] + i`` and attends to sequence
positions ``j <= cursor[b] + i`` (window-limited) — the unified in-place
masking rule of ``models/transformer.attend_over_pool``: the fresh chunk's
KV is scattered into the arena *before* attention, so the causal mask
alone hides this step's not-yet-visible writes, stale tokens of previous
block occupants, and trash-block padding.  S=1 with ``cursor = pos``
reproduces the old decode contract (visible count ``pos + 1``).

Three implementations behind one entry point:

- ``reference``: gather the row's blocks into a contiguous
  ``[B, nb*block_size, KV, hd]`` view with ``arena[block_tables]`` and run
  the masked softmax.  A table maps sequence position ``p`` to gathered
  index ``p`` exactly, so the mask carries over unchanged — XLA fuses the
  gather, so this is also the portable CPU/GPU path.
- ``pallas``: a TPU kernel (interpret-mode fallback off-TPU) that never
  materializes the gathered view.  The block table rides in as a
  scalar-prefetch operand, the grid is ``(B, nb)`` with blocks innermost,
  and each step DMAs exactly one physical KV block — the index map reads
  ``block_tables[b, j]`` — accumulating flash-style (running max / sum /
  weighted value in VMEM scratch) over ALL S queries of the row at once.
  HBM traffic is therefore proportional to the tokens a request has
  actually written, not to a reserved ``max_len``.
- ``pallas`` head-tiled: same kernel with an extra grid axis over KV-head
  tiles, for models whose full [S, H, hd] q/accumulator tiles would
  pressure VMEM (large H*hd).  Selected automatically when
  ``H * hd >= _HEAD_TILE_THRESHOLD`` (env ``REPRO_PAGED_HEAD_TILE``
  forces a tile width; 0 disables).

All paths mask with a finite ``-1e30`` (exp underflows to exactly 0.0
against any real row max), so masked positions contribute exactly nothing
and the result is comparable with the contiguous slot-arena attention.
"""
from __future__ import annotations

import functools
import math
import os

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_NEG_INF = -1e30

# head-tiled kernel kicks in at this many q-head * head-dim lanes; chosen
# so a [S, H, hd] f32 accumulator tile stays well under VMEM at serving
# chunk sizes (S <= a few hundred)
_HEAD_TILE_THRESHOLD = 4096


def _default_backend() -> str:
    env = os.environ.get("REPRO_PAGED_BACKEND")
    if env:
        return env
    # interpret-mode Pallas is a Python loop over the grid — fine for
    # validation, far too slow to serve from on CPU
    return "pallas" if jax.default_backend() == "tpu" else "reference"


def _head_tile(H: int, KV: int, hd: int) -> int | None:
    """KV heads per kernel tile, or None for the untiled kernel.  The
    ``REPRO_PAGED_HEAD_TILE`` override falls back to untiled (None) when
    the requested tile cannot legally tile this model's KV heads, so one
    fleet-wide knob never crashes a smaller model's serving path."""
    env = os.environ.get("REPRO_PAGED_HEAD_TILE")
    if env is not None:
        t = int(env)
        if t <= 0 or t >= KV or KV % t:
            return None
        return t
    if H * hd < _HEAD_TILE_THRESHOLD:
        return None
    n_rep = H // KV
    per_tile = max(_HEAD_TILE_THRESHOLD // (2 * n_rep * hd), 1)
    while KV % per_tile:
        per_tile -= 1
    return per_tile if per_tile < KV else None


# --------------------------------------------------------------------------
# reference (jnp gather)
# --------------------------------------------------------------------------

def paged_attention_ref(q, k_arena, v_arena, block_tables, cursor,
                        *, window: int | None = None,
                        k_scale=None, v_scale=None) -> jax.Array:
    """q [B,S,H,hd]; arenas [n_blocks, bs, KV, hd]; block_tables [B, nb]
    int32; cursor [B] (tokens visible per row before this step's S fresh
    ones).  Returns [B,S,H,hd].

    A table maps sequence position ``p`` to gathered index ``p`` exactly,
    so after the gather this IS the contiguous length-masked attention —
    delegated to ``models/layers.attend_length_masked`` so the masking
    rule lives in one place.  ``k_scale``/``v_scale`` [n_blocks, bs, KV]
    are an int8 arena's per-position scales, gathered through the same
    tables (XLA fuses gather + dequant — no bf16 arena copy)."""
    from ...models.layers import attend_length_masked
    B, S, H, hd = q.shape
    _, bs, KV, _ = k_arena.shape
    nb = block_tables.shape[1]
    k = k_arena[block_tables].reshape(B, nb * bs, KV, hd)
    v = v_arena[block_tables].reshape(B, nb * bs, KV, hd)
    ks = vs = None
    if k_scale is not None:
        ks = k_scale[block_tables].reshape(B, nb * bs, KV)
        vs = v_scale[block_tables].reshape(B, nb * bs, KV)
    return attend_length_masked(q, k, v, cursor, window=window,
                                k_scale=ks, v_scale=vs)


# --------------------------------------------------------------------------
# pallas kernel
# --------------------------------------------------------------------------

def _paged_attn_kernel(bt_ref, cur_ref, q_ref, k_ref, v_ref, *refs,
                       bs, nb, n_rep, window, head_tiled, quantized):
    if quantized:
        # int8 arenas: per-position scale tiles ride the same block-table
        # index map as the KV tiles and dequantize in-register, inside the
        # online softmax — the gathered bf16 KV never exists in HBM
        ks_ref, vs_ref, o_ref, m_ref, l_ref, acc_ref = refs
    else:
        o_ref, m_ref, l_ref, acc_ref = refs
        ks_ref = vs_ref = None
    if head_tiled:
        b, j = pl.program_id(0), pl.program_id(2)
    else:
        b, j = pl.program_id(0), pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    S, hd = q_ref.shape[1], q_ref.shape[-1]
    qf = q_ref[0].astype(jnp.float32) / math.sqrt(hd)         # [S, Ht, hd]
    k = k_ref[0].astype(jnp.float32)                          # [bs, KVt, hd]
    v = v_ref[0].astype(jnp.float32)
    if quantized:
        k = k * ks_ref[0][..., None]                          # [bs, KVt, 1]
        v = v * vs_ref[0][..., None]
    if n_rep > 1:
        k = jnp.repeat(k, n_rep, axis=1)                      # [bs, Ht, hd]
        v = jnp.repeat(v, n_rep, axis=1)
    s = jnp.einsum("qhd,thd->hqt", qf, k)                     # [Ht, S, bs]

    qpos = cur_ref[b] + jax.lax.iota(jnp.int32, S)            # [S]
    idx = j * bs + jax.lax.iota(jnp.int32, bs)                # [bs]
    valid = idx[None, :] <= qpos[:, None]                     # [S, bs]
    if window is not None:
        valid &= idx[None, :] > qpos[:, None] - window
    s = jnp.where(valid[None], s, _NEG_INF)

    m_prev, l_prev = m_ref[...], l_ref[...]                   # [Ht, S]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=2))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[..., None])                         # [Ht, S, bs]
    m_ref[...] = m_new
    l_ref[...] = l_prev * alpha + jnp.sum(p, axis=2)
    acc_ref[...] = (acc_ref[...] * alpha[..., None]
                    + jnp.einsum("hqt,thd->hqd", p, v))

    @pl.when(j == nb - 1)
    def _finish():
        denom = jnp.maximum(l_ref[...], 1e-30)                # [Ht, S]
        out = acc_ref[...] / denom[..., None]                 # [Ht, S, hd]
        o_ref[...] = out.transpose(1, 0, 2)[None].astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("window", "interpret", "head_tile"))
def paged_attention_pallas(q, k_arena, v_arena, block_tables, cursor,
                           *, window: int | None = None,
                           interpret: bool = True,
                           head_tile: int | None = None,
                           k_scale=None, v_scale=None) -> jax.Array:
    """Same contract as ``paged_attention_ref``; one grid step per
    (row[, head tile], block), KV blocks DMA'd by table lookup via scalar
    prefetch.  ``head_tile`` = KV heads per grid tile (None: all heads in
    one tile) — the large-H*hd variant walks head tiles as a middle grid
    axis so q/accumulator tiles stay VMEM-sized.  ``k_scale``/``v_scale``
    [n_blocks, bs, KV] ride as two extra operands whose index map is the
    same block-table lookup; the kernel dequantizes in-register."""
    B, S, H, hd = q.shape
    n_blocks, bs, KV, _ = k_arena.shape
    nb = block_tables.shape[1]
    n_rep = H // KV
    quantized = k_scale is not None

    if head_tile is not None and (KV % head_tile or head_tile >= KV):
        raise ValueError(f"head_tile {head_tile} must divide and be "
                         f"smaller than KV={KV}")
    kvt = head_tile if head_tile is not None else KV
    ht = kvt * n_rep
    kern = functools.partial(_paged_attn_kernel, bs=bs, nb=nb, n_rep=n_rep,
                             window=window,
                             head_tiled=head_tile is not None,
                             quantized=quantized)
    if head_tile is None:
        grid = (B, nb)
        q_spec = pl.BlockSpec((1, S, H, hd), lambda b, j, bt, cu: (b, 0, 0, 0))
        kv_spec = pl.BlockSpec((1, bs, KV, hd),
                               lambda b, j, bt, cu: (bt[b, j], 0, 0, 0))
        sc_spec = pl.BlockSpec((1, bs, KV),
                               lambda b, j, bt, cu: (bt[b, j], 0, 0))
        o_spec = pl.BlockSpec((1, S, H, hd), lambda b, j, bt, cu: (b, 0, 0, 0))
    else:
        grid = (B, KV // kvt, nb)
        q_spec = pl.BlockSpec((1, S, ht, hd),
                              lambda b, h, j, bt, cu: (b, 0, h, 0))
        kv_spec = pl.BlockSpec((1, bs, kvt, hd),
                               lambda b, h, j, bt, cu: (bt[b, j], 0, h, 0))
        sc_spec = pl.BlockSpec((1, bs, kvt),
                               lambda b, h, j, bt, cu: (bt[b, j], 0, h))
        o_spec = pl.BlockSpec((1, S, ht, hd),
                              lambda b, h, j, bt, cu: (b, 0, h, 0))

    in_specs = [q_spec, kv_spec, kv_spec]
    operands = [q, k_arena, v_arena]
    if quantized:
        in_specs += [sc_spec, sc_spec]
        operands += [k_scale, v_scale]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,                  # block tables, cursors
        grid=grid,
        in_specs=in_specs,
        out_specs=o_spec,
        scratch_shapes=[
            pltpu.VMEM((ht, S), jnp.float32),     # running max
            pltpu.VMEM((ht, S), jnp.float32),     # running sum
            pltpu.VMEM((ht, S, hd), jnp.float32),  # weighted-value acc
        ],
    )
    out = pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, S, H, hd), q.dtype),
        interpret=interpret,
    )(block_tables.astype(jnp.int32), cursor.astype(jnp.int32),
      *operands)
    return out


# --------------------------------------------------------------------------
# dispatch
# --------------------------------------------------------------------------

def paged_attention(q, k_arena, v_arena, block_tables, cursor, *,
                    window: int | None = None,
                    backend: str | None = None,
                    k_scale=None, v_scale=None) -> jax.Array:
    backend = backend or _default_backend()
    if backend == "pallas":
        H, hd = q.shape[2], q.shape[3]
        KV = k_arena.shape[2]
        return paged_attention_pallas(
            q, k_arena, v_arena, block_tables, cursor, window=window,
            interpret=jax.default_backend() != "tpu",
            head_tile=_head_tile(H, KV, hd),
            k_scale=k_scale, v_scale=v_scale)
    return paged_attention_ref(q, k_arena, v_arena, block_tables, cursor,
                               window=window, k_scale=k_scale,
                               v_scale=v_scale)
