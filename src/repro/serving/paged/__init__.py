"""Paged KV-cache subsystem: block-granular KV memory for the serving
engine (``ServingEngine(..., kv_layout="paged")``).

  block_pool.py      ref-counted allocator over one [L, n_blocks,
                     block_size, KV, hd] arena, with copy-on-write
  block_table.py     per-request logical->physical page maps
  prefix_cache.py    hash-chained full-block prefix sharing (LRU evict)
  paged_attention.py in-place attention over block tables (decode AND
                     prefill chunks): jnp reference + Pallas
                     scalar-prefetch kernel, head-tiled for large H*hd
                     (interpret off-TPU)
  pool.py            PagedKVPool — the cache-pool-protocol facade — and
                     PagedPoolView, what attend_over_pool sees of it
"""

from .block_pool import BlockPool, BlockPoolError, OutOfBlocks
from .block_table import BlockTable, blocks_needed
from .paged_attention import (paged_attention, paged_attention_pallas,
                              paged_attention_ref)
from .pool import PagedKVPool, PagedPoolView
from .prefix_cache import PrefixCache
