"""Per-request block tables: the logical-to-physical page map.

A request's KV sequence position ``p`` lives in physical block
``blocks[p // block_size]`` at in-block offset ``p % block_size`` — the
paged-attention gather reconstructs the contiguous view from exactly this
mapping, so the table is the single source of truth for where a request's
tokens are.
"""
from __future__ import annotations

import dataclasses


def blocks_needed(n_tokens: int, block_size: int) -> int:
    """Physical blocks required to hold ``n_tokens`` KV entries."""
    return -(-n_tokens // block_size) if n_tokens > 0 else 0


@dataclasses.dataclass
class BlockTable:
    block_size: int
    blocks: list[int] = dataclasses.field(default_factory=list)
    # how many leading tokens were satisfied from the prefix cache (the
    # request's prefill skipped computing them)
    n_cached_tokens: int = 0

    @property
    def n_blocks(self) -> int:
        return len(self.blocks)

    @property
    def capacity(self) -> int:
        """Token positions the mapped blocks can hold."""
        return len(self.blocks) * self.block_size

    def block_index(self, pos: int) -> int:
        return pos // self.block_size

    def physical_block(self, pos: int) -> int:
        return self.blocks[pos // self.block_size]

    def slot(self, pos: int) -> int:
        """Flat arena token slot for sequence position ``pos`` (the arena
        viewed as [n_blocks * block_size] token rows)."""
        return self.blocks[pos // self.block_size] * self.block_size \
            + pos % self.block_size

    def append_block(self, block: int) -> None:
        self.blocks.append(block)

    def replace_block(self, index: int, block: int) -> None:
        self.blocks[index] = block
