"""Continuous-batching serving engine over the model zoo's compressed-weight
path.

The engine owns a preallocated KV pool and runs iteration-level
scheduling: every ``step()`` evicts expired queue entries, admits new
requests (bounded prefill work interleaved between decode steps), then
advances ALL running requests by one token in a single fused decode step.
New requests join the running batch without disturbing it — per-row
attention/norms are independent and each lane carries its own cache
position, so a request's tokens are identical whether it runs alone or
packed next to strangers (tested).

Two KV layouts behind one API (``kv_layout=``):

  "slot"   SlotKVPool: contiguous [L, n_slots, max_len, KV, hd] buffers,
           one slot reserved per request for its lifetime.  Simplest and
           compile-once, but reserves max_len tokens of HBM per slot.
  "paged"  PagedKVPool (serving/paged/): KV lives in block_size-token
           blocks allocated on demand from a shared arena, found through
           per-request block tables and attended via a gather-based
           paged decode step (models/transformer.decode_step_paged).
           Identical prefixes share blocks read-only (prefix cache), so
           a fleet of requests with one system prompt stores its KV
           once and skips recomputing it (lower TTFT).  Admission is
           block-aware and decode pressure preempts the youngest request
           back to the queue instead of failing; a preempted request
           resumes by re-prefilling prompt + generated-so-far, which
           reproduces its token stream exactly.

Works unchanged for dense weights or ``SparseWeight`` compressed params
(models/sparse_serving.py): the weights are just a pytree passed through the
jitted prefill/decode functions, so the 8:16 (+structured outlier) serving
path gets continuous batching for free.

Supported families: token-input transformers with [L, B, S, KV, hd] KV
caches ("dense", "moe").  Recurrent/enc-dec families keep the one-shot path
in launch/serve.py.

Prefill batching: admitted prompts are padded to power-of-two length buckets
and grouped, so the number of distinct compiled prefill shapes stays small
under mixed prompt lengths.  With causal attention the bucket padding
(after the prompt) cannot influence prompt logits or KV — including MoE,
whose local routing is capacity-free (models/moe.py _moe_local).  The
engine's traced functions run under ``policy.suspended()`` precisely to
keep that path on every mesh: an active activation-sharding policy would
flip MoE to the capacity-BOUNDED expert-parallel route, where pad tokens
compete with real tokens for expert capacity.

Mesh-native serving (``mesh=``): pass a ``("data", "model")`` mesh and the
engine becomes tensor-parallel end to end through one placement layer
(serving/placement.py): params — dense and SparseWeight compressed buffers
alike — are committed out-dim-sharded over "model", both KV layouts shard
their arenas' KV-head dim, and every jitted prefill/decode function carries
explicit in/out shardings.  Block tables, the prefix cache, and all
scheduling state stay host-side and layout-agnostic.  Token streams are
identical to the single-device engine (tests/test_mesh_serving.py); with no
mesh (default) nothing changes from the single-device behavior.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from ..models import transformer as tfm
from ..parallel import policy as pol
from .cache_pool import CachePoolError, SlotKVPool
from .paged import OutOfBlocks, PagedKVPool
from .placement import ServingPlacement
from .request import Request, SamplingParams, Status
from .sampling import sample_tokens
from .scheduler import (QueueFull, RequestQueue, admission_budget,
                        pick_preemption_victim)

SUPPORTED_FAMILIES = ("dense", "moe")
KV_LAYOUTS = ("slot", "paged")


def _bucket(n: int, lo: int = 8) -> int:
    b = lo
    while b < n:
        b *= 2
    return b


class ServingEngine:
    def __init__(self, cfg, params, *, n_slots: int = 8, max_len: int = 256,
                 max_queue: int = 64, queue_timeout_s: float | None = None,
                 max_prefill_per_step: int = 2, kv_layout: str = "slot",
                 block_size: int = 16, n_blocks: int | None = None,
                 prefix_caching: bool = True, lookahead_blocks: int = 1,
                 paged_attn_backend: str | None = None, mesh=None,
                 clock=time.monotonic):
        if cfg.family not in SUPPORTED_FAMILIES:
            raise ValueError(
                f"ServingEngine supports {SUPPORTED_FAMILIES} families, not "
                f"{cfg.family!r}; use the one-shot path in launch/serve.py")
        if kv_layout not in KV_LAYOUTS:
            raise ValueError(f"kv_layout must be one of {KV_LAYOUTS}, "
                             f"not {kv_layout!r}")
        self.cfg = cfg
        self.placement = ServingPlacement(mesh, cfg)
        # one sharding-tree walk serves both the initial device_put and the
        # jitted functions' explicit in_shardings below
        psh = self.placement.param_shardings(params)
        self.params = params if psh is None else jax.device_put(params, psh)
        self.kv_layout = kv_layout
        if kv_layout == "paged":
            self.pool = PagedKVPool(cfg, n_slots, max_len,
                                    block_size=block_size, n_blocks=n_blocks,
                                    prefix_caching=prefix_caching,
                                    placement=self.placement)
        else:
            self.pool = SlotKVPool(cfg, n_slots, max_len,
                                   placement=self.placement)
        self.queue = RequestQueue(max_queue, queue_timeout_s)
        self.max_prefill_per_step = max_prefill_per_step
        self.lookahead_blocks = lookahead_blocks
        self.running: dict[int, Request] = {}        # slot/row -> request
        self.finished: list[Request] = []
        self._clock = clock
        self._next_id = 0
        self.n_steps = 0
        self.n_preemptions = 0
        self.max_running = 0

        # per-slot sampling state (host side, fixed shapes)
        self._temps = np.zeros((n_slots,), np.float32)
        self._topks = np.zeros((n_slots,), np.int32)
        self._seeds = np.zeros((n_slots,), np.int32)
        self._gen_count = np.zeros((n_slots,), np.int32)
        self._last_token = np.zeros((n_slots,), np.int32)
        # logits of each slot's most recent position (prefill scatters here
        # so first-token sampling reuses the one slot-wide sampler)
        self._slot_logits = self.placement.place_replicated(
            jnp.zeros((n_slots, cfg.vocab), jnp.float32))

        # Every traced function is wrapped in policy.suspended() so an
        # ambient activation-sharding policy can't leak into serving traces
        # (it would flip MoE to the capacity-bounded path — module docstring).
        def suspend(fn):
            def traced(*args):
                with pol.suspended():
                    return fn(*args)
            return traced

        pl = self.placement

        def jit(fn, in_sh=None, out_sh=None, donate=()):
            """jit with the placement's explicit in/out shardings; a plain
            single-device jit when no mesh is set (today's behavior)."""
            if not pl.active:
                return jax.jit(suspend(fn), donate_argnums=donate)
            return jax.jit(suspend(fn), in_shardings=in_sh,
                           out_shardings=out_sh, donate_argnums=donate)

        rep, kvsh = pl.replicated, pl.kv
        self._prefill_fn = jit(
            lambda p, t: tfm.forward(p, {"tokens": t}, cfg, collect_kv=True),
            in_sh=(psh, rep), out_sh=(rep, (kvsh, kvsh)))
        # suffix prefill against gathered prefix KV (paged prefix-cache
        # hits); retraces once per (prefix_len, bucket) shape pair
        self._prefix_prefill_fn = jit(
            lambda p, t, pk, pv: tfm.forward_with_prefix(
                p, {"tokens": t}, cfg, pk, pv),
            in_sh=(psh, rep, kvsh, kvsh), out_sh=(rep, (kvsh, kvsh)))
        # k/v are donated: the pool adopts the step's output buffers, so the
        # multi-GB caches update in place instead of being copied every token
        # (cache out shardings == in shardings, so donation stays in place
        # shard-for-shard on the mesh)
        self._decode_fn = jit(
            lambda p, k, v, pos, t: tfm.decode_step(
                p, {"k": k, "v": v, "pos": pos}, {"tokens": t}, cfg),
            in_sh=(psh, kvsh, kvsh, rep, rep),
            out_sh=(rep, {"k": kvsh, "v": kvsh, "pos": rep}),
            donate=(1, 2))
        self._decode_paged_fn = jit(
            lambda p, k, v, bt, pos, t: tfm.decode_step_paged(
                p, {"k": k, "v": v, "block_tables": bt, "pos": pos},
                {"tokens": t}, cfg, attn_backend=paged_attn_backend),
            in_sh=(psh, kvsh, kvsh, rep, rep, rep),
            out_sh=(rep, {"k": kvsh, "v": kvsh, "block_tables": rep,
                          "pos": rep}),
            donate=(1, 2))

    # ------------------------------------------------------------ admission
    def submit(self, prompt, sampling: SamplingParams | None = None,
               on_token=None, on_finish=None) -> Request:
        """Enqueue a request; raises QueueFull when admission control
        rejects (queue at capacity) and ValueError when the request can
        never fit the KV pool."""
        sampling = sampling or SamplingParams()
        prompt = [int(t) for t in np.asarray(prompt).reshape(-1)]
        if not prompt:
            raise ValueError("empty prompt")
        if sampling.max_new_tokens < 1:
            raise ValueError("max_new_tokens must be >= 1")
        capacity = self.pool.max_request_tokens
        if len(prompt) + sampling.max_new_tokens > capacity:
            raise ValueError(
                f"prompt ({len(prompt)}) + max_new_tokens "
                f"({sampling.max_new_tokens}) exceeds KV capacity "
                f"{capacity}")
        req = Request(self._next_id, prompt, sampling,
                      on_token=on_token, on_finish=on_finish)
        self._next_id += 1
        req.metrics.arrival = self._clock()
        if not self.queue.try_push(req):
            raise QueueFull(f"queue at capacity ({self.queue.max_size})")
        return req

    # ------------------------------------------------------------ stepping
    @property
    def has_work(self) -> bool:
        return bool(self.running) or len(self.queue) > 0

    def step(self) -> dict:
        """One scheduling iteration: evict -> admit/prefill -> decode."""
        now = self._clock()
        stats = {"evicted": 0, "admitted": 0, "finished": 0, "decoded": 0,
                 "preempted": 0}

        for req in self.queue.evict_expired(now):
            req._finish(Status.EVICTED, now)
            self.finished.append(req)
            stats["evicted"] += 1

        budget = admission_budget(len(self.queue), self.pool.n_free,
                                  len(self.running), self.max_prefill_per_step)
        if budget:
            admits = [self.queue.pop() for _ in range(budget)]
            stats["finished"] += self._admit(admits, stats)

        self.max_running = max(self.max_running, len(self.running))
        if self.running:
            stats["decoded"] = len(self.running)
            stats["finished"] += self._decode_once(stats)

        self.n_steps += 1
        return stats

    def run(self, max_steps: int | None = None) -> list[Request]:
        """Step until queue and slots drain; returns finished requests."""
        steps = 0
        while self.has_work and (max_steps is None or steps < max_steps):
            self.step()
            steps += 1
        return self.finished

    def stats(self) -> dict:
        """Engine-level counters plus the pool's memory/prefix accounting."""
        out = {"n_steps": self.n_steps, "max_running": self.max_running,
               "n_preemptions": self.n_preemptions,
               "kv_layout": self.kv_layout,
               "placement": self.placement.describe()}
        if self.kv_layout == "paged":
            out["pool"] = self.pool.stats()
        return out

    def reset_stats(self) -> None:
        """Zero the step/preemption/concurrency/prefix counters (cached KV
        and compiled functions are kept) — benchmarks call this between a
        warm-up pass and the measured window."""
        self.n_steps = 0
        self.n_preemptions = 0
        self.max_running = 0
        if self.kv_layout == "paged":
            self.pool.reset_stats()

    # ------------------------------------------------------------ internals
    @staticmethod
    def _seq(req: Request) -> list[int]:
        """The token sequence a (re-)prefill must cover: the prompt plus
        anything already generated before a preemption."""
        return list(req.prompt) + req.tokens

    def _admit(self, reqs: list[Request], stats: dict) -> int:
        """Prefill ``reqs`` (grouped so each shape compiles exactly once),
        install their KV, and emit each request's next token.  Returns the
        number of requests that finished immediately."""
        if self.kv_layout == "paged":
            placed, deferred = [], []
            for i, r in enumerate(reqs):
                if deferred:
                    deferred.append(r)
                    continue
                seq = self._seq(r)
                if not self.pool.can_admit(len(seq), self.lookahead_blocks):
                    deferred.append(r)
                    continue
                try:
                    row, n_cached = self.pool.admit(seq)
                except OutOfBlocks:
                    deferred.append(r)
                    continue
                placed.append((r, row, n_cached))
            for r in reversed(deferred):      # keep FIFO order at the head
                self.queue.push_front(r)
            stats["admitted"] += len(placed)
            by_shape: dict[tuple[int, int], list] = {}
            for r, row, n_cached in placed:
                suffix = len(self._seq(r)) - n_cached
                by_shape.setdefault((n_cached, _bucket(suffix)),
                                    []).append((r, row))
            n_finished = 0
            chunk = max(self.max_prefill_per_step, 1)
            for (n_cached, bucket), group in sorted(by_shape.items()):
                for start in range(0, len(group), chunk):
                    n_finished += self._prefill_group_paged(
                        group[start:start + chunk], n_cached, bucket, chunk)
            return n_finished

        stats["admitted"] += len(reqs)
        by_bucket: dict[int, list[Request]] = {}
        for r in reqs:
            by_bucket.setdefault(_bucket(len(self._seq(r))), []).append(r)
        n_finished = 0
        chunk = max(self.max_prefill_per_step, 1)
        for bucket, bucket_group in sorted(by_bucket.items()):
            for start in range(0, len(bucket_group), chunk):
                group = bucket_group[start:start + chunk]
                n_finished += self._prefill_group(group, bucket, chunk)
        return n_finished

    def _install_running(self, req: Request, slot: int, now: float) -> None:
        req.slot = slot
        req.status = Status.RUNNING
        req.metrics.admitted = now
        self.running[slot] = req
        self._temps[slot] = req.sampling.temperature
        self._topks[slot] = req.sampling.top_k
        self._seeds[slot] = req.sampling.seed
        # resumed requests continue their sampling stream at token index
        # len(tokens); fresh requests start at 0
        self._gen_count[slot] = len(req.tokens)

    def _prefill_group(self, group: list[Request], bucket: int,
                       batch_pad: int) -> int:
        """Slot-layout prefill: full prompts, contiguous slot install."""
        B = max(len(group), batch_pad)
        seqs = [self._seq(r) for r in group]
        tokens = np.zeros((B, bucket), np.int32)
        for i, s in enumerate(seqs):
            tokens[i, :len(s)] = s
        logits, (k, v) = self._prefill_fn(self.params, jnp.asarray(tokens))

        now = self._clock()
        slots = []
        for r in group:
            slot = self.pool.alloc()
            if slot is None:
                raise CachePoolError("scheduler admitted past free slots")
            self._install_running(r, slot, now)
            slots.append(slot)
        n = len(group)                      # real rows; the rest is batch pad
        self.pool.write_prefill_group(slots, k[:, :n], v[:, :n],
                                      [len(s) for s in seqs])

        lens = np.array([len(s) for s in seqs]) - 1
        last_logits = logits[jnp.arange(n), jnp.asarray(lens)]
        self._slot_logits = self._slot_logits.at[jnp.asarray(slots)].set(
            last_logits.astype(jnp.float32))
        return self._emit_tokens(slots)

    def _prefill_group_paged(self, group: list[tuple], n_cached: int,
                             bucket: int, batch_pad: int) -> int:
        """Paged prefill of rows sharing (prefix length, suffix bucket):
        compute only the uncached suffix, scatter its KV into the rows'
        blocks, and publish full prompt blocks to the prefix cache."""
        B = max(len(group), batch_pad)
        rows = [row for _, row in group]
        seqs = [self._seq(r) for r, _ in group]
        suffixes = [s[n_cached:] for s in seqs]
        tokens = np.zeros((B, bucket), np.int32)
        for i, s in enumerate(suffixes):
            tokens[i, :len(s)] = s
        if n_cached > 0:
            pk, pv = self.pool.gather_prefix(rows, n_cached, B)
            logits, (k, v) = self._prefix_prefill_fn(
                self.params, jnp.asarray(tokens), pk, pv)
        else:
            logits, (k, v) = self._prefill_fn(self.params,
                                              jnp.asarray(tokens))

        now = self._clock()
        for r, row in group:
            self._install_running(r, row, now)
        n = len(group)
        self.pool.write_prefill(rows, k[:, :n], v[:, :n], n_cached,
                                [len(s) for s in suffixes])
        for (r, row), seq in zip(group, seqs):
            self.pool.register_prefix(row, seq)

        lens = np.array([len(s) for s in suffixes]) - 1
        last_logits = logits[jnp.arange(n), jnp.asarray(lens)]
        self._slot_logits = self._slot_logits.at[jnp.asarray(rows)].set(
            last_logits.astype(jnp.float32))
        return self._emit_tokens(rows)

    def _preempt_one(self, stats: dict) -> None:
        """Push the youngest running request back to the queue head and
        release its blocks; it will resume by re-prefilling."""
        victim_slot = pick_preemption_victim(self.running)
        req = self.running.pop(victim_slot)
        self.pool.release(victim_slot)
        req.slot = None
        req.status = Status.QUEUED
        req.n_preempted += 1
        self.queue.push_front(req)
        self.n_preemptions += 1
        if self.kv_layout == "paged":
            self.pool.n_preemptions += 1
        stats["preempted"] += 1

    def _decode_once(self, stats: dict | None = None) -> int:
        """Advance every running slot one token in a single fused step."""
        stats = stats if stats is not None else {"preempted": 0}
        if self.kv_layout == "paged":
            while True:
                try:
                    self.pool.prepare_decode(sorted(self.running))
                    break
                except OutOfBlocks:
                    if len(self.running) <= 1:
                        # cannot happen for admissible requests (submit
                        # bounds prompt+gen by pool capacity), so this is
                        # an accounting bug, not workload pressure
                        raise CachePoolError(
                            "sole running request cannot grow its KV")
                    self._preempt_one(stats)
            if not self.running:
                return 0
            active = sorted(self.running)
            tokens = jnp.asarray(self._last_token[:, None])
            logits, caches = self._decode_paged_fn(
                self.params, self.pool.k, self.pool.v,
                self.pool.block_tables, self.pool.pos, tokens)
        else:
            active = sorted(self.running)
            tokens = jnp.asarray(self._last_token[:, None])
            logits, caches = self._decode_fn(self.params, self.pool.k,
                                             self.pool.v, self.pool.pos,
                                             tokens)
        self._slot_logits = logits.astype(jnp.float32)
        n_finished = self._emit_tokens(active)
        still = np.zeros((self.pool.n_slots,), bool)
        still[sorted(self.running)] = True
        self.pool.update(caches, jnp.asarray(still))
        return n_finished

    def _emit_tokens(self, slots: list[int]) -> int:
        """Sample one token for ``slots`` from _slot_logits, stream it, and
        retire requests that hit max_new_tokens / EOS.  Returns retirements."""
        toks = np.asarray(sample_tokens(
            self._slot_logits, jnp.asarray(self._temps),
            jnp.asarray(self._topks), jnp.asarray(self._seeds),
            jnp.asarray(self._gen_count)))
        now = self._clock()
        n_finished = 0
        for slot in slots:
            req = self.running[slot]
            tok = int(toks[slot])
            req._emit(tok, now)
            self._last_token[slot] = tok
            self._gen_count[slot] += 1
            sp = req.sampling
            if (len(req.tokens) >= sp.max_new_tokens
                    or (sp.eos_id is not None and tok == sp.eos_id)):
                req._finish(Status.FINISHED, now)
                self.finished.append(req)
                del self.running[slot]
                self.pool.release(slot)
                n_finished += 1
        return n_finished
