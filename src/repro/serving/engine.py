"""Continuous-batching serving engine over the model zoo's compressed-weight
path.

The engine owns a preallocated KV pool and runs a SINGLE token-budgeted
iteration: every ``step()`` evicts expired queue entries, then assembles a
mixed batch of work under one ``token_budget`` of prefill tokens — in-flight
partial prefills advance first, then new admissions from the queue head —
and finally advances every prefill-complete request by one token in a single
fused decode step.  Long prompts no longer monopolize a step: a prompt
larger than the budget is split into chunks that land across consecutive
steps (per-request ``prefill_cursor``), each chunk attending to all KV the
request has already written.

ONE attention path: every piece of model work — one-shot prefill, prefill
chunk, fused decode — is ``models/transformer.unified_step`` over a pool
view (``attend_over_pool``).  The step function scatters its fresh KV into
the KV arena (slot rows or paged blocks) and attends IN PLACE against the
arena with the per-request cursor as a length mask; RoPE positions and the
causal/sliding-window mask are offset by the cursor, so chunked prefill is
numerically the one-shot prefill it replaces.  Nothing ever gathers a copy
of the already-written prefix, so each chunk's HBM traffic is independent
of the cursor — prefilling a P-token prompt costs O(P) arena traffic
total, not the O(P^2/budget) the old gather-per-chunk path paid.  Two
jitted functions cover everything: ``_step_fn`` (chunk-or-prefill,
retraces once per (batch, bucket) shape) and ``_decode_fn`` (fused decode,
compiles once).  Decoding requests keep emitting a token every step while
a long prompt trickles in beside them — bounded decode-tail inter-token
latency under mixed workloads, the regime where the paper's 8:16+outlier
compressed weights are deployed.  New requests join the running batch
without disturbing it — per-row attention/norms are independent and each
lane carries its own cursor, so a request's tokens are identical whether
it runs alone, packed next to strangers, or chunked under any budget
(tested).

Two KV layouts behind one API (``kv_layout=``):

  "slot"   SlotKVPool: contiguous [L, n_slots, max_len, KV, hd] arenas,
           one slot reserved per request for its lifetime.  Simplest and
           compile-once, but reserves max_len tokens of HBM per slot.
           The step functions address lanes through a ``SlotPoolView``
           (lane->slot rows + cursors).
  "paged"  PagedKVPool (serving/paged/): KV lives in block_size-token
           blocks allocated on demand from a shared arena, addressed
           through per-request block tables (``PagedPoolView``) and
           attended via the chunk-capable paged-attention kernel
           (serving/paged/paged_attention.py — jnp reference off-TPU,
           Pallas online-softmax over block tables on TPU, head-tiled
           automatically for large H*hd).  Block allocation is
           chunk-aware — a half-prefilled prompt holds only the blocks
           its cursor has filled.  Identical prefixes share blocks
           read-only (prefix cache); decode or prefill pressure preempts
           the youngest request back to the queue, whose fully-written
           blocks are first published to the prefix cache so the resume
           restarts its cursor at the last fully-written block instead of
           recomputing everything.

Works unchanged for dense weights or ``SparseWeight`` compressed params
(models/sparse_serving.py): the weights are just a pytree passed through the
jitted step functions, so the 8:16 (+structured outlier) serving path gets
continuous batching and chunked prefill for free.

Supported families: every family in the model zoo, through one family
adapter layer (serving/families.py).  The engine owns scheduling — queue,
token budget, chunk planning, slot lifecycle, sampling — against one
primary pool; the adapter owns what a family actually keeps per request
(KV arenas, recurrent-state slots, encoder context rows) and the jitted
step functions over its ``unified_step``:

  dense/moe  Slot/Paged KV pool (this module's original path, verbatim)
  ssm        RecurrentStatePool only — O(1) state per request, no KV, so
             the chunk quantum widens to the whole token budget (no block
             math, no shape ladder worth bounding) and preemption swaps
             the state out and back (recompute would change float
             summation order)
  hybrid     shared-attention KV pool + mamba state slots under one slot
             identity, slot or paged (paged disables the prefix cache:
             cached KV blocks cannot reconstruct SSM state)
  encdec     decoder KV slots + read-only encoder context rows; the
             encoder runs once at admission at the TRUE input length
             (``submit(embeds=...)``)

Chunk batching: chunks at the same cursor are padded to power-of-two length
buckets and grouped, so the number of distinct compiled step shapes stays
small under mixed prompt lengths.  With the in-place causal mask the bucket
padding (after each chunk) cannot influence real logits or KV — pad lanes'
writes are dropped (slot) or routed to the trash block (paged), and pad
query outputs are never read — including MoE, whose local routing is
capacity-free (models/moe.py _moe_local).  The engine's traced functions
run under ``policy.suspended()`` precisely to keep that path on every mesh:
an active activation-sharding policy would flip MoE to the
capacity-BOUNDED expert-parallel route, where pad tokens compete with real
tokens for expert capacity.

Mesh-native serving (``mesh=``): pass a ``("data", "model")`` mesh and the
engine becomes tensor-parallel end to end through one placement layer
(serving/placement.py): params — dense and SparseWeight compressed buffers
alike — are committed out-dim-sharded over "model", both KV layouts shard
their arenas' KV-head dim, and both jitted step functions carry the
explicit in/out shardings of ``placement.step_fn_shardings`` (donated
arenas stay in place shard-for-shard).  Block tables, the prefix cache,
and all scheduling state stay host-side and layout-agnostic.  Token
streams are identical to the single-device engine
(tests/test_mesh_serving.py, tests/test_chunked_prefill.py); with no mesh
(default) nothing changes from the single-device behavior.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from . import families
from .cache_pool import CachePoolError
from .observe import NULL_TRACER
from .paged import OutOfBlocks
from .placement import ServingPlacement
from .request import Request, SamplingParams, Status
from .sampling import sample_tokens_logprobs, verify_draft
from .scheduler import (CHUNK_QUANTUM, PREEMPT_DECODE_PRESSURE,
                        PREEMPT_PREFILL_PRESSURE, QueueFull, RequestQueue,
                        pick_preemption_victim, plan_chunks,
                        resolve_token_budget, spec_verify_reserve)
from .speculative import SpeculativeConfig, Speculator, verify_bucket

SUPPORTED_FAMILIES = ("dense", "moe", "ssm", "hybrid", "encdec")
KV_LAYOUTS = ("slot", "paged")


def _bucket(n: int, lo: int = 8) -> int:
    b = lo
    while b < n:
        b *= 2
    return b


class ServingEngine:
    def __init__(self, cfg, params, *, n_slots: int = 8, max_len: int = 256,
                 max_queue: int = 64, queue_timeout_s: float | None = None,
                 token_budget: int | None = None,
                 max_prefill_per_step: int | None = None,
                 kv_layout: str = "slot", kv_dtype: str = "bf16",
                 block_size: int = 16, n_blocks: int | None = None,
                 prefix_caching: bool = True, lookahead_blocks: int = 1,
                 paged_attn_backend: str | None = None, mesh=None,
                 max_ctx: int | None = None, clock=time.monotonic,
                 tracer=None, draft: SpeculativeConfig | None = None):
        if cfg.family not in SUPPORTED_FAMILIES:
            raise ValueError(
                f"ServingEngine supports {SUPPORTED_FAMILIES} families, not "
                f"{cfg.family!r}")
        if kv_layout not in KV_LAYOUTS:
            raise ValueError(f"kv_layout must be one of {KV_LAYOUTS}, "
                             f"not {kv_layout!r}")
        self.cfg = cfg
        self.placement = ServingPlacement(mesh, cfg)
        # one sharding-tree walk serves both the initial device_put and the
        # adapter's jitted functions' explicit in_shardings
        psh = self.placement.param_shardings(params)
        self.params = params if psh is None else jax.device_put(params, psh)
        # the family adapter owns the state substrate (pools + arenas) and
        # the jitted step functions; the engine schedules against its
        # primary pool.  ssm coerces the layout to "slot" (it has no KV to
        # page); encdec rejects "paged"
        if cfg.family == "ssm":
            kv_layout = "slot"
        self.adapter = families.build_adapter(
            cfg, self.params, self.placement, psh, kv_layout=kv_layout,
            n_slots=n_slots, max_len=max_len, block_size=block_size,
            n_blocks=n_blocks, prefix_caching=prefix_caching,
            paged_attn_backend=paged_attn_backend, max_ctx=max_ctx,
            kv_dtype=kv_dtype)
        self.kv_layout = kv_layout
        self.kv_dtype = kv_dtype
        self.pool = self.adapter.pool
        # kept for introspection and the compiled-cost tests
        self._step_fn = self.adapter._step_fn
        self._decode_fn = self.adapter._decode_fn
        self.queue = RequestQueue(max_queue, queue_timeout_s)
        # per-step prefill token budget (max_prefill_per_step is the
        # deprecated request-count knob, aliased with a one-time warning).
        # resolve -> validate_token_budget raises a construction-time
        # ValueError when the budget cannot cover the chunk quantum or the
        # longest admissible prompt's first chunk — instead of a deep
        # stall inside scheduler.plan_chunks.  Pure-recurrent requests
        # carry O(1) state: no block math and no shape ladder worth
        # bounding, so the quantum floor check is waived (quantum=1) and
        # the effective planning quantum widens to the whole budget
        self.token_budget = resolve_token_budget(
            token_budget, max_prefill_per_step, max_len,
            quantum=1 if cfg.family == "ssm" else CHUNK_QUANTUM)
        self.chunk_quantum = (self.token_budget if cfg.family == "ssm"
                              else CHUNK_QUANTUM)
        self.lookahead_blocks = lookahead_blocks
        # speculative decoding (serving/speculative.py): a draft proposer
        # shares slot identity with the target; each decode step drafts k
        # tokens per request and verifies all k+1 candidate positions in
        # ONE chunk-shaped step (n_new = k+1 per lane) — same jitted fn,
        # same bucket ladder, so speculation adds no new compiled shapes
        # beyond the S buckets it actually uses
        self.spec = None
        if draft is not None:
            if cfg.family not in ("dense", "moe"):
                raise ValueError(
                    "speculative decoding needs a KV-transformer target "
                    f"(dense/moe), not family {cfg.family!r}: rollback "
                    "relies on the cursor hiding rejected positions, which "
                    "recurrent state cannot do")
            self.spec = Speculator(draft, cfg, self.placement,
                                   n_slots=n_slots, max_len=max_len,
                                   kv_dtype=kv_dtype)
        self.n_spec_steps = 0
        self.n_drafted = 0
        self.n_accepted = 0
        self.running: dict[int, Request] = {}        # slot/row -> request
        self.finished: list[Request] = []
        self._clock = clock
        # observability: NULL_TRACER is a no-op singleton and every hot-path
        # call site is guarded by ``tracer.enabled``, so the disabled engine
        # does zero observability work per step (serving/observe.py)
        self.tracer = NULL_TRACER if tracer is None else tracer
        if self.tracer.enabled:
            self.tracer.attach(self)
            self.adapter.tracer = self.tracer
            if self.spec is not None:
                self.spec.set_tracer(self.tracer)
        self._next_id = 0
        self.n_steps = 0
        self.n_preemptions = 0
        self.max_running = 0

        # per-slot sampling state (host side, fixed shapes)
        self._temps = np.zeros((n_slots,), np.float32)
        self._topks = np.zeros((n_slots,), np.int32)
        self._seeds = np.zeros((n_slots,), np.int32)
        self._gen_count = np.zeros((n_slots,), np.int32)
        self._last_token = np.zeros((n_slots,), np.int32)
        # logits of each slot's most recent position (a final prefill chunk
        # scatters here so first-token sampling reuses the one slot-wide
        # sampler)
        self._slot_logits = self.placement.place_replicated(
            jnp.zeros((n_slots, cfg.vocab), jnp.float32))

    # ------------------------------------------------------------ admission
    def submit(self, prompt, sampling: SamplingParams | None = None,
               on_token=None, on_finish=None, embeds=None,
               request_id: int | None = None) -> Request:
        """Enqueue a request; raises QueueFull when admission control
        rejects (queue at capacity) and ValueError when the request can
        never fit the pool.  ``embeds`` is the enc-dec family's encoder
        input ([S_enc, d] frontend features, run once at admission); other
        families reject it.  ``request_id`` lets a fleet router issue
        globally-unique ids across replicas; omitted, the engine numbers
        requests itself."""
        sampling = sampling or SamplingParams()
        prompt = [int(t) for t in np.asarray(prompt).reshape(-1)]
        if not prompt:
            raise ValueError("empty prompt")
        if sampling.max_new_tokens < 1:
            raise ValueError("max_new_tokens must be >= 1")
        capacity = self.pool.max_request_tokens
        if len(prompt) + sampling.max_new_tokens > capacity:
            raise ValueError(
                f"prompt ({len(prompt)}) + max_new_tokens "
                f"({sampling.max_new_tokens}) exceeds KV capacity "
                f"{capacity}")
        self.adapter.validate_submit(prompt, sampling, embeds)
        rid = self._next_id if request_id is None else int(request_id)
        req = Request(rid, prompt, sampling,
                      on_token=on_token, on_finish=on_finish, embeds=embeds)
        req.metrics.family = self.cfg.family
        self._next_id = max(self._next_id + 1, rid + 1)
        req.metrics.arrival = self._clock()
        if not self.queue.try_push(req):
            raise QueueFull(f"queue at capacity ({self.queue.max_size})")
        if self.tracer.enabled:
            self.tracer.on_submit(req)
        return req

    def ingest(self, req: Request) -> None:
        """Adopt an already-constructed request from another engine (fleet
        work-stealing / preemption drain).  The request must be queued and
        unscheduled — it holds no slot, no KV, no per-engine state — so
        migrating it is just re-enqueueing: its sampling stream is keyed
        by (seed, tokens generated), which makes the token stream
        engine-agnostic.  Metrics (arrival time, preemption count) ride
        along untouched."""
        if req.status is not Status.QUEUED or req.slot is not None:
            raise ValueError(
                f"ingest needs a queued, unscheduled request, got "
                f"{req.status} (slot={req.slot})")
        capacity = self.pool.max_request_tokens
        need = len(self._seq(req)) + req.sampling.max_new_tokens \
            - len(req.tokens)
        if need > capacity:
            raise ValueError(
                f"request {req.request_id} needs {need} tokens, over this "
                f"engine's KV capacity {capacity}")
        if not self.queue.try_push(req):
            raise QueueFull(f"queue at capacity ({self.queue.max_size})")
        self._next_id = max(self._next_id, req.request_id + 1)
        if self.tracer.enabled:
            self.tracer.on_submit(req)

    def withdraw(self, req: Request) -> bool:
        """Remove a queued request from this engine so a fleet router can
        ``ingest`` it elsewhere.  Returns False when the request is no
        longer in this engine's queue (admitted or evicted since the
        router looked)."""
        if not self.queue.remove(req):
            return False
        if self.tracer.enabled:
            self.tracer.on_withdraw(req)
        return True

    def steal_youngest(self) -> Request | None:
        """Withdraw the YOUNGEST queued request (fleet work-stealing) —
        the tail of the FIFO queue, so the head-of-line request and
        everything the scheduler has promised service order to stays
        put.  None when the queue is empty."""
        req = self.queue.pop_back()
        if req is not None and self.tracer.enabled:
            self.tracer.on_withdraw(req)
        return req

    def prefix_match_length(self, prompt) -> int:
        """How many leading tokens of ``prompt`` this engine's prefix
        cache already holds — a side-effect-free host-side probe (no
        refcounts, no LRU touch; see ``PrefixCache.match_length``).
        Returns 0 for layouts/configs without a prefix cache, so routers
        can score any engine uniformly."""
        fn = getattr(self.pool, "prefix_match_length", None)
        if fn is None:
            return 0
        return fn([int(t) for t in np.asarray(prompt).reshape(-1)])

    # ------------------------------------------------------------ stepping
    @property
    def has_work(self) -> bool:
        return bool(self.running) or len(self.queue) > 0

    def step(self) -> dict:
        """One token-budgeted iteration: evict -> prefill chunks under the
        budget (in-flight cursors first, then admissions) -> fused decode
        of every prefill-complete request."""
        now = self._clock()
        tr = self.tracer
        if tr.enabled:
            tr.begin_step(self.n_steps, now)
        stats = {"evicted": 0, "admitted": 0, "finished": 0, "decoded": 0,
                 "preempted": 0, "prefill_tokens": 0, "prefill_chunks": 0}

        for req in self.queue.evict_expired(now):
            req._finish(Status.EVICTED, now)
            self.finished.append(req)
            stats["evicted"] += 1
            if tr.enabled:
                tr.on_evict(req)

        # speculative decoding charges each decoding request's k+1 verify
        # tokens against the step's token budget before prefill planning
        # (scheduler.spec_verify_reserve) — the fused verify runs through
        # the same chunk pipeline prefill does, so the budget stays an
        # honest bound on the step's total token work
        budget = self.token_budget
        if self.spec is not None:
            reserve = spec_verify_reserve(self.running, self.spec.cfg.k)
            budget = max(budget - reserve, 0)
            stats["spec_reserved"] = min(reserve, self.token_budget)

        self._prefill_phase(stats, now, budget)

        self.max_running = max(self.max_running, len(self.running))
        if any(r.status is Status.RUNNING for r in self.running.values()):
            stats["finished"] += self._decode_once(stats)

        self.n_steps += 1
        if tr.enabled:
            tr.end_step(self, stats)
        return stats

    def run(self, max_steps: int | None = None) -> list[Request]:
        """Step until queue and slots drain; returns finished requests."""
        steps = 0
        while self.has_work and (max_steps is None or steps < max_steps):
            self.step()
            steps += 1
        return self.finished

    def stats(self) -> dict:
        """Engine-level counters plus the pool's memory/prefix accounting."""
        out = {"n_steps": self.n_steps, "max_running": self.max_running,
               "n_preemptions": self.n_preemptions,
               "n_running": len(self.running),
               "queue_depth": len(self.queue),
               "n_finished": len(self.finished),
               "family": self.cfg.family,
               "kv_layout": self.kv_layout,
               "kv_dtype": self.kv_dtype,
               "token_budget": self.token_budget,
               "placement": self.placement.describe()}
        pool_stats = getattr(self.pool, "stats", None)
        if pool_stats is not None:
            out["pool"] = pool_stats()
        if self.spec is not None:
            out["speculative"] = {
                "method": self.spec.cfg.method,
                "k": self.spec.cfg.k,
                "n_spec_steps": self.n_spec_steps,
                "drafted": self.n_drafted,
                "accepted": self.n_accepted,
                "acceptance_rate": (self.n_accepted / self.n_drafted
                                    if self.n_drafted else 0.0),
                # >1 means speculation is beating sequential decode: each
                # verify step emits accepted/steps drafts plus its
                # correction/bonus token
                "accepted_per_step": (self.n_accepted / self.n_spec_steps
                                      if self.n_spec_steps else 0.0)}
        return out

    def reset_stats(self) -> None:
        """Zero the step/preemption/concurrency/prefix counters (cached KV
        and compiled functions are kept) — benchmarks call this between a
        warm-up pass and the measured window."""
        self.n_steps = 0
        self.n_preemptions = 0
        self.max_running = 0
        self.n_spec_steps = 0
        self.n_drafted = 0
        self.n_accepted = 0
        if self.kv_layout == "paged":
            self.pool.reset_stats()

    # ------------------------------------------------------------ internals
    @staticmethod
    def _seq(req: Request) -> list[int]:
        """The token sequence prefill must cover: the prompt plus anything
        already generated before a preemption."""
        return list(req.prompt) + req.tokens

    def _written_seq(self, req: Request) -> list[int]:
        """The leading tokens whose KV the request has actually written —
        what a preemption can publish to the prefix cache for cursor
        resume.  Mid-prefill that is the cursor; for a decoding request
        everything but the last generated token (whose KV is only written
        when it is fed into the next decode step)."""
        seq = self._seq(req)
        if req.status is Status.PREFILLING:
            return seq[:req.prefill_cursor]
        return seq[:-1] if req.tokens else seq

    # -------------------------------------------------------- prefill phase
    def _prefill_phase(self, stats: dict, now: float,
                       budget: int | None = None) -> None:
        """Spend up to ``budget`` (default: the full token budget) prompt
        tokens: advance in-flight prefill cursors first (admission order),
        then admit new requests from the queue head, FIFO, with
        layout-aware placement."""
        if budget is None:
            budget = self.token_budget
        tr = self.tracer
        if tr.enabled:
            tr.begin_phase("plan")
        in_flight = sorted(
            (r for r in self.running.values()
             if r.status is Status.PREFILLING),
            key=lambda r: (r.metrics.admitted, r.request_id))
        flight = [(r, len(self._seq(r)) - r.prefill_cursor)
                  for r in in_flight]
        queued = [(r, len(self._seq(r))) for r in self.queue]

        def try_admit(req, chunk):
            seq = self._seq(req)
            cache_lookup = False
            if self.kv_layout == "paged":
                if not self.pool.can_admit(chunk, self.lookahead_blocks):
                    return None
                cache_lookup = self.pool.prefix_cache is not None
                try:
                    row, n_cached = self.pool.admit(seq, alloc_tokens=0)
                except OutOfBlocks:
                    return None
                end = n_cached + min(chunk, len(seq) - n_cached)
                try:
                    self.pool.ensure_capacity(row, end)
                except OutOfBlocks:
                    self.pool.release(row)
                    return None
            else:
                row = self.pool.alloc()
                if row is None:
                    return None
                n_cached = 0
            popped = self.queue.pop()          # the planned head, by FIFO
            if popped is not req:
                raise CachePoolError("queue head changed during planning")
            self._install_running(req, row, now)
            if tr.enabled:
                tr.on_admit(req, n_cached, cache_lookup)
            # family admission work: swap-restore (stateful slot layouts
            # resume with their saved state/KV/context and cursor), or the
            # enc-dec encoder run — may raise past n_cached
            n_cached = max(n_cached, self.adapter.on_admit(req, row))
            req.prefill_cursor = n_cached
            stats["admitted"] += 1
            return len(seq) - n_cached

        chunk_plan = plan_chunks(flight, queued, budget,
                                 self.chunk_quantum, try_admit)

        runnable = []
        for req, take in chunk_plan:
            if self.running.get(req.slot) is not req:
                continue                       # preempted by a prior chunk
            if (self.kv_layout == "paged"
                    and not self._ensure_chunk_capacity(req, take, stats)):
                continue
            runnable.append((req, take))

        by_shape: dict[tuple[int, int], list] = {}
        for req, take in runnable:
            by_shape.setdefault((req.prefill_cursor, _bucket(take)),
                                []).append((req, take))
        if tr.enabled:
            tr.end_phase(planned=len(runnable))
        for (cursor, bucket), group in sorted(by_shape.items()):
            # a LATER plan entry's capacity loop may have preempted a
            # request after it was validated into runnable (its slot is
            # None and its cursor reset) — re-check liveness per group
            group = [(r, t) for r, t in group
                     if self.running.get(r.slot) is r
                     and r.prefill_cursor == cursor]
            if group:
                if tr.enabled:
                    tr.begin_phase("chunk", cursor=cursor, bucket=bucket,
                                   n_rows=len(group),
                                   tokens=sum(t for _, t in group))
                stats["finished"] += self._run_chunk_group(group, cursor,
                                                           bucket, stats)
                if tr.enabled:
                    tr.end_phase()

    def _ensure_chunk_capacity(self, req: Request, take: int,
                               stats: dict) -> bool:
        """Grow the row's block table to hold the next chunk.  Under
        pressure: if anything is decoding, skip the chunk this step (the
        decoders drain and free blocks); otherwise preempt the youngest
        OTHER request and retry — the oldest prefill always makes
        progress, so the engine cannot livelock on its own prefills."""
        while True:
            try:
                self.pool.ensure_capacity(req.slot,
                                          req.prefill_cursor + take)
                return True
            except OutOfBlocks:
                if any(r.status is Status.RUNNING
                       for r in self.running.values()):
                    return False
                others = {s: r for s, r in self.running.items() if r is not req}
                if not others:
                    # cannot happen for admissible requests (submit bounds
                    # prompt+gen by pool capacity), so this is an
                    # accounting bug, not workload pressure
                    raise CachePoolError(
                        "sole prefilling request cannot grow its KV")
                self._preempt_one(stats, exclude=req,
                                  reason=PREEMPT_PREFILL_PRESSURE)

    def _install_running(self, req: Request, slot: int, now: float) -> None:
        req.slot = slot
        req.status = Status.PREFILLING
        req.metrics.admitted = now
        self.running[slot] = req
        self._temps[slot] = req.sampling.temperature
        self._topks[slot] = req.sampling.top_k
        self._seeds[slot] = req.sampling.seed
        # resumed requests continue their sampling stream at token index
        # len(tokens); fresh requests start at 0
        self._gen_count[slot] = len(req.tokens)
        if self.spec is not None:
            if req.draft_k == 0:
                req.draft_k = self.spec.cfg.k
            # whatever the draft arena holds at this slot belongs to a
            # previous occupant; the drafter catches up lazily from 0
            self.spec.on_admit(slot)

    def _run_chunk_group(self, group: list[tuple], cursor: int, bucket: int,
                         stats: dict) -> int:
        """Run one batched step for rows sharing (cursor, bucket): write
        tokens [cursor, cursor+take) straight into the arena and attend in
        place against the already-written context (``unified_step`` — at
        cursor 0 this IS the one-shot prefill), then emit a first token
        for every row whose cursor reached its sequence end.  Returns the
        number of requests that finished immediately."""
        n = len(group)
        B = _bucket(n, 1)                   # batch pad, power-of-two ladder
        rows = [req.slot for req, _ in group]
        seqs = [self._seq(req) for req, _ in group]
        takes = [take for _, take in group]
        tokens = np.zeros((B, bucket), np.int32)
        cur = np.zeros((B,), np.int32)
        n_new = np.zeros((B,), np.int32)
        for i, (seq, take) in enumerate(zip(seqs, takes)):
            tokens[i, :take] = seq[cursor:cursor + take]
            cur[i] = cursor
            n_new[i] = take
        if self.kv_layout == "paged":
            lanes = self.pool.lane_tables(rows, B)
        else:
            self.pool.chunk_end_check(cursor, takes)
            lanes = self.pool.lane_rows(rows, B)
        logits = self.adapter.step_chunk(
            rows, jnp.asarray(lanes), jnp.asarray(cur), jnp.asarray(n_new),
            jnp.asarray(tokens))
        self.pool.advance_prefill(rows, [cursor + t for t in takes])
        stats["prefill_tokens"] += sum(takes)
        stats["prefill_chunks"] += n

        tr = self.tracer
        done_idx, done_rows, done_last = [], [], []
        for i, ((req, take), seq) in enumerate(zip(group, seqs)):
            req.prefill_cursor = cursor + take
            req.metrics.prefill_chunks += 1
            if tr.enabled:
                tr.on_chunk(req, cursor, take)
            if req.prefill_cursor == len(seq):
                req.status = Status.RUNNING
                if self.kv_layout == "paged":
                    self.pool.register_prefix(req.slot, seq)
                if tr.enabled:
                    tr.on_prefill_complete(req)
                done_idx.append(i)
                done_rows.append(req.slot)
                done_last.append(take - 1)
        if not done_rows:
            return 0
        last_logits = logits[jnp.asarray(done_idx), jnp.asarray(done_last)]
        self._slot_logits = self._slot_logits.at[jnp.asarray(done_rows)].set(
            last_logits.astype(jnp.float32))
        return self._emit_tokens(done_rows)

    # -------------------------------------------------------------- decode
    def _preempt_one(self, stats: dict, exclude: Request | None = None,
                     reason: str = PREEMPT_DECODE_PRESSURE) -> None:
        """Push the youngest running request (never ``exclude``) back to
        the queue head and release its blocks — after publishing its
        fully-written blocks to the prefix cache, so the resume restarts
        its cursor at the last fully-written block instead of
        re-prefilling prompt + generated from scratch (when the cache has
        been evicted in the meantime, the chunked prefill recomputes —
        token streams are identical either way)."""
        candidates = ({s: r for s, r in self.running.items() if r is not exclude}
                      if exclude is not None else self.running)
        victim_slot = pick_preemption_victim(candidates)
        req = self.running.pop(victim_slot)
        # stateful slot-layout families swap their state out (recompute
        # would change float summation order); attention-only families
        # return None and recompute exactly
        req.swap = self.adapter.save_for_preempt(
            req, victim_slot, len(self._written_seq(req)))
        if self.kv_layout == "paged":
            self.pool.register_prefix(victim_slot, self._written_seq(req))
        self.pool.release(victim_slot)
        req.slot = None
        req.status = Status.QUEUED
        req.prefill_cursor = 0
        req.n_preempted += 1
        req.metrics.n_preemptions += 1
        req.metrics.last_preempt_reason = reason
        self.queue.push_front(req)
        self.n_preemptions += 1
        if self.kv_layout == "paged":
            self.pool.n_preemptions += 1
        stats["preempted"] += 1
        if self.tracer.enabled:
            self.tracer.on_preempt(req, reason)

    def _decode_rows(self) -> list[int]:
        return sorted(s for s, r in self.running.items()
                      if r.status is Status.RUNNING)

    def _decode_once(self, stats: dict | None = None) -> int:
        """Advance every prefill-complete request one token in a single
        fused step (``unified_step`` at S=1 over every lane).  Rows
        mid-prefill share the batch but are masked out of position
        updates and sampling (their lanes compute a discarded garbage
        token — see cache_pool/pool docstrings for why the stray write is
        harmless)."""
        stats = stats if stats is not None else {"preempted": 0}
        if self.spec is not None:
            return self._speculative_decode(stats)
        tr = self.tracer
        active = self._decode_rows()
        if self.kv_layout == "paged":
            while True:
                try:
                    self.pool.prepare_decode(active)
                    break
                except OutOfBlocks:
                    if len(self.running) <= 1:
                        # cannot happen for admissible requests (submit
                        # bounds prompt+gen by pool capacity), so this is
                        # an accounting bug, not workload pressure
                        raise CachePoolError(
                            "sole running request cannot grow its KV")
                    self._preempt_one(stats, reason=PREEMPT_DECODE_PRESSURE)
                    active = self._decode_rows()
            if not active:
                return 0
        stats["decoded"] = len(active)
        if tr.enabled:
            tr.begin_phase("decode", n_active=len(active))
        tokens = jnp.asarray(self._last_token[:, None])
        logits = self.adapter.step_decode(tokens, active)
        self._slot_logits = logits[:, 0].astype(jnp.float32)
        n_finished = self._emit_tokens(active)
        advanced = np.zeros((self.pool.n_slots,), bool)
        advanced[[s for s in active if s in self.running]] = True
        self.pool.advance_decode(advanced)
        if tr.enabled:
            tr.end_phase(finished=n_finished)
        return n_finished

    def _speculative_decode(self, stats: dict) -> int:
        """Draft k tokens per decoding request, verify all k+1 candidate
        positions in ONE fused chunk-shaped step, emit the accepted prefix
        plus a correction/bonus token, and roll the cursor back over the
        rejected tail.

        The verify call is the engine's existing ``step_chunk`` with
        per-lane ``cursor = len(seq) - 1`` (the last emitted token's KV is
        written here, preserving the written-positions invariant) and
        ``n_new = n_draft + 1``; both the batch and S axes ride the
        ``_bucket`` ladders, so speculation compiles a handful of shapes
        total, never one per k.  Rollback is ``advance_prefill`` to
        ``cursor + accepted + 1``: the garbage KV beyond it is hidden by
        the cursor length mask (slot) or sits in blocks the row still owns
        (paged) until the next step overwrites it."""
        tr = self.tracer
        spec = self.spec
        active = self._decode_rows()
        seqs = {s: self._seq(self.running[s]) for s in active}
        cap = self.pool.max_request_tokens
        ks = []
        for s in active:
            req = self.running[s]
            # never draft past the request's finish line or the row's KV
            # capacity (the verify writes len(seq)-1 + k + 1 positions)
            k = min(req.draft_k,
                    req.sampling.max_new_tokens - len(req.tokens) - 1,
                    cap - len(seqs[s]))
            ks.append(max(k, 0))

        if tr.enabled:
            tr.begin_phase("draft", n_rows=len(active))
        proposals = spec.propose(active, [seqs[s] for s in active], ks)
        drafts = dict(zip(active, proposals))
        if tr.enabled:
            tr.end_phase(drafted=sum(len(d) for d in proposals))

        if self.kv_layout == "paged":
            while True:
                try:
                    self.pool.prepare_decode(
                        active, [len(drafts[s]) + 1 for s in active])
                    break
                except OutOfBlocks:
                    if len(self.running) <= 1:
                        raise CachePoolError(
                            "sole running request cannot grow its KV")
                    self._preempt_one(stats, reason=PREEMPT_DECODE_PRESSURE)
                    active = self._decode_rows()
            if not active:
                return 0

        n = len(active)
        nds = [len(drafts[s]) for s in active]
        # Verify always runs the full lane complement (like fused decode):
        # a constant B keeps the compiled-variant count linear in the S
        # ladder instead of B x S, so a trickle of arrivals can't hit
        # batch shapes the warmup never saw.
        B = _bucket(self.pool.n_slots, 1)
        S = verify_bucket(max(nds) + 1, self.spec.cfg.k)
        tokens = np.zeros((B, S), np.int32)
        cur = np.zeros((B,), np.int32)
        n_new = np.zeros((B,), np.int32)
        draft_arr = np.zeros((B, S), np.int32)
        n_draft = np.zeros((B,), np.int32)
        lane_slot = np.zeros((B,), np.int64)     # pad lanes borrow slot 0
        for i, s in enumerate(active):
            nd = nds[i]
            cur[i] = len(seqs[s]) - 1
            tokens[i, 0] = self._last_token[s]
            if nd:
                tokens[i, 1:1 + nd] = drafts[s]
                draft_arr[i, :nd] = drafts[s]
            n_new[i] = nd + 1
            n_draft[i] = nd
            lane_slot[i] = s
        if self.kv_layout == "paged":
            lanes = self.pool.lane_tables(active, B)
        else:
            lanes = self.pool.lane_rows(active, B)
        if tr.enabled:
            tr.begin_phase("verify", n_rows=n, s_bucket=S,
                           drafted=int(n_draft.sum()))
        logits = self.adapter.step_chunk(
            active, jnp.asarray(lanes), jnp.asarray(cur), jnp.asarray(n_new),
            jnp.asarray(tokens))
        # leave-one-in verification over the whole (bucketed) batch — pad
        # lanes verify slot 0's parameters against garbage and are never
        # read, keeping verify_draft's compiled shapes on the same ladder
        n_acc, v_toks, v_lps = verify_draft(
            logits.astype(jnp.float32), jnp.asarray(draft_arr),
            jnp.asarray(n_draft), jnp.asarray(self._temps[lane_slot]),
            jnp.asarray(self._topks[lane_slot]),
            jnp.asarray(self._seeds[lane_slot]),
            jnp.asarray(self._gen_count[lane_slot]))
        n_acc = np.asarray(n_acc)
        v_toks = np.asarray(v_toks)
        v_lps = np.asarray(v_lps)
        if tr.enabled:
            tr.end_phase(accepted=int(n_acc[:n].sum()))

        # cursor rollback/advance BEFORE emission releases any slot: each
        # row's written positions become exactly len(seq) - 1 again once
        # its accepted+1 tokens are appended (the engine invariant)
        self.pool.advance_prefill(
            active, [int(cur[i]) + 1 + int(n_acc[i]) for i in range(n)])

        if tr.enabled:
            tr.begin_phase("emit", n_rows=n)
        now = self._clock()
        n_finished = 0
        drafted = accepted = emitted = 0
        for i, s in enumerate(active):
            req = self.running[s]
            nd, a = nds[i], int(n_acc[i])
            drafted += nd
            accepted += a
            req.metrics.spec_drafted += nd
            req.metrics.spec_accepted += a
            if spec.cfg.adaptive and nd > 0:
                if a == nd:
                    req.draft_k = min(req.draft_k + 1, spec.cfg.max_k)
                elif 2 * a < nd:
                    req.draft_k = max(req.draft_k - 1, spec.cfg.min_k)
            spec.rollback(s, nd, a)
            sp = req.sampling
            for j in range(a + 1):
                tok = int(v_toks[i, j])
                req._emit(tok, now, logprob=float(v_lps[i, j]))
                self._last_token[s] = tok
                self._gen_count[s] += 1
                emitted += 1
                if (len(req.tokens) >= sp.max_new_tokens
                        or (sp.eos_id is not None and tok == sp.eos_id)):
                    req._finish(Status.FINISHED, now)
                    self.finished.append(req)
                    del self.running[s]
                    self.pool.release(s)
                    n_finished += 1
                    if tr.enabled:
                        tr.on_finish(req)
                    break
        self.n_spec_steps += 1
        self.n_drafted += drafted
        self.n_accepted += accepted
        stats["decoded"] = n
        stats["spec_drafted"] = drafted
        stats["spec_accepted"] = accepted
        stats["spec_emitted"] = emitted
        if tr.enabled:
            tr.end_phase(finished=n_finished)
        return n_finished

    def _emit_tokens(self, slots: list[int]) -> int:
        """Sample one token for ``slots`` from _slot_logits, stream it, and
        retire requests that hit max_new_tokens / EOS.  Returns retirements."""
        tr = self.tracer
        if tr.enabled:
            tr.begin_phase("emit", n_rows=len(slots))
        toks, lps = sample_tokens_logprobs(
            self._slot_logits, jnp.asarray(self._temps),
            jnp.asarray(self._topks), jnp.asarray(self._seeds),
            jnp.asarray(self._gen_count))
        toks, lps = np.asarray(toks), np.asarray(lps)
        now = self._clock()
        n_finished = 0
        for slot in slots:
            req = self.running[slot]
            tok = int(toks[slot])
            req._emit(tok, now, logprob=float(lps[slot]))
            self._last_token[slot] = tok
            self._gen_count[slot] += 1
            sp = req.sampling
            if (len(req.tokens) >= sp.max_new_tokens
                    or (sp.eos_id is not None and tok == sp.eos_id)):
                req._finish(Status.FINISHED, now)
                self.finished.append(req)
                del self.running[slot]
                self.pool.release(slot)
                n_finished += 1
                if tr.enabled:
                    tr.on_finish(req)
        if tr.enabled:
            tr.end_phase(finished=n_finished)
        return n_finished
