"""Continuous-batching serving engine over the model zoo's compressed-weight
path.

The engine owns a slot-based preallocated KV pool (cache_pool.py) and runs
iteration-level scheduling: every ``step()`` evicts expired queue entries,
admits new requests into free slots (bounded prefill work interleaved
between decode steps), then advances ALL running requests by one token in a
single slot-indexed decode step.  New requests join the running batch
without disturbing it — per-row attention/norms are independent and each
slot carries its own cache position, so a request's tokens are identical
whether it runs alone or packed next to strangers (tested).

Works unchanged for dense weights or ``SparseWeight`` compressed params
(models/sparse_serving.py): the weights are just a pytree passed through the
jitted prefill/decode functions, so the 8:16 (+structured outlier) serving
path gets continuous batching for free.

Supported families: token-input transformers with [L, B, S, KV, hd] KV
caches ("dense", "moe").  Recurrent/enc-dec families keep the one-shot path
in launch/serve.py.

Prefill batching: admitted prompts are padded to power-of-two length buckets
and grouped, so the number of distinct compiled prefill shapes stays small
under mixed prompt lengths.  With causal attention the bucket padding
(after the prompt) cannot influence prompt logits or KV on the single-host
path this engine runs today — including MoE, whose local routing is
capacity-free (models/moe.py _moe_local).  A sharded engine on the
production mesh would route through the capacity-BOUNDED expert-parallel
path, where pad tokens compete for expert capacity and can perturb real
tokens; padding must be masked out of routing before that lands (see
ROADMAP open items).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from ..models import transformer as tfm
from .cache_pool import SlotKVPool
from .request import Request, SamplingParams, Status
from .sampling import sample_tokens
from .scheduler import QueueFull, RequestQueue, admission_budget

SUPPORTED_FAMILIES = ("dense", "moe")


def _bucket(n: int, lo: int = 8) -> int:
    b = lo
    while b < n:
        b *= 2
    return b


class ServingEngine:
    def __init__(self, cfg, params, *, n_slots: int = 8, max_len: int = 256,
                 max_queue: int = 64, queue_timeout_s: float | None = None,
                 max_prefill_per_step: int = 2, clock=time.monotonic):
        if cfg.family not in SUPPORTED_FAMILIES:
            raise ValueError(
                f"ServingEngine supports {SUPPORTED_FAMILIES} families, not "
                f"{cfg.family!r}; use the one-shot path in launch/serve.py")
        self.cfg = cfg
        self.params = params
        self.pool = SlotKVPool(cfg, n_slots, max_len)
        self.queue = RequestQueue(max_queue, queue_timeout_s)
        self.max_prefill_per_step = max_prefill_per_step
        self.running: dict[int, Request] = {}        # slot -> request
        self.finished: list[Request] = []
        self._clock = clock
        self._next_id = 0
        self.n_steps = 0

        # per-slot sampling state (host side, fixed shapes)
        self._temps = np.zeros((n_slots,), np.float32)
        self._topks = np.zeros((n_slots,), np.int32)
        self._seeds = np.zeros((n_slots,), np.int32)
        self._gen_count = np.zeros((n_slots,), np.int32)
        self._last_token = np.zeros((n_slots,), np.int32)
        # logits of each slot's most recent position (prefill scatters here
        # so first-token sampling reuses the one slot-wide sampler)
        self._slot_logits = jnp.zeros((n_slots, cfg.vocab), jnp.float32)

        self._prefill_fn = jax.jit(
            lambda p, t: tfm.forward(p, {"tokens": t}, cfg, collect_kv=True))
        # k/v are donated: the pool adopts the step's output buffers, so the
        # multi-GB caches update in place instead of being copied every token
        self._decode_fn = jax.jit(
            lambda p, k, v, pos, t: tfm.decode_step(
                p, {"k": k, "v": v, "pos": pos}, {"tokens": t}, cfg),
            donate_argnums=(1, 2))

    # ------------------------------------------------------------ admission
    def submit(self, prompt, sampling: SamplingParams | None = None,
               on_token=None, on_finish=None) -> Request:
        """Enqueue a request; raises QueueFull when admission control
        rejects (queue at capacity) and ValueError when the request can
        never fit a slot."""
        sampling = sampling or SamplingParams()
        prompt = [int(t) for t in np.asarray(prompt).reshape(-1)]
        if not prompt:
            raise ValueError("empty prompt")
        if sampling.max_new_tokens < 1:
            raise ValueError("max_new_tokens must be >= 1")
        if len(prompt) + sampling.max_new_tokens > self.pool.max_len:
            raise ValueError(
                f"prompt ({len(prompt)}) + max_new_tokens "
                f"({sampling.max_new_tokens}) exceeds slot capacity "
                f"{self.pool.max_len}")
        req = Request(self._next_id, prompt, sampling,
                      on_token=on_token, on_finish=on_finish)
        self._next_id += 1
        req.metrics.arrival = self._clock()
        if not self.queue.try_push(req):
            raise QueueFull(f"queue at capacity ({self.queue.max_size})")
        return req

    # ------------------------------------------------------------ stepping
    @property
    def has_work(self) -> bool:
        return bool(self.running) or len(self.queue) > 0

    def step(self) -> dict:
        """One scheduling iteration: evict -> admit/prefill -> decode."""
        now = self._clock()
        stats = {"evicted": 0, "admitted": 0, "finished": 0, "decoded": 0}

        for req in self.queue.evict_expired(now):
            req._finish(Status.EVICTED, now)
            self.finished.append(req)
            stats["evicted"] += 1

        budget = admission_budget(len(self.queue), self.pool.n_free,
                                  len(self.running), self.max_prefill_per_step)
        if budget:
            admits = [self.queue.pop() for _ in range(budget)]
            stats["admitted"] = len(admits)
            stats["finished"] += self._admit(admits)

        if self.running:
            stats["decoded"] = len(self.running)
            stats["finished"] += self._decode_once()

        self.n_steps += 1
        return stats

    def run(self, max_steps: int | None = None) -> list[Request]:
        """Step until queue and slots drain; returns finished requests."""
        steps = 0
        while self.has_work and (max_steps is None or steps < max_steps):
            self.step()
            steps += 1
        return self.finished

    # ------------------------------------------------------------ internals
    def _admit(self, reqs: list[Request]) -> int:
        """Prefill ``reqs`` (grouped by padded-length bucket, chunked to a
        fixed batch of max_prefill_per_step rows so each bucket compiles
        exactly one prefill shape), install their KV into slots, and emit
        each request's first token.  Returns the number of requests that
        finished immediately (max_new_tokens == 1 or instant EOS)."""
        by_bucket: dict[int, list[Request]] = {}
        for r in reqs:
            by_bucket.setdefault(_bucket(r.prompt_len), []).append(r)

        n_finished = 0
        chunk = max(self.max_prefill_per_step, 1)
        for bucket, bucket_group in sorted(by_bucket.items()):
            for start in range(0, len(bucket_group), chunk):
                group = bucket_group[start:start + chunk]
                n_finished += self._prefill_group(group, bucket, chunk)
        return n_finished

    def _prefill_group(self, group: list[Request], bucket: int,
                       batch_pad: int) -> int:
        B = max(len(group), batch_pad)
        tokens = np.zeros((B, bucket), np.int32)
        for i, r in enumerate(group):
            tokens[i, :r.prompt_len] = r.prompt
        logits, (k, v) = self._prefill_fn(self.params, jnp.asarray(tokens))

        now = self._clock()
        slots = []
        for r in group:
            slot = self.pool.alloc()
            assert slot is not None, "scheduler admitted past free slots"
            r.slot = slot
            r.status = Status.RUNNING
            r.metrics.admitted = now
            self.running[slot] = r
            self._temps[slot] = r.sampling.temperature
            self._topks[slot] = r.sampling.top_k
            self._seeds[slot] = r.sampling.seed
            self._gen_count[slot] = 0
            slots.append(slot)
        n = len(group)                      # real rows; the rest is batch pad
        self.pool.write_prefill_group(slots, k[:, :n], v[:, :n],
                                      [r.prompt_len for r in group])

        lens = np.array([r.prompt_len for r in group]) - 1
        last_logits = logits[jnp.arange(n), jnp.asarray(lens)]
        self._slot_logits = self._slot_logits.at[jnp.asarray(slots)].set(
            last_logits.astype(jnp.float32))
        return self._emit_tokens(slots)

    def _decode_once(self) -> int:
        """Advance every running slot one token in a single fused step."""
        active = sorted(self.running)
        tokens = jnp.asarray(self._last_token[:, None])
        logits, caches = self._decode_fn(self.params, self.pool.k, self.pool.v,
                                         self.pool.pos, tokens)
        self._slot_logits = logits.astype(jnp.float32)
        n_finished = self._emit_tokens(active)
        still = np.zeros((self.pool.n_slots,), bool)
        still[sorted(self.running)] = True
        self.pool.update(caches, jnp.asarray(still))
        return n_finished

    def _emit_tokens(self, slots: list[int]) -> int:
        """Sample one token for ``slots`` from _slot_logits, stream it, and
        retire requests that hit max_new_tokens / EOS.  Returns retirements."""
        toks = np.asarray(sample_tokens(
            self._slot_logits, jnp.asarray(self._temps),
            jnp.asarray(self._topks), jnp.asarray(self._seeds),
            jnp.asarray(self._gen_count)))
        now = self._clock()
        n_finished = 0
        for slot in slots:
            req = self.running[slot]
            tok = int(toks[slot])
            req._emit(tok, now)
            self._last_token[slot] = tok
            self._gen_count[slot] += 1
            sp = req.sampling
            if (len(req.tokens) >= sp.max_new_tokens
                    or (sp.eos_id is not None and tok == sp.eos_id)):
                req._finish(Status.FINISHED, now)
                self.finished.append(req)
                del self.running[slot]
                self.pool.free(slot)
                n_finished += 1
        return n_finished
