"""Per-request state pools beyond KV: recurrent slots and encoder context.

The KV arenas of ``cache_pool.py``/``paged/`` cover softmax attention; the
other families keep different per-request state, and this module puts it
behind the same ``KVCachePool`` protocol so the engine schedules every
family identically:

``RecurrentStatePool``
    Fixed-size state slots — a degenerate one-"block" arena whose "tokens"
    axis has collapsed to O(1): each slot holds one request's recurrent
    carries (mLSTM matrix memory + normalizer, sLSTM scalar carries,
    Mamba2 SSM state), as a list of per-layer pytrees with leading dim
    ``n_slots``.  Same lifecycle as ``SlotKVPool``: ``alloc``/``release``
    manage the free list, ``adopt`` takes ownership of a jitted step's
    donated-output state leaves, ``advance_prefill``/``advance_decode``
    track per-slot positions.  ``save_slot``/``restore_slot`` support
    swap-style preemption: unlike attention (whose KV can be recomputed
    from tokens with identical results), a recurrent state recomputed
    under different chunk boundaries differs in float summation order — so
    the engine swaps the state out and back instead of recomputing,
    keeping preempted-and-resumed token streams exactly identical.

``RecurrentStateView``
    What a family ``unified_step`` sees of the pool inside the jitted
    step: per-layer gather (lane -> slot) and scatter (slot <- lane, OOB
    lanes dropped), mirroring ``SlotPoolView`` addressing.  Fresh-state
    initialisation happens INSIDE the jitted step: at lanes whose cursor
    is 0 the family selects its init state (zeros, or -inf stabilizer
    fills) instead of the slot's stale content, so slot reuse needs no
    host-side reset and a swap-restored slot resumes untouched
    (cursor > 0).

``EncoderContextPool``
    Read-only cross-attention context rows for the enc-dec family: the
    per-decoder-layer projected encoder KV ``[L, n_slots, max_ctx, KV,
    hd]`` plus a per-slot true context length.  Written host-side ONCE at
    admission (the encoder runs at the true audio length — padding would
    corrupt a bidirectional encoder), then only read by the jitted steps;
    never donated.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from .cache_pool import CapacityError, DoubleFree, SlotPoolView


@dataclasses.dataclass(frozen=True)
class RecurrentStateView:
    """Lane addressing over a ``RecurrentStatePool``'s state arenas.

    ``states`` is the pool's list of per-layer pytrees (leading dim
    n_slots).  ``rows`` [B] maps batch lanes to slots (values >= n_slots
    are padding: their gathers clamp harmlessly, their scatters drop);
    ``rows=None`` means the batch IS the arena (fused decode).  ``cursor``
    [B] counts tokens already absorbed into each lane's state — cursor 0
    marks a fresh lane whose family init state must be selected in-jit.
    ``n_new`` [B] is how many of the step's S token positions are real per
    lane; families mask their gates past it so padded/inactive lanes leave
    their state bit-identical.
    """
    states: Any
    rows: Any | None
    cursor: Any
    n_new: Any

    def gather_layer(self, i: int):
        """Per-lane state pytree for layer ``i`` ([B, ...] leaves)."""
        st = self.states[i]
        if self.rows is None:
            return st
        return jax.tree.map(lambda a: a[self.rows], st)

    def scatter_layer(self, i: int, new_state):
        """Layer ``i``'s arena with each lane's new state written back at
        its slot (padding lanes dropped).  Returns the updated arena
        pytree; with ``rows=None`` the new state IS the arena."""
        if self.rows is None:
            return new_state
        return jax.tree.map(
            lambda arena, fresh: arena.at[self.rows].set(
                fresh.astype(arena.dtype), mode="drop"),
            self.states[i], new_state)

    def select_fresh(self, lane_state, init_state):
        """Where a lane's cursor is 0, replace its (stale, previous
        occupant's) state with the family's init state — the in-jit
        equivalent of zeroing a slot at alloc time, and a no-op for
        resumed (swap-restored) lanes whose cursor is > 0."""
        fresh = self.cursor == 0
        return jax.tree.map(
            lambda init, cur: jnp.where(
                fresh.reshape(fresh.shape + (1,) * (cur.ndim - 1)),
                init.astype(cur.dtype), cur),
            init_state, lane_state)


class RecurrentStatePool:
    """Recurrent-state slots behind the ``KVCachePool`` protocol.

    ``init_states(cfg, n_slots)`` (the family's ``init_state``-style hook)
    allocates the arenas; placement commits each leaf to its
    recurrent-state sharding (``ServingPlacement.state_shardings``).  The
    pool's positions bound nothing physical — state is O(1) per request —
    but ``max_len`` still caps admissible prompt+generation so scheduling
    invariants (and the shared submit-time capacity check) stay uniform
    across families.
    """

    def __init__(self, cfg, n_slots: int, max_len: int, init_states,
                 placement=None):
        from .placement import ServingPlacement
        pl = placement or ServingPlacement()
        self.states = pl.place_states(init_states(cfg, n_slots))
        self.pos = pl.place_replicated(jnp.zeros((n_slots,), jnp.int32))
        self.n_slots = n_slots
        self.max_len = max_len
        self._free = list(range(n_slots - 1, -1, -1))   # pop() -> ascending

    # ---------------------------------------------------------------- slots
    @property
    def n_free(self) -> int:
        return len(self._free)

    @property
    def max_request_tokens(self) -> int:
        return self.max_len

    def alloc(self) -> int | None:
        return self._free.pop() if self._free else None

    def release(self, slot: int) -> None:
        if slot in self._free:
            raise DoubleFree(f"release of free slot {slot}")
        if not 0 <= slot < self.n_slots:
            raise CapacityError(f"slot {slot} outside pool of {self.n_slots}")
        self._free.append(slot)

    free = release

    def stats(self) -> dict:
        """Occupancy snapshot, same shape as SlotKVPool.stats()."""
        return {"layout": "state", "n_slots": self.n_slots,
                "n_free": self.n_free, "max_len": self.max_len}

    # ---------------------------------------------------------------- views
    def lane_rows(self, rows: list[int], n_rows_padded: int) -> np.ndarray:
        out = np.full((n_rows_padded,), self.n_slots, np.int32)
        out[:len(rows)] = rows
        return out

    def chunk_end_check(self, cursor: int, lengths: list[int]) -> None:
        if cursor + max(lengths) > self.max_len:
            raise CapacityError(
                f"prefill of {max(lengths)} tokens at offset {cursor} "
                f"exceeds request capacity {self.max_len}")

    # ------------------------------------------------------------ lifecycle
    def adopt(self, states) -> None:
        """Take ownership of a jitted step's output state arenas (inputs
        were donated, so this is an in-place handoff)."""
        self.states = states

    def advance_prefill(self, rows: list[int], ends: list[int]) -> None:
        self.pos = self.pos.at[jnp.asarray(rows)].set(
            jnp.asarray(ends, jnp.int32))

    def advance_decode(self, active_mask) -> None:
        self.pos = jnp.where(jnp.asarray(active_mask), self.pos + 1,
                             self.pos)

    # ----------------------------------------------------- swap preemption
    def save_slot(self, slot: int):
        """One slot's state leaves (small device arrays) for swap-out."""
        return jax.tree.map(lambda a: a[slot], self.states)

    def restore_slot(self, slot: int, saved) -> None:
        self.states = jax.tree.map(
            lambda arena, leaf: arena.at[slot].set(leaf.astype(arena.dtype)),
            self.states, saved)


class EncoderContextPool:
    """Read-only cross-attention context rows for the enc-dec family.

    ``ck``/``cv`` hold the per-decoder-layer projected encoder KV
    ``[L, n_slots, max_ctx, KV, hd]`` (same shape grammar — and the same
    head-sharded placement — as a KV arena); ``lens`` is the host-side
    true context length per slot.  Rows are written once at admission and
    only read afterwards, so the arenas ride through the jitted steps
    WITHOUT donation and need no adopt/advance lifecycle.
    """

    def __init__(self, cfg, n_slots: int, max_ctx: int, placement=None):
        from .placement import ServingPlacement
        pl = placement or ServingPlacement()
        L, KV, hd = cfg.n_layers, cfg.n_kv_heads, cfg.hd
        shape = (L, n_slots, max_ctx, KV, hd)
        self.ck = pl.place_kv(jnp.zeros(shape, cfg.dtype))
        self.cv = pl.place_kv(jnp.zeros(shape, cfg.dtype))
        self.lens = np.zeros((n_slots,), np.int32)
        self.n_slots = n_slots
        self.max_ctx = max_ctx

    def write(self, slot: int, ck, cv) -> None:
        """Install one request's projected context ([L, S_enc, KV, hd]) at
        its true encoder length."""
        n = ck.shape[1]
        if n > self.max_ctx:
            raise CapacityError(
                f"encoder context of {n} exceeds max_ctx {self.max_ctx}")
        self.ck = jax.lax.dynamic_update_slice(
            self.ck, ck[:, None].astype(self.ck.dtype), (0, slot, 0, 0, 0))
        self.cv = jax.lax.dynamic_update_slice(
            self.cv, cv[:, None].astype(self.cv.dtype), (0, slot, 0, 0, 0))
        self.lens[slot] = n

    def save_slot(self, slot: int):
        return (self.ck[:, slot], self.cv[:, slot], int(self.lens[slot]))

    def restore_slot(self, slot: int, saved) -> None:
        ck, cv, n = saved
        self.ck = self.ck.at[:, slot].set(ck.astype(self.ck.dtype))
        self.cv = self.cv.at[:, slot].set(cv.astype(self.cv.dtype))
        self.lens[slot] = n

    def lane_lens(self, rows: list[int], n_rows_padded: int) -> np.ndarray:
        """Per-lane context lengths for a chunk group (padding lanes get 0:
        their cross-attention output is garbage the engine discards)."""
        out = np.zeros((n_rows_padded,), np.int32)
        out[:len(rows)] = self.lens[rows]
        return out


@dataclasses.dataclass(frozen=True)
class EncDecPoolView(SlotPoolView):
    """A ``SlotPoolView`` (decoder self-attention KV arenas + lane
    addressing) extended with the read-only encoder context: per-layer
    ``ck``/``cv`` arenas and the per-lane true context length [B]."""
    ck: Any = None
    cv: Any = None
    ctx_len: Any = None

    def lane_ctx(self, ck_l, cv_l):
        """Per-lane [B, max_ctx, KV, hd] context rows for one layer."""
        if self.rows is None:
            return ck_l, cv_l
        return ck_l[self.rows], cv_l[self.rows]


@dataclasses.dataclass(frozen=True)
class HybridPoolView:
    """One step's view for the hybrid family: a KV pool view
    (``SlotPoolView`` or ``PagedPoolView``) for the shared-attention
    applications and a ``RecurrentStateView`` for the mamba layers —
    mixed freely inside one jitted step.  The two sub-views carry their
    own ``n_new``: decode steps write KV for every lane (harmless, see
    cache_pool docstring) but must mask state updates to active lanes,
    whose recurrence has no overwrite-before-read safety net."""
    kv: Any
    state: RecurrentStateView

    @property
    def cursor(self):
        return self.state.cursor
