"""Request objects for the continuous-batching serving engine.

A ``Request`` carries the prompt, per-request sampling parameters, and
optional streaming callbacks; the engine mutates its lifecycle state as it
moves through the token-budgeted step pipeline:

    QUEUED -> PREFILLING -> RUNNING -> FINISHED
       ^          |            |
       +----------+------------+   (preempted back to the queue head)

``prefill_cursor`` is the request's position in that pipeline: how many
tokens of prompt + already-generated history have their KV written.  The
engine advances it chunk-by-chunk under the step token budget; when the
cursor reaches the full sequence length the request samples its first
(next) token and joins the fused decode batch.  A preempted request's
cursor resets — on re-admission it is restored to however many leading
blocks the prefix cache still holds (resume-from-last-written-block).
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Any, Callable, Sequence

from ..runtime.metrics import RequestMetrics


class Status(enum.Enum):
    QUEUED = "queued"
    PREFILLING = "prefilling"          # scheduled; prompt KV partially written
    RUNNING = "running"                # prefill complete; in the decode batch
    FINISHED = "finished"
    EVICTED = "evicted"                # timed out in queue


@dataclasses.dataclass(frozen=True)
class SamplingParams:
    """Per-request decoding controls.

    ``temperature <= 0`` is greedy argmax (the default — matches the one-shot
    serve loop token-for-token); otherwise softmax sampling at the given
    temperature, optionally restricted to the ``top_k`` highest logits.
    """
    max_new_tokens: int = 16
    temperature: float = 0.0
    top_k: int = 0                     # 0 = no top-k restriction
    seed: int = 0
    eos_id: int | None = None


@dataclasses.dataclass
class Request:
    request_id: int
    prompt: Sequence[int]              # token ids
    sampling: SamplingParams = dataclasses.field(default_factory=SamplingParams)
    # streaming hooks: on_token(request, token_id) per generated token,
    # on_finish(request) once the request leaves the engine (any status)
    on_token: Callable | None = None
    on_finish: Callable | None = None

    # engine-managed state
    status: Status = Status.QUEUED
    slot: int | None = None
    tokens: list[int] = dataclasses.field(default_factory=list)
    metrics: RequestMetrics = dataclasses.field(default_factory=RequestMetrics)
    # tokens of prompt + generated history whose KV is written (valid while
    # scheduled; reset on preemption, restored from prefix-cache matches)
    prefill_cursor: int = 0
    # times the paged engine preempted this request back to the queue
    # (generated tokens are kept; the resume re-prefills whatever the
    # prefix cache no longer covers)
    n_preempted: int = 0
    # enc-dec family: precomputed encoder-frontend embeddings [S_enc, d]
    # (the encoder runs once at admission, at the true length)
    embeds: Any = None
    # swap-preemption blob for stateful slot-layout families: the family
    # adapter's saved (recurrent state, KV rows, context, position) at
    # preemption, restored verbatim at re-admission so resumed token
    # streams are exactly the uninterrupted ones
    swap: Any = None
    # speculative decoding: this request's current draft length (the
    # engine initializes it from SpeculativeConfig.k at admission and,
    # when adaptive, walks it within [min_k, max_k] by the request's own
    # acceptance history — it survives preemption with the request)
    draft_k: int = 0
    # per-token chosen-token log-probabilities (log-softmax of the raw
    # logits at each emitted token), parallel to ``tokens``
    logprobs: list[float] = dataclasses.field(default_factory=list)

    @property
    def prompt_len(self) -> int:
        return len(self.prompt)

    @property
    def done(self) -> bool:
        return self.status in (Status.FINISHED, Status.EVICTED)

    def _emit(self, token: int, now: float,
              logprob: float | None = None) -> None:
        if not self.tokens:
            self.metrics.first_token = now
        else:
            # inter-token gap as the user experiences it: includes any
            # engine stall (long prefill in the step, preemption wait)
            # — a speculative verify step's burst arrives with 0 gaps
            self.metrics.itl.append(now - self.metrics.last_token_at)
        self.metrics.last_token_at = now
        self.tokens.append(token)
        if logprob is not None:
            self.logprobs.append(logprob)
        self.metrics.n_tokens = len(self.tokens)
        if self.on_token is not None:
            self.on_token(self, token)

    def _finish(self, status: Status, now: float) -> None:
        self.status = status
        self.metrics.finished = now
        if self.on_finish is not None:
            self.on_finish(self)
