"""Draft-verify speculative decoding over the serving engine's pools.

The paper's 8:16(+outlier) compression crosses the Performance Threshold —
the compressed model matches its dense parent closely enough that token-
level agreement is high — which makes it a near-free draft model for the
dense target the engine already serves.  Each decode step becomes:

            draft                      verify (ONE fused step)
  ┌──────────────────────┐   ┌───────────────────────────────────────┐
  │ proposer suggests    │   │ target runs [last_token, d1 .. dk]    │
  │ d1 .. dk per request │ → │ through unified_step at S = k+1:      │
  │ (8:16 model, or      │   │ writes k+1 KV positions, attends in   │
  │  n-gram self-draft)  │   │ place, logits[j] checks draft d_{j+1} │
  └──────────────────────┘   └───────────────────────────────────────┘
                                   ↓ leave-one-in verification
                             accept a leading drafts, emit a+1 tokens
                             (a accepted + 1 correction/bonus), roll
                             the cursor back to pos + a + 1

Verification costs one fused step instead of k sequential decodes because
``attend_over_pool`` (and the q-chunk paged kernel) already attends S
queries per lane — the verify step IS the engine's existing chunk step
function with per-lane ``n_new = k+1``, so no new jitted functions are
introduced and the S shapes ride the same power-of-two ``_bucket`` ladder
as prefill chunks (compiled-variant growth stays logarithmic in k, not
linear — pinned by tests/test_speculative.py).

Rollback is free in both KV layouts.  The engine's invariant is that
``pool.pos`` counts positions actually WRITTEN and the last emitted token's
KV is only written when it is fed into the next step; a verify step feeds
k+1 tokens and accepts a, so the cursor advances to ``pos + a + 1`` and
the positions beyond it hold rejected-draft garbage that (slot) the cursor
length-mask hides until the next step overwrites it, or (paged) sits in
blocks still owned by the row — exactly the half-filled-block state
chunk-aware allocation already handles.  Nothing is copied or zeroed.
``PagedKVPool.fork`` (copy-on-write block sharing) is the enabler for
tree/forked drafts on top of this.

Two proposers:

  ``ModelDrafter``  a second model (the 8:16-compressed zoo member) with
      its own slot-layout KV arena, co-resident on the engine's mesh with
      the same out-dim tensor-parallel placement as the target.  It keeps
      a per-slot draft cursor ``dpos`` and catches up LAZILY: before
      drafting it absorbs ``seq[dpos:]`` in one bucketed chunk — which
      uniformly covers fresh requests (drafter prefills the prompt),
      post-preemption resumes, and prefix-cache-hit admissions (the
      drafter has no prefix cache; ``dpos`` resets to 0 whenever the
      target (re)allocates the slot) — then proposes k tokens greedily
      with k-1 batched S=1 decodes.  After verification the draft cursor
      rolls back to the accepted prefix, so a rejection costs the drafter
      nothing either.
  ``NGramProposer``  prompt-lookup self-drafting: match the last n tokens
      of the sequence against its own history and propose the
      continuation of the most recent earlier occurrence.  Zero compute,
      zero state; rows with no match simply verify 0 drafts (a plain
      decode).

Acceptance-aware k adaptation lives in the engine (it owns the Request):
a request that accepts everything grows its ``draft_k`` toward ``max_k``;
one that rejects more than half shrinks toward ``min_k``.  Per-row k
variation is just per-lane ``n_new`` — no shape change.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from . import families


def _bucket(n: int, lo: int = 1) -> int:
    b = lo
    while b < n:
        b *= 2
    return b


def verify_bucket(n_new_max: int, k0: int) -> int:
    """S bucket for the fused verify step's q_len axis.

    Rung 1 covers draft-free steps (every proposer came back empty, so
    the verify degenerates to a decode-shaped step); any drafted step
    lands on a power-of-two ladder anchored at the CONFIGURED operating
    point ``_bucket(k0 + 1)`` rather than densely at every power of two
    below it.  Proposers with variable draft length (n-gram matches run
    0..k tokens; adaptive per-request k walks [min_k, max_k]) therefore
    reuse ONE compiled verify shape across the whole [1, k0] range — pad
    positions are masked by per-lane ``n_new`` — and only excursions
    above k0 add rungs, at most log2(max_k/k0) of them.  The old
    ``_bucket(max_nd + 1)`` ladder retraced once per draft-length bucket
    the workload happened to hit (9 ``step`` retraces for adaptive n-gram
    drafting in the serving bench); this trades a few masked pad columns
    on short-draft steps for a variant count that is workload-independent.
    """
    if n_new_max <= 1:
        return 1
    b = _bucket(k0 + 1)
    while b < n_new_max:
        b *= 2
    return b


@dataclasses.dataclass(frozen=True)
class SpeculativeConfig:
    """Engine-level speculative decoding configuration (``draft=``).

    ``method="model"`` drafts with a second model (``params`` required —
    typically the 8:16+outlier compressed counterpart of the target;
    ``cfg`` defaults to the target's config and must share its vocab).
    ``method="ngram"`` self-drafts by prompt lookup (suffix length
    ``ngram``).  ``k`` is the initial per-request draft length; with
    ``adaptive`` on, each request's k walks within [min_k, max_k] by its
    own acceptance history.
    """
    k: int = 4
    method: str = "model"
    params: Any = None
    cfg: Any = None
    ngram: int = 2
    adaptive: bool = True
    min_k: int = 1
    max_k: int = 8

    def __post_init__(self):
        if self.method not in ("model", "ngram"):
            raise ValueError(
                f"draft method must be 'model' or 'ngram', not "
                f"{self.method!r}")
        if self.method == "model" and self.params is None:
            raise ValueError("draft method 'model' needs draft params")
        if not (1 <= self.min_k <= self.k <= self.max_k):
            raise ValueError(
                f"need 1 <= min_k <= k <= max_k, got min_k={self.min_k} "
                f"k={self.k} max_k={self.max_k}")
        if self.ngram < 1:
            raise ValueError("ngram suffix length must be >= 1")


class NGramProposer:
    """Prompt-lookup drafting: propose the continuation of the most recent
    earlier occurrence of the sequence's last-``n`` suffix."""

    def __init__(self, n: int = 2):
        self.n = n

    def propose(self, seq: list[int], k: int) -> list[int]:
        n = self.n
        if k <= 0 or len(seq) <= n:
            return []
        suffix = seq[-n:]
        for start in range(len(seq) - n - 1, -1, -1):
            if seq[start:start + n] == suffix:
                return list(seq[start + n:start + n + k])
        return []


class ModelDrafter:
    """A second model proposing tokens over its own slot-layout KV arena.

    Shares slot identity with the target engine (slot i of the draft arena
    belongs to whichever request holds target slot i) and the engine's
    placement — draft params are committed with the same out-dim
    tensor-parallel shardings as the target's, so both models are
    co-resident on one mesh.  Jitted draft calls are attributed as
    ``draft_step``/``draft_decode`` variants in traces
    (``trace_kind_prefix``).  Proposals are greedy (argmax): for a
    deterministic proposer the leave-one-in verification in sampling.py
    preserves the target distribution regardless, and greedy maximizes
    acceptance for the low-temperature traffic speculation targets.
    """

    def __init__(self, cfg, params, placement, *, n_slots: int,
                 max_len: int, kv_dtype: str = "bf16"):
        psh = placement.param_shardings(params)
        params = params if psh is None else jax.device_put(params, psh)
        self.cfg = cfg
        self.adapter = families.TransformerAdapter(
            cfg, params, placement, psh, kv_layout="slot", n_slots=n_slots,
            max_len=max_len, block_size=16, n_blocks=None,
            prefix_caching=False, paged_attn_backend=None,
            kv_dtype=kv_dtype)
        self.adapter.trace_kind_prefix = "draft_"
        self.max_len = max_len
        # dpos[slot]: draft-arena positions holding the slot's TRUE
        # sequence prefix (the draft cursor); _from[slot]: the sequence
        # length at the last catch-up, i.e. where this round's proposals
        # started writing — what rollback measures acceptance against
        self.dpos = np.zeros((n_slots,), np.int64)
        self._from = np.zeros((n_slots,), np.int64)

    def on_admit(self, slot: int) -> None:
        """Target (re)allocated this slot: whatever the draft arena holds
        there belongs to a previous occupant."""
        self.dpos[slot] = 0

    def propose(self, slots: list[int], seqs: list[list[int]],
                ks: list[int]) -> list[list[int]]:
        """Catch the draft KV up to each row's sequence and propose up to
        ``ks[i]`` greedy continuations.  One bucketed chunk absorbs
        ``seq[dpos:]`` for every row at once (per-lane cursors — rows at
        different depths share the call), whose last real logit is d1;
        then max(k)-1 batched S=1 decodes extend the drafts."""
        pool = self.adapter.pool
        # constant batch width (pad lanes hit the pool sentinel row): the
        # catch-up chunk compiles one variant per S bucket, not B x S
        B = _bucket(pool.n_slots)
        # the engine only speculates on decoding rows, which have emitted
        # at least one token since the last catch-up/rollback — so every
        # row has >= 1 token to absorb and a d1 logit to read
        needs = [len(seq) - int(self.dpos[s]) for s, seq in zip(slots, seqs)]
        ks = [min(k, self.max_len - len(seq))
              for k, seq in zip(ks, seqs)]           # never write past arena
        S = _bucket(max(needs))
        tokens = np.zeros((B, S), np.int32)
        cur = np.zeros((B,), np.int32)
        n_new = np.zeros((B,), np.int32)
        for i, (slot, seq, need) in enumerate(zip(slots, seqs, needs)):
            tokens[i, :need] = seq[len(seq) - need:]
            cur[i] = int(self.dpos[slot])
            n_new[i] = need
        lanes = pool.lane_rows(slots, B)
        logits = self.adapter.step_chunk(
            slots, jnp.asarray(lanes), jnp.asarray(cur), jnp.asarray(n_new),
            jnp.asarray(tokens))
        pool.advance_prefill(slots, [len(seq) for seq in seqs])
        for slot, seq in zip(slots, seqs):
            self.dpos[slot] = self._from[slot] = len(seq)
        first = np.asarray(jnp.argmax(
            logits[jnp.arange(len(slots)), jnp.asarray(needs) - 1], -1))
        drafts = [[int(first[i])] if ks[i] >= 1 else []
                  for i in range(len(slots))]

        feed = np.zeros((pool.n_slots,), np.int32)
        for i, slot in enumerate(slots):
            feed[slot] = first[i]
        for j in range(1, max(ks, default=0)):
            act = [s for i, s in enumerate(slots) if ks[i] > j]
            if not act:
                break
            logits = self.adapter.step_decode(jnp.asarray(feed[:, None]), act)
            mask = np.zeros((pool.n_slots,), bool)
            mask[act] = True
            pool.advance_decode(mask)
            self.dpos[act] += 1
            nxt = np.asarray(jnp.argmax(logits[:, 0, :], -1))
            for i, slot in enumerate(slots):
                if ks[i] > j:
                    drafts[i].append(int(nxt[slot]))
                    feed[slot] = nxt[slot]
        return drafts

    def rollback(self, slot: int, n_drafted: int, n_accepted: int) -> None:
        """Roll the draft cursor back to the verified prefix.  The last
        proposal round wrote drafts d1..d_{k-1} at sequence positions
        [_from, _from + k - 1); the first ``n_accepted`` of them are now
        true sequence tokens, the rest is garbage the cursor hides."""
        if n_drafted > 0:
            self.dpos[slot] = self._from[slot] + min(n_accepted,
                                                     n_drafted - 1)


class Speculator:
    """The engine's handle on speculation: one proposer + the config."""

    def __init__(self, spec: SpeculativeConfig, target_cfg, placement, *,
                 n_slots: int, max_len: int, kv_dtype: str = "bf16"):
        self.cfg = spec
        self.drafter = None
        self.ngram = None
        if spec.method == "model":
            dcfg = spec.cfg if spec.cfg is not None else target_cfg
            if dcfg.vocab != target_cfg.vocab:
                raise ValueError(
                    f"draft vocab {dcfg.vocab} != target vocab "
                    f"{target_cfg.vocab}: draft tokens must be target "
                    f"tokens")
            self.drafter = ModelDrafter(dcfg, spec.params, placement,
                                        n_slots=n_slots, max_len=max_len,
                                        kv_dtype=kv_dtype)
        else:
            self.ngram = NGramProposer(spec.ngram)

    def set_tracer(self, tracer) -> None:
        if self.drafter is not None:
            self.drafter.adapter.tracer = tracer

    def on_admit(self, slot: int) -> None:
        if self.drafter is not None:
            self.drafter.on_admit(slot)

    def propose(self, slots: list[int], seqs: list[list[int]],
                ks: list[int]) -> list[list[int]]:
        if self.drafter is not None:
            return self.drafter.propose(slots, seqs, ks)
        return [self.ngram.propose(seq, k) for seq, k in zip(seqs, ks)]

    def rollback(self, slot: int, n_drafted: int, n_accepted: int) -> None:
        if self.drafter is not None:
            self.drafter.rollback(slot, n_drafted, n_accepted)
