"""Serving observability: request/step tracing, the serving counter set,
and step-time attribution — zero-cost when disabled.

The engine takes a ``tracer=`` at construction.  The default is
``NULL_TRACER``, a singleton whose every hook is a no-op and whose
``enabled`` flag is False; every call site in the hot path is guarded by
``if tracer.enabled`` so the disabled engine allocates NOTHING for
observability per step (tests/test_observe.py pins both the identity and
the token-identity of traced vs untraced runs).  Pass a ``ServingTracer``
and the same hooks populate three artifacts:

1. **Spans** (``runtime/telemetry.py`` ``TraceBuffer``, Chrome/Perfetto
   ``trace_event`` JSON — load the written file in ``ui.perfetto.dev``):

   - an *engine* process: one "step" span per ``engine.step()`` with
     child spans for the plan / chunk / decode / emit phases and each
     jitted call ("jit:step", "jit:decode", ...), plus instants for
     compiles and retraces (a new ``_step_fn`` shape bucket), preemptions
     (victim + reason), and prefix-cache lookups (matched-block depth);
   - a *requests* process: one thread per request id carrying its
     lifecycle spans — "queued" (arrival -> admitted, re-opened on
     preemption), "prefill" (admitted -> prefill complete, with "chunk"
     instants per chunk), "decode" (first-token eligibility -> finish) —
     and a final "request_summary" instant whose args restate the
     request's ``RequestMetrics`` (admit time, chunk count, token count,
     preemptions), so traces and summaries come from one event stream
     and can be cross-checked exactly.

2. **Counters/gauges** (``MetricsRegistry``): tokens prefilled/decoded,
   requests finished/evicted, preemptions by reason, compiles/retraces
   per jitted function, prefix-cache lookups/hit-tokens, speculative
   draft/accept/emit token counters (plus a per-step acceptance-rate
   gauge — the live Divergent-Token probe), and per-step
   gauges (queue depth, running, pool occupancy, budget utilization) —
   all labelled by model family — rendered as a Prometheus text snapshot
   (``counters_text()``) and sampled into the trace as "C" counter
   events every ``sample_every`` steps.

3. **Step-time attribution** (``jit_call``): every jitted step call is
   wall-clocked (blocking on its outputs) and keyed by its argument
   shapes — the exact retrace key, params aside — and each new variant
   is costed once through ``launch/hlo_analysis.cost_summary`` (compiled
   FLOPs / bytes-accessed), so a tok/s regression decomposes into
   compute (flops/bytes grew), scheduling (more steps, lower budget
   utilization), or recompilation (retrace instants in the window).

Multiple engines can share one ``TraceBuffer`` and one
``MetricsRegistry`` (the benchmark traces dense/sparse x slot/paged runs
into a single file): give each engine its own ``ServingTracer`` with the
shared ``buffer=``/``registry=`` — each tracer allocates its own
process-id pair and labels its metrics by engine name and family.

Timestamps come from the engine's injected clock (``attach`` adopts it
unless the tracer was built with an explicit ``clock=``), so virtual-time
tests produce exact, deterministic traces.
"""
from __future__ import annotations

import time

import jax

from ..runtime.telemetry import MetricsRegistry, TraceBuffer


class _NullSpan:
    """Inert context manager; one shared instance, never allocates."""
    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


NULL_SPAN = _NullSpan()


class NullTracer:
    """The disabled tracer: every hook is a no-op returning a shared
    singleton.  ``enabled`` is False so engine call sites skip even
    argument construction; the hooks still exist so an unguarded call is
    harmless rather than fatal."""
    enabled = False

    def attach(self, engine, name=""):
        return self

    def begin_step(self, n_step, now):
        return NULL_SPAN

    def end_step(self, engine, stats):
        pass

    def begin_phase(self, name, **args):
        return NULL_SPAN

    def end_phase(self, **args):
        pass

    def instant(self, name, **args):
        pass

    def on_submit(self, req):
        pass

    def on_admit(self, req, n_cached=0, cache_lookup=False):
        pass

    def on_chunk(self, req, cursor, take):
        pass

    def on_prefill_complete(self, req):
        pass

    def on_preempt(self, req, reason):
        pass

    def on_finish(self, req):
        pass

    def on_evict(self, req):
        pass

    def on_withdraw(self, req):
        pass

    def jit_call(self, kind, fn, args):
        return fn(*args)


NULL_TRACER = NullTracer()

_ENGINE_TID = 0


class ServingTracer:
    """The enabled tracer; see the module docstring for what it records.

    ``buffer``/``registry`` default to fresh private instances; pass
    shared ones to merge several engines into one trace/counter set.
    ``clock`` defaults to adopting the engine's clock at ``attach`` (falling
    back to ``time.monotonic``); pass the engine's virtual clock explicitly
    only when events must be stamped before an engine exists.
    ``sample_every`` thins the per-step counter samples written into the
    trace (the registry itself is always current).
    """

    enabled = True

    def __init__(self, *, buffer: TraceBuffer | None = None,
                 registry: MetricsRegistry | None = None,
                 clock=None, sample_every: int = 1, name: str = ""):
        self.buffer = buffer if buffer is not None else TraceBuffer()
        self.registry = registry if registry is not None else MetricsRegistry()
        self.clock = clock
        self.sample_every = max(int(sample_every), 1)
        self.t0: float | None = None
        self.name = name
        self.family = ""
        self._pid_engine: int | None = None
        self._pid_requests: int | None = None
        # open spans: engine-phase stack, per-step state, per-request state
        self._phase_stack: list[tuple[str, float, dict]] = []
        self._step_t0: float | None = None
        self._step_n: int = 0
        self._req_open: dict[int, dict[str, float]] = {}
        self._req_cached: dict[int, int] = {}
        # jit variants: shape-key -> attribution record
        self._variants: dict[tuple, dict] = {}
        self._kind_counts: dict[str, int] = {}
        self._counters_made = False

    # --------------------------------------------------------------- setup
    def attach(self, engine, name: str = "") -> "ServingTracer":
        """Bind this tracer to an engine: adopt its clock (unless one was
        given), allocate the engine/requests process ids, and register the
        serving counter set labelled by the engine's family."""
        if self.clock is None:
            self.clock = getattr(engine, "_clock", time.monotonic)
        if self.t0 is None:
            self.t0 = self.clock()
        self.family = getattr(getattr(engine, "cfg", None), "family", "")
        self.name = (self.name or name
                     or (f"{self.family}/{getattr(engine, 'kv_layout', '')}"
                         if self.family else "engine"))
        base = len(self.buffer._named_processes)
        self._pid_engine = 2 * base + 1
        self._pid_requests = 2 * base + 2
        self.buffer.set_process_name(self._pid_engine,
                                     f"engine {self.name}")
        self.buffer.set_process_name(self._pid_requests,
                                     f"requests {self.name}")
        self.buffer.set_thread_name(self._pid_engine, _ENGINE_TID, "steps")
        self._make_counters()
        return self

    def _make_counters(self):
        r = self.registry
        self.c_prefilled = r.counter(
            "serving_tokens_prefilled_total",
            "prompt tokens written through prefill chunks")
        self.c_decoded = r.counter(
            "serving_tokens_decoded_total", "generated tokens emitted")
        self.c_finished = r.counter(
            "serving_requests_finished_total",
            "requests retired, by terminal status")
        self.c_preempted = r.counter(
            "serving_preemptions_total",
            "requests preempted back to the queue, by pressure source")
        self.c_compiles = r.counter(
            "serving_jit_compiles_total",
            "first-time compilations of a jitted step variant")
        self.c_retraces = r.counter(
            "serving_jit_retraces_total",
            "additional shape-bucket variants of an already-compiled fn")
        self.c_cache_lookups = r.counter(
            "serving_prefix_cache_lookups_total",
            "prefix-cache lookups at admission")
        self.c_cache_hits = r.counter(
            "serving_prefix_cache_hits_total",
            "admissions that matched at least one cached block")
        self.c_cache_hit_tokens = r.counter(
            "serving_prefix_cache_hit_tokens_total",
            "prompt tokens skipped via prefix-cache matches")
        self.c_steps = r.counter("serving_steps_total", "engine steps run")
        self.c_spec_drafted = r.counter(
            "serving_spec_tokens_drafted_total",
            "draft tokens proposed to the speculative verify step")
        self.c_spec_accepted = r.counter(
            "serving_spec_tokens_accepted_total",
            "draft tokens the target model accepted")
        self.c_spec_emitted = r.counter(
            "serving_spec_tokens_emitted_total",
            "tokens emitted by speculative steps (accepted + bonus)")
        self.g_spec_accept = r.gauge(
            "serving_spec_acceptance_rate",
            "per-step draft acceptance rate (accepted / drafted)")
        self.g_queue = r.gauge("serving_queue_depth", "requests queued")
        self.g_running = r.gauge("serving_running", "requests in slots")
        self.g_pool_free = r.gauge(
            "serving_pool_free", "free concurrency units (slots/rows)")
        self.g_blocks_free = r.gauge(
            "serving_kv_blocks_free", "free KV blocks (paged layout)")
        self.g_cache_entries = r.gauge(
            "serving_prefix_cache_entries", "live prefix-cache entries")
        self.g_budget_util = r.gauge(
            "serving_budget_utilization",
            "prefill tokens spent this step / token budget")
        self._counters_made = True

    def _labels(self) -> dict:
        """Shared metric labels: several engines can feed one registry
        (the benchmark's dense/sparse x slot/paged grid), so every series
        carries both the engine name and its family."""
        return {"engine": self.name, "family": self.family}

    # ---------------------------------------------------------- time/pids
    def _ts(self, t: float | None = None) -> float:
        if self.t0 is None:
            self.t0 = self.clock() if self.clock else 0.0
        t = self.clock() if t is None else t
        return (t - self.t0) * 1e6

    # -------------------------------------------------------- step spans
    def begin_step(self, n_step: int, now: float) -> None:
        self._step_t0 = now
        self._step_n = n_step

    def end_step(self, engine, stats: dict) -> None:
        now = self.clock()
        lb = self._labels()
        self.c_steps.inc(**lb)
        self.c_prefilled.inc(stats.get("prefill_tokens", 0), **lb)
        util = (stats.get("prefill_tokens", 0)
                / max(engine.token_budget, 1))
        self.g_budget_util.set(util, **lb)
        queue_depth = len(engine.queue)
        running = len(engine.running)
        self.g_queue.set(queue_depth, **lb)
        self.g_running.set(running, **lb)
        self.g_pool_free.set(engine.pool.n_free, **lb)
        sample = {"queue_depth": queue_depth, "running": running,
                  "pool_free": engine.pool.n_free,
                  "budget_utilization": round(util, 4)}
        if "spec_drafted" in stats:
            drafted = stats["spec_drafted"]
            accepted = stats.get("spec_accepted", 0)
            self.c_spec_drafted.inc(drafted, **lb)
            self.c_spec_accepted.inc(accepted, **lb)
            self.c_spec_emitted.inc(stats.get("spec_emitted", 0), **lb)
            rate = accepted / drafted if drafted else 0.0
            self.g_spec_accept.set(rate, **lb)
            sample["spec_acceptance_rate"] = round(rate, 4)
        if engine.kv_layout == "paged":
            pool = engine.pool
            self.g_blocks_free.set(pool.blocks.n_free, **lb)
            sample["blocks_free"] = pool.blocks.n_free
            if pool.prefix_cache is not None:
                self.g_cache_entries.set(len(pool.prefix_cache), **lb)
                sample["prefix_cache_entries"] = len(pool.prefix_cache)
        ts0 = self._ts(self._step_t0)
        if self._step_n % self.sample_every == 0:
            self.buffer.counter("engine", self._ts(now), sample,
                                pid=self._pid_engine, tid=_ENGINE_TID)
        self.buffer.complete("step", ts0, self._ts(now) - ts0,
                             pid=self._pid_engine, tid=_ENGINE_TID,
                             cat="step", args=dict(stats))
        self._step_t0 = None

    def begin_phase(self, name: str, **args) -> None:
        self._phase_stack.append((name, self.clock(), args))

    def end_phase(self, **args) -> None:
        if not self._phase_stack:
            return
        name, t0, a = self._phase_stack.pop()
        if args:
            a.update(args)
        ts0 = self._ts(t0)
        self.buffer.complete(name, ts0, self._ts() - ts0,
                             pid=self._pid_engine, tid=_ENGINE_TID,
                             cat="phase", args=a or None)

    def instant(self, name: str, **args) -> None:
        self.buffer.instant(name, self._ts(), pid=self._pid_engine,
                            tid=_ENGINE_TID, cat="engine", args=args or None)

    # ----------------------------------------------------- request spans
    def _req_begin(self, req, span: str, t: float) -> None:
        self._req_open.setdefault(req.request_id, {})[span] = t

    def _req_end(self, req, span: str, t: float,
                 args: dict | None = None) -> None:
        open_spans = self._req_open.get(req.request_id, {})
        t0 = open_spans.pop(span, None)
        if t0 is None:
            return
        ts0 = self._ts(t0)
        self.buffer.complete(span, ts0, self._ts(t) - ts0,
                             pid=self._pid_requests, tid=req.request_id,
                             cat="request", args=args)

    def on_submit(self, req) -> None:
        self.buffer.set_thread_name(self._pid_requests, req.request_id,
                                    f"req {req.request_id}")
        self._req_begin(req, "queued", req.metrics.arrival)

    def on_admit(self, req, n_cached: int = 0,
                 cache_lookup: bool = False) -> None:
        t = req.metrics.admitted
        self._req_end(req, "queued", t)
        self._req_begin(req, "prefill", t)
        self._req_cached[req.request_id] = \
            self._req_cached.get(req.request_id, 0) + n_cached
        if cache_lookup:
            lb = self._labels()
            self.c_cache_lookups.inc(**lb)
            if n_cached > 0:
                self.c_cache_hits.inc(**lb)
                self.c_cache_hit_tokens.inc(n_cached, **lb)
            self.instant("prefix_cache",
                         request=req.request_id,
                         hit=n_cached > 0, cached_tokens=n_cached)

    def on_chunk(self, req, cursor: int, take: int) -> None:
        self.buffer.instant("chunk", self._ts(), pid=self._pid_requests,
                            tid=req.request_id, cat="request",
                            args={"cursor": cursor, "take": take})

    def on_prefill_complete(self, req) -> None:
        t = self.clock()
        self._req_end(req, "prefill", t,
                      args={"chunks": req.metrics.prefill_chunks,
                            "cached_tokens":
                                self._req_cached.get(req.request_id, 0)})
        self._req_begin(req, "decode", t)

    def on_preempt(self, req, reason: str) -> None:
        t = self.clock()
        self._req_end(req, "prefill", t)
        self._req_end(req, "decode", t)
        self.c_preempted.inc(reason=reason, **self._labels())
        self.buffer.instant("preempted", self._ts(t),
                            pid=self._pid_requests, tid=req.request_id,
                            cat="request", args={"reason": reason})
        self.instant("preempt", victim=req.request_id, reason=reason,
                     tokens_kept=len(req.tokens))
        self._req_begin(req, "queued", t)

    def _summary(self, req) -> None:
        m = req.metrics
        self.buffer.instant(
            "request_summary", self._ts(m.finished),
            pid=self._pid_requests, tid=req.request_id, cat="lifecycle",
            args={"id": req.request_id, "family": m.family,
                  "status": req.status.value, "admitted": m.admitted,
                  "first_token": m.first_token, "finished": m.finished,
                  "n_tokens": m.n_tokens,
                  "prefill_chunks": m.prefill_chunks,
                  "n_preemptions": m.n_preemptions,
                  "last_preempt_reason": m.last_preempt_reason,
                  "cached_tokens":
                      self._req_cached.pop(req.request_id, 0)})
        self._req_open.pop(req.request_id, None)

    def on_finish(self, req) -> None:
        t = req.metrics.finished
        self.c_decoded.inc(req.metrics.n_tokens, **self._labels())
        self.c_finished.inc(status=req.status.value, **self._labels())
        self._req_end(req, "decode", t,
                      args={"n_tokens": req.metrics.n_tokens})
        self._summary(req)

    def on_evict(self, req) -> None:
        t = req.metrics.finished
        self.c_finished.inc(status=req.status.value, **self._labels())
        self._req_end(req, "queued", t)
        self.buffer.instant("evicted", self._ts(t), pid=self._pid_requests,
                            tid=req.request_id, cat="request",
                            args={"reason": "queue_timeout"})
        self._summary(req)

    def on_withdraw(self, req) -> None:
        """A fleet router pulled this queued request out of the engine
        (work-steal or preemption drain); the destination engine's tracer
        re-opens "queued" via its own ``on_submit``, so the request's
        thread shows one queued span per engine it visited."""
        t = self.clock()
        self._req_end(req, "queued", t, args={"withdrawn": True})
        self.buffer.instant("withdrawn", self._ts(t),
                            pid=self._pid_requests, tid=req.request_id,
                            cat="request")

    # --------------------------------------------- jitted-call attribution
    def jit_call(self, kind: str, fn, args):
        """Run ``fn(*args)`` timed and attributed.

        The variant key is the tuple of top-level array argument shapes
        and dtypes — exactly what can trigger a retrace once the params
        pytree is fixed.  A new variant is costed (lower + compile +
        ``hlo_analysis.cost_summary``) BEFORE the real call, both because
        donation invalidates the buffers afterwards and so the compile
        instant lands at the moment the stall happens.  The call blocks
        on its outputs so the recorded wall time is the device time plus
        dispatch, not just the async enqueue.
        """
        shapes = tuple((tuple(a.shape), str(a.dtype)) for a in args
                       if hasattr(a, "shape"))
        key = (kind, shapes)
        rec = self._variants.get(key)
        if rec is None:
            n = self._kind_counts.get(kind, 0)
            self._kind_counts[kind] = n + 1
            rec = self._variants[key] = {
                "kind": kind, "variant": f"{kind}#{n}",
                "shapes": [list(s) for s, _ in shapes],
                "calls": 0, "total_s": 0.0, "first_call_s": None,
                "cost": self._variant_cost(fn, args)}
            is_retrace = n > 0
            (self.c_retraces if is_retrace else self.c_compiles).inc(
                fn=kind, engine=self.name)
            self.instant("retrace" if is_retrace else "compile",
                         fn=kind, variant=rec["variant"],
                         flops=rec["cost"].get("flops"),
                         bytes_accessed=rec["cost"].get("bytes_accessed"))
        t0 = self.clock()
        out = fn(*args)
        jax.block_until_ready(out)
        t1 = self.clock()
        dt = t1 - t0
        rec["calls"] += 1
        rec["total_s"] += dt
        if rec["first_call_s"] is None:
            rec["first_call_s"] = dt     # includes compilation
        ts0 = self._ts(t0)
        self.buffer.complete(f"jit:{kind}", ts0, self._ts(t1) - ts0,
                             pid=self._pid_engine, tid=_ENGINE_TID,
                             cat="jit", args={"variant": rec["variant"]})
        return out

    @staticmethod
    def _variant_cost(fn, args) -> dict:
        """Compiled cost model of one variant (per-device in SPMD); {} when
        the backend or function shape defeats AOT lowering."""
        try:
            from ..launch.hlo_analysis import cost_summary
            return cost_summary(fn.lower(*args).compile())
        except Exception:
            return {}

    def attribution(self) -> dict:
        """Per-variant wall-clock and cost-model table, JSON-embeddable:
        tok/s regressions decompose into compute (flops/bytes), schedule
        (calls), and recompilation (variants, first_call_s)."""
        out = {}
        for rec in self._variants.values():
            steady_calls = max(rec["calls"] - 1, 0)
            steady_s = rec["total_s"] - (rec["first_call_s"] or 0.0)
            out[rec["variant"]] = {
                "kind": rec["kind"], "shapes": rec["shapes"],
                "calls": rec["calls"], "total_s": rec["total_s"],
                "first_call_s": rec["first_call_s"],
                "steady_mean_s": (steady_s / steady_calls
                                  if steady_calls else None),
                "flops": rec["cost"].get("flops"),
                "bytes_accessed": rec["cost"].get("bytes_accessed"),
            }
        return out

    # -------------------------------------------------------------- export
    def counters_text(self) -> str:
        return self.registry.prometheus_text()

    def write_trace(self, path: str) -> None:
        self.buffer.write(path)


class NullRouterTracer:
    """Disabled fleet-router tracer, mirroring ``NullTracer``: ``enabled``
    is False, every hook no-ops, and the router guards call sites on the
    flag so an untraced fleet does zero observability work per route."""
    enabled = False

    def attach(self, fleet, name=""):
        return self

    def on_route(self, req_id, decision):
        pass

    def on_reroute(self, req_id, kind, src, dst):
        pass

    def on_imbalance(self, spread):
        pass


NULL_ROUTER_TRACER = NullRouterTracer()

_ROUTER_TID = 0


class RouterTracer:
    """Fleet-router observability, sharing the replica tracers' buffer and
    registry so one trace file shows the router's decisions interleaved
    with every replica's step/request tracks.

    The router gets its own Perfetto process (pid allocation composes
    with ``ServingTracer.attach``'s pair scheme: pids are derived from
    the buffer's named-process count, which only grows, so tracks never
    collide).  Per routing decision it emits a "route" instant carrying
    the chosen replica, the policy, which score component won, the
    matched-prefix fraction, and the loser loads — enough to replay any
    routing decision from the trace alone.  Rebalance actions ("steal",
    "drain") get their own instants, and counters land in the shared
    registry: ``fleet_routing_decisions_total{policy,picked_by}``,
    ``fleet_reroutes_total{kind}``, ``fleet_route_prefix_tokens_total``,
    and a ``fleet_queue_imbalance`` gauge (max - min replica queue
    depth, sampled every rebalance check).
    """

    enabled = True

    def __init__(self, *, buffer: TraceBuffer | None = None,
                 registry: MetricsRegistry | None = None,
                 clock=None, name: str = "router"):
        self.buffer = buffer if buffer is not None else TraceBuffer()
        self.registry = registry if registry is not None else MetricsRegistry()
        self.clock = clock
        self.name = name
        self.t0: float | None = None
        self._pid: int | None = None

    def attach(self, fleet, name: str = "") -> "RouterTracer":
        if self.clock is None:
            self.clock = getattr(fleet, "_clock", time.monotonic)
        if self.t0 is None:
            self.t0 = self.clock()
        self.name = self.name or name or "router"
        base = len(self.buffer._named_processes)
        self._pid = 2 * base + 1
        self.buffer.set_process_name(self._pid, f"fleet {self.name}")
        self.buffer.set_thread_name(self._pid, _ROUTER_TID, "routing")
        r = self.registry
        self.c_decisions = r.counter(
            "fleet_routing_decisions_total",
            "routing decisions, by policy and winning score component")
        self.c_reroutes = r.counter(
            "fleet_reroutes_total",
            "queued requests moved between replicas, by mechanism "
            "(steal = imbalance rebalance, drain = preemption re-admit)")
        self.c_prefix_tokens = r.counter(
            "fleet_route_prefix_tokens_total",
            "prompt tokens already cached on the replica each request "
            "was routed to (routing-time estimate, not admission truth)")
        self.g_imbalance = r.gauge(
            "fleet_queue_imbalance",
            "max - min replica queue depth at the last rebalance check")
        return self

    def _ts(self, t: float | None = None) -> float:
        if self.t0 is None:
            self.t0 = self.clock() if self.clock else 0.0
        t = self.clock() if t is None else t
        return (t - self.t0) * 1e6

    def on_route(self, req_id: int, decision) -> None:
        self.c_decisions.inc(policy=decision.policy,
                             picked_by=decision.picked_by, fleet=self.name)
        if decision.prefix_tokens > 0:
            self.c_prefix_tokens.inc(decision.prefix_tokens, fleet=self.name)
        self.buffer.instant(
            "route", self._ts(), pid=self._pid, tid=_ROUTER_TID,
            cat="routing",
            args={"request": req_id, "replica": decision.replica,
                  "policy": decision.policy,
                  "picked_by": decision.picked_by,
                  "prefix_frac": round(decision.prefix_frac, 4),
                  "loads": [round(l, 4) for l in decision.loads]})

    def on_reroute(self, req_id: int, kind: str, src: int, dst: int) -> None:
        self.c_reroutes.inc(kind=kind, fleet=self.name)
        self.buffer.instant(
            kind, self._ts(), pid=self._pid, tid=_ROUTER_TID, cat="routing",
            args={"request": req_id, "src": src, "dst": dst})

    def on_imbalance(self, spread: int) -> None:
        self.g_imbalance.set(spread, fleet=self.name)
