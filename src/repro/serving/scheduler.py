"""Request queue (admission/eviction) and the token-budgeted step policy.

Admission control is two-level: ``submit`` rejects outright when the queue is
at capacity or the request can never fit the KV pool (prompt + max_new_tokens
> pool capacity); queued requests past ``queue_timeout_s`` are evicted at the
head of every engine step, bounding worst-case queue wait.

Per-step scheduling is **token-budget accounting** (``plan_chunks``): every
engine step may spend up to ``token_budget`` prompt tokens on prefill work,
split into per-request *chunks*.  A prompt longer than the budget advances
chunk-by-chunk across steps (the engine tracks a ``prefill_cursor`` per
request), so one long prompt can no longer monopolize a step and stall every
decoding request — the Sarathi/vLLM-style chunked-prefill schedule, here on
top of the paper's 8:16+outlier compressed-weight serving path.  Priority
order inside a step:

  1. in-flight partial prefills, oldest admission first — they hold
     rows/blocks, so finishing them releases capacity soonest;
  2. new admissions from the queue head, strictly FIFO — the head is never
     skipped (a long prompt at the head is admitted and simply takes more
     steps), which is what makes the policy starvation-free.

Chunk lengths are quantized to ``CHUNK_QUANTUM`` (except a sequence's final
chunk), so cursor values — and with them the compiled (prefix_len, bucket)
shape ladder of the chunked prefill function — stay small.

``max_prefill_per_step`` (the old bounded-request-count interleave knob) is
deprecated: ``resolve_token_budget`` maps it to the equivalent token budget
(N requests of up to ``max_len`` tokens each) and warns once.

Under the paged KV layout admission is additionally *block-aware*: a request
is only scheduled while the pool's obtainable blocks (free list plus
evictable prefix-cache entries) cover its NEXT CHUNK plus a decode lookahead
margin — chunk-aware allocation, blocks arrive as the cursor advances — and
when decode outgrows the arena anyway the engine preempts the youngest
running request back to the queue head (``pick_preemption_victim``) rather
than hard-failing.  Before releasing a victim's blocks the engine publishes
its fully-written blocks to the prefix cache, so a resumed request matches
them and restarts its cursor at the last fully-written block instead of
re-prefilling prompt + generated from scratch (token streams are preserved
exactly either way; sampling keys are derived from (seed, token index)).
"""
from __future__ import annotations

import collections
import warnings
from typing import Callable, Iterator

from .request import Request, Status

# chunk lengths (and therefore prefill cursors) are multiples of this,
# except a sequence's final chunk — bounds the compiled shape ladder
CHUNK_QUANTUM = 8

# preemption reasons, recorded on RequestMetrics and as counter labels:
# decode pressure = the arena ran dry growing a decode step; prefill
# pressure = an in-flight chunk could not get blocks for its next cursor
PREEMPT_DECODE_PRESSURE = "decode_pressure"
PREEMPT_PREFILL_PRESSURE = "prefill_pressure"


class QueueFull(RuntimeError):
    """Raised by ServingEngine.submit when admission control rejects."""


_budget_alias_warned = False


def resolve_token_budget(token_budget: int | None,
                         max_prefill_per_step: int | None,
                         max_len: int, *,
                         quantum: int = CHUNK_QUANTUM) -> int:
    """Resolve the engine's per-step prefill token budget.

    ``max_prefill_per_step`` is the deprecated request-count knob; when
    given it maps to the equivalent token budget — N requests of up to
    ``max_len`` tokens each per step — and warns once per process.  With
    neither knob set the default budget is ``2 * max_len`` (the historical
    default of two full prefills between decode steps).

    ``quantum`` is the engine's effective chunk quantum.  Families with no
    paged layout and O(1) per-request state (pure-recurrent: no block math,
    no shape ladder worth bounding) pass ``quantum=1`` so the block-quantum
    floor check in ``validate_token_budget`` does not reject budgets that
    are perfectly schedulable for them.
    """
    global _budget_alias_warned
    if max_prefill_per_step is not None:
        if not _budget_alias_warned:
            warnings.warn(
                "max_prefill_per_step is deprecated; pass token_budget "
                "instead (mapping N requests/step to N * max_len tokens)",
                DeprecationWarning, stacklevel=3)
            _budget_alias_warned = True
        if token_budget is None:
            token_budget = max(int(max_prefill_per_step), 1) * max_len
    if token_budget is None:
        token_budget = 2 * max_len
    return validate_token_budget(int(token_budget), max_len=max_len,
                                 quantum=quantum)


def validate_token_budget(token_budget: int, *, max_len: int,
                          quantum: int = CHUNK_QUANTUM) -> int:
    """Construction-time validation of the engine's per-step budget — a
    clear ``ValueError`` at ``ServingEngine(...)`` instead of a deep stall
    or failure inside ``plan_chunks``.

    The budget must cover (a) the chunk quantum, or no mid-sequence chunk
    can ever be scheduled and the queue head stalls forever, and (b) the
    FIRST chunk of the longest admissible prompt — for ``max_len`` below
    the quantum that first chunk is the whole prompt (final chunks are
    exempt from quantization), so the effective floor is
    ``min(quantum, max_len)``; any budget that also satisfies (a) covers
    it.  Returns the validated budget for chaining.
    """
    if max_len < 1:
        raise ValueError(f"max_len must be >= 1, got {max_len}")
    floor = min(quantum, max_len)
    if token_budget < floor:
        raise ValueError(
            f"token_budget={token_budget} cannot schedule any prefill "
            f"chunk: it must cover the chunk quantum ({quantum}) and the "
            f"longest admissible prompt's first chunk "
            f"(min(quantum, max_len={max_len}) = {floor})")
    return token_budget


def spec_verify_reserve(running: dict[int, Request], default_k: int) -> int:
    """Prefill-budget tokens to reserve for this step's speculative verify
    work: every decoding request's fused verify writes and scores its
    ``draft_k + 1`` candidate positions through the same step pipeline the
    prefill chunks use, so those tokens are charged against the step's
    token budget up front — prefill planning sees
    ``token_budget - reserve`` and a step can never exceed the budget it
    advertises.  (No livelock: decoding requests are bounded by
    max_new_tokens, so a fully-reserved budget frees itself as they
    retire.)"""
    return sum((r.draft_k or default_k) + 1 for r in running.values()
               if r.status is Status.RUNNING)


def _chunk_take(budget: int, remaining: int, quantum: int) -> int:
    """Tokens to schedule for one request: the whole remainder when it
    fits, else the largest quantum multiple within budget (0 = no room)."""
    take = min(budget, remaining)
    if take < remaining:
        take -= take % quantum
    return take


def plan_chunks(in_flight: list[tuple], queued: list[tuple],
                token_budget: int, quantum: int,
                try_admit: Callable) -> list[tuple]:
    """One step's prefill schedule under a token budget.

    ``in_flight``: [(key, remaining_tokens)] partial prefills in admission
    order; ``queued``: [(key, seq_len)] FIFO.  ``try_admit(key, chunk)`` is
    called for queue entries in order — it performs the layout-specific
    admission (row/block allocation, prefix-cache match) and returns the
    tokens actually left to compute (< seq_len on a prefix-cache hit), or
    None when the request cannot be placed (planning then stops: the head
    is deferred, never skipped, preserving FIFO).

    Returns [(key, take)] with sum(take) <= token_budget and every take
    positive and quantum-aligned unless it finishes its sequence.
    """
    budget = int(token_budget)
    chunks: list[tuple] = []
    for key, remaining in in_flight:
        if budget <= 0:
            break
        take = _chunk_take(budget, remaining, quantum)
        if take == 0:
            break                       # head-of-line keeps its turn
        chunks.append((key, take))
        budget -= take
    for key, seq_len in queued:
        if budget <= 0:
            break
        want = _chunk_take(budget, seq_len, quantum)
        if want == 0:
            break
        remaining = try_admit(key, want)
        if remaining is None:
            break                       # no capacity: defer the head, stop
        take = min(want, remaining)
        chunks.append((key, take))
        budget -= take
    return chunks


class RequestQueue:
    def __init__(self, max_size: int = 64, queue_timeout_s: float | None = None):
        self.max_size = max_size
        self.queue_timeout_s = queue_timeout_s
        self._q: collections.deque[Request] = collections.deque()

    def __len__(self) -> int:
        return len(self._q)

    def __iter__(self) -> Iterator[Request]:
        """FIFO view (head first) — the planner peeks without popping."""
        return iter(self._q)

    def try_push(self, req: Request) -> bool:
        if len(self._q) >= self.max_size:
            return False
        self._q.append(req)
        return True

    def pop(self) -> Request | None:
        return self._q.popleft() if self._q else None

    def push_front(self, req: Request) -> None:
        """Return an already-admitted request to the head of the queue
        (paged admission ran out of blocks, or a preemption).  Bypasses
        the capacity check: the request was accepted once and must not be
        silently dropped."""
        self._q.appendleft(req)

    def pop_back(self) -> Request | None:
        """Take the YOUNGEST queued request (fleet work-stealing: the tail
        has waited least, so moving it disturbs FIFO service order the
        least and never touches the head-of-line request mid-admission)."""
        return self._q.pop() if self._q else None

    def remove(self, req: Request) -> bool:
        """Remove a specific request wherever it sits in the queue (fleet
        drain of a preempted head).  Returns False when it is not queued
        here — the caller raced an admission or eviction."""
        try:
            self._q.remove(req)
        except ValueError:
            return False
        return True

    def evict_expired(self, now: float) -> list[Request]:
        """Drop queued requests older than queue_timeout_s (FIFO order).

        The timeout bounds the wait for FIRST service: requests that were
        already served and preempted back to the queue (generated tokens
        in hand) are exempt — evicting them would silently discard
        completed work, violating push_front's no-drop contract."""
        if self.queue_timeout_s is None:
            return []
        evicted = []
        kept = collections.deque()
        for req in self._q:
            if (now - req.metrics.arrival > self.queue_timeout_s
                    and not req.tokens and req.n_preempted == 0):
                evicted.append(req)
            else:
                kept.append(req)
        self._q = kept
        return evicted


def pick_preemption_victim(running: dict[int, Request]) -> int:
    """Slot/row of the request to preempt when the paged arena runs dry.

    Youngest-first (latest admission): the request that has sunk the
    least work is restarted, and repeated preemption converges — older
    requests keep their blocks and drain, releasing memory.  Ties (one
    admission group) break toward the higher request id."""
    return max(running,
               key=lambda s: (running[s].metrics.admitted,
                              running[s].request_id))
