"""Request queue (admission/eviction) and prefill/decode interleaving policy.

Admission control is two-level: ``submit`` rejects outright when the queue is
at capacity or the request can never fit a slot (prompt + max_new_tokens >
slot capacity); queued requests past ``queue_timeout_s`` are evicted at the
head of every engine step, bounding worst-case queue wait.

The interleave policy bounds how many prefills run between consecutive
decode steps (``max_prefill_per_step``), so a burst of arrivals cannot
starve in-flight decodes — the classic continuous-batching latency/
throughput trade (Orca / vLLM-style iteration-level scheduling).  When
nothing is decoding, the bound is lifted: prefill-only work fills all free
slots at once.
"""
from __future__ import annotations

import collections

from .request import Request, Status


class QueueFull(RuntimeError):
    """Raised by ServingEngine.submit when admission control rejects."""


class RequestQueue:
    def __init__(self, max_size: int = 64, queue_timeout_s: float | None = None):
        self.max_size = max_size
        self.queue_timeout_s = queue_timeout_s
        self._q: collections.deque[Request] = collections.deque()

    def __len__(self) -> int:
        return len(self._q)

    def try_push(self, req: Request) -> bool:
        if len(self._q) >= self.max_size:
            return False
        self._q.append(req)
        return True

    def pop(self) -> Request | None:
        return self._q.popleft() if self._q else None

    def evict_expired(self, now: float) -> list[Request]:
        """Drop queued requests older than queue_timeout_s (FIFO order)."""
        if self.queue_timeout_s is None:
            return []
        evicted = []
        kept = collections.deque()
        for req in self._q:
            if now - req.metrics.arrival > self.queue_timeout_s:
                evicted.append(req)
            else:
                kept.append(req)
        self._q = kept
        return evicted


def admission_budget(n_queued: int, n_free_slots: int, n_running: int,
                     max_prefill_per_step: int) -> int:
    """How many requests to prefill before the next decode step."""
    budget = min(n_queued, n_free_slots)
    if n_running > 0:
        budget = min(budget, max_prefill_per_step)
    return budget
