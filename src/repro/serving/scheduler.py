"""Request queue (admission/eviction) and prefill/decode interleaving policy.

Admission control is two-level: ``submit`` rejects outright when the queue is
at capacity or the request can never fit the KV pool (prompt + max_new_tokens
> pool capacity); queued requests past ``queue_timeout_s`` are evicted at the
head of every engine step, bounding worst-case queue wait.

The interleave policy bounds how many prefills run between consecutive
decode steps (``max_prefill_per_step``), so a burst of arrivals cannot
starve in-flight decodes — the classic continuous-batching latency/
throughput trade (Orca / vLLM-style iteration-level scheduling).  When
nothing is decoding, the bound is lifted: prefill-only work fills all free
slots at once.

Under the paged KV layout admission is additionally *block-aware*: a
request is only scheduled while the pool's obtainable blocks (free list
plus evictable prefix-cache entries) cover its whole prompt plus a decode
lookahead margin, and when decode outgrows the arena anyway the engine
preempts the youngest running request back to the queue head
(``pick_preemption_victim``) rather than hard-failing — it resumes later
by re-prefilling prompt + generated-so-far, which reproduces its token
stream exactly (sampling keys are derived from (seed, token index)).
"""
from __future__ import annotations

import collections

from .request import Request, Status


class QueueFull(RuntimeError):
    """Raised by ServingEngine.submit when admission control rejects."""


class RequestQueue:
    def __init__(self, max_size: int = 64, queue_timeout_s: float | None = None):
        self.max_size = max_size
        self.queue_timeout_s = queue_timeout_s
        self._q: collections.deque[Request] = collections.deque()

    def __len__(self) -> int:
        return len(self._q)

    def try_push(self, req: Request) -> bool:
        if len(self._q) >= self.max_size:
            return False
        self._q.append(req)
        return True

    def pop(self) -> Request | None:
        return self._q.popleft() if self._q else None

    def push_front(self, req: Request) -> None:
        """Return an already-admitted request to the head of the queue
        (paged admission ran out of blocks, or a preemption).  Bypasses
        the capacity check: the request was accepted once and must not be
        silently dropped."""
        self._q.appendleft(req)

    def evict_expired(self, now: float) -> list[Request]:
        """Drop queued requests older than queue_timeout_s (FIFO order).

        The timeout bounds the wait for FIRST service: requests that were
        already served and preempted back to the queue (generated tokens
        in hand) are exempt — evicting them would silently discard
        completed work, violating push_front's no-drop contract."""
        if self.queue_timeout_s is None:
            return []
        evicted = []
        kept = collections.deque()
        for req in self._q:
            if (now - req.metrics.arrival > self.queue_timeout_s
                    and not req.tokens and req.n_preempted == 0):
                evicted.append(req)
            else:
                kept.append(req)
        self._q = kept
        return evicted


def admission_budget(n_queued: int, n_free_slots: int, n_running: int,
                     max_prefill_per_step: int) -> int:
    """How many requests to prefill before the next decode step."""
    budget = min(n_queued, n_free_slots)
    if n_running > 0:
        budget = min(budget, max_prefill_per_step)
    return budget


def pick_preemption_victim(running: dict[int, Request]) -> int:
    """Slot/row of the request to preempt when the paged arena runs dry.

    Youngest-first (latest admission): the request that has sunk the
    least work is restarted, and repeated preemption converges — older
    requests keep their blocks and drain, releasing memory.  Ties (one
    admission group) break toward the higher request id."""
    return max(running,
               key=lambda s: (running[s].metrics.admitted,
                              running[s].request_id))
