"""Multi-replica serving: N engines behind a prefix-aware router.

::

                         submit(prompt, session)
                                  │
                            ┌─────▼─────┐   score(i) = w_p·prefix_frac(i)
                            │  Router   │             - w_l·load(i)
                            │ (3 pols)  │             + w_a·affinity(i)
                            └─────┬─────┘
              ┌───────────────────┼───────────────────┐
        ┌─────▼─────┐       ┌─────▼─────┐       ┌─────▼─────┐
        │ Engine 0  │ steal │ Engine 1  │ drain │ Engine 2  │
        │ KV+prefix │◄─────►│ KV+prefix │◄─────►│ KV+prefix │
        │ mesh slice│       │ mesh slice│       │ mesh slice│
        └───────────┘       └───────────┘       └───────────┘

See ``router`` and ``replica_set`` module docstrings for the scoring,
rebalance, and token-identity contracts.
"""
from .replica_set import ReplicaSet
from .router import ROUTING_POLICIES, RouteDecision, Router

__all__ = ["ReplicaSet", "Router", "RouteDecision", "ROUTING_POLICIES"]
