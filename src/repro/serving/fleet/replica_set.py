"""N in-process serving-engine replicas behind one router.

``ReplicaSet`` duck-types the single-engine surface that trace replay
and the CLI drive — ``submit`` / ``step`` / ``run`` / ``has_work`` /
``stats`` / ``finished`` — so everything built on one engine (replay,
benchmarks, serve.py) runs unchanged against a fleet.  Each replica is a
full ``ServingEngine`` with its own KV pool, prefix cache, scheduler and
(optionally) mesh slice; the set owns what must be fleet-global:

  * **request ids** — one counter across replicas, so ids are unique in
    a shared trace and ``finished`` (sorted by id) lines up with
    submission order regardless of where each request ran;
  * **routing** — every ``submit`` asks the ``Router`` to score replicas
    (prefix-cache hit potential, load, session affinity);
  * **rebalance** — after each fleet step:
      - *drain/re-admit*: a PREEMPTED request stuck at the head of a
        replica whose pool cannot re-admit it moves to a replica that
        can admit it right now, instead of waiting for its evictor to
        retire;
      - *work-stealing*: when max-min queue depth crosses
        ``steal_threshold``, the youngest queued requests move from the
        richest to the poorest queue.

Migration is safe by construction: only QUEUED, slotless requests move
(they hold no KV, no per-engine state), and a request's token stream
depends only on (params, prompt, sampling) — sampling keys derive from
(seed, tokens generated), prefill after preemption recomputes
prompt+generated — so WHERE a request runs can never change WHAT it
generates (pinned by tests/test_fleet.py: 1 replica vs N, with a forced
mid-trace steal, token-identical).

Replicas are stepped round-robin in-process — this is the data-axis
scale-out for one host.  Cross-process replicas behind the same router
protocol are the follow-up (ROADMAP).
"""
from __future__ import annotations

import time

import numpy as np

from ..engine import ServingEngine
from ..observe import NULL_ROUTER_TRACER
from ..request import Request, SamplingParams
from ..scheduler import QueueFull
from .router import Router

# bound on requests moved per rebalance check: keeps one badly skewed
# burst from thrashing every queue in a single step
_MAX_MOVES_PER_STEP = 8


class ReplicaSet:
    # trace.replay passes each TraceRequest's session id to targets that
    # advertise this (single engines don't take sessions)
    accepts_session = True

    def __init__(self, cfg, params, *, n_replicas: int = 2,
                 routing: str = "prefix", meshes=None, tracers=None,
                 router_tracer=None, router_kwargs: dict | None = None,
                 steal_threshold: int = 4, clock=time.monotonic,
                 **engine_kwargs):
        if n_replicas < 1:
            raise ValueError(f"n_replicas must be >= 1, got {n_replicas}")
        if meshes is not None and len(meshes) != n_replicas:
            raise ValueError(
                f"{len(meshes)} meshes for {n_replicas} replicas")
        if tracers is not None and len(tracers) != n_replicas:
            raise ValueError(
                f"{len(tracers)} tracers for {n_replicas} replicas")
        self._clock = clock
        self.replicas = [
            ServingEngine(cfg, params,
                          mesh=meshes[i] if meshes is not None else None,
                          tracer=tracers[i] if tracers is not None else None,
                          clock=clock, **engine_kwargs)
            for i in range(n_replicas)]
        # Identically-configured replicas on one mesh (or none) trace the
        # exact same step shapes, and the jitted functions close over
        # constants only (cfg, trash index, backend) — every mutable
        # arena is an argument.  Aliasing replica 0's functions gives the
        # fleet ONE compile cache: a (B, S) variant compiled anywhere is
        # warm everywhere, instead of each replica paying its own
        # compiles for the same shapes.  Per-replica meshes shard
        # per-mesh, so there each replica keeps its own functions.
        if meshes is None or all(m is meshes[0] for m in meshes):
            a0 = self.replicas[0].adapter
            for e in self.replicas[1:]:
                for fn in ("_step_fn", "_decode_fn", "_encode_fn"):
                    if hasattr(a0, fn):
                        setattr(e.adapter, fn, getattr(a0, fn))
                e._step_fn = e.adapter._step_fn
                e._decode_fn = e.adapter._decode_fn
        self.router = Router(self.replicas, routing,
                             **(router_kwargs or {}))
        self.tracer = NULL_ROUTER_TRACER if router_tracer is None \
            else router_tracer
        if self.tracer.enabled:
            self.tracer.attach(self)
        self.steal_threshold = max(int(steal_threshold), 1)
        self._next_id = 0
        self.home: dict[int, int] = {}       # request id -> replica index
        self.n_steals = 0
        self.n_drains = 0
        # per-replica busy wall time: in deployment each replica runs on
        # its own mesh slice/host, so the fleet's makespan is the CRITICAL
        # PATH — max over replicas of busy time, plus routing/rebalance —
        # not the sum this in-process loop pays stepping them one by one.
        # The bench reports both (wall_s = host truth, busy_s = what N-way
        # hardware would see).
        self.busy_s = [0.0] * n_replicas
        self.router_busy_s = 0.0

    # ------------------------------------------------------------ admission
    def submit(self, prompt, sampling: SamplingParams | None = None,
               on_token=None, on_finish=None, embeds=None,
               session=None) -> Request:
        """Route and enqueue one request.  Raises QueueFull when every
        replica's queue is at capacity and ValueError when the request
        can never fit a replica's pool — the single-engine contract, so
        replay/bench admission handling works unchanged."""
        prompt = [int(t) for t in np.asarray(prompt).reshape(-1)]
        decision = self.router.route(prompt, session)
        rid = self._next_id
        req = self.replicas[decision.replica].submit(
            prompt, sampling, on_token=on_token, on_finish=on_finish,
            embeds=embeds, request_id=rid)
        self._next_id += 1
        self.home[rid] = decision.replica
        if self.tracer.enabled:
            self.tracer.on_route(rid, decision)
        return req

    # ------------------------------------------------------------ stepping
    @property
    def has_work(self) -> bool:
        return any(e.has_work for e in self.replicas)

    def step(self) -> dict:
        """One fleet iteration: step every replica that has work (idle
        replicas cost nothing — the fleet's throughput edge over one
        wide engine, which pays its full fused-decode lane complement
        every step), then rebalance queues."""
        stepped = 0
        for i, e in enumerate(self.replicas):
            if e.has_work:
                t0 = time.monotonic()
                e.step()
                self.busy_s[i] += time.monotonic() - t0
                stepped += 1
        t0 = time.monotonic()
        moved = self._rebalance()
        self.router_busy_s += time.monotonic() - t0
        return {"stepped": stepped, "moved": moved}

    def run(self, max_steps: int | None = None) -> list[Request]:
        steps = 0
        while self.has_work and (max_steps is None or steps < max_steps):
            self.step()
            steps += 1
        return self.finished

    # ----------------------------------------------------------- rebalance
    def _can_admit_now(self, i: int, req: Request) -> bool:
        """Could replica i place ``req`` in its next step?  Conservative:
        a free row, and (paged) obtainable blocks for the whole
        sequence-so-far plus the engine's decode lookahead."""
        e = self.replicas[i]
        if e.pool.n_free < 1:
            return False
        if e.kv_layout != "paged":
            return True
        seq_len = len(req.prompt) + len(req.tokens)
        return e.pool.can_admit(seq_len, e.lookahead_blocks)

    def _move(self, req: Request, src: int, dst: int, kind: str) -> None:
        self.replicas[dst].ingest(req)
        self.home[req.request_id] = dst
        if kind == "steal":
            self.n_steals += 1
        else:
            self.n_drains += 1
        if self.tracer.enabled:
            self.tracer.on_reroute(req.request_id, kind, src, dst)

    def _rebalance(self) -> int:
        moved = 0
        n = len(self.replicas)
        if n < 2:
            return 0

        # drain/re-admit: a preempted request parked at the head of a
        # replica that cannot re-admit it is blocked on ITS OWN victim's
        # memory; any replica with room now serves it sooner (and FIFO
        # is preserved where it matters — the head was going nowhere)
        for i, e in enumerate(self.replicas):
            head = next(iter(e.queue), None)
            if head is None or head.n_preempted == 0 \
                    or self._can_admit_now(i, head):
                continue
            for j in sorted(range(n),
                            key=lambda j: (self.router.load(j), j)):
                if j == i or not self.router._admissible(j) \
                        or not self._can_admit_now(j, head):
                    continue
                if e.withdraw(head):
                    self._move(head, i, j, "drain")
                    moved += 1
                break

        # work-stealing: level queue depths when the spread crosses the
        # threshold, moving youngest-queued requests rich -> poor
        depths = [len(e.queue) for e in self.replicas]
        if self.tracer.enabled:
            self.tracer.on_imbalance(max(depths) - min(depths))
        while moved < _MAX_MOVES_PER_STEP:
            rich = max(range(n), key=lambda i: (depths[i], -i))
            poor = min(range(n), key=lambda i: (depths[i], i))
            if depths[rich] - depths[poor] <= self.steal_threshold:
                break
            if depths[poor] >= self.replicas[poor].queue.max_size:
                break
            req = self.replicas[rich].steal_youngest()
            if req is None:
                break
            self._move(req, rich, poor, "steal")
            depths[rich] -= 1
            depths[poor] += 1
            moved += 1
        return moved

    # ------------------------------------------------------------- results
    @property
    def finished(self) -> list[Request]:
        """All retired requests fleet-wide, sorted by (globally unique)
        request id — i.e. submission order, wherever each one ran."""
        out: list[Request] = []
        for e in self.replicas:
            out.extend(e.finished)
        return sorted(out, key=lambda r: r.request_id)

    def clear_finished(self) -> None:
        for e in self.replicas:
            e.finished.clear()

    def prefix_match_length(self, prompt) -> int:
        """Best cached-prefix length across the fleet (probe; no side
        effects) — what a router one level up would see."""
        return max(e.prefix_match_length(prompt) for e in self.replicas)

    # ------------------------------------------------------------ counters
    def stats(self) -> dict:
        per = [e.stats() for e in self.replicas]
        agg = {"lookups": 0, "hits": 0, "hit_tokens": 0, "probes": 0}
        for p in per:
            pc = p.get("pool", {}).get("prefix_cache")
            if pc:
                for k in agg:
                    agg[k] += pc.get(k, 0)
        agg["hit_rate"] = agg["hits"] / agg["lookups"] if agg["lookups"] \
            else 0.0
        return {"n_replicas": len(self.replicas),
                "routing": self.router.policy,
                "n_steps": max((p["n_steps"] for p in per), default=0),
                "n_steals": self.n_steals,
                "n_drains": self.n_drains,
                "busy_s": list(self.busy_s),
                "critical_path_s": max(self.busy_s) + self.router_busy_s,
                "router_busy_s": self.router_busy_s,
                "router": self.router.stats(),
                "prefix_cache": agg,
                "replicas": per}

    def reset_stats(self) -> None:
        for e in self.replicas:
            e.reset_stats()
        self.router.reset_stats()
        self.n_steals = 0
        self.n_drains = 0
        self.busy_s = [0.0] * len(self.replicas)
        self.router_busy_s = 0.0
