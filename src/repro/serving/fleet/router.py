"""Request routing across serving-engine replicas.

One ``Router`` scores every incoming request against every replica and
picks where it runs.  The score combines the three signals that matter
for a prefix-cached continuous-batching fleet:

  score(i) = w_prefix   * prefix_frac(i)        cached-prompt fraction,
                                                via the side-effect-free
                                                ``prefix_match_length``
           - w_load     * load(i)               occupancy + queue depth,
                                                normalized by capacity
           + w_affinity * [session sticky to i] last replica this session
                                                was routed to

``prefix_frac(i)`` is ``match_length(prompt) / len(prompt)`` probed
against replica i's hash-chained prefix cache — host-side dict walks, no
refcounts, no LRU disturbance (see ``PrefixCache.match_length``), so
probing all N replicas per request costs microseconds.  The prefix term
is what concentrates each tenant's shared system prompt on one replica
(N small caches behave like one big cache instead of N thrashing
copies); the load term keeps a hot tenant from melting its home replica;
session affinity breaks ties toward cache locality before the first
block is ever cached.

Three policies share the machinery — ``prefix`` (the full score),
``least_loaded`` (load term only), ``round_robin`` (cycling baseline) —
so benchmarks compare them on identical workloads.  Replicas whose
admission queue is full are never candidates; when every queue is full
the router raises ``QueueFull``, same contract as a single engine.

Scoring is deterministic (ties break toward the less-loaded, then
lower-indexed replica) — with a seeded trace, a fleet run is exactly
reproducible.
"""
from __future__ import annotations

import dataclasses

from ..scheduler import QueueFull

ROUTING_POLICIES = ("prefix", "round_robin", "least_loaded")


@dataclasses.dataclass(frozen=True)
class RouteDecision:
    """One routing decision, carrying enough to audit it later: which
    replica won, under which policy, which score component decided it
    ("prefix" / "affinity" / "load" / "round_robin"), how much of the
    prompt that replica already had cached, and every replica's load at
    decision time."""
    replica: int
    policy: str
    picked_by: str
    score: float
    prefix_frac: float
    prefix_tokens: int
    loads: tuple


class Router:
    def __init__(self, replicas, policy: str = "prefix", *,
                 w_prefix: float = 2.0, w_load: float = 1.0,
                 w_affinity: float = 0.25):
        if policy not in ROUTING_POLICIES:
            raise ValueError(
                f"unknown routing policy {policy!r}; "
                f"choose from {ROUTING_POLICIES}")
        self.replicas = list(replicas)
        if not self.replicas:
            raise ValueError("Router needs at least one replica")
        self.policy = policy
        self.w_prefix = w_prefix
        self.w_load = w_load
        self.w_affinity = w_affinity
        self._rr_next = 0
        # session -> replica index of the most recent routing decision
        self.affinity: dict = {}
        self.n_decisions = 0
        self.decisions_by: dict[str, int] = {}
        self.prefix_tokens_routed = 0

    # ------------------------------------------------------------ signals
    def load(self, i: int) -> float:
        """Replica load: requests holding or waiting for a slot, per slot
        of compute.  1.0 = exactly full, 2.0 = a full batch is queued
        behind the running one.  Normalizing by SLOTS (not slots+queue
        room) keeps the signal proportional to waiting time, so a deep
        queue actually outweighs ``w_prefix`` — with a near-zero load
        term, prefix affinity piles every tenant onto the first replica
        that caches it and the fleet serializes."""
        e = self.replicas[i]
        return (len(e.running) + len(e.queue)) / max(e.pool.n_slots, 1)

    def _admissible(self, i: int) -> bool:
        e = self.replicas[i]
        return len(e.queue) < e.queue.max_size

    # ------------------------------------------------------------ routing
    def route(self, prompt, session=None) -> RouteDecision:
        """Pick a replica for ``prompt``; raises QueueFull when every
        replica's queue is at capacity.  ``session`` is an opaque
        hashable id; consecutive requests of one session prefer each
        other's replica (and the affinity map is updated to the winner,
        whatever policy chose it)."""
        n = len(self.replicas)
        candidates = [i for i in range(n) if self._admissible(i)]
        if not candidates:
            raise QueueFull("every replica's queue is at capacity")
        loads = tuple(self.load(i) for i in range(n))

        if self.policy == "round_robin":
            pick = next(i for off in range(n)
                        for i in [(self._rr_next + off) % n]
                        if i in candidates)
            self._rr_next = (pick + 1) % n
            decision = RouteDecision(pick, self.policy, "round_robin",
                                     0.0, 0.0, 0, loads)
        elif self.policy == "least_loaded":
            pick = min(candidates, key=lambda i: (loads[i], i))
            decision = RouteDecision(pick, self.policy, "load",
                                     -loads[pick], 0.0, 0, loads)
        else:                                       # prefix (full score)
            prompt = list(prompt)
            toks = {i: self.replicas[i].prefix_match_length(prompt)
                    for i in candidates}
            home = self.affinity.get(session) if session is not None \
                else None
            scores = {
                i: (self.w_prefix * toks[i] / max(len(prompt), 1)
                    - self.w_load * loads[i]
                    + (self.w_affinity if i == home else 0.0))
                for i in candidates}
            pick = max(candidates,
                       key=lambda i: (scores[i], -loads[i], -i))
            picked_by = ("prefix" if toks[pick] > 0
                         else "affinity" if pick == home else "load")
            decision = RouteDecision(
                pick, self.policy, picked_by, scores[pick],
                toks[pick] / max(len(prompt), 1), toks[pick], loads)

        if session is not None:
            self.affinity[session] = decision.replica
        self.n_decisions += 1
        self.decisions_by[decision.picked_by] = \
            self.decisions_by.get(decision.picked_by, 0) + 1
        self.prefix_tokens_routed += decision.prefix_tokens
        return decision

    # ------------------------------------------------------------- stats
    def stats(self) -> dict:
        return {"policy": self.policy,
                "weights": {"prefix": self.w_prefix, "load": self.w_load,
                            "affinity": self.w_affinity},
                "n_decisions": self.n_decisions,
                "decisions_by": dict(self.decisions_by),
                "prefix_tokens_routed": self.prefix_tokens_routed,
                "sessions": len(self.affinity)}

    def reset_stats(self) -> None:
        """Zero decision counters; affinity and round-robin state persist
        (they are routing state, not measurement)."""
        self.n_decisions = 0
        self.decisions_by = {}
        self.prefix_tokens_routed = 0
