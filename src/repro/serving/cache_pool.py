"""KV cache pools: the slot layout, and the protocol both layouts satisfy.

``SlotKVPool`` is the original contiguous layout: one allocation at engine
start of k/v buffers [L, n_slots, max_len, KV, hd] plus a per-slot
filled-position vector [n_slots].  Requests are assigned a slot for their
lifetime; prefill KV is scattered into the slot at the request's cursor
(chunked prefill writes each chunk at its own offset), decode steps write at
each slot's own position (models/transformer.py slot-indexed decode).
Buffer shapes never change, so the decode step compiles exactly once — at
the cost of reserving ``max_len`` tokens of HBM per slot whether a request
uses them or not.  ``serving/paged/`` removes that reservation.

Freed slots are immediately reusable and rows mid-prefill may share a fused
decode step with decoding rows: every KV position a request's attention can
see ([0, pos)) is freshly written by its own prefill chunk or decode before
it becomes visible, and any position >= pos is overwritten (by the next
chunk's scatter, or by decode's write-before-attend) before any query reads
it — so neither zeroing on release nor masking the batch-wide decode write
is needed.

Invariant violations raise ``CachePoolError`` subclasses — real
exceptions, not ``assert``, so the checks survive ``python -O``.
"""
from __future__ import annotations

from functools import partial
from typing import Protocol, runtime_checkable

import jax
import jax.numpy as jnp
import numpy as np


class CachePoolError(RuntimeError):
    """Cache-pool invariant violation (these indicate engine bugs, not
    workload conditions — workload pressure raises QueueFull/OutOfBlocks)."""


class DoubleFree(CachePoolError):
    """A slot/row/block was released twice."""


class CapacityError(CachePoolError):
    """A write or admission exceeded what the pool can physically hold."""


@runtime_checkable
class KVCachePool(Protocol):
    """What the engine requires of a KV layout.

    Attributes: ``k``/``v`` device buffers consumed by the jitted decode,
    ``pos`` per-lane filled positions, ``n_slots`` decode-batch width,
    ``n_free`` free concurrency units, ``max_request_tokens`` the longest
    admissible request, ``gather_prefix`` the chunked-prefill context
    fetch.  Layout-specific admission/write paths stay on the concrete
    classes; the engine dispatches on ``kv_layout`` for those.
    """
    n_slots: int

    @property
    def n_free(self) -> int: ...

    @property
    def max_request_tokens(self) -> int: ...

    def release(self, slot: int) -> None: ...

    def update(self, caches: dict, active_mask) -> None: ...


@partial(jax.jit, donate_argnums=(0,))
def _scatter_tokens(pool, vals, slots):
    """Write ``vals [L, T, KV, hd]`` at flat token ``slots [T]`` of the pool
    (viewed as [L, n_slots*max_len, KV, hd]), in place (donated).  Indices
    past the flat extent are dropped — batch/bucket padding routes there, so
    one compiled scatter per (T,) shape serves every (slot, offset) mix."""
    L, ns, ml = pool.shape[:3]
    flat = pool.reshape(L, ns * ml, *pool.shape[3:])
    flat = flat.at[:, slots].set(vals.astype(pool.dtype), mode="drop")
    return flat.reshape(pool.shape)


class SlotKVPool:
    def __init__(self, cfg, n_slots: int, max_len: int, placement=None):
        from .placement import ServingPlacement
        pl = placement or ServingPlacement()
        L, KV, hd = cfg.n_layers, cfg.n_kv_heads, cfg.hd
        shape = (L, n_slots, max_len, KV, hd)
        # arenas are committed to the placement's KV-head-sharded layout at
        # birth; the jitted decode then updates them shard-local in place
        self.k = pl.place_kv(jnp.zeros(shape, cfg.dtype))
        self.v = pl.place_kv(jnp.zeros(shape, cfg.dtype))
        self.pos = pl.place_replicated(jnp.zeros((n_slots,), jnp.int32))
        self.n_slots = n_slots
        self.max_len = max_len
        self._free = list(range(n_slots - 1, -1, -1))   # pop() -> ascending

    # ---------------------------------------------------------------- slots
    @property
    def n_free(self) -> int:
        return len(self._free)

    @property
    def max_request_tokens(self) -> int:
        return self.max_len

    def alloc(self) -> int | None:
        return self._free.pop() if self._free else None

    def release(self, slot: int) -> None:
        if slot in self._free:
            raise DoubleFree(f"release of free slot {slot}")
        self._free.append(slot)

    # kept for existing callers; same semantics as release
    free = release

    # ---------------------------------------------------------------- data
    def write_prefill_group(self, slots: list[int], k, v,
                            lengths: list[int], offset: int = 0) -> None:
        """Scatter a prefill-chunk group into its slots at ``offset``.

        ``k``/``v``: [L, B, S_bucket, KV, hd] with B >= len(slots) (batch
        pad) and S_bucket >= each row's chunk length (bucket pad).  Real
        (slot, position) pairs map into the flat pool; every pad element
        maps past the pool's extent and is dropped by the scatter, so the
        compiled shape depends only on (B, S_bucket) — not on the offset,
        which is what keeps chunked prefill at one compile per bucket."""
        L, B, S = k.shape[:3]
        if offset + max(lengths) > self.max_len:
            raise CapacityError(
                f"prefill of {max(lengths)} tokens at offset {offset} "
                f"exceeds slot capacity {self.max_len}")
        oob = self.n_slots * self.max_len          # dropped by the scatter
        idx = np.full((B, S), oob, np.int64)
        for i, (slot, ln) in enumerate(zip(slots, lengths)):
            idx[i, :ln] = slot * self.max_len + offset + np.arange(ln)
        idx = jnp.asarray(idx.reshape(-1))
        self.k = _scatter_tokens(self.k, k.reshape(L, B * S, *k.shape[3:]), idx)
        self.v = _scatter_tokens(self.v, v.reshape(L, B * S, *v.shape[3:]), idx)
        ends = jnp.asarray([offset + ln for ln in lengths], jnp.int32)
        self.pos = self.pos.at[jnp.asarray(slots)].set(ends)

    def gather_prefix(self, slots: list[int], n_prefix: int,
                      n_rows_padded: int):
        """Materialize [L, B, n_prefix, KV, hd] of already-written KV for a
        chunk group (batch-pad rows replicate slot 0's data — computed on
        but never read back)."""
        idx = np.zeros((n_rows_padded,), np.int32)
        idx[:len(slots)] = slots
        idx = jnp.asarray(idx)
        return self.k[:, idx, :n_prefix], self.v[:, idx, :n_prefix]

    def update(self, caches: dict, active_mask) -> None:
        """Adopt a decode step's outputs.  Only rows in ``active_mask``
        (this step's decode batch, minus retirements) advance their
        position; everyone else — free slots and rows mid-prefill — keeps
        its previous position, so a prefill cursor survives sharing the
        fused step with decoders.  (The batch-wide decode write did land a
        garbage token at each inactive row's position, but the next chunk
        scatter / next occupant's prefill overwrites it before any query
        can attend there — see the module docstring.)"""
        self.k = caches["k"]
        self.v = caches["v"]
        self.pos = jnp.where(active_mask, caches["pos"], self.pos)
