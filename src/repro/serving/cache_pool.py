"""KV cache pools: the slot layout, and the protocol both layouts satisfy.

``SlotKVPool`` is the original contiguous layout: one allocation at engine
start of k/v buffers [L, n_slots, max_len, KV, hd] plus a per-slot
filled-position vector [n_slots].  Requests are assigned a slot for their
lifetime; prefill KV is written left-aligned into the slot, decode steps
write at each slot's own position (models/transformer.py slot-indexed
decode).  Buffer shapes never change, so the decode step compiles exactly
once — at the cost of reserving ``max_len`` tokens of HBM per slot whether
a request uses them or not.  ``serving/paged/`` removes that reservation.

Freed slots are immediately reusable: every KV position a new request's
attention can see ([0, pos)) is freshly written by its own prefill/decode
before it becomes visible, so no zeroing pass is needed on release.

Invariant violations raise ``CachePoolError`` subclasses — real
exceptions, not ``assert``, so the checks survive ``python -O``.
"""
from __future__ import annotations

from functools import partial
from typing import Protocol, runtime_checkable

import jax
import jax.numpy as jnp


class CachePoolError(RuntimeError):
    """Cache-pool invariant violation (these indicate engine bugs, not
    workload conditions — workload pressure raises QueueFull/OutOfBlocks)."""


class DoubleFree(CachePoolError):
    """A slot/row/block was released twice."""


class CapacityError(CachePoolError):
    """A write or admission exceeded what the pool can physically hold."""


@runtime_checkable
class KVCachePool(Protocol):
    """What the engine requires of a KV layout.

    Attributes: ``k``/``v`` device buffers consumed by the jitted decode,
    ``pos`` per-lane filled positions, ``n_slots`` decode-batch width,
    ``n_free`` free concurrency units, ``max_request_tokens`` the longest
    admissible request.  Layout-specific admission/write paths stay on the
    concrete classes; the engine dispatches on ``kv_layout`` for those.
    """
    n_slots: int

    @property
    def n_free(self) -> int: ...

    @property
    def max_request_tokens(self) -> int: ...

    def release(self, slot: int) -> None: ...

    def update(self, caches: dict, active_mask) -> None: ...


@partial(jax.jit, donate_argnums=(0,))
def _install(pool, kv, slots):
    """In-place (donated) write of an admission group into the pool."""
    return pool.at[:, slots, :kv.shape[2]].set(kv)


class SlotKVPool:
    def __init__(self, cfg, n_slots: int, max_len: int, placement=None):
        from .placement import ServingPlacement
        pl = placement or ServingPlacement()
        L, KV, hd = cfg.n_layers, cfg.n_kv_heads, cfg.hd
        shape = (L, n_slots, max_len, KV, hd)
        # arenas are committed to the placement's KV-head-sharded layout at
        # birth; the jitted decode then updates them shard-local in place
        self.k = pl.place_kv(jnp.zeros(shape, cfg.dtype))
        self.v = pl.place_kv(jnp.zeros(shape, cfg.dtype))
        self.pos = pl.place_replicated(jnp.zeros((n_slots,), jnp.int32))
        self.n_slots = n_slots
        self.max_len = max_len
        self._free = list(range(n_slots - 1, -1, -1))   # pop() -> ascending

    # ---------------------------------------------------------------- slots
    @property
    def n_free(self) -> int:
        return len(self._free)

    @property
    def max_request_tokens(self) -> int:
        return self.max_len

    def alloc(self) -> int | None:
        return self._free.pop() if self._free else None

    def release(self, slot: int) -> None:
        if slot in self._free:
            raise DoubleFree(f"release of free slot {slot}")
        self._free.append(slot)

    # kept for existing callers; same semantics as release
    free = release

    # ---------------------------------------------------------------- data
    def write_prefill_group(self, slots: list[int], k, v,
                            lengths: list[int]) -> None:
        """Install a prefilled admission group: k/v [L, B, S_bucket, KV, hd].

        The whole padded bucket is written in ONE donated scatter per
        buffer (no per-request pool copies).  Rows past each request's
        prompt length hold pad-token KV but are never visible: attention
        masks by the slot's pos, and decode overwrites position p before
        any query attends to it."""
        if max(lengths) > self.max_len:
            raise CapacityError(f"prefill of {max(lengths)} tokens exceeds "
                                f"slot capacity {self.max_len}")
        w = min(k.shape[2], self.max_len)
        slots_arr = jnp.asarray(slots)
        self.k = _install(self.k, k[:, :, :w], slots_arr)
        self.v = _install(self.v, v[:, :, :w], slots_arr)
        self.pos = self.pos.at[slots_arr].set(jnp.asarray(lengths, jnp.int32))

    def update(self, caches: dict, active_mask) -> None:
        """Adopt a decode step's outputs; inactive slots' positions are
        pinned to 0 so stale counters never walk past max_len."""
        self.k = caches["k"]
        self.v = caches["v"]
        self.pos = jnp.where(active_mask, caches["pos"], 0)
