"""KV cache pools: the slot layout, the protocol both layouts satisfy, and
the slot ``PoolView`` the unified attention primitive consumes.

``SlotKVPool`` is the original contiguous layout: one allocation at engine
start of k/v buffers [L, n_slots, max_len, KV, hd] plus a per-slot
filled-position vector [n_slots].  Requests are assigned a slot for their
lifetime.  All KV writes happen INSIDE the jitted step functions: the pool
hands the engine a ``SlotPoolView`` (arena + lane->slot rows + cursors)
and ``models/transformer.unified_step`` scatters each chunk/decode token's
fresh KV at the cursor and attends in place against the arena with the
cursor as a length mask — no gathered prefix copies, so per-step HBM
traffic is independent of how much prefix a request has already written.
Buffer shapes never change, so each step shape compiles exactly once — at
the cost of reserving ``max_len`` tokens of HBM per slot whether a request
uses them or not.  ``serving/paged/`` removes that reservation.

Freed slots are immediately reusable and rows mid-prefill may share a fused
decode step with decoding rows: every KV position a request's attention can
see ([0, pos)) is freshly written by its own prefill chunk or decode before
it becomes visible, and any position >= pos is overwritten (by the next
chunk's scatter, or by decode's write-before-attend) before any query reads
it — so neither zeroing on release nor masking the batch-wide decode write
is needed.

Invariant violations raise ``CachePoolError`` subclasses — real
exceptions, not ``assert``, so the checks survive ``python -O``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Protocol, runtime_checkable

import jax
import jax.numpy as jnp
import numpy as np


class CachePoolError(RuntimeError):
    """Cache-pool invariant violation (these indicate engine bugs, not
    workload conditions — workload pressure raises QueueFull/OutOfBlocks)."""


class DoubleFree(CachePoolError):
    """A slot/row/block was released twice."""


class CapacityError(CachePoolError):
    """A write or admission exceeded what the pool can physically hold."""


KV_DTYPES = ("bf16", "int8")


def quantize_kv(fresh):
    """Symmetric per-position per-KV-head int8 quantization of fresh KV
    [..., KV, hd]: absmax over the head dim -> int8 values + f32 scales
    [..., KV].  Scale granularity matches the scatter granularity — each
    written position carries its own scale, so incremental chunk/decode
    writes, copy-on-write and prefix sharing never have to re-quantize
    neighbours.  All-zero positions (padding, fresh arenas) get scale 1.0
    so dequantization is always well-defined."""
    f = fresh.astype(jnp.float32)
    absmax = jnp.max(jnp.abs(f), axis=-1)
    scale = jnp.where(absmax > 0, absmax / 127.0, 1.0)
    q = jnp.clip(jnp.round(f / scale[..., None]), -127, 127).astype(jnp.int8)
    return q, scale


def arena_nbytes(*arrays) -> int:
    """Total device bytes of the given arenas (None entries skipped)."""
    return sum(a.size * a.dtype.itemsize for a in arrays if a is not None)


def _flat_scatter(flat_idx, n_rows: int, n_vals: int):
    """Scatter closure over a flattened arena: works for value arenas
    ([rows, KV, hd] trailing dims) and scale arenas ([rows, KV]) alike —
    the same indices route both, which is what keeps scales glued to
    their positions through every write path."""
    def scat(arena, vals):
        flat = arena.reshape(n_rows, *arena.shape[2:])
        flat = flat.at[flat_idx].set(
            vals.reshape(n_vals, *vals.shape[2:]).astype(arena.dtype),
            mode="drop")
        return flat.reshape(arena.shape)
    return scat


@runtime_checkable
class KVCachePool(Protocol):
    """What the engine requires of a KV layout.

    Attributes: ``k``/``v`` device arenas consumed (donated) by the jitted
    step functions, ``pos`` per-lane filled positions, ``n_slots``
    decode-batch width, ``n_free`` free concurrency units,
    ``max_request_tokens`` the longest admissible request.  The step
    lifecycle is: the engine builds a pool view (``chunk_view`` /
    ``decode_view``) whose arenas ride through ``transformer.unified_step``
    donated-in-place, then ``adopt``s the returned arenas and advances
    positions (``advance_prefill`` after a chunk, ``advance_decode`` after
    a fused decode).  Layout-specific admission paths stay on the concrete
    classes; the engine dispatches on ``kv_layout`` for those.
    """
    n_slots: int

    @property
    def n_free(self) -> int: ...

    @property
    def max_request_tokens(self) -> int: ...

    def release(self, slot: int) -> None: ...

    def adopt(self, k, v) -> None: ...

    def advance_prefill(self, rows: list[int], ends: list[int]) -> None: ...

    def advance_decode(self, active_mask) -> None: ...


@dataclasses.dataclass(frozen=True)
class SlotPoolView:
    """What ``transformer.attend_over_pool`` sees of a slot-layout pool:
    the arena itself plus lane addressing — NOT a gathered copy of
    context.  Constructed inside the engine's traced step functions, so
    every field is a tracer at use time.

    ``k``/``v`` are the full [L, n_slots, max_len, KV, hd] arenas at step
    level; inside the per-layer scan the transformer rebinds them to one
    layer's [n_slots, max_len, KV, hd] slice (``dataclasses.replace``).
    ``rows`` [B] maps each batch lane to its arena slot (values >=
    n_slots are padding lanes whose writes drop); ``rows=None`` means the
    batch IS the arena, lane i == slot i (the fused decode).  ``cursor``
    [B] counts tokens already written per lane; ``n_new`` [B] is how many
    of this step's S token positions are real for the lane (the rest are
    bucket padding: their writes are dropped and their queries' outputs
    discarded by the engine).

    ``k_scale``/``v_scale`` ([L, n_slots, max_len, KV] f32, or None for
    bf16 arenas) are the per-position dequantization scales of an int8
    arena; they ride the view through the jitted step exactly like the
    arenas (donated in, scattered in place, adopted out) and share the
    arenas' flat write indices.
    """
    k: Any
    v: Any
    rows: Any | None
    cursor: Any
    n_new: Any
    k_scale: Any | None = None
    v_scale: Any | None = None

    @property
    def block_tables(self):
        return None                       # duck-type marker: slot layout

    def lane_kv(self, k_l, v_l):
        """Per-lane [B, max_len, KV, hd] arena rows for attention.  With
        ``rows=None`` the arena batch dim is used directly (no gather on
        the fused-decode hot path); otherwise a B-row gather whose cost is
        independent of how much prefix the rows have written."""
        if self.rows is None:
            return k_l, v_l
        return k_l[self.rows], v_l[self.rows]

    def _flat_write_idx(self, ns, ml, S):
        """Flat (slot*max_len + pos) scatter index per (lane, i) pair;
        padding/overflow maps to ns*ml (one past the arena) and drops."""
        rows = jnp.arange(ns) if self.rows is None else self.rows
        p = self.cursor[:, None] + jnp.arange(S)[None]        # [B,S]
        oob = ns * ml
        flat_idx = rows[:, None] * ml + p
        valid = (jnp.arange(S)[None] < self.n_new[:, None]) & (p < ml)
        return jnp.where(valid, flat_idx, oob).reshape(-1)

    def write_layer(self, k_l, v_l, fresh_k, fresh_v):
        """Scatter fresh [B, S, KV, hd] KV into one layer's arena slice at
        each lane's cursor, in place under donation.  Real (lane, i<n_new)
        pairs land at flat slot ``rows[b] * max_len + cursor[b] + i``;
        padding maps past the arena extent and is dropped, so the compiled
        scatter depends only on (B, S)."""
        ns, ml = k_l.shape[0], k_l.shape[1]
        B, S = fresh_k.shape[:2]
        flat_idx = self._flat_write_idx(ns, ml, S)
        scat = _flat_scatter(flat_idx, ns * ml, B * S)
        return scat(k_l, fresh_k), scat(v_l, fresh_v)

    def write_layer_quantized(self, k_l, v_l, ks_l, vs_l, fresh_k, fresh_v):
        """Quantize-on-scatter: int8-quantize the fresh KV per position and
        scatter values + scales with the SAME flat indices — the bf16
        projections never touch HBM as an arena copy."""
        ns, ml = k_l.shape[0], k_l.shape[1]
        B, S = fresh_k.shape[:2]
        flat_idx = self._flat_write_idx(ns, ml, S)
        scat = _flat_scatter(flat_idx, ns * ml, B * S)
        qk, sk = quantize_kv(fresh_k)
        qv, sv = quantize_kv(fresh_v)
        return scat(k_l, qk), scat(v_l, qv), scat(ks_l, sk), scat(vs_l, sv)


class SlotKVPool:
    def __init__(self, cfg, n_slots: int, max_len: int, placement=None,
                 kv_dtype: str = "bf16"):
        from .placement import ServingPlacement
        pl = placement or ServingPlacement()
        if kv_dtype not in KV_DTYPES:
            raise ValueError(f"kv_dtype must be one of {KV_DTYPES}, "
                             f"not {kv_dtype!r}")
        L, KV, hd = cfg.n_layers, cfg.n_kv_heads, cfg.hd
        shape = (L, n_slots, max_len, KV, hd)
        arena_dtype = jnp.int8 if kv_dtype == "int8" else cfg.dtype
        # arenas are committed to the placement's KV-head-sharded layout at
        # birth; the jitted steps then update them shard-local in place
        self.k = pl.place_kv(jnp.zeros(shape, arena_dtype))
        self.v = pl.place_kv(jnp.zeros(shape, arena_dtype))
        if kv_dtype == "int8":
            sshape = (L, n_slots, max_len, KV)
            self.k_scale = pl.place_kv_scale(jnp.ones(sshape, jnp.float32))
            self.v_scale = pl.place_kv_scale(jnp.ones(sshape, jnp.float32))
        else:
            self.k_scale = self.v_scale = None
        self.kv_dtype = kv_dtype
        self.pos = pl.place_replicated(jnp.zeros((n_slots,), jnp.int32))
        self.n_slots = n_slots
        self.max_len = max_len
        self._free = list(range(n_slots - 1, -1, -1))   # pop() -> ascending

    # ---------------------------------------------------------------- slots
    @property
    def n_free(self) -> int:
        return len(self._free)

    @property
    def max_request_tokens(self) -> int:
        return self.max_len

    def alloc(self) -> int | None:
        return self._free.pop() if self._free else None

    def release(self, slot: int) -> None:
        if slot in self._free:
            raise DoubleFree(f"release of free slot {slot}")
        self._free.append(slot)

    # kept for existing callers; same semantics as release
    free = release

    def stats(self) -> dict:
        """Occupancy snapshot, shape-compatible with PagedKVPool.stats()
        so benchmarks and the tracer's gauges read one surface.
        ``arena_bytes`` is the full HBM bill — int8 values AND their f32
        scales — so equal-budget comparisons are honest."""
        scale_bytes = arena_nbytes(self.k_scale, self.v_scale)
        return {"layout": "slot", "n_slots": self.n_slots,
                "n_free": self.n_free, "max_len": self.max_len,
                "kv_dtype": self.kv_dtype,
                "arena_bytes": arena_nbytes(self.k, self.v) + scale_bytes,
                "scale_bytes": scale_bytes}

    # ---------------------------------------------------------------- views
    def lane_rows(self, rows: list[int], n_rows_padded: int) -> np.ndarray:
        """Host lane->slot map for a chunk group; padding lanes point past
        the arena (their writes drop, their gathers clamp harmlessly)."""
        out = np.full((n_rows_padded,), self.n_slots, np.int32)
        out[:len(rows)] = rows
        return out

    def chunk_end_check(self, cursor: int, lengths: list[int]) -> None:
        if cursor + max(lengths) > self.max_len:
            raise CapacityError(
                f"prefill of {max(lengths)} tokens at offset {cursor} "
                f"exceeds slot capacity {self.max_len}")

    # ------------------------------------------------------------ lifecycle
    def adopt(self, k, v, k_scale=None, v_scale=None) -> None:
        """Take ownership of a step's output arenas (the jitted step
        donated the previous ones, so this is an in-place handoff).  An
        int8 pool's scale arenas ride the same handoff."""
        self.k = k
        self.v = v
        if k_scale is not None:
            self.k_scale = k_scale
            self.v_scale = v_scale

    def advance_prefill(self, rows: list[int], ends: list[int]) -> None:
        self.pos = self.pos.at[jnp.asarray(rows)].set(
            jnp.asarray(ends, jnp.int32))

    def advance_decode(self, active_mask) -> None:
        """Only rows in ``active_mask`` (this step's decode batch, minus
        retirements) advance their position; everyone else — free slots
        and rows mid-prefill — keeps its previous position, so a prefill
        cursor survives sharing the fused step with decoders.  (The
        batch-wide decode write did land a garbage token at each inactive
        row's position, but the next chunk scatter / next occupant's
        prefill overwrites it before any query can attend there — see the
        module docstring.)"""
        self.pos = jnp.where(jnp.asarray(active_mask), self.pos + 1,
                             self.pos)
