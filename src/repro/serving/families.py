"""Family adapters: one per-request state substrate per model family,
behind one engine-facing surface.

The engine schedules requests; it does not know what a family keeps per
request.  Each adapter owns that answer — the primary pool the scheduler
allocates slots from, any auxiliary arenas, and the jitted step functions
built over the family's ``unified_step`` — behind five hooks:

  ``step_chunk(rows, lanes, cur, n_new, tokens)``
      run one (cursor, bucket) prefill-chunk group, adopt the donated
      output arenas, return the logits [B, S, V];
  ``step_decode(tokens, active)``
      run the fused S=1 decode over every lane, adopt, return
      logits [n_slots, 1, V];
  ``on_admit(req, slot) -> n_restored``
      per-request admission work: restore a swap-preempted request's
      state verbatim (returning how many tokens of its sequence are
      already absorbed, so the engine resumes the cursor there), or run
      the enc-dec encoder at the true input length;
  ``save_for_preempt(req, slot, n_written) -> blob | None``
      what preemption must save to keep the resumed token stream exactly
      the uninterrupted one.  None means "recompute is exact" (softmax
      attention: KV recomputed from tokens is the same numbers) — the
      stateful slot families return a swap blob instead, because a
      recurrent state recomputed under different chunk boundaries differs
      in float summation order;
  ``validate_submit(prompt, sampling, embeds)``
      family-specific admission checks (enc-dec requires encoder embeds
      and bounds them by the context arena).

Per family:

  dense/moe  ``TransformerAdapter`` — Slot/Paged KV pool, the engine's
             original two step functions, verbatim.
  ssm        ``RecurrentAdapter`` — ``RecurrentStatePool`` only: O(1)
             state per request, no KV.  kv_layout is coerced to "slot"
             (there is nothing to page).  Swap preemption.
  hybrid     ``HybridAdapter`` — a ``SlotKVPool``/``PagedKVPool`` sized
             to the shared-attention applications PLUS a
             ``RecurrentStatePool`` for the mamba layers, one slot
             identity across both (``HybridStatePool``), mixed in one
             jitted step via ``HybridPoolView``.  Slot layout swaps
             state+KV on preemption (exact); the paged layout recomputes
             from scratch with the prefix cache disabled — cached KV
             blocks cannot reconstruct SSM state, and the recompute may
             differ from the uninterrupted stream in the last ulp (the
             documented trade for paged memory).
  encdec     ``EncDecAdapter`` — decoder-side ``SlotKVPool`` plus a
             read-only ``EncoderContextPool``; admission runs the encoder
             at the request's TRUE input length (bidirectional encoders
             cannot pad) and installs the projected cross-attention rows.
             Swap preemption (KV rows + context + position).

Every traced function runs under ``policy.suspended()`` for the same
reason the engine's always have (capacity-free MoE routing under bucket
padding), and every family's decode shares float operation order with its
``decode_lockstep`` — the engine-vs-lockstep token-identity property
tests/test_family_engines.py asserts.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from ..models import transformer as tfm
from ..models import whisper as whi
from ..models import xlstm as xls
from ..models import zamba as zam
from ..parallel import policy as pol
from .cache_pool import CachePoolError, SlotKVPool, SlotPoolView
from .observe import NULL_TRACER
from .paged import PagedKVPool, PagedPoolView
from .state_pool import (EncDecPoolView, EncoderContextPool, HybridPoolView,
                         RecurrentStatePool, RecurrentStateView)


def _suspend(fn):
    """Trace ``fn`` under a suspended activation-sharding policy (see the
    engine docstring: an ambient policy would flip MoE onto the
    capacity-bounded expert-parallel path where pad tokens evict real
    ones)."""
    def traced(*args):
        with pol.suspended():
            return fn(*args)
    return traced


def _jit(placement, fn, donate=(), in_shardings=None, out_shardings=None):
    """jit with explicit shardings on a mesh, a plain jit otherwise."""
    if not placement.active:
        return jax.jit(_suspend(fn), donate_argnums=donate)
    return jax.jit(_suspend(fn), donate_argnums=donate,
                   in_shardings=in_shardings, out_shardings=out_shardings)


class FamilyAdapter:
    """Shared no-op hooks; subclasses override what their family needs."""
    cfg = None
    params = None
    pool = None
    kv_layout = "slot"
    # observability: the engine installs its ServingTracer here at
    # construction when tracing is on; the default NULL_TRACER keeps
    # ``_traced`` a direct call with no per-step work (serving/observe.py)
    tracer = NULL_TRACER
    # distinguishes co-resident adapters sharing one tracer: the
    # speculative draft model's adapter sets "draft_" so its jit variants
    # attribute as draft_step/draft_decode, separate from the target's
    trace_kind_prefix = ""

    def _traced(self, kind: str, fn, args: tuple):
        """Run a jitted step function, attributed when tracing is on:
        wall-clock + compile/retrace detection + cost model per shape
        variant (``ServingTracer.jit_call``)."""
        if not self.tracer.enabled:
            return fn(*args)
        return self.tracer.jit_call(self.trace_kind_prefix + kind, fn, args)

    def on_admit(self, req, slot: int) -> int:
        return 0

    def save_for_preempt(self, req, slot: int, n_written: int):
        return None

    def validate_submit(self, prompt, sampling, embeds) -> None:
        if embeds is not None:
            raise ValueError(
                f"family {self.cfg.family!r} takes token prompts only; "
                f"embeds= is for the enc-dec family")


# --------------------------------------------------------------------------
# dense / moe: the engine's original transformer path, verbatim
# --------------------------------------------------------------------------

class TransformerAdapter(FamilyAdapter):
    def __init__(self, cfg, params, placement, psh, *, kv_layout, n_slots,
                 max_len, block_size, n_blocks, prefix_caching,
                 paged_attn_backend, kv_dtype: str = "bf16"):
        self.cfg, self.params, self.kv_layout = cfg, params, kv_layout
        self.kv_dtype = kv_dtype
        quant = kv_dtype == "int8"
        self.quantized = quant
        if kv_layout == "paged":
            self.pool = PagedKVPool(cfg, n_slots, max_len,
                                    block_size=block_size, n_blocks=n_blocks,
                                    prefix_caching=prefix_caching,
                                    placement=placement, kv_dtype=kv_dtype)
        else:
            self.pool = SlotKVPool(cfg, n_slots, max_len, placement=placement,
                                   kv_dtype=kv_dtype)
        sh = placement.step_fn_shardings(psh, kv_layout, kv_dtype)
        # int8 arenas thread two scale tensors right after k/v through both
        # jitted steps, donated alongside (quantize-on-scatter updates them
        # in place); otherwise the signatures are the original ones
        if kv_layout == "paged":
            trash = self.pool.trash_block
            if quant:
                self._step_fn = _jit(
                    placement,
                    lambda p, k, v, ks, vs, bt, cur, nn, t: tfm.unified_step(
                        p, PagedPoolView(k, v, bt, cur, nn, trash, ks, vs),
                        {"tokens": t}, cfg, attn_backend=paged_attn_backend),
                    donate=(1, 2, 3, 4), **sh["step"])
                self._decode_fn = _jit(
                    placement,
                    lambda p, k, v, ks, vs, bt, pos, t: tfm.unified_step(
                        p, PagedPoolView(k, v, bt, pos, jnp.ones_like(pos),
                                         trash, ks, vs),
                        {"tokens": t}, cfg, attn_backend=paged_attn_backend),
                    donate=(1, 2, 3, 4), **sh["decode"])
            else:
                self._step_fn = _jit(
                    placement,
                    lambda p, k, v, bt, cur, nn, t: tfm.unified_step(
                        p, PagedPoolView(k, v, bt, cur, nn, trash),
                        {"tokens": t}, cfg, attn_backend=paged_attn_backend),
                    donate=(1, 2), **sh["step"])
                self._decode_fn = _jit(
                    placement,
                    lambda p, k, v, bt, pos, t: tfm.unified_step(
                        p, PagedPoolView(k, v, bt, pos, jnp.ones_like(pos),
                                         trash),
                        {"tokens": t}, cfg, attn_backend=paged_attn_backend),
                    donate=(1, 2), **sh["decode"])
        elif quant:
            self._step_fn = _jit(
                placement,
                lambda p, k, v, ks, vs, rows, cur, nn, t: tfm.unified_step(
                    p, SlotPoolView(k, v, rows, cur, nn, ks, vs),
                    {"tokens": t}, cfg),
                donate=(1, 2, 3, 4), **sh["step"])
            self._decode_fn = _jit(
                placement,
                lambda p, k, v, ks, vs, pos, t: tfm.unified_step(
                    p, SlotPoolView(k, v, None, pos, jnp.ones_like(pos),
                                    ks, vs),
                    {"tokens": t}, cfg),
                donate=(1, 2, 3, 4), **sh["decode"])
        else:
            self._step_fn = _jit(
                placement,
                lambda p, k, v, rows, cur, nn, t: tfm.unified_step(
                    p, SlotPoolView(k, v, rows, cur, nn), {"tokens": t},
                    cfg),
                donate=(1, 2), **sh["step"])
            self._decode_fn = _jit(
                placement,
                lambda p, k, v, pos, t: tfm.unified_step(
                    p, SlotPoolView(k, v, None, pos, jnp.ones_like(pos)),
                    {"tokens": t}, cfg),
                donate=(1, 2), **sh["decode"])

    def _arena_args(self):
        p = self.pool
        if self.quantized:
            return (p.k, p.v, p.k_scale, p.v_scale)
        return (p.k, p.v)

    def step_chunk(self, rows, lanes, cur, n_new, tokens):
        logits, arenas = self._traced(
            "step", self._step_fn,
            (self.params, *self._arena_args(), lanes, cur, n_new, tokens))
        self.pool.adopt(*arenas)
        return logits

    def step_decode(self, tokens, active):
        if self.kv_layout == "paged":
            logits, arenas = self._traced(
                "decode", self._decode_fn,
                (self.params, *self._arena_args(),
                 self.pool.block_tables, self.pool.pos, tokens))
        else:
            logits, arenas = self._traced(
                "decode", self._decode_fn,
                (self.params, *self._arena_args(), self.pool.pos, tokens))
        self.pool.adopt(*arenas)
        return logits


# --------------------------------------------------------------------------
# ssm (xLSTM): recurrent state slots only — no KV anywhere
# --------------------------------------------------------------------------

class RecurrentAdapter(FamilyAdapter):
    def __init__(self, cfg, params, placement, psh, *, n_slots, max_len):
        self.cfg, self.params = cfg, params
        self.pool = RecurrentStatePool(
            cfg, n_slots, max_len,
            lambda c, n: xls.init_state(c, n), placement=placement)
        rep = placement.replicated
        ssh = placement.state_shardings(self.pool.states)
        self._step_fn = _jit(
            placement,
            lambda p, st, rows, cur, nn, t: xls.unified_step(
                p, RecurrentStateView(st, rows, cur, nn), {"tokens": t}, cfg),
            donate=(1,),
            in_shardings=(psh, ssh, rep, rep, rep, rep),
            out_shardings=(rep, ssh))
        self._decode_fn = _jit(
            placement,
            lambda p, st, pos, act, t: xls.unified_step(
                p, RecurrentStateView(st, None, pos, act), {"tokens": t},
                cfg),
            donate=(1,),
            in_shardings=(psh, ssh, rep, rep, rep),
            out_shardings=(rep, ssh))

    def step_chunk(self, rows, lanes, cur, n_new, tokens):
        logits, states = self._traced(
            "step", self._step_fn,
            (self.params, self.pool.states, lanes, cur, n_new, tokens))
        self.pool.adopt(states)
        return logits

    def step_decode(self, tokens, active):
        # inactive lanes (mid-prefill rows, free slots) decode with
        # n_new=0: their gates are fully masked and their state leaves
        # come back bitwise untouched — unlike KV there is no
        # overwrite-before-read safety net for a recurrence
        act = np.zeros((self.pool.n_slots,), np.int32)
        act[active] = 1
        logits, states = self._traced(
            "decode", self._decode_fn,
            (self.params, self.pool.states, self.pool.pos, jnp.asarray(act),
             tokens))
        self.pool.adopt(states)
        return logits

    def save_for_preempt(self, req, slot, n_written):
        return {"state": self.pool.save_slot(slot), "pos": n_written}

    def on_admit(self, req, slot):
        if req.swap is None:
            return 0
        blob, req.swap = req.swap, None
        self.pool.restore_slot(slot, blob["state"])
        return blob["pos"]


# --------------------------------------------------------------------------
# hybrid (Zamba2): shared-attention KV pool + mamba state slots, one identity
# --------------------------------------------------------------------------

class HybridStatePool:
    """One slot identity across a KV pool (sized to the shared-attention
    applications) and a recurrent-state pool (all mamba layers).

    The engine drives the usual pool protocol; allocation and release hit
    both sub-pools in lockstep (slot layout) so the same id indexes a
    request's KV rows and its state leaves.  Everything else — lane maps,
    capacity checks, positions, the whole paged admission surface —
    forwards to the KV pool, whose per-row position doubles as the state
    cursor (tokens absorbed == tokens written, every layer sees every
    token once).  Under the paged layout rows come from ``admit`` and the
    state arena is simply indexed by row: stale state at a reused row is
    dead weight the in-jit cursor==0 init-select never reads.
    """

    def __init__(self, kv, state, paged: bool):
        self.kv = kv
        self.state = state
        self._paged = paged

    def __getattr__(self, name):
        return getattr(self.kv, name)

    def alloc(self):
        row = self.kv.alloc()
        if row is None:
            return None
        srow = self.state.alloc()
        if srow != row:
            raise CachePoolError(
                f"hybrid sub-pools desynchronized: kv slot {row} vs state "
                f"slot {srow}")
        return row

    def release(self, slot: int) -> None:
        self.kv.release(slot)
        if not self._paged:
            self.state.release(slot)

    free = release


class HybridAdapter(FamilyAdapter):
    def __init__(self, cfg, params, placement, psh, *, kv_layout, n_slots,
                 max_len, block_size, n_blocks, prefix_caching,
                 paged_attn_backend):
        n_attn = cfg.n_layers // cfg.attn_every if cfg.attn_every else 0
        if n_attn == 0:
            raise ValueError(
                "hybrid serving needs at least one shared-attention "
                "application (attn_every > 0); a pure-mamba stack should "
                "use the 'ssm' family path")
        self.cfg, self.params, self.kv_layout = cfg, params, kv_layout
        kv_cfg = dataclasses.replace(cfg, n_layers=n_attn)
        state = RecurrentStatePool(
            cfg, n_slots, max_len,
            lambda c, n: [zam.lane_init(c, i, n) for i in range(c.n_layers)],
            placement=placement)
        if kv_layout == "paged":
            # prefix caching is structurally off: a cached KV block cannot
            # reconstruct the SSM state that absorbed those tokens, so a
            # "hit" would resume with a state that never saw its prefix
            kv = PagedKVPool(kv_cfg, n_slots, max_len, block_size=block_size,
                             n_blocks=n_blocks, prefix_caching=False,
                             placement=placement)
        else:
            kv = SlotKVPool(kv_cfg, n_slots, max_len, placement=placement)
        self.pool = HybridStatePool(kv, state, paged=(kv_layout == "paged"))
        rep = placement.replicated
        kvsh = placement.kv
        ssh = placement.state_shardings(state.states)
        out_sh = (rep, (kvsh, kvsh), ssh)
        if kv_layout == "paged":
            trash = kv.trash_block
            self._step_fn = _jit(
                placement,
                lambda p, k, v, st, bt, srows, cur, nn, t: zam.unified_step(
                    p, HybridPoolView(PagedPoolView(k, v, bt, cur, nn, trash),
                                      RecurrentStateView(st, srows, cur, nn)),
                    {"tokens": t}, cfg, attn_backend=paged_attn_backend),
                donate=(1, 2, 3),
                in_shardings=(psh, kvsh, kvsh, ssh, rep, rep, rep, rep, rep),
                out_shardings=out_sh)
            self._decode_fn = _jit(
                placement,
                lambda p, k, v, st, bt, pos, act, t: zam.unified_step(
                    p, HybridPoolView(
                        PagedPoolView(k, v, bt, pos, jnp.ones_like(pos),
                                      trash),
                        RecurrentStateView(st, None, pos, act)),
                    {"tokens": t}, cfg, attn_backend=paged_attn_backend),
                donate=(1, 2, 3),
                in_shardings=(psh, kvsh, kvsh, ssh, rep, rep, rep, rep),
                out_shardings=out_sh)
        else:
            self._step_fn = _jit(
                placement,
                lambda p, k, v, st, rows, cur, nn, t: zam.unified_step(
                    p, HybridPoolView(SlotPoolView(k, v, rows, cur, nn),
                                      RecurrentStateView(st, rows, cur, nn)),
                    {"tokens": t}, cfg),
                donate=(1, 2, 3),
                in_shardings=(psh, kvsh, kvsh, ssh, rep, rep, rep, rep),
                out_shardings=out_sh)
            self._decode_fn = _jit(
                placement,
                lambda p, k, v, st, pos, act, t: zam.unified_step(
                    p, HybridPoolView(
                        SlotPoolView(k, v, None, pos, jnp.ones_like(pos)),
                        RecurrentStateView(st, None, pos, act)),
                    {"tokens": t}, cfg),
                donate=(1, 2, 3),
                in_shardings=(psh, kvsh, kvsh, ssh, rep, rep, rep),
                out_shardings=out_sh)

    def step_chunk(self, rows, lanes, cur, n_new, tokens):
        kv, st = self.pool.kv, self.pool.state
        if self.kv_layout == "paged":
            srows = jnp.asarray(st.lane_rows(rows, tokens.shape[0]))
            logits, (k, v), states = self._traced(
                "step", self._step_fn,
                (self.params, kv.k, kv.v, st.states, lanes, srows, cur,
                 n_new, tokens))
        else:
            logits, (k, v), states = self._traced(
                "step", self._step_fn,
                (self.params, kv.k, kv.v, st.states, lanes, cur, n_new,
                 tokens))
        kv.adopt(k, v)
        st.adopt(states)
        return logits

    def step_decode(self, tokens, active):
        kv, st = self.pool.kv, self.pool.state
        act = np.zeros((kv.n_slots,), np.int32)
        act[active] = 1
        if self.kv_layout == "paged":
            logits, (k, v), states = self._traced(
                "decode", self._decode_fn,
                (self.params, kv.k, kv.v, st.states, kv.block_tables,
                 kv.pos, jnp.asarray(act), tokens))
        else:
            logits, (k, v), states = self._traced(
                "decode", self._decode_fn,
                (self.params, kv.k, kv.v, st.states, kv.pos,
                 jnp.asarray(act), tokens))
        kv.adopt(k, v)
        st.adopt(states)
        return logits

    def save_for_preempt(self, req, slot, n_written):
        if self.kv_layout == "paged":
            return None                      # recompute (module docstring)
        kv, st = self.pool.kv, self.pool.state
        return {"state": st.save_slot(slot), "k": kv.k[:, slot],
                "v": kv.v[:, slot], "pos": n_written}

    def on_admit(self, req, slot):
        if req.swap is None:
            return 0
        blob, req.swap = req.swap, None
        kv, st = self.pool.kv, self.pool.state
        st.restore_slot(slot, blob["state"])
        kv.adopt(kv.k.at[:, slot].set(blob["k"].astype(kv.k.dtype)),
                 kv.v.at[:, slot].set(blob["v"].astype(kv.v.dtype)))
        return blob["pos"]


# --------------------------------------------------------------------------
# encdec (Whisper): decoder KV slots + read-only encoder context rows
# --------------------------------------------------------------------------

class EncDecAdapter(FamilyAdapter):
    def __init__(self, cfg, params, placement, psh, *, n_slots, max_len,
                 max_ctx):
        self.cfg, self.params = cfg, params
        self.pool = SlotKVPool(cfg, n_slots, max_len, placement=placement)
        self.ctx = EncoderContextPool(cfg, n_slots, max_ctx,
                                      placement=placement)
        rep, kvsh = placement.replicated, placement.kv
        # retraced once per distinct encoder length — padding is not an
        # option for a bidirectional encoder (every position attends to
        # every other), so admission runs at the TRUE length
        self._encode_fn = _jit(
            placement, lambda p, e: whi.encode_ctx(p, e, cfg),
            in_shardings=(psh, rep), out_shardings=(kvsh, kvsh))
        # ck/cv ride through WITHOUT donation: the context rows are read-
        # only for a request's whole lifetime and shared across steps
        self._step_fn = _jit(
            placement,
            lambda p, k, v, ck, cv, cl, rows, cur, nn, t: whi.unified_step(
                p, EncDecPoolView(k=k, v=v, rows=rows, cursor=cur, n_new=nn,
                                  ck=ck, cv=cv, ctx_len=cl),
                {"tokens": t}, cfg),
            donate=(1, 2),
            in_shardings=(psh, kvsh, kvsh, kvsh, kvsh, rep, rep, rep, rep,
                          rep),
            out_shardings=(rep, (kvsh, kvsh)))
        self._decode_fn = _jit(
            placement,
            lambda p, k, v, ck, cv, cl, pos, t: whi.unified_step(
                p, EncDecPoolView(k=k, v=v, rows=None, cursor=pos,
                                  n_new=jnp.ones_like(pos), ck=ck, cv=cv,
                                  ctx_len=cl),
                {"tokens": t}, cfg),
            donate=(1, 2),
            in_shardings=(psh, kvsh, kvsh, kvsh, kvsh, rep, rep, rep),
            out_shardings=(rep, (kvsh, kvsh)))

    def step_chunk(self, rows, lanes, cur, n_new, tokens):
        pool, ctx = self.pool, self.ctx
        clen = jnp.asarray(ctx.lane_lens(rows, tokens.shape[0]))
        logits, (k, v) = self._traced(
            "step", self._step_fn,
            (self.params, pool.k, pool.v, ctx.ck, ctx.cv, clen, lanes, cur,
             n_new, tokens))
        pool.adopt(k, v)
        return logits

    def step_decode(self, tokens, active):
        pool, ctx = self.pool, self.ctx
        logits, (k, v) = self._traced(
            "decode", self._decode_fn,
            (self.params, pool.k, pool.v, ctx.ck, ctx.cv,
             jnp.asarray(ctx.lens), pool.pos, tokens))
        pool.adopt(k, v)
        return logits

    def validate_submit(self, prompt, sampling, embeds):
        if embeds is None:
            raise ValueError(
                "the enc-dec family needs embeds= at submit: the encoder "
                "frontend's [S_enc, d] features, run once at admission")
        n = np.asarray(embeds).shape[0]
        if n > self.ctx.max_ctx:
            raise ValueError(
                f"encoder input of {n} frames exceeds the context arena "
                f"(max_ctx={self.ctx.max_ctx})")

    def on_admit(self, req, slot):
        if req.swap is not None:
            blob, req.swap = req.swap, None
            self.ctx.restore_slot(slot, blob["ctx"])
            pool = self.pool
            pool.adopt(pool.k.at[:, slot].set(blob["k"].astype(pool.k.dtype)),
                       pool.v.at[:, slot].set(blob["v"].astype(pool.v.dtype)))
            return blob["pos"]
        emb = jnp.asarray(req.embeds, self.cfg.dtype)[None]    # [1, Se, d]
        ck, cv = self._traced("encode", self._encode_fn, (self.params, emb))
        self.ctx.write(slot, ck[:, 0], cv[:, 0])
        return 0

    def save_for_preempt(self, req, slot, n_written):
        pool = self.pool
        return {"ctx": self.ctx.save_slot(slot), "k": pool.k[:, slot],
                "v": pool.v[:, slot], "pos": n_written}


# --------------------------------------------------------------------------

def build_adapter(cfg, params, placement, psh, *, kv_layout, n_slots,
                  max_len, block_size, n_blocks, prefix_caching,
                  paged_attn_backend, max_ctx=None, kv_dtype="bf16"):
    """The family's adapter, with its effective kv_layout resolved.

    ssm has no KV at all, so any requested layout coerces to "slot" (a
    layout over nothing); encdec pages neither its decoder slots nor its
    read-only context rows and rejects "paged" explicitly.  Quantized KV
    (``kv_dtype="int8"``) exists only for the pure KV-transformer
    families — recurrent/hybrid/encdec state blobs stay at model dtype.
    """
    fam = cfg.family
    if kv_dtype != "bf16" and fam not in ("dense", "moe"):
        raise ValueError(
            f"kv_dtype={kv_dtype!r} needs a KV-transformer family "
            f"(dense/moe), not {fam!r}")
    if fam in ("dense", "moe"):
        return TransformerAdapter(
            cfg, params, placement, psh, kv_layout=kv_layout,
            n_slots=n_slots, max_len=max_len, block_size=block_size,
            n_blocks=n_blocks, prefix_caching=prefix_caching,
            paged_attn_backend=paged_attn_backend, kv_dtype=kv_dtype)
    if fam == "ssm":
        return RecurrentAdapter(cfg, params, placement, psh,
                                n_slots=n_slots, max_len=max_len)
    if fam == "hybrid":
        return HybridAdapter(
            cfg, params, placement, psh, kv_layout=kv_layout,
            n_slots=n_slots, max_len=max_len, block_size=block_size,
            n_blocks=n_blocks, prefix_caching=prefix_caching,
            paged_attn_backend=paged_attn_backend)
    if fam == "encdec":
        if kv_layout == "paged":
            raise ValueError(
                "the enc-dec family has no paged layout: decoder KV is "
                "slot-resident and the encoder context rows are read-only")
        return EncDecAdapter(cfg, params, placement, psh, n_slots=n_slots,
                             max_len=max_len,
                             max_ctx=max_ctx if max_ctx is not None
                             else max_len)
    raise ValueError(f"no serving adapter for family {fam!r}")
