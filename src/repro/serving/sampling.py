"""Vectorized per-slot token sampling.

One jitted function over the whole slot batch: greedy rows (temperature<=0)
take argmax — bit-identical to the one-shot serve loop — while stochastic
rows apply temperature + optional top-k restriction and draw categorically.
Each row's PRNG key is derived in-graph from its request seed and token
index (fold_in), so the host only ships small int/float vectors per step.
Inactive slots ride along (their outputs are discarded by the engine),
keeping shapes static so nothing retraces.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _sample_row(logits, temperature, top_k, seed, step):
    """logits [V]; returns a sampled token id (scalar int32)."""
    greedy = jnp.argmax(logits).astype(jnp.int32)
    lf = logits.astype(jnp.float32) / jnp.maximum(temperature, 1e-6)
    # top-k: drop everything below the k-th largest logit (k==0 keeps all)
    v = logits.shape[-1]
    kth_idx = jnp.clip(top_k - 1, 0, v - 1)
    kth_val = jnp.sort(lf)[::-1][kth_idx]
    restricted = jnp.where((top_k > 0) & (lf < kth_val), -jnp.inf, lf)
    key = jax.random.fold_in(jax.random.PRNGKey(seed), step)
    drawn = jax.random.categorical(key, restricted).astype(jnp.int32)
    return jnp.where(temperature <= 0.0, greedy, drawn)


@jax.jit
def sample_tokens(logits, temperatures, top_ks, seeds, steps):
    """logits [B, V]; per-row temperature/top_k/seed/token-index -> [B]."""
    return jax.vmap(_sample_row)(logits, temperatures, top_ks, seeds, steps)
