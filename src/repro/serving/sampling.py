"""Vectorized per-slot token sampling and draft verification.

One jitted function over the whole slot batch: greedy rows (temperature<=0)
take argmax — bit-identical to the one-shot serve loop — while stochastic
rows apply temperature + optional top-k restriction and draw categorically.
Each row's PRNG key is derived in-graph from its request seed and token
index (fold_in), so the host only ships small int/float vectors per step.
Inactive slots ride along (their outputs are discarded by the engine),
keeping shapes static so nothing retraces.

``sample_tokens_logprobs`` additionally returns each row's chosen-token
log-probability under the UNMODIFIED model distribution (log-softmax of
the raw logits, temperature-independent — the number APIs report as the
token logprob), so streaming consumers get per-token confidence for free.

``verify_draft`` is the speculative-decoding acceptance rule over a fused
verify step's logits (serving/speculative.py): **leave-one-in rejection
sampling**.  Position j of a row proposes draft token d_j against the
target distribution p_j (temperature/top-k adjusted, exactly the
distribution ``sample_tokens`` draws from):

  greedy rows      accept while argmax(p_j) == d_j; the emitted token at
                   every position IS the argmax, so the accepted prefix
                   plus the first correction is token-identical to
                   sequential greedy decode;
  stochastic rows  accept d_j with probability p_j(d_j) (u < p_j(d_j),
                   u ~ U[0,1) keyed by (seed, token index)); a rejected
                   position leaves the draft token OUT and resamples from
                   p_j renormalized without it — which preserves the
                   target distribution for any deterministic proposer
                   (accept keeps the draft "in", reject removes exactly
                   the mass the acceptance branch already spent).

The position AFTER the last draft (the bonus position) always samples
from the full target distribution, so every verify step emits at least
one token.  Stochastic verification consumes randomness differently from
sequential decode (one acceptance draw + possible resample per position
vs one draw per token), so only GREEDY speculative streams are
token-identical to non-speculative decode — the tested contract.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _restricted_logits(logits, temperature, top_k):
    """Temperature + top-k adjusted logits ([..., V] f32): the
    distribution stochastic sampling draws from (k==0 keeps all)."""
    lf = logits.astype(jnp.float32) / jnp.maximum(temperature, 1e-6)
    v = lf.shape[-1]
    kth_idx = jnp.clip(top_k - 1, 0, v - 1)
    kth_val = jnp.sort(lf, axis=-1)[..., ::-1][..., kth_idx]
    return jnp.where((top_k > 0) & (lf < kth_val[..., None]), -jnp.inf, lf)


def _sample_row(logits, temperature, top_k, seed, step):
    """logits [V]; returns a sampled token id (scalar int32)."""
    greedy = jnp.argmax(logits).astype(jnp.int32)
    restricted = _restricted_logits(logits, temperature, top_k)
    key = jax.random.fold_in(jax.random.PRNGKey(seed), step)
    drawn = jax.random.categorical(key, restricted).astype(jnp.int32)
    return jnp.where(temperature <= 0.0, greedy, drawn)


@jax.jit
def sample_tokens(logits, temperatures, top_ks, seeds, steps):
    """logits [B, V]; per-row temperature/top_k/seed/token-index -> [B]."""
    return jax.vmap(_sample_row)(logits, temperatures, top_ks, seeds, steps)


@jax.jit
def sample_tokens_logprobs(logits, temperatures, top_ks, seeds, steps):
    """Like ``sample_tokens`` but also returns each chosen token's
    log-probability under log-softmax of the raw logits ([B], [B])."""
    toks = jax.vmap(_sample_row)(logits, temperatures, top_ks, seeds, steps)
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    chosen = jnp.take_along_axis(logp, toks[:, None], axis=-1)[:, 0]
    return toks, chosen


def _verify_row(logits, draft, n_draft, temperature, top_k, seed, step0):
    """One row of the fused verify step (see module docstring).

    logits [S, V]: target logits, position j conditioned on the row's
    history plus draft tokens d_1..d_j; draft [S]: d_1..d_{n_draft} left-
    aligned (the rest padding); ``step0`` the token index of the first
    candidate (continues the request's (seed, index) sampling stream).

    Returns (n_accept, tokens [S], logprobs [S]): tokens[:n_accept] are
    the accepted draft tokens, tokens[n_accept] the correction (rejected
    position, leave-one-in resample) or bonus (all accepted) token — the
    engine emits tokens[:n_accept + 1].  Positions past the cut are
    computed but never read.
    """
    S, V = logits.shape
    idx = jnp.arange(S)
    lf32 = logits.astype(jnp.float32)
    greedy_tok = jnp.argmax(lf32, axis=-1).astype(jnp.int32)

    restricted = _restricted_logits(lf32, temperature, top_k)
    logp = jax.nn.log_softmax(restricted, axis=-1)
    keys = jax.vmap(
        lambda i: jax.random.fold_in(jax.random.PRNGKey(seed), step0 + i))(idx)
    u = jax.vmap(lambda k: jax.random.uniform(jax.random.fold_in(k, 0)))(keys)
    p_draft = jnp.exp(jnp.take_along_axis(logp, draft[:, None], axis=-1)[:, 0])
    accept_stoch = u < p_draft
    # leave-one-in: an accepted position keeps the draft token; a rejected
    # one resamples with the draft token's mass removed (renormalized by
    # the softmax), preserving the target distribution overall
    without_draft = jnp.where(jnp.arange(V)[None, :] == draft[:, None],
                              -jnp.inf, logp)
    resampled = jax.vmap(
        lambda k, lp: jax.random.categorical(jax.random.fold_in(k, 1), lp))(
            keys, without_draft).astype(jnp.int32)
    bonus = jax.vmap(
        lambda k, lp: jax.random.categorical(jax.random.fold_in(k, 1), lp))(
            keys, logp).astype(jnp.int32)

    is_draft_pos = idx < n_draft
    stoch_tok = jnp.where(is_draft_pos,
                          jnp.where(accept_stoch, draft, resampled), bonus)
    tok = jnp.where(temperature <= 0.0, greedy_tok, stoch_tok)
    accept = is_draft_pos & jnp.where(temperature <= 0.0,
                                      greedy_tok == draft, accept_stoch)
    n_accept = jnp.sum(jnp.cumprod(accept.astype(jnp.int32)))
    chosen = jnp.take_along_axis(jax.nn.log_softmax(lf32, axis=-1),
                                 tok[:, None], axis=-1)[:, 0]
    return n_accept.astype(jnp.int32), tok, chosen


@jax.jit
def verify_draft(logits, draft, n_draft, temperatures, top_ks, seeds, steps):
    """Batched leave-one-in draft verification.

    logits [B, S, V] (f32), draft [B, S], n_draft [B] (real drafts per
    row; the rest of each row is padding), per-row sampling params, and
    steps [B] = each row's generated-token count (the sampling-stream
    index of its first candidate).  Returns (n_accept [B], tokens [B, S],
    logprobs [B, S]); row b emits tokens[b, :n_accept[b] + 1].
    """
    return jax.vmap(_verify_row)(logits, draft, n_draft, temperatures,
                                 top_ks, seeds, steps)
