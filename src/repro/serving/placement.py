"""Serving placement: ONE layer that decides where every serving buffer lives.

``ServingPlacement`` owns the mapping from serving-side pytrees — model
params (dense arrays and ``SparseWeight`` compressed containers alike),
both KV layouts' device arenas, logits, and the small host-shipped vectors
(tokens, positions, block tables) — to ``NamedSharding``s on a caller-
supplied ``("data", "model")`` mesh.  The engine builds its jitted
prefill/prefix-prefill/decode/decode-paged functions against these
shardings; the pools allocate their arenas through them.  With no mesh
(the default) every hook is an identity/None and the engine behaves
exactly as the single-device path always has.

Placement policy — deliberately different from the training rules in
``parallel/sharding.py``:

  * **Out-dim ("model") tensor parallelism only.**  Projection weights
    shard their output rows; contraction (input) dims stay whole on every
    device.  A split contraction turns one dot product into partial sums
    combined by an all-reduce, whose different summation order perturbs
    logits in the last ulp — out-dim sharding keeps every output element
    the same full-length dot product the single-device engine computes,
    which is what makes sharded token streams match the unsharded engine
    exactly (the tentpole parity property, asserted in
    tests/test_mesh_serving.py).
  * **SparseWeight containers shard as one unit.**  ``nm_values`` /
    ``nm_meta`` / ``o_values`` / ``o_meta`` / ``v_scale`` co-shard along
    the out (row) dim via ``parallel.sharding.sparse_weight_specs`` — the
    compressed bytes (1.30 B/elem for 8:16 + 16:256 outliers) are what
    lands in each shard's HBM.  In-dim sharding is only ever legal on
    N:M-block / 256-wide outlier-group boundaries and the serving policy
    doesn't use it at all (see above).
  * **KV arenas shard the KV-head dim over "model"** — the slot pool's
    ``[L, slots, max_len, KV, hd]`` buffers and the paged
    ``[L, n_blocks, block_size, KV, hd]`` arena use the same spec, so
    decode attention is head-local on every shard.  Block tables, the
    prefix cache, free lists, and refcounts stay host-side numpy —
    placement-agnostic scheduling state, never sharded.
  * **Draft params ride the same placement.**  Speculative decoding's
    drafter (serving/speculative.py) calls ``param_shardings`` on its own
    (smaller) parameter pytree — the rules here are name/shape-generic,
    so the draft model co-resides with the target under the identical
    out-dim policy and the fused verify stays parity-exact on a mesh.
  * **The activation-sharding policy (parallel/policy.py) is NOT
    activated.**  Beyond being unnecessary (GSPMD propagates the weight
    shardings), an active policy flips MoE onto the capacity-bounded
    expert-parallel path where prefill bucket padding can evict real
    tokens; the engine's traced functions run under ``policy.suspended()``
    to keep the exact capacity-free routing on every mesh.
"""
from __future__ import annotations

import re

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# out-dim-sharded projections ([*, out, in] layout) and embeddings/head
_PROJ = re.compile(r"wq|wk|wv|wo|w_gate|w_up|w_down|ws_gate|ws_up|ws_down|"
                   r"in_proj|out_proj|w_q|w_k|w_v|c_wq|c_wk|c_wv|c_wo")
_EMBED = re.compile(r"embed|lm_head")
_EXPERT = re.compile(r"we_(gate|up|down)")       # [L, E, in, out] layout


class ServingPlacement:
    """Placement decisions for one engine instance.

    ``mesh=None`` (default) disables placement entirely: ``active`` is
    False, every ``place_*`` hook returns its input unchanged, and every
    sharding accessor returns ``None`` — the engine then builds plain
    single-device jits, preserving the pre-mesh behavior bit for bit.
    """

    def __init__(self, mesh: Mesh | None = None, cfg=None):
        if mesh is not None:
            if "model" not in mesh.axis_names:
                raise ValueError(f"serving mesh needs a 'model' axis, got "
                                 f"{mesh.axis_names}")
            extra = {a: int(s) for a, s in mesh.shape.items()
                     if a != "model" and int(s) > 1}
            if extra:
                # only model-axis TP is placed today; >1 on any other axis
                # would run fully redundant replicas and silently skew
                # per-device throughput accounting (data-axis serving
                # parallelism is a ROADMAP open item)
                raise ValueError(
                    f"serving placement shards over 'model' only; non-model "
                    f"mesh axes must be size 1, got {extra}")
            if cfg is None:
                raise ValueError("a mesh placement needs the model cfg "
                                 "(KV-head divisibility)")
        self.mesh = mesh
        self.cfg = cfg

    @property
    def active(self) -> bool:
        return self.mesh is not None

    # ------------------------------------------------------------- shardings
    @property
    def replicated(self) -> NamedSharding | None:
        """For host-shipped vectors: tokens, positions, block tables,
        sampling logits — every device sees the whole (small) array."""
        if not self.active:
            return None
        return NamedSharding(self.mesh, P())

    @property
    def kv(self) -> NamedSharding | None:
        """One spec for every ``[L, X, tokens, KV, hd]`` KV buffer — the
        slot pool (X=slots), the paged arena (X=blocks), and prefill /
        prefix-gather outputs (X=batch).  Heads over "model" when they
        divide; a GQA model with fewer KV heads than the axis replicates
        (correct, just not distributed — flash-decoding-style sequence
        sharding is the roadmap item for that regime)."""
        if not self.active:
            return None
        axes = "model" if self.cfg.n_kv_heads % self.mesh.shape["model"] == 0 \
            else None
        return NamedSharding(self.mesh, P(None, None, None, axes, None))

    @property
    def kv_scale(self) -> NamedSharding | None:
        """One spec for every ``[L, X, tokens, KV]`` int8-arena scale
        tensor: co-sharded with the arena's KV-head dim (same axis rule as
        ``kv``, one fewer trailing dim), so quantize-on-scatter and the
        in-kernel dequant both stay shard-local."""
        if not self.active:
            return None
        axes = "model" if self.cfg.n_kv_heads % self.mesh.shape["model"] == 0 \
            else None
        return NamedSharding(self.mesh, P(None, None, None, axes))

    def state_spec(self, shape) -> P:
        """Spec for one recurrent-state arena leaf ``[slots, H, ...]``.

        Same parity discipline as the projections: only dims that are pure
        OUTPUTS of the recurrence may shard, so no dot product is ever
        split into partial sums.

          * dim 1 (heads) over "model" when divisible — head-local
            recurrences (mLSTM memory, SSM state, sLSTM carries) never
            contract over heads, so this is always parity-safe;
          * else, for ndim >= 4 leaves (matrix state ``[slots, H, dk,
            dv]``), the LAST dim (dv): it's the value/output dim of the
            k v^T outer product and the y = q^T C readout — never
            contracted — while dk IS contracted by the normalizer/readout
            and must stay whole;
          * else replicate.  A 2/3-D leaf's trailing dims (dk, dh) all
            feed contractions (normalizer dot, sLSTM recurrent mix), and a
            split contraction's all-reduce perturbs the last ulp — the
            token-identity property is worth more than sharding a small
            vector state."""
        model_n = self.mesh.shape["model"]
        nd = len(shape)
        axes = [None] * nd
        if nd >= 2 and shape[1] % model_n == 0:
            axes[1] = "model"
        elif nd >= 4 and shape[-1] % model_n == 0:
            axes[-1] = "model"
        return P(*axes)

    def state_shardings(self, states):
        """NamedSharding pytree mirroring a recurrent-state arenas list
        (None when no mesh)."""
        if not self.active:
            return None
        return jax.tree.map(
            lambda leaf: NamedSharding(self.mesh, self.state_spec(leaf.shape)),
            states)

    def _dense_spec(self, name: str, shape) -> P:
        model_n = self.mesh.shape["model"]
        nd = len(shape)
        leaf = name.lower().rsplit("/", 1)[-1]

        def over_model(dim_idx):
            axes = [None] * nd
            if shape[dim_idx] % model_n == 0:
                axes[dim_idx] = "model"
            return P(*axes)

        if nd >= 2 and _EMBED.search(leaf):
            return over_model(0)                 # [vocab, d]: rows of vocab
        if _EXPERT.search(leaf):
            return over_model(nd - 1)            # [L, E, in, out]: out last
        if nd >= 2 and _PROJ.search(leaf):
            return over_model(nd - 2)            # [*, out, in]: out rows
        return P(*([None] * nd))                 # norms / router / scalars

    def param_shardings(self, params):
        """Serving-policy NamedSharding pytree mirroring ``params``,
        SparseWeight containers included (None when no mesh)."""
        if not self.active:
            return None
        from ..models.sparse_serving import SparseWeight
        from ..parallel.sharding import sparse_weight_shardings

        def one(path, leaf):
            if isinstance(leaf, SparseWeight):
                return sparse_weight_shardings(self.mesh, leaf, serving=True)
            name = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                            for k in path)
            return NamedSharding(self.mesh, self._dense_spec(name, leaf.shape))
        return jax.tree_util.tree_map_with_path(
            one, params, is_leaf=lambda x: isinstance(x, SparseWeight))

    def step_fn_shardings(self, param_shardings,
                          kv_layout: str = "slot",
                          kv_dtype: str = "bf16") -> dict:
        """Explicit in/out shardings for the TWO jitted step functions of
        the unified attend-over-pool engine, keyed by role:

          "step"    chunk-or-prefill:
                    (params, k, v, lanes, cursor, n_new, tokens)
                    -> (logits, (k, v)).  ``lanes`` is the lane->slot row
                    map (slot layout) or the per-lane block tables (paged)
                    — host-shipped scheduling vectors, replicated.  The
                    arenas ride in donated and come back on the same
                    KV-head-sharded spec, so in-place writes AND the
                    in-place attention reads stay shard-local and the
                    1x8 mesh path remains token-identical to
                    single-device.
          "decode"  fused decode over every lane:
                    slot  (params, k, v, pos, tokens)
                    paged (params, k, v, block_tables, pos, tokens)
                    -> (logits, (k, v)) — donated arenas stay in place
                    shard-for-shard.

        With ``kv_dtype="int8"`` both functions take the two scale arenas
        right after k/v — (params, k, v, k_scale, v_scale, ...) ->
        (logits, (k, v, k_scale, v_scale)) — co-sharded on the KV-head
        dim via ``kv_scale``.

        With no mesh every entry is empty: the engine then builds plain
        single-device jits.
        """
        if not self.active:
            return {k: {} for k in ("step", "decode")}
        psh, rep, kv = param_shardings, self.replicated, self.kv
        if kv_dtype == "int8":
            ksc = self.kv_scale
            out = (rep, (kv, kv, ksc, ksc))
            decode_in = (psh, kv, kv, ksc, ksc, rep, rep, rep) \
                if kv_layout == "paged" else (psh, kv, kv, ksc, ksc, rep, rep)
            step_in = (psh, kv, kv, ksc, ksc, rep, rep, rep, rep)
        else:
            out = (rep, (kv, kv))
            decode_in = (psh, kv, kv, rep, rep, rep) if kv_layout == "paged" \
                else (psh, kv, kv, rep, rep)
            step_in = (psh, kv, kv, rep, rep, rep, rep)
        return {
            "step": dict(in_shardings=step_in, out_shardings=out),
            "decode": dict(in_shardings=decode_in, out_shardings=out),
        }

    # ------------------------------------------------------------ placement
    def place_params(self, params):
        """Commit the (possibly compressed) param pytree to the mesh."""
        if not self.active:
            return params
        return jax.device_put(params, self.param_shardings(params))

    def place_kv(self, arr):
        """Commit a KV arena/pool buffer to its head-sharded layout."""
        if not self.active:
            return arr
        return jax.device_put(arr, self.kv)

    def place_kv_scale(self, arr):
        """Commit an int8 arena's scale tensor next to its arena shards."""
        if not self.active:
            return arr
        return jax.device_put(arr, self.kv_scale)

    def place_replicated(self, arr):
        if not self.active:
            return arr
        return jax.device_put(arr, self.replicated)

    def place_states(self, states):
        """Commit recurrent-state arenas to their parity-safe layout."""
        if not self.active:
            return states
        return jax.device_put(states, self.state_shardings(states))

    # ------------------------------------------------------------- metadata
    def describe(self) -> dict:
        """Benchmark/metrics-facing summary (BENCH_serving.json schema)."""
        if not self.active:
            return {"devices": 1, "mesh": None}
        return {"devices": int(self.mesh.devices.size),
                "mesh": {k: int(v) for k, v in self.mesh.shape.items()}}
