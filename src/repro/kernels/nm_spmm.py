"""Pallas TPU kernel: N:M-compressed weight x dense activation matmul.

TPU adaptation of GPU 2:4 sparse tensor cores (see DESIGN.md §3): the MXU has
no sparse mode, so the win is HBM *bandwidth* — weights stream compressed
(values at N/M density + 4-bit packed indices) and are decompressed inside
VMEM by the VPU just before hitting the MXU.

Layout (produced by core/packing.py):
  values : [out, in * n/m]   kept values, row-major by block
  meta   : [out, in/m] int32 per block: n indices packed 4 bits each (m<=16)

Grid: (b_tiles, out_tiles, k_tiles), k innermost; the f32 output tile
accumulates across k.  Decompression per k-tile:

  idx[o, c, k]  = (meta[o, c] >> 4k) & 0xF              # unpack
  w[o, c*m + j] = sum_k values[o, c, k] * (idx==j)      # compare-select, VPU
  y[b, o]      += x[b, :] @ w[o, :]^T                   # MXU

VPU decompress cost is n ops/weight vs 2*B_tile MXU flops/weight, so for
B_tile >= 8 the decompress is not the bottleneck; the kernel exists to halve
weight bytes from HBM.  VMEM per step (defaults bB=bO=128, bK=512, bf16):
x 128K + vals 64K + meta 4K + w_tile 512K + cmp scratch ~2M + acc 64K << 16M.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _decompress_tile(values, meta, n: int, m: int, out_dtype):
    """values [bO, bK//m * n], meta [bO, bK//m] int32 -> dense [bO, bK]."""
    bo, nc = meta.shape
    vals = values.reshape(bo, nc, n).astype(jnp.float32)
    shifts = 4 * jax.lax.iota(jnp.int32, n)                    # [n]
    idx = (meta[:, :, None] >> shifts[None, None, :]) & 0xF    # [bO, nc, n]
    j = jax.lax.iota(jnp.int32, m)                             # [m]
    onehot = (idx[:, :, :, None] == j[None, None, None, :])    # [bO, nc, n, m]
    dense = jnp.sum(jnp.where(onehot, vals[:, :, :, None], 0.0), axis=2)
    return dense.reshape(bo, nc * m).astype(out_dtype)


def _kernel(x_ref, v_ref, meta_ref, o_ref, acc_ref, *, n, m, n_k):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    w = _decompress_tile(v_ref[...], meta_ref[...], n, m, jnp.float32)
    x = x_ref[...].astype(jnp.float32)
    acc_ref[...] += jax.lax.dot_general(
        x, w, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(k == n_k - 1)
    def _done():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def _kernel_int8(x_ref, v_ref, meta_ref, s_ref, o_ref, acc_ref, *, n, m, n_k):
    """int8 variant: values stream compressed AND quantized; the per-out-row
    f32 scale dequantizes the decompressed tile in-register on the VPU —
    no bf16 weight copy ever touches HBM."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    w = _decompress_tile(v_ref[...], meta_ref[...], n, m, jnp.float32)
    w = w * s_ref[...]                                 # [bO, bK] * [bO, 1]
    x = x_ref[...].astype(jnp.float32)
    acc_ref[...] += jax.lax.dot_general(
        x, w, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(k == n_k - 1)
    def _done():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("n", "m", "block_b", "block_o",
                                             "block_k", "interpret"))
def nm_spmm(x: jax.Array, values: jax.Array, meta: jax.Array, *,
            n: int, m: int, scale: jax.Array | None = None,
            block_b: int = 128, block_o: int = 128,
            block_k: int = 512, interpret: bool = True) -> jax.Array:
    """y[b, out] = x[b, in] @ decompress(values, meta)^T.

    x: [batch, in]; values: [out, in*n//m]; meta: [out, in//m] int32.
    ``scale`` [out] f32 dequantizes int8 values in-register after the
    decompress (per-out-row symmetric quantization); None for bf16 values.
    Requires batch % block_b == in % block_k == out % block_o == 0 after
    clamping (tiles are clamped to the array sizes for small shapes).
    """
    b, kdim = x.shape
    out = values.shape[0]
    assert kdim % m == 0 and values.shape[1] == kdim // m * n
    assert meta.shape == (out, kdim // m)

    bb = min(block_b, b)
    bo = min(block_o, out)
    bk = min(block_k, kdim)
    assert b % bb == 0 and out % bo == 0 and kdim % bk == 0 and bk % m == 0
    n_k = kdim // bk

    grid = (b // bb, out // bo, n_k)
    in_specs = [
        pl.BlockSpec((bb, bk), lambda i, j, k: (i, k)),
        pl.BlockSpec((bo, bk // m * n), lambda i, j, k: (j, k)),
        pl.BlockSpec((bo, bk // m), lambda i, j, k: (j, k)),
    ]
    operands = [x, values, meta]
    if scale is None:
        kernel = functools.partial(_kernel, n=n, m=m, n_k=n_k)
    else:
        assert scale.shape == (out,)
        kernel = functools.partial(_kernel_int8, n=n, m=m, n_k=n_k)
        in_specs.append(pl.BlockSpec((bo, 1), lambda i, j, k: (j, 0)))
        operands.append(scale.astype(jnp.float32).reshape(out, 1))
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bb, bo), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((b, out), x.dtype),
        scratch_shapes=[pltpu.VMEM((bb, bo), jnp.float32)],
        interpret=interpret,
    )(*operands)
