"""Pure-jnp oracles for the sparse kernels.

These are the ground truth the Pallas kernels are validated against
(tests/test_kernels_*.py sweep shapes & dtypes with assert_allclose), and the
portable fallback used on backends without Pallas support.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def decompress_nm(values: jax.Array, indices: jax.Array, m: int,
                  dtype=None) -> jax.Array:
    """[out, nb*n], [out, nb, n] int32 -> dense [out, nb*m].

    Position semantics: ``indices[o, b, k]`` is the column offset inside block
    ``b`` (0..m-1) of value ``values[o, b*n + k]``.
    """
    out, nb, n = indices.shape
    vals = values.reshape(out, nb, n).astype(dtype or values.dtype)
    onehot = jax.nn.one_hot(indices, m, dtype=vals.dtype)       # [out, nb, n, m]
    dense = jnp.einsum("obn,obnm->obm", vals, onehot)
    return dense.reshape(out, nb * m)


def nm_spmm_ref(x: jax.Array, values: jax.Array, indices: jax.Array,
                m: int) -> jax.Array:
    """y = x @ W^T with W the N:M-compressed matrix. x: [b, in]."""
    w = decompress_nm(values, indices, m, dtype=jnp.float32)
    return (x.astype(jnp.float32) @ w.T).astype(x.dtype)


def outlier_spmm_ref(x: jax.Array, values: jax.Array, indices: jax.Array,
                     m: int = 256) -> jax.Array:
    """y = x @ O^T with O the N:256 structured outlier matrix.

    values/indices: [out, in//m, n].
    """
    out, nb, n = values.shape
    w = decompress_nm(values.reshape(out, nb * n), indices, m, dtype=jnp.float32)
    return (x.astype(jnp.float32) @ w.T).astype(x.dtype)


def fused_sparse_linear_ref(x: jax.Array,
                            nm_values: jax.Array, nm_indices: jax.Array, nm_m: int,
                            o_values: jax.Array | None, o_indices: jax.Array | None,
                            o_m: int = 256) -> jax.Array:
    """y = x @ (W_nm + O)^T — the production path.

    By construction (core/pipeline.py) W_nm holds exact zeros at salient
    positions, so plain addition never double-counts.
    """
    w = decompress_nm(nm_values, nm_indices, nm_m, dtype=jnp.float32)
    if o_values is not None:
        out, nb, n = o_values.shape
        w = w + decompress_nm(o_values.reshape(out, nb * n), o_indices, o_m,
                              dtype=jnp.float32)
    return (x.astype(jnp.float32) @ w.T).astype(x.dtype)
