"""Pallas TPU kernel: fused N:M + structured-outlier linear — the production
serving path.

y = x @ (W_nm + O)^T in ONE pass: both compressed streams are decompressed
into the same VMEM tile and hit the MXU once, so x is read once and y written
once (vs 2x for nm_spmm + outlier_spmm).  W_nm carries exact zeros at salient
slots (core/pipeline.py), so the sum is exact.

HBM bytes per weight tile (bf16, 8:16 + 16:256):
  dense:              2.000 B/elem
  fused compressed:   0.5*2 (values) + 4b idx/16-block (0.25)
                      + 0.0625*2 (outlier vals) + 0.0625 (outlier meta 8b)
                    = 1.4375 B/elem -> 1.39x weight-traffic reduction.
  (The paper's 0.875 bits/elem metadata assumes enumerative decoding in
  silicon; the software-decodable 4-bit index layout spends 2 bits/elem.
  With such hardware the ratio improves to 1.30 B/elem = 1.54x.)
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .nm_spmm import _decompress_tile
from .outlier_spmm import OUTLIER_M, _decompress_outlier_tile


def _kernel(x_ref, v_ref, meta_ref, ov_ref, ometa_ref, o_ref, acc_ref,
            *, n, m, o_n, n_k):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    w = _decompress_tile(v_ref[...], meta_ref[...], n, m, jnp.float32)
    w += _decompress_outlier_tile(ov_ref[...], ometa_ref[...], o_n, jnp.float32)
    x = x_ref[...].astype(jnp.float32)
    acc_ref[...] += jax.lax.dot_general(
        x, w, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(k == n_k - 1)
    def _done():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def _kernel_int8(x_ref, v_ref, meta_ref, ov_ref, ometa_ref, s_ref, o_ref,
                 acc_ref, *, n, m, o_n, n_k):
    """int8 N:M values dequantized in-register by the per-out-row scale;
    outliers stay exact bf16 and are added AFTER the scale — only the N:M
    stream is quantized (models/sparse_serving.py keeps outliers exact)."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    w = _decompress_tile(v_ref[...], meta_ref[...], n, m, jnp.float32)
    w = w * s_ref[...]                                 # [bO, bK] * [bO, 1]
    w += _decompress_outlier_tile(ov_ref[...], ometa_ref[...], o_n, jnp.float32)
    x = x_ref[...].astype(jnp.float32)
    acc_ref[...] += jax.lax.dot_general(
        x, w, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(k == n_k - 1)
    def _done():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("n", "m", "o_n", "block_b",
                                             "block_o", "block_k", "interpret"))
def fused_sparse_linear(x: jax.Array, nm_values: jax.Array, nm_meta: jax.Array,
                        o_values: jax.Array, o_meta: jax.Array, *,
                        n: int, m: int, o_n: int,
                        scale: jax.Array | None = None,
                        block_b: int = 128, block_o: int = 128,
                        block_k: int = 512, interpret: bool = True) -> jax.Array:
    """x: [b, in]; nm_values: [out, in*n//m]; nm_meta: [out, in//m] int32;
    o_values: [out, in//256, o_n]; o_meta: [out, in//256, o_n//4] int32.
    ``scale`` [out] f32 dequantizes int8 nm_values in-register (outliers are
    stored exact and added unscaled); None for bf16 values."""
    b, kdim = x.shape
    out = nm_values.shape[0]
    assert kdim % OUTLIER_M == 0 and kdim % m == 0

    bb = min(block_b, b)
    bo = min(block_o, out)
    bk = min(max(block_k, OUTLIER_M), kdim)
    assert b % bb == 0 and out % bo == 0 and kdim % bk == 0 and bk % OUTLIER_M == 0
    n_k = kdim // bk
    nc = bk // OUTLIER_M

    grid = (b // bb, out // bo, n_k)
    in_specs = [
        pl.BlockSpec((bb, bk), lambda i, j, k: (i, k)),
        pl.BlockSpec((bo, bk // m * n), lambda i, j, k: (j, k)),
        pl.BlockSpec((bo, bk // m), lambda i, j, k: (j, k)),
        pl.BlockSpec((bo, nc, o_n), lambda i, j, k: (j, k, 0)),
        pl.BlockSpec((bo, nc, o_n // 4), lambda i, j, k: (j, k, 0)),
    ]
    operands = [x, nm_values, nm_meta, o_values, o_meta]
    if scale is None:
        kernel = functools.partial(_kernel, n=n, m=m, o_n=o_n, n_k=n_k)
    else:
        assert scale.shape == (out,)
        kernel = functools.partial(_kernel_int8, n=n, m=m, o_n=o_n, n_k=n_k)
        in_specs.append(pl.BlockSpec((bo, 1), lambda i, j, k: (j, 0)))
        operands.append(scale.astype(jnp.float32).reshape(out, 1))
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bb, bo), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((b, out), x.dtype),
        scratch_shapes=[pltpu.VMEM((bb, bo), jnp.float32)],
        interpret=interpret,
    )(*operands)
