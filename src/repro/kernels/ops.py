"""Public jit'd entry points for the sparse kernels.

``sparse_matmul(x, SparsifiedLinear)``-style APIs used by models/serving.
Backend selection:
  - "pallas"     : pl.pallas_call, interpret=True on CPU (validation),
                   compiled on real TPU.
  - "reference"  : pure-jnp oracle (ref.py) — portable, used inside pjit'd
                   full-model graphs where the dry-run lowers to HLO (XLA
                   then fuses the decompression einsum itself).

On this CPU container interpret-mode Pallas is slow (Python loop over the
grid), so model-level code defaults to "reference"; kernel correctness is
enforced by the test suite sweeping both paths.
"""
from __future__ import annotations

import os
from functools import partial

import jax
import jax.numpy as jnp

from ..core.packing import PackedNM
from ..core.outliers import StructuredOutliers
from . import ref
from .nm_spmm import nm_spmm
from .outlier_spmm import outlier_spmm, pack_outlier_meta
from .fused_sparse_linear import fused_sparse_linear

_DEFAULT_BACKEND = os.environ.get("REPRO_KERNEL_BACKEND", "reference")
_ON_TPU = jax.default_backend() == "tpu"


def _interpret() -> bool:
    return not _ON_TPU


def nm_matmul(x: jax.Array, packed: PackedNM, backend: str | None = None,
              **tiles) -> jax.Array:
    """y = x @ W_nm^T for a PackedNM weight."""
    backend = backend or _DEFAULT_BACKEND
    if backend == "pallas":
        return nm_spmm(x, packed.values, packed.packed_metadata(),
                       n=packed.n, m=packed.m, interpret=_interpret(), **tiles)
    return ref.nm_spmm_ref(x, packed.values, packed.indices, packed.m)


def outlier_matmul(x: jax.Array, outliers: StructuredOutliers,
                   backend: str | None = None, **tiles) -> jax.Array:
    """y = x @ O^T for structured N:256 outliers."""
    backend = backend or _DEFAULT_BACKEND
    if backend == "pallas":
        return outlier_spmm(x, outliers.values, pack_outlier_meta(outliers.indices),
                            n=outliers.n, interpret=_interpret(), **tiles)
    return ref.outlier_spmm_ref(x, outliers.values, outliers.indices, outliers.m)


def sparse_linear_apply(x: jax.Array, packed: PackedNM,
                        outliers: StructuredOutliers | None,
                        backend: str | None = None, **tiles) -> jax.Array:
    """The production path: y = x @ (W_nm + O)^T, fused when possible."""
    backend = backend or _DEFAULT_BACKEND
    orig_shape = x.shape
    x2 = x.reshape(-1, orig_shape[-1])
    if outliers is None:
        y = nm_matmul(x2, packed, backend=backend, **tiles)
    elif backend == "pallas":
        y = fused_sparse_linear(
            x2, packed.values, packed.packed_metadata(),
            outliers.values, pack_outlier_meta(outliers.indices),
            n=packed.n, m=packed.m, o_n=outliers.n,
            interpret=_interpret(), **tiles)
    else:
        y = ref.fused_sparse_linear_ref(
            x2, packed.values, packed.indices, packed.m,
            outliers.values, outliers.indices, outliers.m)
    return y.reshape(*orig_shape[:-1], y.shape[-1])
