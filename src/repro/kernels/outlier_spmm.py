"""Pallas TPU kernel: structured N:256 salient-weight ("outlier") matmul.

SSP-for-SW (paper contribution 2) on TPU: each 256-wide input block of a row
holds exactly N outliers (N in {4, 8, 16}).  A 256-block spans two 128-lane
registers, so decompress-to-tile keeps accesses perfectly regular — the
paper's hardware-efficiency argument, realized on the MXU.

Layout:
  values : [out, in/256, n]            exact salient values
  meta   : [out, in/256, n/4] int32    indices packed 8 bits x4 per word

Grid and accumulation mirror nm_spmm; typically fused (see
fused_sparse_linear.py) — the standalone kernel exists for composability and
for the unstructured-vs-structured benchmark.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

OUTLIER_M = 256


def pack_outlier_meta(indices: jax.Array) -> jax.Array:
    """[out, nb, n] int32 (0..255) -> [out, nb, n//4] int32, 8 bits each."""
    out, nb, n = indices.shape
    assert n % 4 == 0
    grouped = indices.reshape(out, nb, n // 4, 4)
    shifts = 8 * jnp.arange(4, dtype=jnp.int32)
    return jnp.sum(grouped << shifts[None, None, None, :], axis=-1).astype(jnp.int32)


def unpack_outlier_meta(meta: jax.Array, n: int) -> jax.Array:
    """[out, nb, n//4] int32 -> [out, nb, n] int32."""
    shifts = 8 * jnp.arange(4, dtype=jnp.int32)
    idx = (meta[..., None] >> shifts) & 0xFF
    return idx.reshape(*meta.shape[:-1], n)


def _decompress_outlier_tile(values, meta, n: int, out_dtype):
    """values [bO, nc, n], meta [bO, nc, n//4] -> dense [bO, nc*256]."""
    bo, nc, _ = values.shape
    idx = unpack_outlier_meta(meta, n)                          # [bO, nc, n]
    j = jax.lax.iota(jnp.int32, OUTLIER_M)
    onehot = idx[:, :, :, None] == j[None, None, None, :]      # [bO, nc, n, 256]
    dense = jnp.sum(jnp.where(onehot, values.astype(jnp.float32)[..., None], 0.0),
                    axis=2)
    return dense.reshape(bo, nc * OUTLIER_M).astype(out_dtype)


def _kernel(x_ref, v_ref, meta_ref, o_ref, acc_ref, *, n, n_k):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    w = _decompress_outlier_tile(v_ref[...], meta_ref[...], n, jnp.float32)
    x = x_ref[...].astype(jnp.float32)
    acc_ref[...] += jax.lax.dot_general(
        x, w, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(k == n_k - 1)
    def _done():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("n", "block_b", "block_o",
                                             "block_k", "interpret"))
def outlier_spmm(x: jax.Array, values: jax.Array, meta: jax.Array, *,
                 n: int, block_b: int = 128, block_o: int = 128,
                 block_k: int = 512, interpret: bool = True) -> jax.Array:
    """y[b, out] = x[b, in] @ decompress_outliers^T."""
    b, kdim = x.shape
    out, nb, npk = values.shape[0], values.shape[1], meta.shape[2]
    assert kdim == nb * OUTLIER_M and npk == n // 4

    bb = min(block_b, b)
    bo = min(block_o, out)
    bk = min(max(block_k, OUTLIER_M), kdim)
    assert b % bb == 0 and out % bo == 0 and kdim % bk == 0 and bk % OUTLIER_M == 0
    n_k = kdim // bk
    nc = bk // OUTLIER_M

    grid = (b // bb, out // bo, n_k)
    return pl.pallas_call(
        functools.partial(_kernel, n=n, n_k=n_k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bb, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bo, nc, n), lambda i, j, k: (j, k, 0)),
            pl.BlockSpec((bo, nc, n // 4), lambda i, j, k: (j, k, 0)),
        ],
        out_specs=pl.BlockSpec((bb, bo), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((b, out), x.dtype),
        scratch_shapes=[pltpu.VMEM((bb, bo), jnp.float32)],
        interpret=interpret,
    )(x, values, meta)
