"""Activation-sharding policy: explicit with_sharding_constraint hooks.

GSPMD propagates weight shardings to most activations, but a few reshapes
(GQA head grouping, logits) lose the head/model dimension and silently
replicate multi-GiB temporaries.  Models call ``shard(x, axes)`` with
symbolic axes; when a policy is active (launch/dryrun/train set it), the
constraint is applied with divisibility checks; with no policy it's a no-op
(CPU unit tests, single device).

Symbolic axes: "fsdp" -> ("pod","data") / ("data",), "model" -> "model",
None -> replicated.
"""
from __future__ import annotations

import contextlib
import math
import threading

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_STATE = threading.local()


def _current():
    return getattr(_STATE, "policy", None)


@contextlib.contextmanager
def activation_sharding(mesh: Mesh, seq_shard: bool = False):
    """Enable activation constraints for code traced within this context."""
    fsdp = ("pod", "data") if "pod" in mesh.axis_names else ("data",)
    prev = _current()
    _STATE.policy = {"mesh": mesh, "fsdp": fsdp, "seq_shard": seq_shard}
    try:
        yield
    finally:
        _STATE.policy = prev


@contextlib.contextmanager
def suspended():
    """Deactivate any ambient policy for code traced within this context.

    The serving placement layer (serving/placement.py) shards through
    explicit jit in/out shardings instead of activation constraints, and it
    must NOT inherit a policy leaked from an enclosing dryrun/train scope:
    an active policy flips MoE onto the capacity-bounded expert-parallel
    path (models/moe.py), where prefill bucket padding competes with real
    tokens for expert capacity and token streams stop being batch-invariant.
    """
    prev = _current()
    _STATE.policy = None
    try:
        yield
    finally:
        _STATE.policy = prev


def _resolve(mesh, fsdp, axes, shape):
    spec = []
    for dim, ax in zip(shape, axes):
        if ax is None:
            spec.append(None)
            continue
        real = fsdp if ax == "fsdp" else (ax,) if isinstance(ax, str) else ax
        size = math.prod(mesh.shape[a] for a in real)
        spec.append(real if dim % size == 0 else None)
    return P(*spec)


def shard(x: jax.Array, axes) -> jax.Array:
    """Constrain ``x`` to symbolic ``axes`` (len == x.ndim) if policy active."""
    pol = _current()
    if pol is None:
        return x
    spec = _resolve(pol["mesh"], pol["fsdp"], axes, x.shape)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(pol["mesh"], spec))


def seq_sharded() -> bool:
    pol = _current()
    return bool(pol and pol["seq_shard"])


def divides(axis: str, dim: int) -> bool:
    """True if `dim` can shard over `axis` under the active policy (False
    when no policy: callers then skip layout specialization)."""
    pol = _current()
    if pol is None:
        return False
    real = pol["fsdp"] if axis == "fsdp" else (axis,)
    return dim % math.prod(pol["mesh"].shape[a] for a in real) == 0
