from .sharding import (param_shardings, batch_shardings, cache_shardings,
                       param_spec, batch_spec, cache_spec, fsdp_axes,
                       replicated, tree_paths)
