"""Sharding rules: parameter/cache/batch pytrees -> NamedSharding.

Axes (launch/mesh.py): single-pod mesh (data=16, model=16); multi-pod mesh
(pod=2, data=16, model=16).  Conventions:

  fsdp  = ("pod", "data") when the pod axis exists, else ("data",)
          — ZeRO-3-style weight/optimizer sharding; XLA SPMD inserts the
          per-layer all-gathers.
  model = tensor-parallel axis: attention heads / FFN hidden / vocab / experts.

Every rule is divisibility-checked against the actual dim; axes that don't
divide are dropped (replicated) — this is what makes odd dims (whisper vocab
51865, llama4 40 heads) compile cleanly on a fixed 16x16 mesh.  N:M blocks
live along the *input* dim of each projection; that dim is sharded over fsdp
in multiples of d_model/|fsdp| >= 256, preserving 16- and 256-block
alignment (DESIGN.md §4).
"""
from __future__ import annotations

import dataclasses
import math
import re
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def fsdp_axes(mesh: Mesh):
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def axis_size(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    return math.prod(mesh.shape[a] for a in axes)


def _fit(mesh: Mesh, dim: int, axes):
    """Return axes if dim divides evenly, else None (replicate)."""
    if axes is None:
        return None
    return axes if dim % axis_size(mesh, axes) == 0 else None


def _spec(mesh: Mesh, shape, per_dim_axes) -> P:
    assert len(shape) == len(per_dim_axes)
    return P(*[_fit(mesh, d, a) for d, a in zip(shape, per_dim_axes)])


# --------------------------------------------------------------------------
# parameters
# --------------------------------------------------------------------------

# (regex on the leaf path, per-dim axes for the LAST n dims; leading dims —
# layer stack [L], expert [E] handled explicitly)
def param_spec(mesh: Mesh, path: str, shape) -> P:
    fs = fsdp_axes(mesh)
    p = path.lower()
    nd = len(shape)

    def tail(*axes):
        """Pad with None for leading (stack) dims."""
        return _spec(mesh, shape, (None,) * (nd - len(axes)) + tuple(axes))

    # ---- embeddings / head -------------------------------------------------
    if re.search(r"embed|lm_head", p):
        return tail("model", fs)
    # ---- norms / scalar-ish ------------------------------------------------
    if nd <= 2 and re.search(r"norm|a_log|dt_bias|scale|\bd\b|r_gates", p):
        return P(*([None] * nd))
    if "r_gates" in p:
        return P(*([None] * nd))
    # ---- MoE ---------------------------------------------------------------
    if "router" in p:
        return P(*([None] * nd))                 # small; replicated for EP
    # expert weights match the EP+TP layout (models/moe.py): experts over
    # fsdp (when divisible), ff over model.
    if re.search(r"we_(gate|up)", p):            # [L, E, d, ff]
        if shape[1] % axis_size(mesh, fs) == 0:
            return _spec(mesh, shape, (None, fs, None, "model"))
        return _spec(mesh, shape, (None, None, fs, "model"))
    if "we_down" in p:                           # [L, E, ff, d]
        if shape[1] % axis_size(mesh, fs) == 0:
            return _spec(mesh, shape, (None, fs, "model", None))
        return _spec(mesh, shape, (None, None, "model", fs))
    # ---- compressed SparseWeight buffers (models/sparse_serving.py) --------
    # Name-only fallback for contexts that flatten a SparseWeight without its
    # container (e.g. ShapeDtypeStruct sweeps).  It cannot see n/m/o_n, so it
    # checks raw divisibility only; ``param_shardings`` intercepts real
    # SparseWeight containers and routes them through ``sparse_weight_specs``,
    # which enforces N:M-block and outlier-group alignment.
    if re.search(r"nm_values|nm_meta", p):       # [L, out, X]
        if shape[-1] % axis_size(mesh, fs) == 0:
            return tail("model", fs)
        # odd compressed-in dim (e.g. qwen2-vl d_ff/16=1848): fold fsdp into
        # the out dim instead of replicating multi-GiB metadata
        return tail(("model",) + fs, None)
    if re.search(r"o_values|o_meta", p):         # [L, out, in/256, n*]
        if shape[-2] % axis_size(mesh, fs) == 0:
            return _spec(mesh, shape, (None,) * (nd - 3) + ("model", fs, None))
        return _spec(mesh, shape, (None,) * (nd - 3) + (("model",) + fs, None, None))
    if re.search(r"v_scale", p):                 # [L, out] int8 row scales
        return tail("model")
    # ---- column-parallel: out dim = heads*hd / ff / gates ------------------
    if re.search(r"wq|wk|wv|w_gate|w_up|ws_gate|ws_up|in_proj|w_q|w_k|w_v|"
                 r"w_gates|w_slstm|c_wq|c_wk|c_wv", p):
        return tail("model", fs)
    # ---- row-parallel: in dim = heads*hd / ff ------------------------------
    if re.search(r"wo|w_down|ws_down|out_proj|c_wo", p):
        return tail(fs, "model")
    # default: replicate
    return P(*([None] * nd))


# --------------------------------------------------------------------------
# compressed SparseWeight containers
# --------------------------------------------------------------------------

def sparse_weight_specs(mesh: Mesh, sw, *, serving: bool = False):
    """Co-designed PartitionSpecs for one ``SparseWeight`` container.

    Returns the container with every array field replaced by its
    PartitionSpec (``None`` fields stay ``None``), so the result can feed
    ``jax.device_put`` / ``jit`` sharding trees directly.

    Placement rules (all fields decided together so values, bit-packed
    metadata, and row scales always co-shard):

      * out (row) dim: sharded over ``model`` whenever divisible — always
        safe, no compressed structure crosses rows.
      * in (column) dim: sharded over fsdp ONLY when every shard boundary
        falls on an N:M block (``m``-wide) AND, when structured outliers
        exist, on a 256-wide outlier group.  A split block/group would
        tear bit-packed indices away from the values they address, so
        misaligned in-dims fall back to replication (or fold fsdp into
        the out dim when that divides — same escape the name-only rule
        uses for odd compressed dims).
      * ``serving=True``: the serving placement never shards contraction
        dims at all (partial-sum reductions would perturb logits in the
        last ulp and break token-stream parity with the single-device
        engine), so in-dims replicate unconditionally.
    """
    fs = fsdp_axes(mesh)
    F = axis_size(mesh, fs)
    model_n = axis_size(mesh, ("model",))
    nd = sw.nm_values.ndim
    lead = (None,) * (nd - 2)
    out = sw.nm_values.shape[-2]
    model_ok = out % model_n == 0
    in_ok = (not serving and F > 1 and sw.in_dim % (F * sw.m) == 0
             and (sw.o_n == 0 or sw.in_dim % (F * 256) == 0))
    out_axes = "model" if model_ok else None
    in_axes = fs if in_ok else None
    if not serving and not in_ok and out % (model_n * F) == 0:
        # in-dim not block-aligned: fold fsdp into the out dim rather than
        # replicating multi-GiB value/metadata buffers
        out_axes = ("model",) + fs
    two_d = P(*lead, out_axes, in_axes)          # nm_values / nm_meta
    o_spec = P(*lead, out_axes, in_axes, None)   # o_values / o_meta
    return dataclasses.replace(
        sw, nm_values=two_d, nm_meta=two_d,
        o_values=None if sw.o_values is None else o_spec,
        o_meta=None if sw.o_meta is None else o_spec,
        v_scale=None if sw.v_scale is None else P(*lead, out_axes))


def _is_sparse_weight(x) -> bool:
    from ..models.sparse_serving import SparseWeight
    return isinstance(x, SparseWeight)


def sparse_weight_shardings(mesh: Mesh, sw, *, serving: bool = False):
    """``sparse_weight_specs`` with every spec wrapped in a NamedSharding."""
    specs = sparse_weight_specs(mesh, sw, serving=serving)
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda s: isinstance(s, P))


def tree_paths(tree) -> list[tuple[str, Any]]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        name = "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
        out.append((name, leaf))
    return out


def param_shardings(mesh: Mesh, params) -> Any:
    """NamedSharding pytree mirroring ``params`` (works on ShapeDtypeStructs).

    ``SparseWeight`` containers are intercepted whole so their values,
    metadata, and scales co-shard under the alignment-checked rules of
    ``sparse_weight_specs``; plain leaves go through ``param_spec``."""
    def one(path, leaf):
        if _is_sparse_weight(leaf):
            return sparse_weight_shardings(mesh, leaf)
        name = "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
        return NamedSharding(mesh, param_spec(mesh, name, leaf.shape))
    return jax.tree_util.tree_map_with_path(one, params,
                                            is_leaf=_is_sparse_weight)


# --------------------------------------------------------------------------
# batches / caches / optimizer state
# --------------------------------------------------------------------------

def batch_spec(mesh: Mesh, shape, seq_shard: bool = False) -> P:
    """tokens/labels [B, S] or embeds [B, S, d] / positions [3, B, S]."""
    fs = fsdp_axes(mesh)
    nd = len(shape)
    if nd == 3 and shape[0] == 3:                # M-RoPE positions [3, B, S]
        return _spec(mesh, shape, (None, fs, "model" if seq_shard else None))
    if seq_shard:                                # long-context, B=1: SP
        axes = [None] * nd
        axes[1 if nd >= 2 else 0] = fs
        return _spec(mesh, shape, tuple(axes))
    return _spec(mesh, shape, (fs,) + (None,) * (nd - 1))


def batch_shardings(mesh: Mesh, batch, seq_shard: bool = False):
    return jax.tree.map(
        lambda leaf: NamedSharding(mesh, batch_spec(mesh, leaf.shape, seq_shard)),
        batch)


def cache_spec(mesh: Mesh, path: str, shape, seq_shard: bool = False) -> P:
    """KV caches [L,B,S,KV,hd] / [B,S,KV,hd]; SSM states [B,H,dk,dv] etc."""
    fs = fsdp_axes(mesh)
    nd = len(shape)
    if nd == 0 or "pos" in path:
        return P()
    model_n = axis_size(mesh, ("model",))
    if nd == 5:                                   # [L, B, S, KV, hd]
        if seq_shard:
            return _spec(mesh, shape, (None, None, fs, "model", None))
        if shape[3] % model_n == 0:               # enough KV heads: shard heads
            return _spec(mesh, shape, (None, fs, None, "model", None))
        # GQA with KV < mesh: shard the sequence dim instead (flash-decoding
        # layout — softmax partials all-reduce over `model`)
        return _spec(mesh, shape, (None, fs, "model", None, None))
    if nd == 4:                                   # [B, S, KV, hd] or [B,H,dk,dv]
        if "kv" in path:
            if seq_shard:
                return _spec(mesh, shape, (None, fs, "model", None))
            if shape[2] % model_n == 0:
                return _spec(mesh, shape, (fs, None, "model", None))
            return _spec(mesh, shape, (fs, "model", None, None))
        return _spec(mesh, shape, (fs, "model", None, None))
    if nd >= 2:                                   # SSM state [B, H, ...]
        return _spec(mesh, shape, (fs, "model") + (None,) * (nd - 2))
    return P(None)


def cache_shardings(mesh: Mesh, caches, seq_shard: bool = False):
    def one(path, leaf):
        name = "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
        return NamedSharding(mesh, cache_spec(mesh, name, leaf.shape, seq_shard))
    return jax.tree_util.tree_map_with_path(one, caches)


def replicated(mesh: Mesh):
    return NamedSharding(mesh, P())
