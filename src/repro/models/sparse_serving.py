"""Sparse deployment: swap dense projection weights for compressed N:M (+
structured outlier) containers — the paper's serving story.

``SparseWeight`` is a pytree whose array leaves are exactly the deployed
buffers (bf16 values + bit-packed int32 metadata), so a lowered serving graph
reads compressed bytes from HBM:

  8:16 + 16:256 outliers, bf16:   1.30 B/elem  vs dense 2 B/elem (1.54x)

``layers.linear`` dispatches on this type, so every model in the zoo serves
sparse without code changes.  On TPU the fused Pallas kernel consumes the
packed buffers directly; the portable path unpacks metadata with bit ops and
decompresses via one-hot matmul (XLA fuses it; numerics identical — tested).
"""
from __future__ import annotations

import dataclasses
import re
from functools import partial

import jax
import jax.numpy as jnp

from ..core.packing import PackedNM, pack_nm, unpack_metadata
from ..core.outliers import StructuredOutliers
from ..core.pipeline import SparsifyConfig, sparsify_linear
from ..core.patterns import parse_pattern
from ..core import scoring


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class SparseWeight:
    """Compressed linear weight; stands in for a dense [out, in] array.

    May carry a leading stacked-layer dim on every array leaf.

    Beyond-paper: ``v_scale`` is not None => nm_values are int8 with a
    per-output-row absmax scale (sparsity x quantization composition;
    outlier values stay exact bf16 — they are the weights quantization
    hurts most, so SSP-for-SW doubles as the outlier store for int8)."""

    nm_values: jax.Array                  # [..., out, in*n/m] bf16 | int8
    nm_meta: jax.Array                    # [..., out, in/m] int32, 4-bit idx
    o_values: jax.Array | None            # [..., out, in/256, o_n]
    o_meta: jax.Array | None              # [..., out, in/256, o_n/4] int32
    v_scale: jax.Array | None             # [..., out] f32 (int8 mode)
    n: int = dataclasses.field(metadata=dict(static=True))
    m: int = dataclasses.field(metadata=dict(static=True))
    o_n: int = dataclasses.field(metadata=dict(static=True))
    in_dim: int = dataclasses.field(metadata=dict(static=True))

    @property
    def ndim(self):          # so models can treat it like an array
        return self.nm_values.ndim

    @property
    def shape(self):
        return (*self.nm_values.shape[:-1], self.in_dim)

    def deployed_bytes(self) -> int:
        """Bytes this container actually ships to HBM — every deployed
        buffer counts, including the per-row f32 scales of int8 mode
        (omitting v_scale overstated the int8 compression ratio)."""
        return sum(v.size * v.dtype.itemsize
                   for v in (self.nm_values, self.nm_meta, self.o_values,
                             self.o_meta, self.v_scale) if v is not None)


def _unpack_8bit(meta: jax.Array, n: int) -> jax.Array:
    shifts = 8 * jnp.arange(4, dtype=jnp.int32)
    idx = (meta[..., None] >> shifts) & 0xFF
    return idx.reshape(*meta.shape[:-1], n)


def sparse_apply(sw: SparseWeight, x: jax.Array) -> jax.Array:
    """y = x @ W_hat^T from compressed buffers (portable path)."""
    out = sw.nm_values.shape[-2]
    nb = sw.in_dim // sw.m
    idx = unpack_metadata(sw.nm_meta, sw.n)                     # [out, nb, n]
    nm_vals = sw.nm_values
    if sw.v_scale is not None:                                  # int8 mode
        nm_vals = (nm_vals.astype(jnp.float32)
                   * sw.v_scale[..., None].astype(jnp.float32)).astype(x.dtype)
    vals = nm_vals.reshape(out, nb, sw.n)
    onehot = jax.nn.one_hot(idx, sw.m, dtype=vals.dtype)
    w = jnp.einsum("obn,obnm->obm", vals, onehot).reshape(out, sw.in_dim)
    if sw.o_values is not None:
        ob = sw.in_dim // 256
        o_idx = _unpack_8bit(sw.o_meta, sw.o_n)
        o_onehot = jax.nn.one_hot(o_idx, 256, dtype=sw.o_values.dtype)
        w = w + jnp.einsum("obn,obnm->obm", sw.o_values, o_onehot
                           ).reshape(out, sw.in_dim)
    return jnp.einsum("...k,ok->...o", x, w.astype(x.dtype))


def sparse_apply_pallas(sw: SparseWeight, x: jax.Array) -> jax.Array:
    """TPU path: fused Pallas kernel on the packed buffers.  int8 values
    stream quantized all the way into VMEM; the per-row scale rides as a
    kernel operand and dequantizes in-register after the gather."""
    from ..kernels.fused_sparse_linear import fused_sparse_linear
    from ..kernels.nm_spmm import nm_spmm
    scale = None if sw.v_scale is None else sw.v_scale.astype(jnp.float32)
    lead = x.shape[:-1]
    x2 = x.reshape(-1, sw.in_dim)
    if sw.o_values is None:
        y = nm_spmm(x2, sw.nm_values, sw.nm_meta, n=sw.n, m=sw.m,
                    scale=scale, interpret=jax.default_backend() != "tpu")
    else:
        y = fused_sparse_linear(x2, sw.nm_values, sw.nm_meta, sw.o_values,
                                sw.o_meta, n=sw.n, m=sw.m, o_n=sw.o_n,
                                scale=scale,
                                interpret=jax.default_backend() != "tpu")
    return y.reshape(*lead, -1)


def densify(sw: SparseWeight) -> jax.Array:
    """Dense [..., out, in] reconstruction of the deployed buffers.

    Inverse of the compression for serving purposes: running the engine on
    ``densify_params(sparse)`` computes the same function as serving the
    compressed containers, through the dense matmul path instead of the
    sparse kernels.  The reconstruction einsums run in the deployed value
    dtype — the same precision ``sparse_apply`` accumulates the one-hot
    decompression in — so the two realizations agree to fusion rounding.
    The speculative bench leans on this: the 8:16 draft's "dense
    counterpart" target is its own densification, giving a deterministic
    high-acceptance pair without trained weights.
    """
    lead = sw.nm_values.shape[:-1]                       # [..., out]
    nb = sw.in_dim // sw.m
    nm_vals = sw.nm_values
    if sw.v_scale is not None:                           # int8 mode
        nm_vals = (nm_vals.astype(jnp.float32)
                   * sw.v_scale[..., None].astype(jnp.float32)
                   ).astype(jnp.bfloat16)
    idx = unpack_metadata(sw.nm_meta, sw.n)              # [..., nb, n]
    vals = nm_vals.reshape(*lead, nb, sw.n)
    onehot = jax.nn.one_hot(idx, sw.m, dtype=vals.dtype)
    w = jnp.einsum("...bn,...bnm->...bm", vals, onehot
                   ).reshape(*lead, sw.in_dim)
    if sw.o_values is not None:
        o_idx = _unpack_8bit(sw.o_meta, sw.o_n)
        o_onehot = jax.nn.one_hot(o_idx, 256, dtype=sw.o_values.dtype)
        w = w + jnp.einsum("...bn,...bnm->...bm", sw.o_values, o_onehot
                           ).reshape(*lead, sw.in_dim)
    return w


def densify_params(params):
    """Replace every SparseWeight in a served pytree with its dense
    reconstruction (see ``densify``); dense leaves pass through."""
    return jax.tree_util.tree_map(
        lambda leaf: densify(leaf) if isinstance(leaf, SparseWeight) else leaf,
        params, is_leaf=lambda leaf: isinstance(leaf, SparseWeight))


# --------------------------------------------------------------------------
# conversion
# --------------------------------------------------------------------------

PRUNABLE = re.compile(
    r"wq|wk|wv|wo|w_gate|w_up|w_down|ws_gate|ws_up|ws_down|in_proj|out_proj|"
    r"w_q|w_k|w_v|w_slstm|c_wq|c_wk|c_wv|c_wo")
SKIP = re.compile(r"norm|embed|lm_head|router|gates|A_log|dt_bias|\bD\b")


def _to_sparse_weight(w2d: jax.Array, scfg: SparsifyConfig,
                      stats=None, quantize: bool = False) -> SparseWeight:
    sl = sparsify_linear(w2d, stats, scfg)
    nm = sl.nm
    o = sl.outliers
    from ..kernels.outlier_spmm import pack_outlier_meta
    values, v_scale = nm.values, None
    if quantize:
        absmax = jnp.max(jnp.abs(values.astype(jnp.float32)), axis=-1)
        v_scale = jnp.where(absmax > 0, absmax / 127.0, 1.0)
        values = jnp.clip(jnp.round(values.astype(jnp.float32)
                                    / v_scale[..., None]), -127, 127
                          ).astype(jnp.int8)
    return SparseWeight(
        nm_values=values, nm_meta=nm.packed_metadata(),
        o_values=None if o is None else o.values,
        o_meta=None if o is None else pack_outlier_meta(o.indices),
        v_scale=v_scale,
        n=nm.n, m=nm.m, o_n=0 if o is None else o.n, in_dim=nm.in_dim)


def _leaf_cfg(name: str, leaf, scfg: SparsifyConfig) -> SparsifyConfig | None:
    """Per-leaf config (or None = keep dense). Mirrors core.pipeline's
    degradation: layers too narrow for a 256-block lose outlier recovery
    but are still N:M-pruned."""
    if not hasattr(leaf, "ndim") or leaf.ndim not in (2, 3):
        return None
    if SKIP.search(name) or not PRUNABLE.search(name.split("/")[-1]):
        return None
    wp = parse_pattern(scfg.weight_pattern)
    if leaf.shape[-1] % wp.m:
        return None
    if scfg.outlier_pattern is not None and leaf.shape[-1] % 256:
        return dataclasses.replace(scfg, outlier_pattern=None)
    return scfg


def sparsify_for_serving(params, scfg: SparsifyConfig, stats_by_name=None,
                         quantize: bool = False):
    """Replace eligible projections with SparseWeight; returns (params, report).

    ``quantize=True``: int8 N:M values + exact bf16 structured outliers
    (beyond-paper sparsity x quantization composition)."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    new_leaves, dense_bytes, comp_bytes, n_sp = [], 0, 0, 0
    for path, leaf in flat:
        name = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        leaf_cfg = _leaf_cfg(name, leaf, scfg)
        if leaf_cfg is None:
            new_leaves.append(leaf)
            continue
        st = (stats_by_name or {}).get(name)
        conv = partial(_to_sparse_weight, scfg=leaf_cfg, quantize=quantize)
        if leaf.ndim == 3:
            sw = jax.vmap(lambda w: conv(w, stats=None))(leaf) if st is None \
                else jax.vmap(conv)(leaf, st)
        else:
            sw = conv(leaf, stats=st)
        n_sp += 1
        dense_bytes += leaf.size * leaf.dtype.itemsize
        comp_bytes += sw.deployed_bytes()
        new_leaves.append(sw)
    new_params = jax.tree_util.tree_unflatten(treedef, new_leaves)
    report = {"n_layers_sparsified": n_sp, "dense_bytes": dense_bytes,
              "compressed_bytes": comp_bytes,
              "ratio": comp_bytes / max(dense_bytes, 1)}
    return new_params, report
