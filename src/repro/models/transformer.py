"""Decoder-only transformer (dense / MoE / VLM backbone).

Covers: gemma-7b (GeGLU, head_dim 256), qwen3-8b (qk_norm), internlm2-1.8b,
nemotron-4-340b (squared-ReLU, non-gated), qwen2-vl-72b (M-RoPE, embed-input),
mixtral-8x7b (MoE + SWA), llama4-maverick (MoE top-1 + shared expert), and the
paper's llama2/3 + mistral models.

Layer weights are stacked [L, ...]; the forward pass runs either
``lax.scan`` over layers (training: fast compile, remat-able) or an unrolled
Python loop (``unroll=True``, used by the dry-run so XLA cost analysis counts
every layer — see DESIGN.md §6).

Three entry points per the launch contract:
  loss_fn(params, batch, cfg)                          — training
  prefill(params, batch, cfg) -> (logits, caches)      — inference prefill
  decode_step(params, caches, batch, cfg) -> (logits, caches)

Sharding: the forward/decode paths are placement-agnostic.  Training and
the dry-run shard through the activation policy (parallel/policy.py, a
no-op when inactive); the serving engine instead commits params and KV
arenas to explicit NamedShardings (serving/placement.py) and lets GSPMD
propagate, so the same code serves single-device and tensor-parallel.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp

from .layers import (activation, apply_rope, decode_attention, dense_init,
                     linear, rms_norm, sdpa, split_keys)
from . import moe as moe_lib


# --------------------------------------------------------------------------
# init
# --------------------------------------------------------------------------

def init_params(key, cfg, scale_layers: bool = True):
    d, hd, H, KV, L = cfg.d_model, cfg.hd, cfg.n_heads, cfg.n_kv_heads, cfg.n_layers
    dtype = cfg.dtype
    ks = split_keys(key, 12)

    def stack(initf, *shape_key):
        outs = [initf(k) for k in split_keys(shape_key[0], L)]
        return jnp.stack(outs)

    layers = {
        "attn_norm": jnp.zeros((L, d), dtype),
        "wq": stack(lambda k: dense_init(k, H * hd, d, dtype), ks[0]),
        "wk": stack(lambda k: dense_init(k, KV * hd, d, dtype), ks[1]),
        "wv": stack(lambda k: dense_init(k, KV * hd, d, dtype), ks[2]),
        "wo": stack(lambda k: dense_init(k, d, H * hd, dtype), ks[3]),
        "mlp_norm": jnp.zeros((L, d), dtype),
    }
    if cfg.qk_norm:
        layers["q_norm"] = jnp.zeros((L, hd), dtype)
        layers["k_norm"] = jnp.zeros((L, hd), dtype)
    if cfg.moe is not None:
        sub = [moe_lib.init_moe(k, cfg, dtype) for k in split_keys(ks[4], L)]
        layers["moe"] = jax.tree.map(lambda *xs: jnp.stack(xs), *sub)
    else:
        if cfg.glu:
            layers["w_gate"] = stack(lambda k: dense_init(k, cfg.d_ff, d, dtype), ks[5])
        layers["w_up"] = stack(lambda k: dense_init(k, cfg.d_ff, d, dtype), ks[6])
        layers["w_down"] = stack(lambda k: dense_init(k, d, cfg.d_ff, dtype), ks[7])

    params = {
        "embed": (jax.random.normal(ks[8], (cfg.vocab, d), jnp.float32)
                  * 0.02).astype(dtype),
        "layers": layers,
        "final_norm": jnp.zeros((d,), dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(ks[9], cfg.vocab, d, dtype)
    return params


# --------------------------------------------------------------------------
# one block
# --------------------------------------------------------------------------

def _project_qkv(lp, x, cfg, positions):
    B, S, d = x.shape
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    q = linear(lp["wq"], x).reshape(B, S, H, hd)
    k = linear(lp["wk"], x).reshape(B, S, KV, hd)
    v = linear(lp["wv"], x).reshape(B, S, KV, hd)
    if cfg.qk_norm:
        q = rms_norm(q, lp["q_norm"], cfg.norm_eps)
        k = rms_norm(k, lp["k_norm"], cfg.norm_eps)
    q = apply_rope(q, positions, cfg.rope_theta, cfg.mrope_sections)
    k = apply_rope(k, positions, cfg.rope_theta, cfg.mrope_sections)
    return q, k, v


def _mlp(lp, x, cfg):
    from ..parallel import policy as pol
    if cfg.moe is not None:
        return moe_lib.moe_apply(lp["moe"], x, cfg)
    if cfg.glu:
        hidden = activation(cfg.act, linear(lp["w_gate"], x)) * linear(lp["w_up"], x)
    else:
        hidden = activation(cfg.act, linear(lp["w_up"], x))
    hidden = pol.shard(hidden, ("fsdp", None, "model"))
    return linear(lp["w_down"], hidden)


def block_forward(lp, x, positions, cfg, q_chunks: int = 1, causal: bool = True,
                  prior_kv=None):
    """Full-sequence block (train / prefill). Returns (y, (k, v)).

    ``prior_kv`` = (k, v) [B, P, KV, hd] of an already-computed context
    (paged prefix-cache hit): queries attend to prior + fresh keys with a
    ``q_offset`` of P, and only the fresh suffix KV is returned.

    Activation constraints pin the batch (fsdp) sharding at block boundaries —
    without them GSPMD can flip to a d_model-sharded/batch-replicated layout
    whose temps are mesh-times larger (see DESIGN.md §Perf log)."""
    from ..parallel import policy as pol
    x = pol.shard(x, ("fsdp", None, None))
    h = rms_norm(x, lp["attn_norm"], cfg.norm_eps)
    q, k, v = _project_qkv(lp, h, cfg, positions)
    q = pol.shard(q, ("fsdp", None, "model", None))
    if prior_kv is not None:
        pk, pv = prior_kv
        k_all = jnp.concatenate([pk.astype(k.dtype), k], axis=1)
        v_all = jnp.concatenate([pv.astype(v.dtype), v], axis=1)
        attn = sdpa(q, k_all, v_all, causal=causal, window=cfg.window,
                    q_chunks=q_chunks, q_offset=pk.shape[1])
    else:
        attn = sdpa(q, k, v, causal=causal, window=cfg.window,
                    q_chunks=q_chunks)
    x = x + linear(lp["wo"], attn.reshape(*attn.shape[:2], -1))
    x = pol.shard(x, ("fsdp", None, None))
    h = rms_norm(x, lp["mlp_norm"], cfg.norm_eps)
    x = x + _mlp(lp, h, cfg)
    return x, (k, v)


def block_decode(lp, x, k_cache, v_cache, pos, cfg):
    """One-token block. x: [B,1,d]; caches [B,Smax,KV,hd].

    ``pos`` is either a scalar filled length (lock-step batch: every row sits
    at the same position) or a [B] vector of per-row filled lengths
    (slot-indexed caches — the serving engine's continuous batch, where each
    slot is at a different point in its sequence)."""
    from ..parallel import policy as pol
    B = x.shape[0]
    per_slot = jnp.ndim(pos) == 1
    x = pol.shard(x, ("fsdp", None, None))
    h = rms_norm(x, lp["attn_norm"], cfg.norm_eps)
    base = pos[:, None] if per_slot else jnp.broadcast_to(pos, (B, 1))
    if cfg.mrope_sections is not None:
        positions = jnp.broadcast_to(base[None], (3, B, 1))
    else:
        positions = base
    q, k, v = _project_qkv(lp, h, cfg, positions)
    q = pol.shard(q, ("fsdp", None, "model", None))
    if per_slot:
        upd = lambda c, u, p: jax.lax.dynamic_update_slice_in_dim(c, u, p, 0)
        k_cache = jax.vmap(upd)(k_cache, k.astype(k_cache.dtype), pos)
        v_cache = jax.vmap(upd)(v_cache, v.astype(v_cache.dtype), pos)
        cache_len = pos + 1
    else:
        k_cache = jax.lax.dynamic_update_slice_in_dim(k_cache, k.astype(k_cache.dtype), pos, 1)
        v_cache = jax.lax.dynamic_update_slice_in_dim(v_cache, v.astype(v_cache.dtype), pos, 1)
        cache_len = jnp.full((B,), pos + 1, jnp.int32)
    if cfg.window is not None:
        # sliding window: mask everything older than `window`
        lo = jnp.maximum(pos + 1 - cfg.window, 0)
        valid_from = jnp.broadcast_to(lo, (B,)).astype(jnp.int32)
        attn = _windowed_decode(q, k_cache, v_cache, cache_len, valid_from)
    else:
        attn = decode_attention(q, k_cache, v_cache, cache_len)
    x = x + linear(lp["wo"], attn.reshape(B, 1, -1))
    h = rms_norm(x, lp["mlp_norm"], cfg.norm_eps)
    x = x + _mlp(lp, h, cfg)
    return x, k_cache, v_cache


def block_decode_paged(lp, x, k_arena, v_arena, block_tables, pos, cfg,
                       attn_backend=None):
    """One-token block over a paged KV arena. x: [B,1,d]; arenas
    [n_blocks, block_size, KV, hd]; ``block_tables`` [B, nb] maps each
    row's sequence position p to physical block ``bt[b, p // bs]``;
    ``pos`` [B] is each row's filled length (= write position).

    The fresh k/v is scattered into each row's current block, then
    attention gathers over the row's block list (serving/paged/
    paged_attention.py) instead of a contiguous slot."""
    from ..parallel import policy as pol
    from ..serving.paged.paged_attention import paged_attention
    B = x.shape[0]
    n_blocks, bs = k_arena.shape[0], k_arena.shape[1]
    x = pol.shard(x, ("fsdp", None, None))
    h = rms_norm(x, lp["attn_norm"], cfg.norm_eps)
    base = pos[:, None]
    if cfg.mrope_sections is not None:
        positions = jnp.broadcast_to(base[None], (3, B, 1))
    else:
        positions = base
    q, k, v = _project_qkv(lp, h, cfg, positions)
    q = pol.shard(q, ("fsdp", None, "model", None))
    # write: flat token slot of position p is bt[b, p // bs] * bs + p % bs
    slot = jnp.take_along_axis(block_tables, (pos // bs)[:, None],
                               axis=1)[:, 0] * bs + pos % bs       # [B]
    flat_shape = (n_blocks * bs, *k_arena.shape[2:])
    k_arena = k_arena.reshape(flat_shape).at[slot].set(
        k[:, 0].astype(k_arena.dtype)).reshape(k_arena.shape)
    v_arena = v_arena.reshape(flat_shape).at[slot].set(
        v[:, 0].astype(v_arena.dtype)).reshape(v_arena.shape)
    attn = paged_attention(q, k_arena, v_arena, block_tables, pos + 1,
                           window=cfg.window, backend=attn_backend)
    x = x + linear(lp["wo"], attn.reshape(B, 1, -1))
    h = rms_norm(x, lp["mlp_norm"], cfg.norm_eps)
    x = x + _mlp(lp, h, cfg)
    return x, k_arena, v_arena


def _windowed_decode(q, k_cache, v_cache, cache_len, valid_from):
    import math as _m
    from ..parallel import policy as pol
    from .layers import _repeat_kv
    B, _, H, hd = q.shape
    k = _repeat_kv(k_cache, H)
    v = _repeat_kv(v_cache, H)
    qf = (q.astype(jnp.float32) / _m.sqrt(hd)).reshape(B, H, hd)
    scores = jnp.einsum("bhd,bshd->bhs", qf, k.astype(jnp.float32))
    scores = pol.shard(scores, ("fsdp", "model", None))
    ar = jnp.arange(k_cache.shape[1])[None]
    valid = (ar < cache_len[:, None]) & (ar >= valid_from[:, None])
    scores = jnp.where(valid[:, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhs,bshd->bhd", probs, v.astype(jnp.float32))
    return out.reshape(B, 1, H, hd).astype(q.dtype)


# --------------------------------------------------------------------------
# full forward
# --------------------------------------------------------------------------

def _embed_inputs(params, batch, cfg):
    """Returns (x [B,S,d], positions)."""
    if "embeds" in batch:                      # vlm / audio stub frontend
        x = batch["embeds"].astype(cfg.dtype)
        B, S = x.shape[:2]
    else:
        tokens = batch["tokens"]
        B, S = tokens.shape
        x = jnp.take(params["embed"], tokens, axis=0)
    if "positions" in batch:
        positions = batch["positions"]
    elif cfg.mrope_sections is not None:
        positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
        positions = jnp.broadcast_to(positions[None], (3, B, S))
    else:
        positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    return x, positions


def _auto_q_chunks(S: int) -> int:
    return max(1, S // 4096) if S > 8192 else 1


def forward(params, batch, cfg, unroll: bool = False, collect_kv: bool = False):
    """Full-sequence forward. Returns (logits, caches|None)."""
    from ..parallel import policy as pol
    x, positions = _embed_inputs(params, batch, cfg)
    x = pol.shard(x, ("fsdp", None, None))
    q_chunks = _auto_q_chunks(x.shape[1])

    blk = partial(block_forward, positions=positions, cfg=cfg, q_chunks=q_chunks)
    if unroll:
        ublk = jax.checkpoint(blk) if (cfg.remat and not collect_kv) else blk
        kvs = []
        L = cfg.n_layers
        for i in range(L):
            lp = jax.tree.map(lambda p: p[i], params["layers"])
            x, kv = ublk(lp, x)
            if collect_kv:
                kvs.append(kv)
        caches = _stack_kv(kvs) if collect_kv else None
    else:
        def body(h, lp):
            h, kv = blk(lp, h)
            return h, kv if collect_kv else None
        fn = jax.checkpoint(body) if (cfg.remat and not collect_kv) else body
        x, kvs = jax.lax.scan(fn, x, params["layers"])
        caches = (kvs[0], kvs[1]) if collect_kv else None

    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    head = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    logits = pol.shard(linear(head, x), ("fsdp", None, "model"))
    return logits, caches


def _stack_kv(kvs):
    k = jnp.stack([kv[0] for kv in kvs])
    v = jnp.stack([kv[1] for kv in kvs])
    return (k, v)


# --------------------------------------------------------------------------
# launch contract
# --------------------------------------------------------------------------

def loss_fn(params, batch, cfg, unroll: bool = False):
    logits, _ = forward(params, batch, cfg, unroll=unroll)
    labels = batch["labels"]
    lse = jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)
    gold = jnp.take_along_axis(logits.astype(jnp.float32),
                               labels[..., None], axis=-1)[..., 0]
    mask = (labels >= 0).astype(jnp.float32)
    loss = jnp.sum((lse - gold) * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    aux = {}
    if cfg.moe is not None:
        # load-balance aux on the input embeddings of each layer is costly to
        # recover post-hoc; use first-layer input as proxy signal.
        x, _ = _embed_inputs(params, batch, cfg)
        lp0 = jax.tree.map(lambda p: p[0], params["layers"])
        aux["lb_loss"] = moe_lib.aux_load_balance_loss(lp0["moe"], x, cfg)
        loss = loss + 0.01 * aux["lb_loss"]
    return loss, aux


def init_cache(cfg, batch_size: int, max_len: int):
    L, KV, hd = cfg.n_layers, cfg.n_kv_heads, cfg.hd
    shape = (L, batch_size, max_len, KV, hd)
    return {"k": jnp.zeros(shape, cfg.dtype), "v": jnp.zeros(shape, cfg.dtype),
            "pos": jnp.zeros((), jnp.int32)}


def prefill(params, batch, cfg, unroll: bool = False):
    """Run the full prompt; return (last-token logits, filled caches)."""
    logits, (k, v) = forward(params, batch, cfg, unroll=unroll, collect_kv=True)
    S = k.shape[2]
    caches = {"k": k, "v": v, "pos": jnp.array(S, jnp.int32)}
    return logits[:, -1], caches


def decode_step(params, caches, batch, cfg, unroll: bool = False):
    """One new token for every sequence. batch: {"tokens": [B, 1]}.

    caches: {"k"/"v": [L, B, Smax, KV, hd], "pos": filled length — a scalar
    (lock-step batch) or a [B] vector (slot-indexed caches: each row of the
    batch is an independent serving slot at its own sequence position)}.
    """
    tokens = batch["tokens"]
    B = tokens.shape[0]
    x = jnp.take(params["embed"], tokens, axis=0)        # [B,1,d]
    pos = caches["pos"]

    if unroll:
        ks, vs = [], []
        for i in range(cfg.n_layers):
            lp = jax.tree.map(lambda p: p[i], params["layers"])
            x, kc, vc = block_decode(lp, x, caches["k"][i], caches["v"][i], pos, cfg)
            ks.append(kc); vs.append(vc)
        new_k, new_v = jnp.stack(ks), jnp.stack(vs)
    else:
        def body(h, xs):
            lp, kc, vc = xs
            h, kc, vc = block_decode(lp, h, kc, vc, pos, cfg)
            return h, (kc, vc)
        x, (new_k, new_v) = jax.lax.scan(body, x, (params["layers"], caches["k"], caches["v"]))

    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    head = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    logits = linear(head, x)[:, 0]                        # [B, V]
    return logits, {"k": new_k, "v": new_v, "pos": pos + 1}


def forward_with_prefix(params, batch, cfg, prefix_k, prefix_v):
    """Mid-sequence prefill chunk against already-computed context.

    This is the serving engine's one chunked-forward primitive, covering
    both cases that continue a sequence whose leading KV already exists:
    a paged prefix-cache hit (the context was computed by an earlier
    request) and a chunked-prefill step (the context is this request's own
    earlier chunks — slot or paged layout, the caller gathers it either
    way).

    ``batch["tokens"]`` [B, S] are the next S tokens of each sequence;
    ``prefix_k/v`` [L, B, P, KV, hd] is the KV of the P tokens before
    them.  RoPE positions and the causal/sliding-window mask are offset by
    P, so chunk token i sits at absolute position P + i and attends to the
    whole prefix plus its own causal context — numerically the same as
    prefilling the full sequence in one shot, minus the FLOPs/HBM for the
    P already-written positions.  Where the KV lands (slot offset or block
    table slots) is the pools' concern; this function only returns the
    chunk's fresh KV.

    Returns (logits [B, S, V], (k, v) chunk caches [L, B, S, KV, hd]).
    """
    from ..parallel import policy as pol
    tokens = batch["tokens"]
    B, S = tokens.shape
    P = prefix_k.shape[2]
    x = jnp.take(params["embed"], tokens, axis=0)
    positions = jnp.broadcast_to(P + jnp.arange(S)[None], (B, S))
    if cfg.mrope_sections is not None:
        positions = jnp.broadcast_to(positions[None], (3, B, S))
    x = pol.shard(x, ("fsdp", None, None))
    q_chunks = _auto_q_chunks(S)

    def body(h, xs):
        lp, pk, pv = xs
        h, kv = block_forward(lp, h, positions, cfg, q_chunks=q_chunks,
                              prior_kv=(pk, pv))
        return h, kv
    x, (k, v) = jax.lax.scan(body, x, (params["layers"], prefix_k, prefix_v))

    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    head = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    logits = pol.shard(linear(head, x), ("fsdp", None, "model"))
    return logits, (k, v)


def decode_step_paged(params, caches, batch, cfg, attn_backend=None):
    """One new token for every row over the paged arena.

    caches: {"k"/"v": [L, n_blocks, block_size, KV, hd] arenas,
    "block_tables": [B, nb] int32, "pos": [B] filled lengths}.  Mirrors
    ``decode_step`` but consumes block tables instead of per-slot
    contiguous buffers; rows at different sequence positions (and with
    non-contiguous physical blocks) advance in one fused step.
    """
    tokens = batch["tokens"]
    x = jnp.take(params["embed"], tokens, axis=0)        # [B,1,d]
    bt, pos = caches["block_tables"], caches["pos"]

    def body(h, xs):
        lp, kc, vc = xs
        h, kc, vc = block_decode_paged(lp, h, kc, vc, bt, pos, cfg,
                                       attn_backend=attn_backend)
        return h, (kc, vc)
    x, (new_k, new_v) = jax.lax.scan(
        body, x, (params["layers"], caches["k"], caches["v"]))

    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    head = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    logits = linear(head, x)[:, 0]                        # [B, V]
    return logits, {"k": new_k, "v": new_v, "block_tables": bt,
                    "pos": pos + 1}
