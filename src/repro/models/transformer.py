"""Decoder-only transformer (dense / MoE / VLM backbone).

Covers: gemma-7b (GeGLU, head_dim 256), qwen3-8b (qk_norm), internlm2-1.8b,
nemotron-4-340b (squared-ReLU, non-gated), qwen2-vl-72b (M-RoPE, embed-input),
mixtral-8x7b (MoE + SWA), llama4-maverick (MoE top-1 + shared expert), and the
paper's llama2/3 + mistral models.

Layer weights are stacked [L, ...]; the forward pass runs either
``lax.scan`` over layers (training: fast compile, remat-able) or an unrolled
Python loop (``unroll=True``, used by the dry-run so XLA cost analysis counts
every layer — see DESIGN.md §6).

Entry points per the launch contract:
  loss_fn(params, batch, cfg)                          — training
  prefill(params, batch, cfg) -> (logits, caches)      — inference prefill
  decode_lockstep(params, caches, batch, cfg)          — lock-step decode

Serving runs on ONE attention path: ``unified_step`` /
``attend_over_pool``.  Every serving step — chunked prefill (q_len =
chunk), one-shot prefill (q_len = prompt, cursor = 0), and fused decode
(q_len = 1) — writes its fresh KV into the engine's KV arena (slot rows or
paged blocks, addressed by a pool view from ``serving/cache_pool.py`` /
``serving/paged/pool.py``) and attends IN PLACE against that arena with
the per-request cursor as a length mask.  Nothing ever gathers a copy of
the already-written prefix, so a prefill chunk's HBM traffic is
independent of how much prefix the request has written — O(P) total over
a P-token prompt instead of the O(P^2/budget) the old gather-based
chunk path paid.  ``decode_lockstep`` and ``block_decode`` are thin
adapters over the same primitive for the legacy lock-step loop (and
zamba's shared-attention block), so there is exactly one masking /
RoPE-offset / write implementation.

Speculative verify is the same entry with q_len = k+1: the engine feeds
``[last_token, d1..dk]`` at cursor = written-prefix length and reads a
distribution per position from one call — the per-query causal length
mask makes the batched scoring bitwise-identical to k+1 sequential
decode steps, which is what makes greedy speculative decoding
token-identical to the non-speculative stream (serving/speculative.py).

Sharding: the forward/decode paths are placement-agnostic.  Training and
the dry-run shard through the activation policy (parallel/policy.py, a
no-op when inactive); the serving engine instead commits params and KV
arenas to explicit NamedShardings (serving/placement.py) and lets GSPMD
propagate, so the same code serves single-device and tensor-parallel.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp

from .layers import (activation, apply_rope, attend_length_masked,
                     dense_init, linear, rms_norm, sdpa, split_keys)
from . import moe as moe_lib


# --------------------------------------------------------------------------
# init
# --------------------------------------------------------------------------

def init_params(key, cfg, scale_layers: bool = True):
    d, hd, H, KV, L = cfg.d_model, cfg.hd, cfg.n_heads, cfg.n_kv_heads, cfg.n_layers
    dtype = cfg.dtype
    ks = split_keys(key, 12)

    def stack(initf, *shape_key):
        outs = [initf(k) for k in split_keys(shape_key[0], L)]
        return jnp.stack(outs)

    layers = {
        "attn_norm": jnp.zeros((L, d), dtype),
        "wq": stack(lambda k: dense_init(k, H * hd, d, dtype), ks[0]),
        "wk": stack(lambda k: dense_init(k, KV * hd, d, dtype), ks[1]),
        "wv": stack(lambda k: dense_init(k, KV * hd, d, dtype), ks[2]),
        "wo": stack(lambda k: dense_init(k, d, H * hd, dtype), ks[3]),
        "mlp_norm": jnp.zeros((L, d), dtype),
    }
    if cfg.qk_norm:
        layers["q_norm"] = jnp.zeros((L, hd), dtype)
        layers["k_norm"] = jnp.zeros((L, hd), dtype)
    if cfg.moe is not None:
        sub = [moe_lib.init_moe(k, cfg, dtype) for k in split_keys(ks[4], L)]
        layers["moe"] = jax.tree.map(lambda *xs: jnp.stack(xs), *sub)
    else:
        if cfg.glu:
            layers["w_gate"] = stack(lambda k: dense_init(k, cfg.d_ff, d, dtype), ks[5])
        layers["w_up"] = stack(lambda k: dense_init(k, cfg.d_ff, d, dtype), ks[6])
        layers["w_down"] = stack(lambda k: dense_init(k, d, cfg.d_ff, dtype), ks[7])

    params = {
        "embed": (jax.random.normal(ks[8], (cfg.vocab, d), jnp.float32)
                  * 0.02).astype(dtype),
        "layers": layers,
        "final_norm": jnp.zeros((d,), dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(ks[9], cfg.vocab, d, dtype)
    return params


# --------------------------------------------------------------------------
# one block
# --------------------------------------------------------------------------

def _project_qkv(lp, x, cfg, positions):
    B, S, d = x.shape
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    q = linear(lp["wq"], x).reshape(B, S, H, hd)
    k = linear(lp["wk"], x).reshape(B, S, KV, hd)
    v = linear(lp["wv"], x).reshape(B, S, KV, hd)
    if cfg.qk_norm:
        q = rms_norm(q, lp["q_norm"], cfg.norm_eps)
        k = rms_norm(k, lp["k_norm"], cfg.norm_eps)
    q = apply_rope(q, positions, cfg.rope_theta, cfg.mrope_sections)
    k = apply_rope(k, positions, cfg.rope_theta, cfg.mrope_sections)
    return q, k, v


def _mlp(lp, x, cfg):
    from ..parallel import policy as pol
    if cfg.moe is not None:
        return moe_lib.moe_apply(lp["moe"], x, cfg)
    if cfg.glu:
        hidden = activation(cfg.act, linear(lp["w_gate"], x)) * linear(lp["w_up"], x)
    else:
        hidden = activation(cfg.act, linear(lp["w_up"], x))
    hidden = pol.shard(hidden, ("fsdp", None, "model"))
    return linear(lp["w_down"], hidden)


def block_forward(lp, x, positions, cfg, q_chunks: int = 1, causal: bool = True,
                  prior_kv=None):
    """Full-sequence block (train / legacy prefill). Returns (y, (k, v)).

    ``prior_kv`` = (k, v) [B, P, KV, hd] of an already-computed context:
    queries attend to prior + fresh keys with a ``q_offset`` of P, and
    only the fresh suffix KV is returned.  The serving engine no longer
    uses this (chunks attend in place via ``attend_over_pool``); it stays
    as the gather-style reference that benchmarks measure against.

    Activation constraints pin the batch (fsdp) sharding at block boundaries —
    without them GSPMD can flip to a d_model-sharded/batch-replicated layout
    whose temps are mesh-times larger (see DESIGN.md §Perf log)."""
    from ..parallel import policy as pol
    x = pol.shard(x, ("fsdp", None, None))
    h = rms_norm(x, lp["attn_norm"], cfg.norm_eps)
    q, k, v = _project_qkv(lp, h, cfg, positions)
    q = pol.shard(q, ("fsdp", None, "model", None))
    if prior_kv is not None:
        pk, pv = prior_kv
        k_all = jnp.concatenate([pk.astype(k.dtype), k], axis=1)
        v_all = jnp.concatenate([pv.astype(v.dtype), v], axis=1)
        attn = sdpa(q, k_all, v_all, causal=causal, window=cfg.window,
                    q_chunks=q_chunks, q_offset=pk.shape[1])
    else:
        attn = sdpa(q, k, v, causal=causal, window=cfg.window,
                    q_chunks=q_chunks)
    x = x + linear(lp["wo"], attn.reshape(*attn.shape[:2], -1))
    x = pol.shard(x, ("fsdp", None, None))
    h = rms_norm(x, lp["mlp_norm"], cfg.norm_eps)
    x = x + _mlp(lp, h, cfg)
    return x, (k, v)


# --------------------------------------------------------------------------
# the unified serving attention path: write into the pool, attend in place
# --------------------------------------------------------------------------

def _cursor_vec(pos, B: int):
    """[B] int32 cursor from a scalar (lock-step) or per-row position."""
    if jnp.ndim(pos) == 1:
        return pos.astype(jnp.int32)
    return jnp.broadcast_to(pos, (B,)).astype(jnp.int32)


def _pool_positions(cursor, S: int, cfg):
    """RoPE positions for S fresh tokens per lane starting at ``cursor``
    — [B, S], or [3, B, S] under M-RoPE (t/h/w share the text position
    on the serving path)."""
    base = cursor[:, None] + jnp.arange(S)[None]
    if cfg.mrope_sections is not None:
        return jnp.broadcast_to(base[None], (3,) + base.shape)
    return base


def attend_over_pool(q, pool_view, *, cursor=None, q_offset=None,
                     window: int | None = None, backend: str | None = None):
    """THE serving attention primitive: ``q`` [B, S, H, hd] attends
    directly against a KV pool arena — slot rows or paged blocks — with
    the per-request cursor as a length mask.

    ``pool_view`` is a per-layer ``SlotPoolView`` / ``PagedPoolView``
    (serving/cache_pool.py, serving/paged/pool.py) whose ``k``/``v`` hold
    ONE layer's arena slice and whose addressing fields say where each
    batch lane's sequence lives.  Query i of lane b sits at absolute
    position ``q_offset[b] + i`` and sees arena positions
    ``j <= q_offset[b] + i`` (window-limited); ``q_offset`` defaults to
    ``cursor`` (both default to ``pool_view.cursor``), which is exactly
    right when the step's fresh KV was scattered at the cursor before
    attending — causality then hides this step's not-yet-visible writes,
    stale tokens of previous slot/block occupants, and padding, so
    chunked prefill (S = chunk), one-shot prefill (S = prompt, cursor =
    0), and fused decode (S = 1) are all the same computation.

    Never materializes gathered prefix context: per-step prefix HBM
    traffic is bounded by the arena rows/blocks touched, independent of
    how much prefix each lane has already written.
    """
    cursor = pool_view.cursor if cursor is None else cursor
    q_offset = cursor if q_offset is None else q_offset
    if pool_view.block_tables is not None:
        from ..serving.paged.paged_attention import paged_attention
        return paged_attention(q, pool_view.k, pool_view.v,
                               pool_view.block_tables, q_offset,
                               window=window, backend=backend,
                               k_scale=pool_view.k_scale,
                               v_scale=pool_view.v_scale)
    k_rows, v_rows = pool_view.lane_kv(pool_view.k, pool_view.v)
    ks = vs = None
    if pool_view.k_scale is not None:
        ks, vs = pool_view.lane_kv(pool_view.k_scale, pool_view.v_scale)
    return attend_length_masked(q, k_rows, v_rows, q_offset, window=window,
                                k_scale=ks, v_scale=vs)


def _block_step(lp, x, k_l, v_l, view, positions, cfg, attn_backend,
                ks_l=None, vs_l=None):
    """One block of the unified step: project q/k/v at the lane cursor
    positions, scatter the fresh KV into the layer's arena slice (in
    place under donation), and attend over the pool.  Returns
    (y, k_l, v_l[, ks_l, vs_l]) with the updated arena slices.  With
    scale slices (int8 arena) the fresh KV is quantized on scatter and
    attention dequantizes in place — the quantized path's extra state
    is just the two [.., KV] scale slices riding alongside."""
    from ..parallel import policy as pol
    B, S, _ = x.shape
    h = rms_norm(x, lp["attn_norm"], cfg.norm_eps)
    q, k, v = _project_qkv(lp, h, cfg, positions)
    q = pol.shard(q, ("fsdp", None, "model", None))
    if ks_l is not None:
        k_l, v_l, ks_l, vs_l = view.write_layer_quantized(
            k_l, v_l, ks_l, vs_l, k, v)
        attn = attend_over_pool(
            q, dataclasses.replace(view, k=k_l, v=v_l, k_scale=ks_l,
                                   v_scale=vs_l),
            window=cfg.window, backend=attn_backend)
    else:
        k_l, v_l = view.write_layer(k_l, v_l, k, v)
        attn = attend_over_pool(q, dataclasses.replace(view, k=k_l, v=v_l),
                                window=cfg.window, backend=attn_backend)
    x = x + linear(lp["wo"], attn.reshape(B, S, -1))
    h = rms_norm(x, lp["mlp_norm"], cfg.norm_eps)
    x = x + _mlp(lp, h, cfg)
    if ks_l is not None:
        return x, k_l, v_l, ks_l, vs_l
    return x, k_l, v_l


def unified_step(params, view, batch, cfg, *, attn_backend=None,
                 unroll: bool = False):
    """One attend-in-place step over a KV pool: the only serving
    attention path.

    ``batch["tokens"]`` [B, S] are the next S tokens of each lane,
    starting at ``view.cursor`` (per-lane RoPE/mask offset — chunk token
    i sits at absolute position cursor + i).  Fresh KV is written into
    the view's arenas layer by layer (the engine donates them, so the
    multi-GB buffers update in place), and attention reads the arena
    directly with the cursor as a length mask.  Covers every serving
    shape: S = prompt & cursor = 0 is one-shot prefill, S = chunk is
    chunked prefill (numerically the one-shot prefill it replaces), and
    S = 1 over all lanes is the fused decode.

    Returns (logits [B, S, V], (k, v)) — the updated [L, ...] arenas —
    or (logits, (k, v, k_scale, v_scale)) when the view carries an int8
    arena's scale tensors (they join the per-layer scan as two more
    donated-through leaves).
    """
    from ..parallel import policy as pol
    tokens = batch["tokens"]
    B, S = tokens.shape
    x = jnp.take(params["embed"], tokens, axis=0)
    positions = _pool_positions(view.cursor, S, cfg)
    x = pol.shard(x, ("fsdp", None, None))
    quantized = view.k_scale is not None

    if unroll:
        ks, vs, kss, vss = [], [], [], []
        for i in range(cfg.n_layers):
            lp = jax.tree.map(lambda p: p[i], params["layers"])
            if quantized:
                x, k_l, v_l, ks_l, vs_l = _block_step(
                    lp, x, view.k[i], view.v[i], view, positions, cfg,
                    attn_backend, view.k_scale[i], view.v_scale[i])
                kss.append(ks_l)
                vss.append(vs_l)
            else:
                x, k_l, v_l = _block_step(lp, x, view.k[i], view.v[i], view,
                                          positions, cfg, attn_backend)
            ks.append(k_l)
            vs.append(v_l)
        k, v = jnp.stack(ks), jnp.stack(vs)
        if quantized:
            ksc, vsc = jnp.stack(kss), jnp.stack(vss)
    elif quantized:
        def body(h, xs):
            lp, k_l, v_l, ks_l, vs_l = xs
            h, k_l, v_l, ks_l, vs_l = _block_step(
                lp, h, k_l, v_l, view, positions, cfg, attn_backend,
                ks_l, vs_l)
            return h, (k_l, v_l, ks_l, vs_l)
        x, (k, v, ksc, vsc) = jax.lax.scan(
            body, x, (params["layers"], view.k, view.v, view.k_scale,
                      view.v_scale))
    else:
        def body(h, xs):
            lp, k_l, v_l = xs
            h, k_l, v_l = _block_step(lp, h, k_l, v_l, view, positions,
                                      cfg, attn_backend)
            return h, (k_l, v_l)
        x, (k, v) = jax.lax.scan(body, x, (params["layers"], view.k, view.v))

    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    head = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    logits = pol.shard(linear(head, x), ("fsdp", None, "model"))
    if quantized:
        return logits, (k, v, ksc, vsc)
    return logits, (k, v)


def block_decode(lp, x, k_cache, v_cache, pos, cfg):
    """One-token block over contiguous caches. x: [B,1,d]; caches
    [B,Smax,KV,hd]; ``pos`` a scalar (lock-step batch) or [B] vector of
    filled lengths.  A thin adapter over the unified in-place block for
    lock-step callers outside the engine (zamba's shared-attention
    block)."""
    from ..serving.cache_pool import SlotPoolView
    B = x.shape[0]
    cursor = _cursor_vec(pos, B)
    view = SlotPoolView(k=None, v=None, rows=None, cursor=cursor,
                        n_new=jnp.ones((B,), jnp.int32))
    return _block_step(lp, x, k_cache, v_cache, view,
                       _pool_positions(cursor, 1, cfg), cfg, None)


def decode_lockstep(params, caches, batch, cfg, unroll: bool = False):
    """One new token for every sequence through the unified primitive —
    the model-zoo decode contract for the legacy lock-step loop and the
    dry-run.  batch: {"tokens": [B, 1]}.

    caches: {"k"/"v": [L, B, Smax, KV, hd], "pos": filled length — a
    scalar (lock-step batch) or a [B] vector (each row at its own
    sequence position)}.  The [B, Smax] cache layout IS a slot arena with
    one slot per row, so this is ``unified_step`` with an identity lane
    map and S = 1.
    """
    from ..serving.cache_pool import SlotPoolView
    tokens = batch["tokens"]
    B = tokens.shape[0]
    pos = caches["pos"]
    view = SlotPoolView(k=caches["k"], v=caches["v"], rows=None,
                        cursor=_cursor_vec(pos, B),
                        n_new=jnp.ones((B,), jnp.int32))
    logits, (k, v) = unified_step(params, view, {"tokens": tokens}, cfg,
                                  unroll=unroll)
    return logits[:, -1], {"k": k, "v": v, "pos": pos + 1}


# --------------------------------------------------------------------------
# full forward
# --------------------------------------------------------------------------

def _embed_inputs(params, batch, cfg):
    """Returns (x [B,S,d], positions)."""
    if "embeds" in batch:                      # vlm / audio stub frontend
        x = batch["embeds"].astype(cfg.dtype)
        B, S = x.shape[:2]
    else:
        tokens = batch["tokens"]
        B, S = tokens.shape
        x = jnp.take(params["embed"], tokens, axis=0)
    if "positions" in batch:
        positions = batch["positions"]
    elif cfg.mrope_sections is not None:
        positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
        positions = jnp.broadcast_to(positions[None], (3, B, S))
    else:
        positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    return x, positions


def _auto_q_chunks(S: int) -> int:
    return max(1, S // 4096) if S > 8192 else 1


def forward(params, batch, cfg, unroll: bool = False, collect_kv: bool = False):
    """Full-sequence forward. Returns (logits, caches|None)."""
    from ..parallel import policy as pol
    x, positions = _embed_inputs(params, batch, cfg)
    x = pol.shard(x, ("fsdp", None, None))
    q_chunks = _auto_q_chunks(x.shape[1])

    blk = partial(block_forward, positions=positions, cfg=cfg, q_chunks=q_chunks)
    if unroll:
        ublk = jax.checkpoint(blk) if (cfg.remat and not collect_kv) else blk
        kvs = []
        L = cfg.n_layers
        for i in range(L):
            lp = jax.tree.map(lambda p: p[i], params["layers"])
            x, kv = ublk(lp, x)
            if collect_kv:
                kvs.append(kv)
        caches = _stack_kv(kvs) if collect_kv else None
    else:
        def body(h, lp):
            h, kv = blk(lp, h)
            return h, kv if collect_kv else None
        fn = jax.checkpoint(body) if (cfg.remat and not collect_kv) else body
        x, kvs = jax.lax.scan(fn, x, params["layers"])
        caches = (kvs[0], kvs[1]) if collect_kv else None

    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    head = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    logits = pol.shard(linear(head, x), ("fsdp", None, "model"))
    return logits, caches


def _stack_kv(kvs):
    k = jnp.stack([kv[0] for kv in kvs])
    v = jnp.stack([kv[1] for kv in kvs])
    return (k, v)


# --------------------------------------------------------------------------
# launch contract
# --------------------------------------------------------------------------

def loss_fn(params, batch, cfg, unroll: bool = False):
    logits, _ = forward(params, batch, cfg, unroll=unroll)
    labels = batch["labels"]
    lse = jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)
    gold = jnp.take_along_axis(logits.astype(jnp.float32),
                               labels[..., None], axis=-1)[..., 0]
    mask = (labels >= 0).astype(jnp.float32)
    loss = jnp.sum((lse - gold) * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    aux = {}
    if cfg.moe is not None:
        # load-balance aux on the input embeddings of each layer is costly to
        # recover post-hoc; use first-layer input as proxy signal.
        x, _ = _embed_inputs(params, batch, cfg)
        lp0 = jax.tree.map(lambda p: p[0], params["layers"])
        aux["lb_loss"] = moe_lib.aux_load_balance_loss(lp0["moe"], x, cfg)
        loss = loss + 0.01 * aux["lb_loss"]
    return loss, aux


def init_cache(cfg, batch_size: int, max_len: int):
    L, KV, hd = cfg.n_layers, cfg.n_kv_heads, cfg.hd
    shape = (L, batch_size, max_len, KV, hd)
    return {"k": jnp.zeros(shape, cfg.dtype), "v": jnp.zeros(shape, cfg.dtype),
            "pos": jnp.zeros((), jnp.int32)}


def prefill(params, batch, cfg, unroll: bool = False):
    """Run the full prompt; return (last-token logits, filled caches)."""
    logits, (k, v) = forward(params, batch, cfg, unroll=unroll, collect_kv=True)
    S = k.shape[2]
    caches = {"k": k, "v": v, "pos": jnp.array(S, jnp.int32)}
    return logits[:, -1], caches
