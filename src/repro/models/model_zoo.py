"""Uniform model API over all families.

  zoo = get_model(cfg)
  params = zoo.init(key)
  loss, aux = zoo.loss(params, batch)
  logits, caches = zoo.prefill(params, batch)
  logits, caches = zoo.decode(params, caches, batch)

``input_specs(cfg, shape, dtype)`` builds jax.ShapeDtypeStruct stand-ins for
every input of the corresponding step — the dry-run contract (no allocation).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from . import transformer as tfm
from . import whisper as whi
from . import xlstm as xls
from . import zamba as zam


@dataclasses.dataclass(frozen=True)
class ModelZoo:
    cfg: Any
    init: Callable
    loss: Callable
    prefill: Callable
    decode: Callable
    init_cache: Callable | None = None


def get_model(cfg) -> ModelZoo:
    fam = cfg.family
    if fam in ("dense", "moe", "vlm"):
        return ModelZoo(
            cfg=cfg,
            init=lambda key: tfm.init_params(key, cfg),
            loss=lambda p, b, unroll=False: tfm.loss_fn(p, b, cfg, unroll),
            prefill=lambda p, b, unroll=False: tfm.prefill(p, b, cfg, unroll),
            decode=lambda p, c, b, unroll=False: tfm.decode_lockstep(p, c, b, cfg, unroll),
            init_cache=lambda bs, ml: tfm.init_cache(cfg, bs, ml),
        )
    if fam == "ssm":
        return ModelZoo(
            cfg=cfg,
            init=lambda key: xls.init_params(key, cfg),
            loss=lambda p, b, unroll=False: xls.loss_fn(p, b, cfg, unroll),
            prefill=lambda p, b, unroll=False: xls.prefill(p, b, cfg, unroll),
            decode=lambda p, c, b, unroll=False: xls.decode_lockstep(p, c, b, cfg, unroll),
            init_cache=lambda bs, ml: {"states": xls.init_state(cfg, bs),
                                       "pos": jnp.zeros((), jnp.int32)},
        )
    if fam == "hybrid":
        return ModelZoo(
            cfg=cfg,
            init=lambda key: zam.init_params(key, cfg),
            loss=lambda p, b, unroll=False: zam.loss_fn(p, b, cfg, unroll),
            prefill=lambda p, b, unroll=False: zam.prefill(p, b, cfg, unroll),
            decode=lambda p, c, b, unroll=False: zam.decode_lockstep(p, c, b, cfg, unroll),
            init_cache=lambda bs, ml: zam.init_cache(cfg, bs, ml),
        )
    if fam == "encdec":
        return ModelZoo(
            cfg=cfg,
            init=lambda key: whi.init_params(key, cfg),
            loss=lambda p, b, unroll=False: whi.loss_fn(p, b, cfg, unroll),
            prefill=lambda p, b, unroll=False: whi.prefill(p, b, cfg, unroll),
            decode=lambda p, c, b, unroll=False: whi.decode_lockstep(p, c, b, cfg, unroll),
        )
    raise ValueError(f"unknown family {fam}")


# --------------------------------------------------------------------------
# dry-run input specs (ShapeDtypeStruct stand-ins, no allocation)
# --------------------------------------------------------------------------

def input_specs(cfg, shape, *, for_decode_cache: bool = False) -> dict:
    """Inputs for the step implied by ``shape.kind``.

    train:   {"tokens"/"embeds", "labels", ...}
    prefill: prompt batch
    decode:  {"tokens": [B,1]} + cache specs (built by cache_specs()).
    """
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32

    def tok(shape_):
        return jax.ShapeDtypeStruct(shape_, i32)

    if cfg.family == "vlm":
        base = {"embeds": jax.ShapeDtypeStruct((B, S, cfg.d_model), cfg.dtype),
                "positions": tok((3, B, S))}
    elif cfg.family == "encdec":
        base = {"embeds": jax.ShapeDtypeStruct((B, S, cfg.d_model), cfg.dtype),
                "tokens": tok((B, S))}
    else:
        base = {"tokens": tok((B, S))}

    if shape.kind == "train":
        return {**base, "labels": tok((B, S))}
    if shape.kind == "prefill":
        return base
    # decode: one new token against a cache of length S
    return {"tokens": tok((B, 1))}


def cache_specs(cfg, shape) -> dict:
    """ShapeDtypeStruct pytree matching the model's decode cache."""
    B, S = shape.global_batch, shape.seq_len
    dt = cfg.dtype
    f32 = jnp.float32
    i32 = jnp.int32
    sds = jax.ShapeDtypeStruct
    if cfg.family in ("dense", "moe", "vlm"):
        L, KV, hd = cfg.n_layers, cfg.n_kv_heads, cfg.hd
        return {"k": sds((L, B, S, KV, hd), dt), "v": sds((L, B, S, KV, hd), dt),
                "pos": sds((), i32)}
    if cfg.family == "ssm":
        di = 2 * cfg.d_model
        H = cfg.n_heads
        dh = di // H
        states = []
        for i in range(cfg.n_layers):
            if cfg.slstm_every and (i % cfg.slstm_every) == (cfg.slstm_every - 1):
                states.append((sds((B, H, dh), f32),) * 3
                              + (sds((B, H, dh), f32),))
            else:
                states.append((sds((B, H, dh, dh), f32), sds((B, H, dh), f32)))
        return {"states": states, "pos": sds((), i32)}
    if cfg.family == "hybrid":
        di = 2 * cfg.d_model
        H = di // cfg.ssm_head_dim
        states = [(sds((B, H, cfg.ssm_state, cfg.ssm_head_dim), f32), None)
                  for _ in range(cfg.n_layers)]
        n_attn = cfg.n_layers // cfg.attn_every if cfg.attn_every else 0
        kvs = [(sds((B, S, cfg.n_kv_heads, cfg.hd), dt),
                sds((B, S, cfg.n_kv_heads, cfg.hd), dt)) for _ in range(n_attn)]
        return {"states": states, "kv": kvs, "pos": sds((), i32)}
    if cfg.family == "encdec":
        L, KV, hd = cfg.n_layers, cfg.n_kv_heads, cfg.hd
        return {"k": sds((L, B, S, KV, hd), dt), "v": sds((L, B, S, KV, hd), dt),
                "ck": sds((L, B, S, KV, hd), dt), "cv": sds((L, B, S, KV, hd), dt),
                "pos": sds((), i32)}
    raise ValueError(cfg.family)


def grow_caches(caches: dict, new_len: int) -> dict:
    """Pad decode caches so the sequence axis holds ``new_len`` positions.

    Handles every family's cache layout: dense/moe/vlm and encdec grow the
    self-attention "k"/"v" buffers [L, B, S, KV, hd] (encdec cross-attention
    "ck"/"cv" stay at encoder length); hybrid grows each per-application
    ("kv") pair [B, S, KV, hd]; recurrent state ("states") needs no growth.
    No-op for buffers already at >= new_len.
    """
    out = dict(caches)
    for key in ("k", "v"):
        if key in out and hasattr(out[key], "shape"):
            cur = out[key].shape[2]
            if cur < new_len:
                widths = [(0, 0)] * out[key].ndim
                widths[2] = (0, new_len - cur)
                out[key] = jnp.pad(out[key], widths)
    if "kv" in out:
        def pad_pair(kv):
            k, v = kv
            if k.shape[1] >= new_len:
                return (k, v)
            widths = [(0, 0), (0, new_len - k.shape[1]), (0, 0), (0, 0)]
            return (jnp.pad(k, widths), jnp.pad(v, widths))
        out["kv"] = [pad_pair(kv) for kv in out["kv"]]
    return out


def param_specs(cfg) -> Any:
    """ShapeDtypeStruct pytree of the model params (eval_shape, no alloc)."""
    zoo = get_model(cfg)
    return jax.eval_shape(lambda: zoo.init(jax.random.PRNGKey(0)))
