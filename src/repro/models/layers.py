"""Shared neural building blocks (pure JAX, parameter dicts).

Conventions:
  - Linear weights are stored ``[out, in]`` (paper convention W[C_out, C_in]);
    apply is ``y = einsum('...k,ok->...o', x, w)``.
  - All blocks are bias-free with RMSNorm unless noted (llama lineage).
  - Functions take a params dict and are vmap/scan/jit friendly.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp


def linear(w, x: jax.Array) -> jax.Array:
    """y = x @ W^T.  Dispatches on compressed SparseWeight containers
    (models/sparse_serving.py) so the whole zoo serves sparse unchanged."""
    if hasattr(w, "nm_values"):
        from .sparse_serving import sparse_apply
        return sparse_apply(w, x)
    return jnp.einsum("...k,ok->...o", x, w)


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * (1.0 + scale.astype(jnp.float32))
            ).astype(x.dtype)


def activation(name: str, x: jax.Array) -> jax.Array:
    if name == "silu":
        return jax.nn.silu(x)
    if name == "gelu":
        return jax.nn.gelu(x)
    if name == "sq_relu":          # nemotron-4: squared ReLU
        r = jax.nn.relu(x)
        return r * r
    raise ValueError(f"unknown activation {name}")


# --------------------------------------------------------------------------
# RoPE (standard + M-RoPE)
# --------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float = 10000.0) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float = 10000.0,
               mrope_sections: tuple[int, ...] | None = None) -> jax.Array:
    """Rotate ``x [..., S, H, hd]`` by position.

    ``positions``: [..., S] for standard RoPE, or [3, ..., S] (t/h/w) with
    ``mrope_sections`` = per-section pair counts summing to hd//2 (Qwen2-VL
    M-RoPE: each frequency pair is driven by one of the three position ids).
    """
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                               # [hd/2]
    if mrope_sections is None:
        angles = positions[..., None].astype(jnp.float32) * freqs  # [...,S,hd/2]
    else:
        assert positions.shape[0] == 3 and sum(mrope_sections) == hd // 2
        parts = []
        start = 0
        for sec_i, sec in enumerate(mrope_sections):
            f = freqs[start:start + sec]
            parts.append(positions[sec_i][..., None].astype(jnp.float32) * f)
            start += sec
        angles = jnp.concatenate(parts, axis=-1)                # [...,S,hd/2]
    cos = jnp.cos(angles)[..., None, :]                          # [...,S,1,hd/2]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------------
# Attention (GQA / MQA / MHA), full + chunked(flash-style) + decode
# --------------------------------------------------------------------------

def _repeat_kv(k: jax.Array, H: int) -> jax.Array:
    """[B,S,KV,hd] -> [B,S,H,hd] by repeating each KV head H//KV times.

    Keeping attention in an H-major layout lets the `model` axis sharding of
    the q heads propagate through scores/probs (the grouped [KV, g] layout
    silently replicates multi-GiB score tensors under GSPMD)."""
    KV = k.shape[2]
    if KV == H:
        return k
    return jnp.repeat(k, H // KV, axis=2)


def _sdpa_full(q, k, v, causal: bool, window: int | None,
               q_offset: int = 0) -> jax.Array:
    """q [B,Sq,H,hd], k/v [B,Sk,KV,hd]; repeats KV groups; returns [B,Sq,H,hd]."""
    from ..parallel import policy as pol
    B, Sq, H, hd = q.shape
    k = _repeat_kv(k, H)
    v = _repeat_kv(v, H)
    k = pol.shard(k, ("fsdp", None, "model", None))
    v = pol.shard(v, ("fsdp", None, "model", None))
    qf = q.astype(jnp.float32) / math.sqrt(hd)
    scores = jnp.einsum("bqhd,bshd->bhqs", qf, k.astype(jnp.float32))
    scores = pol.shard(scores, ("fsdp", "model", None, None))
    qpos = jnp.arange(Sq) + q_offset
    kpos = jnp.arange(k.shape[1])
    mask = jnp.ones((Sq, k.shape[1]), bool)
    if causal:
        mask &= kpos[None, :] <= qpos[:, None]
    if window is not None:
        mask &= kpos[None, :] > qpos[:, None] - window
    scores = jnp.where(mask[None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhqs,bshd->bqhd", probs, v.astype(jnp.float32))
    return out.astype(q.dtype)


def sdpa(q, k, v, *, causal: bool = True, window: int | None = None,
         q_chunks: int = 1, q_offset: int = 0) -> jax.Array:
    """Scaled dot-product attention with optional query chunking.

    ``q_chunks > 1`` processes queries in chunks (memory O(Sq/q_chunks * Sk)
    per step) via lax.scan — the pure-JAX flash-attention analogue used for
    long-context prefill.  Chunking only changes memory, not math (keys are
    not chunked; no online softmax needed).
    """
    if q_chunks <= 1 or q.shape[1] % q_chunks:
        return _sdpa_full(q, k, v, causal, window, q_offset)
    B, Sq, H, hd = q.shape
    cs = Sq // q_chunks
    qc = q.reshape(B, q_chunks, cs, H, hd).transpose(1, 0, 2, 3, 4)

    def body(carry, args):
        i, qi = args
        o = _sdpa_full(qi, k, v, causal, window, q_offset + i * cs)
        return carry, o

    _, outs = jax.lax.scan(body, None, (jnp.arange(q_chunks), qc))
    return outs.transpose(1, 0, 2, 3, 4).reshape(B, Sq, H, hd)


def attend_length_masked(q, k_cache, v_cache, q_offset, *,
                         window: int | None = None,
                         k_scale=None, v_scale=None) -> jax.Array:
    """Length-masked attention over statically-sized caches: the serving
    in-place attention for contiguous (slot) KV buffers.

    ``q`` [B,S,H,hd] holds S fresh queries per row; query i of row b sits
    at absolute position ``q_offset[b] + i`` and attends to cache
    positions ``j <= q_offset[b] + i`` (window-limited when ``window`` is
    set) of ``k_cache``/``v_cache`` [B,T,KV,hd].  The caches are full
    arenas with static T; everything past each query's own position —
    stale tokens of a previous occupant, this step's not-yet-causal
    writes, allocation padding — is masked with a finite ``-1e30`` whose
    exp underflows to exactly 0.0, so masked garbage contributes nothing.

    ``k_scale``/``v_scale`` [B,T,KV] dequantize int8 caches on the fly:
    the multiply fuses into the f32 upcast the einsums already do, so the
    int8 arena is the only KV ever read from HBM — no bf16 copy.

    S=1 with ``q_offset = filled_len - 1`` is classic decode attention;
    S>1 with ``q_offset = prefill cursor`` is an in-place prefill chunk.
    """
    from ..parallel import policy as pol
    B, S, H, hd = q.shape
    if k_scale is not None:
        k_cache = k_cache.astype(jnp.float32) * k_scale[..., None]
        v_cache = v_cache.astype(jnp.float32) * v_scale[..., None]
    k = _repeat_kv(k_cache, H)
    v = _repeat_kv(v_cache, H)
    qf = q.astype(jnp.float32) / math.sqrt(hd)
    scores = jnp.einsum("bqhd,bshd->bhqs", qf, k.astype(jnp.float32))
    scores = pol.shard(scores, ("fsdp", "model", None, None))
    qpos = q_offset[:, None] + jnp.arange(S)[None]            # [B,S]
    kpos = jnp.arange(k_cache.shape[1])                       # [T]
    valid = kpos[None, None, :] <= qpos[:, :, None]           # [B,S,T]
    if window is not None:
        valid &= kpos[None, None, :] > qpos[:, :, None] - window
    scores = jnp.where(valid[:, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhqs,bshd->bqhd", probs, v.astype(jnp.float32))
    return out.astype(q.dtype)


def decode_attention(q, k_cache, v_cache, cache_len) -> jax.Array:
    """Single-token attention: q [B,1,H,hd] over caches [B,S,KV,hd].

    ``cache_len`` masks positions >= len (static S buffers, dynamic fill).
    The S=1 specialization of ``attend_length_masked`` (kept as the
    lock-step decode entry point for the enc-dec family)."""
    return attend_length_masked(q, k_cache, v_cache, cache_len - 1)


def attend_kv_length(q, k_cache, v_cache, kv_len) -> jax.Array:
    """Non-causal attention over a length-masked KV buffer: cross-attention
    for serving.  ``q`` [B,S,H,hd] attends to ``k_cache``/``v_cache``
    [B,T,KV,hd] positions ``j < kv_len[b]`` — every query of a row sees the
    same keys regardless of its own position (encoder context is fully
    visible), with per-row true lengths masking arena padding at -1e30.
    Identical einsum/softmax structure to ``attend_length_masked`` so a
    decode step through either is bitwise-comparable across batch shapes."""
    from ..parallel import policy as pol
    B, S, H, hd = q.shape
    k = _repeat_kv(k_cache, H)
    v = _repeat_kv(v_cache, H)
    qf = q.astype(jnp.float32) / math.sqrt(hd)
    scores = jnp.einsum("bqhd,bshd->bhqs", qf, k.astype(jnp.float32))
    scores = pol.shard(scores, ("fsdp", "model", None, None))
    kpos = jnp.arange(k_cache.shape[1])                       # [T]
    valid = kpos[None, :] < kv_len[:, None]                   # [B,T]
    scores = jnp.where(valid[:, None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhqs,bshd->bqhd", probs, v.astype(jnp.float32))
    return out.astype(q.dtype)


# --------------------------------------------------------------------------
# Parameter init helpers
# --------------------------------------------------------------------------

def dense_init(key, out_dim: int, in_dim: int, dtype) -> jax.Array:
    scale = 1.0 / math.sqrt(in_dim)
    return (jax.random.normal(key, (out_dim, in_dim), jnp.float32) * scale
            ).astype(dtype)


def split_keys(key, n: int):
    return list(jax.random.split(key, n))
