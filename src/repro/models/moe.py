"""Mixture-of-Experts FFN — two execution paths:

1. ``_moe_local`` (single device / CPU tests): capacity-free sort +
   jax.lax.ragged_dot (megablocks-style), exact, no token dropping.

2. ``_moe_ep`` (active sharding policy, i.e. a real mesh): GShard-style
   expert parallelism under shard_map —
     - tokens stay sharded over fsdp=(pod,data); experts are owned by fsdp
       shards (E % |fsdp| == 0: mixtral 8e/16 falls back to ff-TP-only),
     - capacity-bounded dispatch buffers [E, C, d] move tokens to their
       expert's shard with ONE all_to_all over fsdp, results come back with a
       second all_to_all (EP),
     - each expert's FFN hidden dim is sharded over `model`; the down-proj
       partial sums psum over `model` (TP within expert).
   Per-chip buffers are O(T_local * capacity_factor), never O(T_global) —
   this is what keeps llama4-maverick (128e) compilable at 256-4096 chips.

Router always runs in fp32.  Capacity overflow drops tokens (standard GShard
semantics); the local path is exact, and tests bound the disagreement.
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .layers import activation, dense_init, linear, split_keys
from ..parallel import policy as pol


def init_moe(key, cfg, dtype):
    m = cfg.moe
    d, ff, E = cfg.d_model, m.d_ff_expert, m.num_experts
    ks = split_keys(key, 8)
    p = {
        "router": dense_init(ks[0], E, d, jnp.float32),
        # expert weights laid out for grouped GEMMs: [E, in, out]
        "we_gate": (jax.random.normal(ks[1], (E, d, ff), jnp.float32)
                    / jnp.sqrt(d)).astype(dtype),
        "we_up": (jax.random.normal(ks[2], (E, d, ff), jnp.float32)
                  / jnp.sqrt(d)).astype(dtype),
        "we_down": (jax.random.normal(ks[3], (E, ff, d), jnp.float32)
                    / jnp.sqrt(ff)).astype(dtype),
    }
    if m.shared_expert:
        p["ws_gate"] = dense_init(ks[4], ff, d, dtype)
        p["ws_up"] = dense_init(ks[5], ff, d, dtype)
        p["ws_down"] = dense_init(ks[6], d, ff, dtype)
    return p


# --------------------------------------------------------------------------
# local exact path (ragged_dot)
# --------------------------------------------------------------------------

def _expert_ffn_ragged(p, xs, group_sizes, cfg):
    if cfg.glu:
        g = jax.lax.ragged_dot(xs, p["we_gate"], group_sizes)
        u = jax.lax.ragged_dot(xs, p["we_up"], group_sizes)
        h = activation(cfg.act, g) * u
    else:
        h = activation(cfg.act, jax.lax.ragged_dot(xs, p["we_up"], group_sizes))
    return jax.lax.ragged_dot(h, p["we_down"], group_sizes)


def _moe_local(p, x2, cfg):
    m = cfg.moe
    T = x2.shape[0]
    logits = x2.astype(jnp.float32) @ p["router"].T
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_e = jax.lax.top_k(probs, m.top_k)
    top_w = top_w / jnp.sum(top_w, axis=-1, keepdims=True)

    flat_e = top_e.reshape(-1)
    flat_t = jnp.repeat(jnp.arange(T), m.top_k)
    order = jnp.argsort(flat_e, stable=True)
    xs = x2[flat_t[order]]
    group_sizes = jnp.bincount(flat_e, length=m.num_experts).astype(jnp.int32)
    ys = _expert_ffn_ragged(p, xs, group_sizes, cfg)
    ys = ys * top_w.reshape(-1)[order][:, None].astype(ys.dtype)
    return jnp.zeros_like(x2).at[flat_t[order]].add(ys)


# --------------------------------------------------------------------------
# EP + TP path (shard_map)
# --------------------------------------------------------------------------

def _dispatch_local(x2, top_e, top_w, E, k, C):
    """Build per-expert capacity buffers [E, C, d] + combine metadata."""
    T = x2.shape[0]
    flat_e = top_e.reshape(-1)                                  # [T*k]
    flat_t = jnp.repeat(jnp.arange(T), k)
    # position of each (token,choice) within its expert queue:
    onehot_cum = jnp.cumsum(jax.nn.one_hot(flat_e, E, dtype=jnp.int32), axis=0)
    pos = jnp.take_along_axis(onehot_cum, flat_e[:, None], axis=1)[:, 0] - 1
    keep = pos < C
    buf = jnp.zeros((E, C, x2.shape[1]), x2.dtype)
    buf = buf.at[flat_e, jnp.clip(pos, 0, C - 1)].add(
        jnp.where(keep[:, None], x2[flat_t], 0))
    return buf, (flat_e, flat_t, pos, keep)


def _combine_local(y_buf, meta, top_w, T, k):
    flat_e, flat_t, pos, keep = meta
    gathered = y_buf[flat_e, jnp.clip(pos, 0, y_buf.shape[1] - 1)]
    gathered = jnp.where(keep[:, None], gathered, 0)
    w = top_w.reshape(-1)[:, None].astype(gathered.dtype)
    out = jnp.zeros((T, y_buf.shape[-1]), gathered.dtype)
    return out.at[flat_t].add(gathered * w)


def _moe_ep_body(x2, router, wg, wu, wd, cfg, fsdp_axes, ep: bool,
                 capacity_factor: float = 1.25):
    """Runs per (fsdp, model) shard. x2: [T_l, d] local tokens; w*: local
    expert slices — [E(_l if ep), d, ff_l] etc."""
    m = cfg.moe
    E, k = m.num_experts, m.top_k
    T_l, d = x2.shape

    logits = x2.astype(jnp.float32) @ router.T                   # [T_l, E]
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_e = jax.lax.top_k(probs, k)
    top_w = top_w / jnp.sum(top_w, axis=-1, keepdims=True)

    C = max(8, int(math.ceil(T_l * k * capacity_factor / E)))
    buf, meta = _dispatch_local(x2, top_e, top_w, E, k, C)       # [E, C, d]

    if ep:
        D = jax.lax.psum(1, fsdp_axes)                           # |fsdp| shards
        E_l = E // D
        send = buf.reshape(D, E_l, C, d)
        recv = jax.lax.all_to_all(send, fsdp_axes, split_axis=0,
                                  concat_axis=0, tiled=False)     # [D, E_l, C, d]
        xs = recv.reshape(E_l, D * C, d)                          # my experts
    else:
        xs = buf                                                  # [E, C, d]

    def ffn(w_gate, w_up, w_down, h_in):
        if cfg.glu:
            hidden = activation(cfg.act, jnp.einsum("ecd,edf->ecf", h_in, w_gate)) \
                * jnp.einsum("ecd,edf->ecf", h_in, w_up)
        else:
            hidden = activation(cfg.act, jnp.einsum("ecd,edf->ecf", h_in, w_up))
        return jnp.einsum("ecf,efd->ecd", hidden, w_down)

    y = ffn(wg, wu, wd, xs)
    y = jax.lax.psum(y, "model")                                 # TP-ff partials

    if ep:
        back = y.reshape(D, E_l, C, d)
        y_buf = jax.lax.all_to_all(back, fsdp_axes, split_axis=0,
                                   concat_axis=0, tiled=False).reshape(E, C, d)
    else:
        y_buf = y
    return _combine_local(y_buf, meta, top_w, T_l, k).astype(x2.dtype)


def _moe_ep(p, x2, cfg):
    """shard_map wrapper; x2: [T, d] with T sharded over fsdp."""
    from jax.experimental.shard_map import shard_map
    polst = pol._current()
    mesh = polst["mesh"]
    fs = polst["fsdp"]
    m = cfg.moe
    n_fsdp = math.prod(mesh.shape[a] for a in fs)
    ep = m.num_experts % n_fsdp == 0 and x2.shape[0] % n_fsdp == 0
    fsdp_in_body = fs if len(fs) > 1 else fs[0]

    x2_spec = P(fs, None)
    # expert weights: [E, d, ff] — E over fsdp when EP, ff over model
    if ep:
        wg_spec = P(fs, None, "model")
        wd_spec = P(fs, "model", None)
    else:
        wg_spec = P(None, None, "model")
        wd_spec = P(None, "model", None)

    body = partial(_moe_ep_body, cfg=cfg, fsdp_axes=fsdp_in_body, ep=ep)
    fn = shard_map(body, mesh=mesh,
                   in_specs=(x2_spec, P(None, None), wg_spec, wg_spec, wd_spec),
                   out_specs=x2_spec, check_rep=False)
    return fn(x2, p["router"], p["we_gate"], p["we_up"], p["we_down"])


def moe_apply(p, x: jax.Array, cfg) -> jax.Array:
    """x: [..., d] -> [..., d]. Chooses EP+TP (mesh) or exact local path."""
    lead = x.shape[:-1]
    d = x.shape[-1]
    x2 = x.reshape(-1, d)
    if pol._current() is not None:
        out = _moe_ep(p, x2, cfg)
    else:
        out = _moe_local(p, x2, cfg)
    if cfg.moe.shared_expert:
        sg = activation(cfg.act, linear(p["ws_gate"], x2))
        hidden = sg * linear(p["ws_up"], x2)
        if pol._current() is not None:
            hidden = pol.shard(hidden, ("fsdp", "model"))
        out = out + linear(p["ws_down"], hidden)
    return out.reshape(*lead, d)


def aux_load_balance_loss(p, x: jax.Array, cfg) -> jax.Array:
    """Switch-style load-balancing auxiliary loss (used by train_step)."""
    m = cfg.moe
    x2 = x.reshape(-1, x.shape[-1])
    probs = jax.nn.softmax(x2.astype(jnp.float32) @ p["router"].T, axis=-1)
    top_e = jnp.argmax(probs, axis=-1)
    frac_tokens = jnp.mean(jax.nn.one_hot(top_e, m.num_experts), axis=0)
    frac_probs = jnp.mean(probs, axis=0)
    return m.num_experts * jnp.sum(frac_tokens * frac_probs)
