"""xLSTM (Beck et al., 2024 — arXiv:2405.04517): mLSTM + sLSTM blocks.

xlstm-350m: 24 layers, d_model=1024, 4 heads; mostly mLSTM with sLSTM blocks
interleaved every ``cfg.slstm_every`` layers (xLSTM[a:b] style).

mLSTM block (pre-LN, projection factor 2):
  x -> up-proj to 2*di, split (cell input, output gate branch)
  q,k,v projections at di; scalar i/f gates per head from the cell input
  chunkwise matrix-memory recurrence (linear_attn.chunked_gla, normalizer on)
  y = cell_out * silu(gate branch); down-proj back to d; residual.

sLSTM block: scalar-memory recurrence with per-head recurrent mixing,
strictly sequential (lax.scan over time) — kept faithful since sLSTM's
non-diagonalizable recurrence has no parallel form (xLSTM paper §2.3).

State layout for serving: per layer dict (kind-dependent):
  mLSTM: C [B,H,dk,dv], n [B,H,dk]
  sLSTM: c,n,h [B,di]
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .layers import dense_init, linear, rms_norm, split_keys
from .linear_attn import chunked_gla, masked_gates


def _di(cfg):
    return 2 * cfg.d_model


def init_params(key, cfg):
    d, L = cfg.d_model, cfg.n_layers
    di = _di(cfg)
    H = cfg.n_heads
    dh = di // H
    dtype = cfg.dtype
    ks = split_keys(key, 10)

    def stack(initf, key):
        return jnp.stack([initf(k) for k in split_keys(key, L)])

    layers = {
        "norm": jnp.zeros((L, d), dtype),
        "w_up": stack(lambda k: dense_init(k, 2 * di, d, dtype), ks[0]),
        "w_q": stack(lambda k: dense_init(k, di, di, dtype), ks[1]),
        "w_k": stack(lambda k: dense_init(k, di, di, dtype), ks[2]),
        "w_v": stack(lambda k: dense_init(k, di, di, dtype), ks[3]),
        "w_gates": stack(lambda k: dense_init(k, 2 * H, di, dtype), ks[4]),
        "w_down": stack(lambda k: dense_init(k, d, di, dtype), ks[5]),
        # sLSTM recurrent weights (used only at sLSTM layers; per-head block
        # diagonal approximated by per-head dense R over dh):
        "r_gates": stack(lambda k: (jax.random.normal(k, (4, H, dh, dh), jnp.float32)
                                    / jnp.sqrt(dh)).astype(dtype), ks[6]),
        "w_slstm": stack(lambda k: dense_init(k, 4 * di, di, dtype), ks[7]),
    }
    params = {
        "embed": (jax.random.normal(ks[8], (cfg.vocab, d), jnp.float32) * 0.02
                  ).astype(dtype),
        "layers": layers,
        "final_norm": jnp.zeros((d,), dtype),
        "lm_head": dense_init(ks[9], cfg.vocab, d, dtype),
    }
    return params


def _is_slstm(cfg, i: int) -> bool:
    k = cfg.slstm_every
    return k > 0 and (i % k) == (k - 1)


# ---------------------------------------------------------------- mLSTM ----

def _mlstm_qkvgates(lp, xc, cfg):
    di = xc.shape[-1]
    H = cfg.n_heads
    dh = di // H
    B, S = xc.shape[:2]
    q = linear(lp["w_q"], xc).reshape(B, S, H, dh) / jnp.sqrt(dh).astype(xc.dtype)
    k = linear(lp["w_k"], xc).reshape(B, S, H, dh) / jnp.sqrt(dh).astype(xc.dtype)
    v = linear(lp["w_v"], xc).reshape(B, S, H, dh)
    gates = linear(lp["w_gates"], xc).astype(jnp.float32)        # [B,S,2H]
    log_i = jax.nn.log_sigmoid(gates[..., :H])
    log_f = jax.nn.log_sigmoid(gates[..., H:])
    return q, k, v, log_f, log_i


def mlstm_block(lp, x, cfg, state=None, chunk: int = 64, valid=None):
    """Full-sequence mLSTM block. Returns (y, new_state).

    ``valid`` [B,S] marks the real tokens of a right-padded batch; padded
    positions get neutral gates (masked_gates) so the carried state is
    bit-identical to processing the real prefix alone."""
    from ..parallel import policy as pol
    B, S, d = x.shape
    # xlstm-350m is small (4 heads): DP-only activation layout — every [B,...]
    # tensor is pinned to the fsdp axis so nothing replicates across `model`.
    x = pol.shard(x, ("fsdp", None, None))
    h = rms_norm(x, lp["norm"], cfg.norm_eps)
    up = pol.shard(linear(lp["w_up"], h), ("fsdp", None, None))
    xc, xg = jnp.split(up, 2, axis=-1)                           # [B,S,di] each
    q, k, v, log_f, log_i = _mlstm_qkvgates(lp, xc, cfg)
    if valid is not None:
        log_f, log_i = masked_gates(log_f, log_i, valid)
    y, new_state = chunked_gla(q, k, v, log_f, log_i, chunk=chunk,
                               normalizer=True, initial_state=state)
    y = y.reshape(B, S, -1) * jax.nn.silu(xg)
    return x + linear(lp["w_down"], y), new_state


# ---------------------------------------------------------------- sLSTM ----

def _slstm_step(lp, cfg, carry, zifo_t):
    """carry: (c, n, h, m) each [B,H,dh]; zifo_t: [B,4,H,dh] pre-activations."""
    c, n, h, m = carry
    H = cfg.n_heads
    rec = jnp.einsum("bhd,ghde->bghe", h, lp["r_gates"].astype(jnp.float32))
    z_t, i_t, f_t, o_t = [zifo_t[:, g].astype(jnp.float32) + rec[:, g]
                          for g in range(4)]
    # stabilized exponential gating (xLSTM eq. 15-17)
    log_f = jax.nn.log_sigmoid(f_t)
    m_new = jnp.maximum(log_f + m, i_t)
    i_s = jnp.exp(i_t - m_new)
    f_s = jnp.exp(log_f + m - m_new)
    c_new = f_s * c + i_s * jnp.tanh(z_t)
    n_new = f_s * n + i_s
    h_new = jax.nn.sigmoid(o_t) * c_new / jnp.maximum(n_new, 1.0)
    return (c_new, n_new, h_new, m_new), h_new


def slstm_block(lp, x, cfg, state=None, valid=None):
    from ..parallel import policy as pol
    B, S, d = x.shape
    di = _di(cfg)
    H = cfg.n_heads
    dh = di // H
    x = pol.shard(x, ("fsdp", None, None))
    h_in = rms_norm(x, lp["norm"], cfg.norm_eps)
    up = pol.shard(linear(lp["w_up"], h_in), ("fsdp", None, None))
    xc, xg = jnp.split(up, 2, axis=-1)
    zifo = linear(lp["w_slstm"], xc).reshape(B, S, 4, H, dh)
    if state is None:
        z = jnp.zeros((B, H, dh), jnp.float32)
        state = (z, z, z, jnp.full((B, H, dh), -1e30, jnp.float32))
    if valid is None:
        carry, hs = jax.lax.scan(partial(_slstm_step, lp, cfg), state,
                                 zifo.swapaxes(0, 1))             # scan over S
    else:
        # padded positions: where-select keeps each lane's carry bitwise
        # untouched (the scan still runs, its result is discarded per lane)
        def step(carry, xs):
            zifo_t, valid_t = xs
            new_carry, h = _slstm_step(lp, cfg, carry, zifo_t)
            vm = valid_t[:, None, None]
            kept = tuple(jnp.where(vm, nc, oc)
                         for nc, oc in zip(new_carry, carry))
            return kept, h
        carry, hs = jax.lax.scan(step, state,
                                 (zifo.swapaxes(0, 1), valid.swapaxes(0, 1)))
    y = hs.swapaxes(0, 1).reshape(B, S, di).astype(x.dtype) * jax.nn.silu(xg)
    return x + linear(lp["w_down"], y), carry


# ------------------------------------------------------------ full model ---

def _layer_params(params, i):
    return jax.tree.map(lambda p: p[i], params["layers"])


def forward(params, batch, cfg, unroll: bool = False, states=None,
            return_states: bool = False):
    """xLSTM blocks are heterogeneous (mLSTM/sLSTM) so the layer loop is
    always a Python loop; time-recurrence inside each block uses lax.scan."""
    tokens = batch["tokens"]
    x = jnp.take(params["embed"], tokens, axis=0)
    new_states = []
    # remat each block: backward keeps only [B,S,d] inputs per layer
    s_fn = partial(slstm_block, cfg=cfg)
    m_fn = partial(mlstm_block, cfg=cfg)
    if cfg.remat:
        s_fn, m_fn = jax.checkpoint(s_fn), jax.checkpoint(m_fn)
    for i in range(cfg.n_layers):
        lp = _layer_params(params, i)
        st = states[i] if states is not None else None
        if _is_slstm(cfg, i):
            x, s = s_fn(lp, x, state=st)
        else:
            x, s = m_fn(lp, x, state=st)
        new_states.append(s)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = linear(params["lm_head"], x)
    return (logits, new_states) if return_states else (logits, None)


def loss_fn(params, batch, cfg, unroll: bool = False):
    logits, _ = forward(params, batch, cfg)
    labels = batch["labels"]
    lse = jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)
    gold = jnp.take_along_axis(logits.astype(jnp.float32), labels[..., None],
                               axis=-1)[..., 0]
    mask = (labels >= 0).astype(jnp.float32)
    return jnp.sum((lse - gold) * mask) / jnp.maximum(mask.sum(), 1.0), {}


def init_state(cfg, batch_size: int):
    di = _di(cfg)
    H = cfg.n_heads
    dh = di // H
    states = []
    for i in range(cfg.n_layers):
        if _is_slstm(cfg, i):
            # three SEPARATE buffers: serving donates the state arenas into
            # the jitted step, and aliased leaves would be donated twice
            states.append(tuple(jnp.zeros((batch_size, H, dh), jnp.float32)
                                for _ in range(3))
                          + (jnp.full((batch_size, H, dh), -1e30, jnp.float32),))
        else:
            states.append((jnp.zeros((batch_size, H, dh, dh), jnp.float32),
                           jnp.zeros((batch_size, H, dh), jnp.float32)))
    return states


def prefill(params, batch, cfg, unroll: bool = False):
    logits, states = forward(params, batch, cfg, states=None, return_states=True)
    return logits[:, -1], {"states": states,
                           "pos": jnp.array(batch["tokens"].shape[1], jnp.int32)}


def lane_init(cfg, i: int, batch_size: int):
    """Layer ``i``'s fresh state for ``batch_size`` lanes — the per-layer
    unit of ``init_state``, used by ``unified_step`` to initialise fresh
    lanes in-jit (RecurrentStateView.select_fresh)."""
    di = _di(cfg)
    H = cfg.n_heads
    dh = di // H
    if _is_slstm(cfg, i):
        z = jnp.zeros((batch_size, H, dh), jnp.float32)
        return (z, z, z, jnp.full((batch_size, H, dh), -1e30, jnp.float32))
    return (jnp.zeros((batch_size, H, dh, dh), jnp.float32),
            jnp.zeros((batch_size, H, dh), jnp.float32))


def unified_step(params, view, batch, cfg, *, unroll: bool = False):
    """One serving step over a ``RecurrentStateView`` — the xLSTM analogue
    of ``transformer.unified_step``.

    ``batch["tokens"]`` [B,S] holds each lane's next tokens right-padded to
    S; ``view.n_new`` masks the padding (neutral gates / carry selects), so
    per-lane state after the step is bit-identical to running the real
    tokens alone.  Lanes at cursor 0 pick up their fresh family init state
    inside the jit; lanes with n_new == 0 (inactive / padding rows) leave
    their slot's state leaves bitwise untouched.  Returns
    (logits [B,S,V], new state arenas) — arenas to be pool.adopt()ed.
    """
    tokens = batch["tokens"]
    B, S = tokens.shape
    x = jnp.take(params["embed"], tokens, axis=0)
    valid = jnp.arange(S)[None, :] < view.n_new[:, None]          # [B,S]
    took = (view.n_new > 0)
    new_arenas = []
    for i in range(cfg.n_layers):
        lp = _layer_params(params, i)
        lane_st = view.gather_layer(i)
        st = view.select_fresh(lane_st, lane_init(cfg, i, B))
        if _is_slstm(cfg, i):
            x, s = slstm_block(lp, x, cfg, state=st, valid=valid)
        else:
            x, s = mlstm_block(lp, x, cfg, state=st, valid=valid)
        # inactive lanes: restore the slot's original bits (masking already
        # makes the update a numeric no-op; this also keeps signed zeros)
        s = jax.tree.map(
            lambda new, old: jnp.where(
                took.reshape(took.shape + (1,) * (new.ndim - 1)), new, old),
            s, lane_st)
        new_arenas.append(view.scatter_layer(i, s))
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = linear(params["lm_head"], x)
    return logits, new_arenas


def decode_lockstep(params, caches, batch, cfg, unroll: bool = False):
    """Reference lock-step decode: one token for every row of the batch.

    Built on ``unified_step`` (S=1 over the whole batch as one state view)
    so its float operation order is IDENTICAL to the engine's fused decode
    — the parity oracle for engine token streams."""
    from ..serving.state_pool import RecurrentStateView
    tokens = batch["tokens"]
    B = tokens.shape[0]
    cursor = jnp.broadcast_to(jnp.asarray(caches["pos"], jnp.int32), (B,))
    view = RecurrentStateView(caches["states"], None, cursor,
                              jnp.ones((B,), jnp.int32))
    logits, new_states = unified_step(params, view, batch, cfg, unroll=unroll)
    return logits[:, -1], {"states": new_states, "pos": caches["pos"] + 1}
