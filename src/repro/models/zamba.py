"""Mamba2 (SSD) blocks + Zamba2 hybrid (arXiv:2411.15242).

Mamba2 block (Dao & Gu 2024, simplified — no causal conv, noted in DESIGN):
  x -> in_proj -> (z [di], xc [di], B [N], C [N], dt [H])  with di = 2*d,
  H = di/head_dim heads, N = ssm_state.
  scalar-decay recurrence per head:  h' = exp(dt*A) h + dt * B x
  -> shared chunkwise engine (linear_attn.chunked_gla) with
     q=C, k=B (broadcast over heads), v=dt*x, log_f=dt*A.
  y = (ssd_out + D*xc) * silu(z); out_proj; residual.

Zamba2 hybrid: ``cfg.n_layers`` Mamba2 blocks; ONE shared transformer block
(full attention + MLP, single weight set) applied after every
``cfg.attn_every`` Mamba2 blocks — weight sharing across applications is the
Zamba signature; each application has its own KV cache.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .layers import dense_init, linear, rms_norm, split_keys
from .linear_attn import chunked_gla, masked_gates
from . import transformer as tfm


def _dims(cfg):
    di = 2 * cfg.d_model
    H = di // cfg.ssm_head_dim
    return di, H, cfg.ssm_state


def init_params(key, cfg):
    d, L = cfg.d_model, cfg.n_layers
    di, H, N = _dims(cfg)
    dtype = cfg.dtype
    ks = split_keys(key, 8)

    def stack(initf, key):
        return jnp.stack([initf(k) for k in split_keys(key, L)])

    proj_out = 2 * di + 2 * N + H
    mamba = {
        "norm": jnp.zeros((L, d), dtype),
        "in_proj": stack(lambda k: dense_init(k, proj_out, d, dtype), ks[0]),
        "out_proj": stack(lambda k: dense_init(k, d, di, dtype), ks[1]),
        "A_log": jnp.zeros((L, H), jnp.float32),       # A = -exp(A_log)
        "D": jnp.ones((L, H), jnp.float32),
        "dt_bias": jnp.zeros((L, H), jnp.float32),
    }
    params = {
        "embed": (jax.random.normal(ks[2], (cfg.vocab, d), jnp.float32) * 0.02
                  ).astype(dtype),
        "mamba": mamba,
        "final_norm": jnp.zeros((d,), dtype),
        "lm_head": dense_init(ks[3], cfg.vocab, d, dtype),
    }
    if cfg.attn_every:
        # ONE shared attention+MLP block (Zamba2 signature)
        shared_cfg = cfg
        sub = tfm.init_params(jax.random.fold_in(ks[4], 1),
                              _shared_block_cfg(cfg))
        params["shared_attn"] = jax.tree.map(lambda p: p[0], sub["layers"])
    return params


def _shared_block_cfg(cfg):
    import dataclasses
    return dataclasses.replace(cfg, n_layers=1, moe=None, family="dense")


def _ssm_inputs(lp, x, cfg):
    """x: [B,S,d] -> z, q(C), k(B), v(dt*xc), log_f, xc_heads."""
    di, H, N = _dims(cfg)
    B_, S = x.shape[:2]
    proj = linear(lp["in_proj"], x)
    z, xc, Bv, Cv, dt = jnp.split(
        proj, [di, 2 * di, 2 * di + N, 2 * di + 2 * N], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + lp["dt_bias"])   # [B,S,H]
    A = -jnp.exp(lp["A_log"])                                      # [H]
    log_f = dt * A[None, None]                                     # <= 0
    xh = xc.reshape(B_, S, H, cfg.ssm_head_dim)
    v = xh * dt[..., None].astype(xh.dtype)
    q = jnp.broadcast_to(Cv[:, :, None], (B_, S, H, N))
    k = jnp.broadcast_to(Bv[:, :, None], (B_, S, H, N))
    return z, q, k, v, log_f, xh


def mamba_block(lp, x, cfg, state=None, chunk: int = 128, valid=None):
    from ..parallel import policy as pol
    B_, S, d = x.shape
    di, H, N = _dims(cfg)
    x = pol.shard(x, ("fsdp", None, None))
    h = rms_norm(x, lp["norm"], cfg.norm_eps)
    z, q, k, v, log_f, xh = _ssm_inputs(lp, h, cfg)
    z = pol.shard(z, ("fsdp", None, "model"))
    log_i = None
    if valid is not None:
        # right-padded serving batch: neutral gates keep the carried SSM
        # state bit-identical to processing the real prefix alone
        log_f, log_i = masked_gates(log_f, log_i, valid)
    y, new_state = chunked_gla(q, k, v, log_f, log_i, chunk=chunk,
                               normalizer=False, initial_state=state)
    y = y + xh * lp["D"][None, None, :, None].astype(y.dtype)
    y = y.reshape(B_, S, di) * jax.nn.silu(z)
    return x + linear(lp["out_proj"], y), new_state


# ------------------------------------------------------------ full model ---

def _shared_positions(cfg, B, S, offset=0):
    return jnp.broadcast_to(jnp.arange(S)[None] + offset, (B, S))


def forward(params, batch, cfg, unroll: bool = False, states=None,
            return_states: bool = False):
    tokens = batch["tokens"]
    B_, S = tokens.shape
    x = jnp.take(params["embed"], tokens, axis=0)
    new_states, kvs = [], []
    # Python layer loop (heterogeneous blocks): remat each block so backward
    # saves only the [B,S,d] block inputs, not every SSD intermediate.
    mamba_fn = jax.checkpoint(partial(mamba_block, cfg=cfg)) if cfg.remat \
        else partial(mamba_block, cfg=cfg)
    qc = max(1, S // 4096) if S > 8192 else 1
    attn_fn = partial(tfm.block_forward, cfg=_shared_block_cfg(cfg), q_chunks=qc)
    if cfg.remat:
        attn_fn = jax.checkpoint(attn_fn)
    for i in range(cfg.n_layers):
        lp = jax.tree.map(lambda p: p[i], params["mamba"])
        st = states[i] if states is not None else None
        x, s = mamba_fn(lp, x, state=st)
        new_states.append(s)
        if cfg.attn_every and (i % cfg.attn_every) == (cfg.attn_every - 1):
            pos = _shared_positions(cfg, B_, S)
            x, kv = attn_fn(params["shared_attn"], x, pos)
            kvs.append(kv)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = linear(params["lm_head"], x)
    if return_states:
        return logits, (new_states, kvs)
    return logits, None


def loss_fn(params, batch, cfg, unroll: bool = False):
    logits, _ = forward(params, batch, cfg)
    labels = batch["labels"]
    lse = jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)
    gold = jnp.take_along_axis(logits.astype(jnp.float32), labels[..., None],
                               axis=-1)[..., 0]
    mask = (labels >= 0).astype(jnp.float32)
    return jnp.sum((lse - gold) * mask) / jnp.maximum(mask.sum(), 1.0), {}


def prefill(params, batch, cfg, unroll: bool = False, max_len: int | None = None):
    """Returns caches with SSM states + per-application KV caches."""
    tokens = batch["tokens"]
    B_, S = tokens.shape
    max_len = max_len or S
    logits, (states, kvs) = forward(params, batch, cfg, return_states=True)
    # pad KV caches to max_len for decode
    def pad(kv):
        k, v = kv
        pad_width = [(0, 0), (0, max_len - k.shape[1]), (0, 0), (0, 0)]
        return (jnp.pad(k, pad_width), jnp.pad(v, pad_width))
    kvs = [pad(kv) for kv in kvs]
    return logits[:, -1], {"states": states, "kv": kvs,
                           "pos": jnp.array(S, jnp.int32)}


def init_cache(cfg, batch_size: int, max_len: int):
    di, H, N = _dims(cfg)
    states = [(jnp.zeros((batch_size, H, N, cfg.ssm_head_dim), jnp.float32), None)
              for _ in range(cfg.n_layers)]
    n_attn = cfg.n_layers // cfg.attn_every if cfg.attn_every else 0
    KV, hd = cfg.n_kv_heads, cfg.hd
    kvs = [(jnp.zeros((batch_size, max_len, KV, hd), cfg.dtype),
            jnp.zeros((batch_size, max_len, KV, hd), cfg.dtype))
           for _ in range(n_attn)]
    return {"states": states, "kv": kvs, "pos": jnp.zeros((), jnp.int32)}


def lane_init(cfg, i: int, batch_size: int):
    """Layer ``i``'s fresh SSM state for ``batch_size`` lanes (the
    per-layer unit of ``init_cache``'s states list)."""
    di, H, N = _dims(cfg)
    return (jnp.zeros((batch_size, H, N, cfg.ssm_head_dim), jnp.float32),
            None)


def unified_step(params, view, batch, cfg, *, attn_backend=None,
                 unroll: bool = False):
    """One serving step for the hybrid family over a ``HybridPoolView``:
    mamba layers run on the recurrent-state sub-view (``view.state``, gate
    masking + in-jit fresh-state select), shared-attention applications run
    on the KV sub-view (``view.kv`` — SlotPoolView OR PagedPoolView)
    through the same in-place block as the transformer engine, all inside
    ONE jitted step.  The sub-views carry independent ``n_new``: decode
    writes KV for every lane (overwritten-before-read, harmless) but masks
    state updates to active lanes.

    Returns (logits [B,S,V], (k, v) stacked [n_attn, ...] arenas | None,
    new state arenas)."""
    tokens = batch["tokens"]
    B_, S = tokens.shape
    x = jnp.take(params["embed"], tokens, axis=0)
    sview, kview = view.state, view.kv
    valid = jnp.arange(S)[None, :] < sview.n_new[:, None]         # [B,S]
    took = sview.n_new > 0
    scfg = _shared_block_cfg(cfg)
    positions = tfm._pool_positions(kview.cursor, S, scfg) \
        if cfg.attn_every else None
    new_states, ks, vs = [], [], []
    ai = 0
    for i in range(cfg.n_layers):
        lp = jax.tree.map(lambda p: p[i], params["mamba"])
        lane_st = sview.gather_layer(i)
        st = sview.select_fresh(lane_st, lane_init(cfg, i, B_))
        x, s = mamba_block(lp, x, cfg, state=st, valid=valid)
        s = jax.tree.map(
            lambda new, old: jnp.where(
                took.reshape(took.shape + (1,) * (new.ndim - 1)), new, old),
            s, lane_st)
        new_states.append(sview.scatter_layer(i, s))
        if cfg.attn_every and (i % cfg.attn_every) == (cfg.attn_every - 1):
            x, k_l, v_l = tfm._block_step(params["shared_attn"], x,
                                          kview.k[ai], kview.v[ai], kview,
                                          positions, scfg, attn_backend)
            ks.append(k_l)
            vs.append(v_l)
            ai += 1
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = linear(params["lm_head"], x)
    kv = (jnp.stack(ks), jnp.stack(vs)) if ks else None
    return logits, kv, new_states


def decode_lockstep(params, caches, batch, cfg, unroll: bool = False):
    """Reference lock-step decode via ``unified_step`` (S=1, identity lane
    map) — same float operation order as the engine's fused decode."""
    from ..serving.cache_pool import SlotPoolView
    from ..serving.state_pool import HybridPoolView, RecurrentStateView
    tokens = batch["tokens"]
    B_ = tokens.shape[0]
    pos = caches["pos"]
    cursor = tfm._cursor_vec(pos, B_)
    ones = jnp.ones((B_,), jnp.int32)
    sview = RecurrentStateView(caches["states"], None, cursor, ones)
    kvs = caches["kv"]
    k = jnp.stack([kv[0] for kv in kvs]) if kvs else None
    v = jnp.stack([kv[1] for kv in kvs]) if kvs else None
    kview = SlotPoolView(k=k, v=v, rows=None, cursor=cursor, n_new=ones)
    logits, kv, states = unified_step(params, HybridPoolView(kview, sview),
                                      batch, cfg, unroll=unroll)
    new_kvs = [(kv[0][i], kv[1][i]) for i in range(len(kvs))] if kvs else []
    return logits[:, -1], {"states": states, "kv": new_kvs, "pos": pos + 1}
