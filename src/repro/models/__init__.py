"""Model zoo: dense/MoE/VLM transformer, xLSTM, Mamba2+Zamba2 hybrid,
Whisper enc-dec — uniform API via model_zoo.get_model."""

from .model_zoo import (ModelZoo, get_model, grow_caches, input_specs,
                        cache_specs, param_specs)
