"""Chunkwise scalar-decay linear attention — shared engine for mLSTM (xLSTM)
and Mamba2 (SSD).  Both are gated outer-product recurrences

    C_t = f_t * C_{t-1} + i_t * k_t v_t^T          (C: [dk, dv] per head)
    y_t = q_t^T C_t   (/ normalizer for mLSTM)

computed in chunk-parallel form: within a chunk all timesteps are evaluated
with dense matmuls (MXU-friendly), the state is carried across chunks with a
lax.scan.  Gates are scalar per (step, head) with log_f <= 0 (sigmoid/SSD
decay), so intra-chunk factors exp(F_t - F_s) are always <= 1 — numerically
safe without running-max tricks.  (xLSTM's exponential input gating is
replaced by sigmoid gating; shapes/FLOPs identical — DESIGN.md §5.)
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp


def chunked_gla(q, k, v, log_f, log_i=None, *, chunk: int = 64,
                normalizer: bool = False, initial_state=None):
    """q,k: [B,S,H,dk]; v: [B,S,H,dv]; log_f/log_i: [B,S,H] (<= 0).

    Returns (y [B,S,H,dv], final_state) where final_state = (C [B,H,dk,dv],
    n [B,H,dk] or None).
    """
    from ..parallel import policy as pol
    B, S, H, dk = q.shape
    dv = v.shape[-1]
    c = min(chunk, S)
    assert S % c == 0
    nc = S // c
    # Layout: shard heads over `model` when they divide (zamba2: H=80);
    # otherwise shard the state's dv dim (xlstm: H=4, dh=512 — the [dk,dv]
    # matrix state is the memory hog, and every einsum below keeps a
    # dv-sharded layout local, no extra collectives).
    if pol.divides("model", H):
        ax_qk = ("fsdp", None, "model", None)
        ax_v = ("fsdp", None, "model", None)
        ax_state = ("fsdp", "model", None, None)
    else:
        ax_qk = ("fsdp", None, None, None)
        ax_v = ("fsdp", None, None, "model")
        ax_state = ("fsdp", None, None, "model")
    q = pol.shard(q, ax_qk)
    k = pol.shard(k, ax_qk)
    v = pol.shard(v, ax_v)

    def to_chunks(x):
        return x.reshape(B, nc, c, *x.shape[2:]).swapaxes(0, 1)  # [nc,B,c,...]

    qc, kc, vc = map(to_chunks, (q, k, v))
    fc = to_chunks(log_f.astype(jnp.float32))
    ic = to_chunks((log_i if log_i is not None else jnp.zeros_like(log_f))
                   .astype(jnp.float32))

    if initial_state is None:
        C0 = jnp.zeros((B, H, dk, dv), jnp.float32)
        n0 = jnp.zeros((B, H, dk), jnp.float32)
    else:
        C0, n0 = initial_state
        if n0 is None:
            n0 = jnp.zeros((B, H, dk), jnp.float32)
    C0 = pol.shard(C0, ax_state)

    def body(carry, xs):
        C, n = carry
        C = pol.shard(C, ax_state)                   # keep the carry sharded
        qi, ki, vi, fi, ii = xs                      # [B,c,H,*]
        F = jnp.cumsum(fi, axis=1)                   # [B,c,H] inclusive
        Ftot = F[:, -1]                              # [B,H]
        qf = qi.astype(jnp.float32)
        kf = ki.astype(jnp.float32)
        vf = vi.astype(jnp.float32)

        # inter-chunk: y_t += exp(F_t) q_t^T C_prev
        y_inter = jnp.einsum("bthk,bhkv->bthv", qf * jnp.exp(F)[..., None], C)

        # intra-chunk: A[t,s] = exp(F_t - F_s + i_s) for s<=t
        gap = F[:, :, None, :] - F[:, None, :, :] + ii[:, None, :, :]
        tri = jnp.tril(jnp.ones((c, c), bool))
        A = jnp.where(tri[None, :, :, None], jnp.exp(gap), 0.0)   # [B,t,s,H]
        scores = jnp.einsum("bthk,bshk->btsh", qf, kf) * A
        y = y_inter + jnp.einsum("btsh,bshv->bthv", scores, vf)

        # decayed keys for state/normalizer updates
        kdec = kf * jnp.exp(Ftot[:, None] - F + ii)[..., None]     # [B,c,H,dk]
        C_new = C * jnp.exp(Ftot)[..., None, None] \
            + jnp.einsum("bthk,bthv->bhkv", kdec, vf)

        if normalizer:
            n_t = jnp.einsum("bshk,btsh->bthk", kf,
                             jnp.exp(gap) * tri[None, :, :, None].astype(jnp.float32)) \
                + n[:, None] * jnp.exp(F)[..., None]
            denom = jnp.abs(jnp.einsum("bthk,bthk->bth", qf, n_t))
            y = y / jnp.maximum(denom, 1.0)[..., None]
            n_new = n * jnp.exp(Ftot)[..., None] + kdec.sum(axis=1)
        else:
            n_new = n
        return (C_new, n_new), y

    (Cf, nf), ys = jax.lax.scan(body, (C0, n0), (qc, kc, vc, fc, ic))
    y = ys.swapaxes(0, 1).reshape(B, S, H, dv).astype(v.dtype)
    return y, (Cf, nf if normalizer else None)


def masked_gates(log_f, log_i, valid):
    """Neutralize gates at padded positions so ``chunked_gla`` over a padded
    sequence leaves the state BIT-IDENTICAL to running the real tokens only.

    At positions where ``valid`` [B,S] is False: log_f becomes exactly 0.0
    (cumsum adds zeros, decay factor exp(0)=1 — no decay) and log_i becomes
    -1e30 (exp underflows to exactly 0.0 — the k v^T outer product is
    multiplied by a true float zero, not a tiny residue).  ``log_i=None``
    (Mamba's fused i=dt convention folds the input gate into v) maps padded
    positions to an explicit -1e30 gate, so callers must pass the returned
    log_i onward even when they supplied None.
    """
    vm = valid[..., None]                         # [B,S,1] over heads
    log_f = jnp.where(vm, log_f, 0.0)
    base = log_i if log_i is not None else jnp.zeros_like(log_f)
    log_i = jnp.where(vm, base, -1e30)
    return log_f, log_i


def gla_decode_step(q, k, v, log_f, log_i, state, normalizer: bool = False):
    """Single-token recurrence. q,k: [B,H,dk]; v: [B,H,dv]; gates [B,H]."""
    C, n = state
    f = jnp.exp(log_f.astype(jnp.float32))[..., None, None]
    i = jnp.exp((log_i if log_i is not None else jnp.zeros_like(log_f))
                .astype(jnp.float32))[..., None, None]
    kv = jnp.einsum("bhk,bhv->bhkv", k.astype(jnp.float32), v.astype(jnp.float32))
    C_new = f * C + i * kv
    y = jnp.einsum("bhk,bhkv->bhv", q.astype(jnp.float32), C_new)
    if normalizer:
        n_new = f[..., 0] * n + i[..., 0] * k.astype(jnp.float32)
        denom = jnp.abs(jnp.einsum("bhk,bhk->bh", q.astype(jnp.float32), n_new))
        y = y / jnp.maximum(denom, 1.0)[..., None]
    else:
        n_new = n
    return y.astype(v.dtype), (C_new, n_new)
