"""Whisper-style encoder-decoder backbone (arXiv:2212.04356).

Per the assignment spec the audio frontend (log-mel + conv downsampling) is a
STUB: ``input_specs()`` feeds precomputed frame embeddings [B, S_enc, d].
Backbone: bidirectional encoder (24L) + causal decoder (24L) with
cross-attention.  Deviations from upstream Whisper, noted per DESIGN.md:
bias-free linears, RMSNorm instead of LayerNorm, RoPE instead of learned
absolute positions — the transformer backbone shape/FLOPs are identical.

Decode state: decoder self-attn KV caches [L, B, Smax, KV, hd] plus the
projected cross-attention KV (computed once from encoder output at prefill).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .layers import (activation, apply_rope, attend_kv_length, dense_init,
                     linear, rms_norm, sdpa, split_keys)
from . import transformer as tfm


def init_params(key, cfg):
    d, hd, H, KV = cfg.d_model, cfg.hd, cfg.n_heads, cfg.n_kv_heads
    dtype = cfg.dtype
    ks = split_keys(key, 6)

    def stack(initf, key, L):
        return jnp.stack([initf(k) for k in split_keys(key, L)])

    def attn_stack(key, L):
        k1, k2, k3, k4 = split_keys(key, 4)
        return {
            "wq": stack(lambda k: dense_init(k, H * hd, d, dtype), k1, L),
            "wk": stack(lambda k: dense_init(k, KV * hd, d, dtype), k2, L),
            "wv": stack(lambda k: dense_init(k, KV * hd, d, dtype), k3, L),
            "wo": stack(lambda k: dense_init(k, d, H * hd, dtype), k4, L),
        }

    def mlp_stack(key, L):
        k1, k2 = split_keys(key, 2)
        return {
            "w_up": stack(lambda k: dense_init(k, cfg.d_ff, d, dtype), k1, L),
            "w_down": stack(lambda k: dense_init(k, d, cfg.d_ff, dtype), k2, L),
        }

    Le, Ld = cfg.enc_layers, cfg.n_layers
    enc = {"attn_norm": jnp.zeros((Le, d), dtype),
           "mlp_norm": jnp.zeros((Le, d), dtype),
           **attn_stack(ks[0], Le), **mlp_stack(ks[1], Le)}
    dec = {"attn_norm": jnp.zeros((Ld, d), dtype),
           "cross_norm": jnp.zeros((Ld, d), dtype),
           "mlp_norm": jnp.zeros((Ld, d), dtype),
           **attn_stack(ks[2], Ld), **mlp_stack(ks[3], Ld)}
    cross = attn_stack(ks[4], Ld)
    dec.update({f"c_{k}": v for k, v in cross.items()})

    k5, k6 = split_keys(ks[5], 2)
    return {
        "embed": (jax.random.normal(k5, (cfg.vocab, d), jnp.float32) * 0.02
                  ).astype(dtype),
        "enc": enc, "dec": dec,
        "enc_norm": jnp.zeros((d,), dtype),
        "final_norm": jnp.zeros((d,), dtype),
        "lm_head": dense_init(k6, cfg.vocab, d, dtype),
    }


def _attn(lp, prefix, x, kv_x, cfg, causal, positions_q, positions_k,
          q_chunks=1):
    B, S, d = x.shape
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    q = linear(lp[prefix + "wq"], x).reshape(B, S, H, hd)
    k = linear(lp[prefix + "wk"], kv_x).reshape(B, kv_x.shape[1], KV, hd)
    v = linear(lp[prefix + "wv"], kv_x).reshape(B, kv_x.shape[1], KV, hd)
    if positions_q is not None:
        q = apply_rope(q, positions_q, cfg.rope_theta)
        k = apply_rope(k, positions_k, cfg.rope_theta)
    o = sdpa(q, k, v, causal=causal, q_chunks=q_chunks)
    return linear(lp[prefix + "wo"], o.reshape(B, S, -1)), (k, v)


def encode(params, embeds, cfg, unroll: bool = False):
    x = embeds.astype(cfg.dtype)
    B, S, _ = x.shape
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    qc = max(1, S // 4096) if S > 8192 else 1

    def body(h, lp):
        from ..parallel import policy as pol
        h = pol.shard(h, ("fsdp", None, None))
        a, _ = _attn(lp, "", rms_norm(h, lp["attn_norm"], cfg.norm_eps),
                     rms_norm(h, lp["attn_norm"], cfg.norm_eps), cfg,
                     causal=False, positions_q=pos, positions_k=pos, q_chunks=qc)
        h = h + a
        m = rms_norm(h, lp["mlp_norm"], cfg.norm_eps)
        hidden = pol.shard(activation(cfg.act, linear(lp["w_up"], m)),
                           ("fsdp", None, "model"))
        h = h + linear(lp["w_down"], hidden)
        return h, None

    if unroll:
        ubody = jax.checkpoint(body) if cfg.remat else body
        for i in range(cfg.enc_layers):
            lp = jax.tree.map(lambda p: p[i], params["enc"])
            x, _ = ubody(x, lp)
    else:
        fn = jax.checkpoint(body) if cfg.remat else body
        x, _ = jax.lax.scan(fn, x, params["enc"])
    return rms_norm(x, params["enc_norm"], cfg.norm_eps)


def decode_full(params, tokens, enc_out, cfg, unroll: bool = False,
                collect_kv: bool = False):
    B, S = tokens.shape
    x = jnp.take(params["embed"], tokens, axis=0)
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    epos = jnp.broadcast_to(jnp.arange(enc_out.shape[1])[None],
                            (B, enc_out.shape[1]))
    qc = max(1, S // 4096) if S > 8192 else 1

    def body(h, lp):
        from ..parallel import policy as pol
        h = pol.shard(h, ("fsdp", None, None))
        a, kv = _attn(lp, "", rms_norm(h, lp["attn_norm"], cfg.norm_eps),
                      rms_norm(h, lp["attn_norm"], cfg.norm_eps), cfg,
                      causal=True, positions_q=pos, positions_k=pos, q_chunks=qc)
        h = h + a
        c, ckv = _attn(lp, "c_", rms_norm(h, lp["cross_norm"], cfg.norm_eps),
                       enc_out, cfg, causal=False, positions_q=None,
                       positions_k=None, q_chunks=qc)
        h = h + c
        m = rms_norm(h, lp["mlp_norm"], cfg.norm_eps)
        hidden = pol.shard(activation(cfg.act, linear(lp["w_up"], m)),
                           ("fsdp", None, "model"))
        h = h + linear(lp["w_down"], hidden)
        return h, (kv, ckv) if collect_kv else None

    if unroll:
        ubody = jax.checkpoint(body) if (cfg.remat and not collect_kv) else body
        kvs = []
        for i in range(cfg.n_layers):
            lp = jax.tree.map(lambda p: p[i], params["dec"])
            x, kv = ubody(x, lp)
            kvs.append(kv)
        stacked = None
        if collect_kv:
            stacked = (jnp.stack([a[0][0] for a in kvs]),
                       jnp.stack([a[0][1] for a in kvs]),
                       jnp.stack([a[1][0] for a in kvs]),
                       jnp.stack([a[1][1] for a in kvs]))
    else:
        fn = jax.checkpoint(body) if (cfg.remat and not collect_kv) else body
        x, ys = jax.lax.scan(fn, x, params["dec"])
        stacked = (ys[0][0], ys[0][1], ys[1][0], ys[1][1]) if collect_kv else None
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    return linear(params["lm_head"], x), stacked


def loss_fn(params, batch, cfg, unroll: bool = False):
    enc_out = encode(params, batch["embeds"], cfg, unroll)
    logits, _ = decode_full(params, batch["tokens"], enc_out, cfg, unroll)
    labels = batch["labels"]
    lse = jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)
    gold = jnp.take_along_axis(logits.astype(jnp.float32), labels[..., None],
                               axis=-1)[..., 0]
    mask = (labels >= 0).astype(jnp.float32)
    return jnp.sum((lse - gold) * mask) / jnp.maximum(mask.sum(), 1.0), {}


def prefill(params, batch, cfg, unroll: bool = False):
    enc_out = encode(params, batch["embeds"], cfg, unroll)
    logits, (k, v, ck, cv) = decode_full(params, batch["tokens"], enc_out, cfg,
                                         unroll, collect_kv=True)
    return logits[:, -1], {"k": k, "v": v, "ck": ck, "cv": cv,
                           "pos": jnp.array(batch["tokens"].shape[1], jnp.int32)}


def encode_ctx(params, embeds, cfg, unroll: bool = False):
    """Run the encoder at its TRUE length and project the per-decoder-layer
    cross-attention KV.  Returns (ck, cv) [L, B, S_enc, KV, hd] — the rows
    an ``EncoderContextPool`` stores per slot.  Admission-time entry point
    for the serving engine (re-traced per distinct S_enc; padding is not an
    option for a bidirectional encoder, every position attends everywhere).
    """
    enc_out = encode(params, embeds, cfg, unroll)
    B, Se, _ = enc_out.shape
    KV, hd = cfg.n_kv_heads, cfg.hd

    def body(carry, lp):
        k = linear(lp["c_wk"], enc_out).reshape(B, Se, KV, hd)
        v = linear(lp["c_wv"], enc_out).reshape(B, Se, KV, hd)
        return carry, (k, v)

    _, (ck, cv) = jax.lax.scan(body, None, params["dec"])
    return ck, cv


def unified_step(params, view, batch, cfg, *, attn_backend=None,
                 unroll: bool = False):
    """One serving step for the enc-dec family over an ``EncDecPoolView``:
    decoder self-attention writes fresh KV into the slot arenas and attends
    in place (cursor as length mask, exactly the transformer path), cross
    attention reads each lane's read-only encoder context rows masked to
    its true length (``attend_kv_length`` — non-causal, so chunked prefill
    and fused decode see identical context math).

    Returns (logits [B,S,V], (k, v)) — the updated self-attention arenas
    (``ck``/``cv`` ride through untouched and are NOT returned)."""
    import dataclasses as _dc
    tokens = batch["tokens"]
    B, S = tokens.shape
    x = jnp.take(params["embed"], tokens, axis=0)
    positions = tfm._pool_positions(view.cursor, S, cfg)
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd

    def block(lp, h, k_l, v_l, ck_l, cv_l):
        hn = rms_norm(h, lp["attn_norm"], cfg.norm_eps)
        q = linear(lp["wq"], hn).reshape(B, S, H, hd)
        k = linear(lp["wk"], hn).reshape(B, S, KV, hd)
        v = linear(lp["wv"], hn).reshape(B, S, KV, hd)
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
        k_l, v_l = view.write_layer(k_l, v_l, k, v)
        attn = tfm.attend_over_pool(q, _dc.replace(view, k=k_l, v=v_l),
                                    backend=attn_backend)
        h = h + linear(lp["wo"], attn.reshape(B, S, -1))
        cn = rms_norm(h, lp["cross_norm"], cfg.norm_eps)
        cq = linear(lp["c_wq"], cn).reshape(B, S, H, hd)
        ckr, cvr = view.lane_ctx(ck_l, cv_l)
        c = attend_kv_length(cq, ckr, cvr, view.ctx_len)
        h = h + linear(lp["c_wo"], c.reshape(B, S, -1))
        m = rms_norm(h, lp["mlp_norm"], cfg.norm_eps)
        h = h + linear(lp["w_down"], activation(cfg.act, linear(lp["w_up"], m)))
        return h, k_l, v_l

    if unroll:
        ks, vs = [], []
        for i in range(cfg.n_layers):
            lp = jax.tree.map(lambda p: p[i], params["dec"])
            x, k_l, v_l = block(lp, x, view.k[i], view.v[i],
                                view.ck[i], view.cv[i])
            ks.append(k_l)
            vs.append(v_l)
        k, v = jnp.stack(ks), jnp.stack(vs)
    else:
        def scan_body(h, xs):
            lp, k_l, v_l, ck_l, cv_l = xs
            h, k_l, v_l = block(lp, h, k_l, v_l, ck_l, cv_l)
            return h, (k_l, v_l)

        x, (k, v) = jax.lax.scan(
            scan_body, x,
            (params["dec"], view.k, view.v, view.ck, view.cv))
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = linear(params["lm_head"], x)
    return logits, (k, v)


def decode_lockstep(params, caches, batch, cfg, unroll: bool = False):
    """Reference lock-step decode via ``unified_step`` (S=1, identity lane
    map; every row's context is the full encoder output) — same float
    operation order as the engine's fused decode."""
    from ..serving.state_pool import EncDecPoolView
    tokens = batch["tokens"]
    B = tokens.shape[0]
    pos = caches["pos"]
    Se = caches["ck"].shape[2]
    view = EncDecPoolView(k=caches["k"], v=caches["v"], rows=None,
                          cursor=tfm._cursor_vec(pos, B),
                          n_new=jnp.ones((B,), jnp.int32),
                          ck=caches["ck"], cv=caches["cv"],
                          ctx_len=jnp.full((B,), Se, jnp.int32))
    logits, (k, v) = unified_step(params, view, batch, cfg, unroll=unroll)
    return logits[:, -1], {"k": k, "v": v, "ck": caches["ck"],
                           "cv": caches["cv"], "pos": pos + 1}
