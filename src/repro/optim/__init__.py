from .adamw import AdamWConfig, init as adamw_init, step as adamw_step, global_norm
from .schedule import cosine_with_warmup, constant
from . import grad_compress
