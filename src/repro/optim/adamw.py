"""AdamW in pure JAX pytrees — FSDP-friendly (state mirrors param shardings).

Options used at scale:
  - ``state_dtype``: bf16 first/second moments (halves optimizer HBM — the
    config used for the 340B train dry-run cell).
  - ``mask``: frozen-structure training (EBFT): updates are projected through
    a boolean pytree each step.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    state_dtype: Any = jnp.float32


def init(params, cfg: AdamWConfig):
    zeros = lambda p: jnp.zeros(p.shape, cfg.state_dtype)
    return {"m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
            "step": jnp.zeros((), jnp.int32)}


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(l.astype(jnp.float32)))
              for l in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def step(params, grads, state, cfg: AdamWConfig, lr_scale=1.0, mask=None):
    """Returns (new_params, new_state, metrics)."""
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9)) if cfg.grad_clip \
        else 1.0
    t = state["step"] + 1
    b1c = 1 - cfg.beta1 ** t.astype(jnp.float32)
    b2c = 1 - cfg.beta2 ** t.astype(jnp.float32)

    def upd(p, g, m, v, mk):
        g = g.astype(jnp.float32) * clip
        if mk is not None and mk is not True:
            g = g * mk.astype(jnp.float32)
        m_new = cfg.beta1 * m.astype(jnp.float32) + (1 - cfg.beta1) * g
        v_new = cfg.beta2 * v.astype(jnp.float32) + (1 - cfg.beta2) * g * g
        update = (m_new / b1c) / (jnp.sqrt(v_new / b2c) + cfg.eps)
        update = update + cfg.weight_decay * p.astype(jnp.float32)
        p_new = p.astype(jnp.float32) - cfg.lr * lr_scale * update
        if mk is not None and mk is not True:
            p_new = jnp.where(mk, p_new, p.astype(jnp.float32))
        return (p_new.astype(p.dtype), m_new.astype(cfg.state_dtype),
                v_new.astype(cfg.state_dtype))

    flat_p, tdef = jax.tree_util.tree_flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = tdef.flatten_up_to(state["m"])
    flat_v = tdef.flatten_up_to(state["v"])
    flat_mask = tdef.flatten_up_to(mask) if mask is not None \
        else [None] * len(flat_p)
    out = [upd(p, g, m, v, mk) for p, g, m, v, mk
           in zip(flat_p, flat_g, flat_m, flat_v, flat_mask)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_state = {"m": tdef.unflatten([o[1] for o in out]),
                 "v": tdef.unflatten([o[2] for o in out]),
                 "step": t}
    return new_p, new_state, {"grad_norm": gnorm}
