"""LR schedules (pure functions of step)."""
from __future__ import annotations

import jax.numpy as jnp


def cosine_with_warmup(step, *, base_lr: float, warmup: int, total: int,
                       min_ratio: float = 0.1):
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(warmup, 1), 1.0)
    prog = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1), 0.0, 1.0)
    cos = min_ratio + (1 - min_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return base_lr * warm * cos


def constant(step, *, base_lr: float):
    return jnp.full_like(step, base_lr, dtype=jnp.float32)
