"""Int8 gradient compression with error feedback — the DP all-reduce trick.

At 1000+ nodes the data-parallel gradient all-reduce dominates the step for
small per-chip batches.  Compressing gradients to int8 (per-leaf absmax
scaling) cuts DP collective bytes 4x (f32) / 2x (bf16); the quantization
residual is carried to the next step (error feedback, Seide et al. 2014) so
convergence is preserved.

Usage inside train_step (launch/train.py):
    g_q, new_err = compress_with_feedback(grads, err)
    g_sync = jax.lax.pmean(decompress(g_q), "data")   # or implicit via psum

Under jit+GSPMD the all-reduce is inserted by XLA; quantizing before the
mean is expressed by wrapping the gradient pytree — XLA reduces the int8
payloads' decompressed values but the *communicated* tensor is the int8 one
when the compression boundary is placed at the collective (shard_map path).
The jit path compresses/decompresses around gradient accumulation, which
still halves the HBM-resident gradient bytes between microbatches.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _quantize(g: jax.Array):
    a = jnp.max(jnp.abs(g))
    scale = jnp.where(a > 0, a / 127.0, 1.0).astype(jnp.float32)
    q = jnp.clip(jnp.round(g.astype(jnp.float32) / scale), -127, 127
                 ).astype(jnp.int8)
    return q, scale


def _dequantize(q: jax.Array, scale: jax.Array, dtype):
    return (q.astype(jnp.float32) * scale).astype(dtype)


def init_error(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compress_with_feedback(grads, error):
    """Returns ((q, scale) pytrees, new_error)."""
    def one(g, e):
        gf = g.astype(jnp.float32) + e
        q, s = _quantize(gf)
        deq = _dequantize(q, s, jnp.float32)
        return (q, s), gf - deq
    flat_g, tdef = jax.tree_util.tree_flatten(grads)
    flat_e = tdef.flatten_up_to(error)
    outs = [one(g, e) for g, e in zip(flat_g, flat_e)]
    qs = tdef.unflatten([o[0] for o in outs])
    new_err = tdef.unflatten([o[1] for o in outs])
    return qs, new_err


def decompress(qs, like):
    flat_q, tdef = jax.tree_util.tree_flatten(like)
    qs_flat = tdef.flatten_up_to(qs)
    return tdef.unflatten([_dequantize(q, s, l.dtype)
                           for (q, s), l in zip(qs_flat, flat_q)])
