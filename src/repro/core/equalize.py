"""SmoothQuant-inspired weight equalization (paper §4.1).

Channel-wise scaling factors  s_j = max|x_j| / max|W_:,j|  redistribute
importance between activations and weights:

    W_ec = W @ diag(s)^-1 ,   x_scaled = x * s        (Eq. 1)

Crucially (paper "Implementation Note"): W_ec is used ONLY to compute the
pruning importance metric.  The stored weights and the model's activations are
never changed — equalization reshapes the score landscape so RIA separates
salient from non-salient weights more cleanly.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

EPS = 1e-8


def smoothquant_scales(w: jax.Array, act_max_abs: jax.Array,
                       alpha: float | None = None) -> jax.Array:
    """Per-input-channel scales s_j.

    Default (paper Eq. 1): s_j = max|x_j| / max|W_:,j|.
    With ``alpha`` given, uses the original SmoothQuant interpolation
    s_j = max|x_j|^alpha / max|W_:,j|^(1-alpha).
    """
    w_max = jnp.max(jnp.abs(w.astype(jnp.float32)), axis=0)      # [in]
    x_max = act_max_abs.astype(jnp.float32)
    if alpha is None:
        s = x_max / (w_max + EPS)
    else:
        s = (x_max + EPS) ** alpha / (w_max + EPS) ** (1.0 - alpha)
    # Guard degenerate channels (dead activations): scale 1.
    return jnp.where(x_max <= EPS, 1.0, jnp.maximum(s, EPS))


def equalize_weights(w: jax.Array, scales: jax.Array) -> jax.Array:
    """W_ec = W * s_j  per input channel.

    Note the sign convention: with x_scaled = x / s_j the product is invariant
    when W_ec = W * s_j.  The paper writes W·S^-1 with x·S; either direction is
    mathematically equivalent — what matters for scoring is that channels with
    large activations get their weights *inflated* in the metric so RIA keeps
    them.  We fold the activation magnitude INTO the weight copy used for
    scoring (importance must rise with activation scale).
    """
    return w * scales[None, :].astype(w.dtype)


def equalized_view_for_scoring(w: jax.Array, act_max_abs: jax.Array,
                               alpha: float | None = None) -> jax.Array:
    """The W_ec used by the pipeline's scoring stage (weights unchanged)."""
    return equalize_weights(w, smoothquant_scales(w, act_max_abs, alpha))


def check_equivalence(w: jax.Array, x: jax.Array, scales: jax.Array):
    """(W*s)(x/s) == W x — the Eq. 1 invariant; used by tests."""
    lhs = (x / scales) @ equalize_weights(w, scales).T
    rhs = x @ w.T
    return lhs, rhs
