"""Core: the paper's contribution — N:M sparsity with structured outliers,
SmoothQuant-style equalization, variance correction, EBFT."""

from .patterns import (Pattern, parse_pattern, nm_mask, topn_block_mask,
                       validate_nm_mask, block_topn_indices, mask_sparsity,
                       WEIGHT_PATTERNS, OUTLIER_PATTERNS)
from .scoring import ActStats, score, magnitude_score, wanda_score, ria_score
from .equalize import (smoothquant_scales, equalize_weights,
                       equalized_view_for_scoring)
from .variance import variance_correction_factor, apply_variance_correction
from .outliers import (StructuredOutliers, extract_structured_outliers,
                       unstructured_outlier_mask, structured_outlier_mask)
from .packing import PackedNM, pack_nm, unpack_metadata, compression_report
from .pipeline import (SparsifyConfig, SparsifiedLinear, sparsify_linear,
                       sparsify_tree, dense_effective_weight)
from .ebft import EBFTConfig, ebft_block, masked_adam_init, masked_adam_step
