"""EBFT — Efficient Blockwise Fine-Tuning (Guo et al., 2024; paper stage 4).

The model is split into independent blocks (here: one transformer block =
one EBFT unit).  For each block, with a frozen sparsity mask M, we minimize the
block-output reconstruction error against the *dense* block's outputs on
calibration data, updating only the non-salient kept weights:

    min_{W ⊙ M}  || f_block(X; W ⊙ M) - f_block(X; W_dense) ||_F^2

Gradients are projected through the mask each step (W stays exactly N:M +
outlier structured).  We use Adam on the masked weights; norm parameters are
also trainable (the paper fine-tunes "only W_nonsalient and BatchNorm
parameters" — transformer blocks have RMSNorm scales, which play that role).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class EBFTConfig:
    steps: int = 100
    lr: float = 1e-4
    beta1: float = 0.9
    beta2: float = 0.999
    eps: float = 1e-8
    batch_size: int = 8
    train_norms: bool = True


def _is_norm_path(name: str) -> bool:
    return "norm" in name.lower() or "scale" in name.lower()


def masked_adam_init(params):
    zeros = jax.tree.map(lambda p: jnp.zeros_like(p, dtype=jnp.float32), params)
    return dict(m=zeros, v=jax.tree.map(jnp.copy, zeros), step=jnp.zeros((), jnp.int32))


def masked_adam_step(params, grads, state, masks, cfg: EBFTConfig):
    """One Adam step; gradient (and hence update) is zeroed off-mask.

    ``masks`` mirrors params: bool array for masked leaves, ``None`` (leaf)
    entries mean fully trainable, ``False`` scalar means frozen.
    """
    step = state["step"] + 1

    def upd(p, g, m, v, mask):
        if mask is False:
            return p, m, v
        g = g.astype(jnp.float32)
        if mask is not None and mask is not True:
            g = g * mask.astype(jnp.float32)
        m_new = cfg.beta1 * m + (1 - cfg.beta1) * g
        v_new = cfg.beta2 * v + (1 - cfg.beta2) * g * g
        mhat = m_new / (1 - cfg.beta1 ** step)
        vhat = v_new / (1 - cfg.beta2 ** step)
        delta = cfg.lr * mhat / (jnp.sqrt(vhat) + cfg.eps)
        p_new = (p.astype(jnp.float32) - delta)
        if mask is not None and mask is not True:
            p_new = p_new * mask.astype(jnp.float32)  # keep exact sparsity
        return p_new.astype(p.dtype), m_new, v_new

    flat_p, tdef = jax.tree_util.tree_flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = tdef.flatten_up_to(state["m"])
    flat_v = tdef.flatten_up_to(state["v"])
    flat_mask = tdef.flatten_up_to(masks)
    out = [upd(p, g, m, v, mk) for p, g, m, v, mk in
           zip(flat_p, flat_g, flat_m, flat_v, flat_mask)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_m = tdef.unflatten([o[1] for o in out])
    new_v = tdef.unflatten([o[2] for o in out])
    return new_p, dict(m=new_m, v=new_v, step=step)


def ebft_block(block_fn: Callable, sparse_params, dense_params, masks,
               calib_inputs: jax.Array, cfg: EBFTConfig,
               extra_inputs: tuple = ()) -> tuple:
    """Fine-tune one block to match its dense teacher.

    block_fn(params, x, *extra) -> y.  ``masks`` mirrors sparse_params with
    bool masks on pruned weight leaves, True on norm leaves (if
    cfg.train_norms), False elsewhere.  ``calib_inputs``: [n_calib, ...]
    inputs to the block recorded from the dense model.

    Returns (tuned_params, losses[steps]).
    """
    targets = block_fn(dense_params, calib_inputs, *extra_inputs)

    def loss_fn(p, x, y):
        pred = block_fn(p, x, *extra_inputs)
        return jnp.mean((pred.astype(jnp.float32) - y.astype(jnp.float32)) ** 2)

    opt = masked_adam_init(sparse_params)
    n = calib_inputs.shape[0]
    bs = min(cfg.batch_size, n)

    @jax.jit
    def step(p, o, x, y):
        l, g = jax.value_and_grad(loss_fn)(p, x, y)
        p, o = masked_adam_step(p, g, o, masks, cfg)
        return p, o, l

    params = sparse_params
    losses = []
    for i in range(cfg.steps):
        s = (i * bs) % max(n - bs + 1, 1)
        params, opt, l = step(params, opt, calib_inputs[s:s + bs], targets[s:s + bs])
        losses.append(float(l))
    return params, losses


def make_block_masks(sparse_params, mask_by_path: dict, train_norms: bool = True):
    """Build the mask pytree for one block's params.

    mask_by_path: {leaf path: bool array} for pruned weights; norm scales get
    True (trainable), everything else False (frozen).
    """
    flat, tdef = jax.tree_util.tree_flatten_with_path(sparse_params)
    masks = []
    for path, leaf in flat:
        name = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        if name in mask_by_path:
            masks.append(mask_by_path[name])
        elif train_norms and _is_norm_path(name):
            masks.append(True)
        else:
            masks.append(False)
    return tdef.unflatten(masks)
