"""N:M semi-structured sparsity patterns.

An (N, M) pattern keeps the N highest-importance elements out of every
contiguous block of M elements along the *input* (last) dimension of a weight
matrix ``W[out, in]``.  The paper studies 2:4, 4:8, 8:16 and 16:32 for weight
sparsity and the high-compression patterns 4:256, 8:256, 16:256 for salient
("outlier") weights.

All mask functions are pure-jnp and jit-safe.  Selection is done with a
sort-based top-N per block (O(M log M) per block, vectorized), which is exact
and differentiable-free (masks are constants after pruning).
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial

import jax
import jax.numpy as jnp

# Patterns the paper evaluates for the main weights.
WEIGHT_PATTERNS = ((2, 4), (4, 8), (8, 16), (16, 32))
# Patterns the paper evaluates for salient-weight (outlier) storage.
OUTLIER_PATTERNS = ((4, 256), (8, 256), (16, 256))


@dataclasses.dataclass(frozen=True)
class Pattern:
    """An N:M sparsity pattern with its hardware metadata accounting."""

    n: int
    m: int

    def __post_init__(self):
        if not (0 < self.n <= self.m):
            raise ValueError(f"invalid pattern {self.n}:{self.m}")

    @property
    def density(self) -> float:
        return self.n / self.m

    @property
    def configurations(self) -> int:
        """Number of distinct block layouts = C(M, N)  (paper Table 1)."""
        return math.comb(self.m, self.n)

    def bits_per_element(self, pack_blocks: int = 1,
                         word_align: bool = False) -> float:
        """Metadata bits/element via enumerative coding of the block layout.

        ``ceil(log2 C(M,N) * pack_blocks) / (M * pack_blocks)`` — packing
        several blocks into one codeword amortizes the ceil.  Paper Table 1
        uses pack_blocks=1 for 2:4 (3/4 = 0.75), pack_blocks=2 for 4:8
        (13/16 = 0.8125), pack_blocks=1 for 8:16 (14/16 = 0.875) and a
        word-aligned bitmap for 16:32 -> 32/32 = 1.0 (``word_align=True``
        rounds the codeword up to the next 32-bit boundary).
        """
        raw = math.log2(self.configurations) * pack_blocks
        bits = math.ceil(raw)
        if word_align:
            bits = 32 * math.ceil(bits / 32)
        return bits / (self.m * pack_blocks)

    def paper_bits_per_element(self) -> float:
        """The exact Table 1 accounting per pattern."""
        if (self.n, self.m) == (4, 8):
            return self.bits_per_element(pack_blocks=2)
        if (self.n, self.m) == (16, 32):
            return self.bits_per_element(word_align=True)
        return self.bits_per_element()

    def __str__(self) -> str:  # "8:16"
        return f"{self.n}:{self.m}"


def parse_pattern(spec) -> Pattern:
    """Accept 'N:M' strings, (N, M) tuples, or Pattern instances."""
    if isinstance(spec, Pattern):
        return spec
    if isinstance(spec, str):
        n, m = spec.split(":")
        return Pattern(int(n), int(m))
    n, m = spec
    return Pattern(int(n), int(m))


def _check_blockable(width: int, m: int) -> None:
    if width % m:
        raise ValueError(f"last dim {width} not divisible by block size {m}")


@partial(jax.jit, static_argnames=("n", "m"))
def topn_block_mask(scores: jax.Array, n: int, m: int) -> jax.Array:
    """Boolean mask keeping the top-``n`` scores in every block of ``m``.

    ``scores`` has shape ``[..., in_dim]`` with ``in_dim % m == 0``.  Ties are
    broken toward lower index (stable, matches a deterministic hardware
    encoder).  Returns a bool mask of the same shape with exactly ``n`` True
    per block.
    """
    _check_blockable(scores.shape[-1], m)
    blocks = scores.reshape(*scores.shape[:-1], scores.shape[-1] // m, m)
    # rank within block: position of each element in descending score order.
    order = jnp.argsort(-blocks, axis=-1, stable=True)          # [..., m]
    ranks = jnp.argsort(order, axis=-1, stable=True)            # inverse perm
    mask = ranks < n
    return mask.reshape(scores.shape)


def nm_mask(scores: jax.Array, pattern) -> jax.Array:
    p = parse_pattern(pattern)
    return topn_block_mask(scores, p.n, p.m)


def mask_sparsity(mask: jax.Array) -> jax.Array:
    """Fraction of zeros."""
    return 1.0 - jnp.mean(mask.astype(jnp.float32))


def validate_nm_mask(mask: jax.Array, pattern) -> jax.Array:
    """True iff every M-block has exactly N nonzeros (the N:M invariant)."""
    p = parse_pattern(pattern)
    _check_blockable(mask.shape[-1], p.m)
    blocks = mask.reshape(*mask.shape[:-1], -1, p.m)
    return jnp.all(blocks.sum(-1) == p.n)


def block_topn_indices(scores: jax.Array, n: int, m: int) -> jax.Array:
    """Per-block indices (ascending) of the kept elements.

    Returns int32 ``[..., in_dim//m, n]`` with values in [0, m).  This is the
    canonical compressed *metadata* layout used by the kernels and the
    packing utilities.
    """
    _check_blockable(scores.shape[-1], m)
    blocks = scores.reshape(*scores.shape[:-1], scores.shape[-1] // m, m)
    _, idx = jax.lax.top_k(blocks, n)                            # desc by score
    return jnp.sort(idx, axis=-1).astype(jnp.int32)              # asc by index
