"""Variance Correction (paper §4.2, Eq. 2).

Pruning removes ~50% of each layer's weights, shrinking the variance of the
weight distribution and hence of the layer's pre-activations.  VC rescales the
surviving non-salient weights so the *dense* weight variance is restored:

    W_kept_corrected = W_kept * sqrt( Var(W_dense) / (Var(W_kept) + eps) )

Only non-salient kept weights are rescaled; salient (outlier) weights are
stored exactly.  Variance is computed per weight matrix (the paper's layer-wise
granularity); a per-output-row mode is provided as a beyond-paper knob.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

EPS = 1e-12


def _masked_var(w: jax.Array, mask: jax.Array, axis=None):
    """Variance of w over entries where mask is True (biased, like jnp.var)."""
    wf = w.astype(jnp.float32)
    m = mask.astype(jnp.float32)
    n = jnp.sum(m, axis=axis, keepdims=axis is not None)
    mean = jnp.sum(wf * m, axis=axis, keepdims=axis is not None) / jnp.maximum(n, 1.0)
    var = jnp.sum(m * (wf - mean) ** 2, axis=axis, keepdims=axis is not None) / jnp.maximum(n, 1.0)
    return var


def variance_correction_factor(w_dense: jax.Array, kept_mask: jax.Array,
                               per_row: bool = False) -> jax.Array:
    """sqrt(Var(W_dense) / (Var(W_kept) + eps)).

    ``kept_mask`` marks the surviving non-salient weights.  ``per_row=True``
    computes the factor per output row (axis=1) instead of per matrix.
    """
    axis = 1 if per_row else None
    var_dense = jnp.var(w_dense.astype(jnp.float32), axis=axis,
                        keepdims=per_row)
    var_kept = _masked_var(w_dense, kept_mask, axis=axis)
    factor = jnp.sqrt(var_dense / (var_kept + EPS))
    # If a row kept nothing (degenerate), leave it alone.
    return jnp.where(jnp.isfinite(factor), factor, 1.0)


def apply_variance_correction(w_dense: jax.Array, kept_mask: jax.Array,
                              per_row: bool = False) -> jax.Array:
    """Return pruned-and-corrected weights: zeros off-mask, rescaled on-mask."""
    factor = variance_correction_factor(w_dense, kept_mask, per_row)
    w_kept = jnp.where(kept_mask, w_dense.astype(jnp.float32), 0.0)
    return (w_kept * factor).astype(w_dense.dtype)
