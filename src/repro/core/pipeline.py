"""The paper's 4-stage sparsification pipeline (§4).

Per linear layer W[out, in] with calibration activation stats:

  1. Weights Equalization  — SmoothQuant-style W_ec used ONLY for scoring.
  2. Importance-Aware Pruning — RIA (or wanda/magnitude) on W_ec; salient
     weights isolated in a structured [4|8|16]:256 pattern; the rest pruned
     to 2:4 / 8:16 / ... N:M.
  3. Variance Correction    — rescale kept non-salient weights to restore
     Var(W_dense).
  4. Blockwise Fine-Tuning  — EBFT (core/ebft.py) updates only non-salient
     kept weights through the frozen mask.

``sparsify_linear`` is the single-layer entry point; ``sparsify_tree`` walks a
model's parameter pytree.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Any, Callable

import jax
import jax.numpy as jnp

from . import scoring
from .equalize import equalized_view_for_scoring
from .outliers import StructuredOutliers, extract_structured_outliers, unstructured_outlier_mask
from .packing import PackedNM, pack_nm
from .patterns import parse_pattern, nm_mask
from .variance import apply_variance_correction


@dataclasses.dataclass(frozen=True)
class SparsifyConfig:
    weight_pattern: Any = "8:16"        # N:M for non-salient weights
    outlier_pattern: Any | None = "16:256"  # None => no outlier recovery
    scorer: str = "ria"                 # magnitude | wanda | ria
    ria_alpha: float = 0.5
    use_smoothquant: bool = True        # stage 1 on/off
    sq_alpha: float | None = None       # None => paper Eq.1; else SmoothQuant interp
    use_variance_correction: bool = True
    vc_per_row: bool = False            # beyond-paper knob
    unstructured_outliers: bool = False  # Table 7 baseline at matched budget


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class SparsifiedLinear:
    """Deployable result for one linear layer."""

    nm: PackedNM                         # VC-corrected non-salient weights
    outliers: StructuredOutliers | None  # exact salient weights (or None)
    # masks kept for EBFT / analysis (bool, dense shape):
    nm_mask: jax.Array                   # N:M kept positions (incl. salient overlap slots)
    salient_mask: jax.Array              # structured salient positions

    def to_dense(self) -> jax.Array:
        w = self.nm.to_dense()
        if self.outliers is not None:
            w = jnp.where(self.outliers.mask(), 0.0, w) + self.outliers.to_dense()
        return w

    @property
    def effective_mask(self) -> jax.Array:
        m = self.nm_mask
        if self.outliers is not None:
            m = m | self.salient_mask
        return m

    @property
    def nonsalient_kept_mask(self) -> jax.Array:
        """The EBFT-trainable positions: kept by N:M, not salient."""
        if self.outliers is None:
            return self.nm_mask
        return self.nm_mask & ~self.salient_mask


def sparsify_linear(w: jax.Array, stats: scoring.ActStats | None,
                    cfg: SparsifyConfig) -> SparsifiedLinear:
    """Run stages 1-3 on one weight matrix. W: [out, in]."""
    wp = parse_pattern(cfg.weight_pattern)
    if w.shape[-1] % wp.m:
        raise ValueError(
            f"in_dim {w.shape[-1]} not divisible by N:M block {wp.m}")

    # --- Stage 1: equalized view (scoring only; weights unchanged) ---------
    if cfg.use_smoothquant and stats is not None:
        w_view = equalized_view_for_scoring(w, stats.max_abs, cfg.sq_alpha)
    else:
        w_view = w

    # --- Stage 2: importance + salient isolation + N:M pruning ------------
    s = scoring.score(cfg.scorer, w_view, stats, cfg.ria_alpha)

    outliers = None
    salient_mask = jnp.zeros(w.shape, bool)
    if cfg.outlier_pattern is not None:
        op = parse_pattern(cfg.outlier_pattern)
        if cfg.unstructured_outliers:
            salient_mask = unstructured_outlier_mask(s, op.density)
            # store as "structured" container with m = in_dim for to_dense;
            # unstructured baseline is only used for quality comparisons, so
            # keep the dense mask + values path:
            outliers = None  # handled via dense add below in to_dense callers
        else:
            if w.shape[-1] % op.m:
                raise ValueError(
                    f"in_dim {w.shape[-1]} not divisible by outlier block {op.m}")
            outliers = extract_structured_outliers(w, s, op)
            salient_mask = outliers.mask()

    keep = nm_mask(s, wp)                           # N:M structure on scores

    # --- Stage 3: variance correction on kept non-salient weights ---------
    nonsalient_kept = keep & ~salient_mask
    if cfg.use_variance_correction:
        w_corr = apply_variance_correction(w, nonsalient_kept, cfg.vc_per_row)
    else:
        w_corr = jnp.where(nonsalient_kept, w, jnp.zeros_like(w))

    # Salient positions inside N:M slots carry 0 so nm + outliers never
    # double-count; the slot stays allocated (hardware N:M invariant holds).
    nm = pack_nm(w_corr, keep, wp)

    res = SparsifiedLinear(nm=nm, outliers=outliers, nm_mask=keep,
                           salient_mask=salient_mask)
    if cfg.unstructured_outliers and cfg.outlier_pattern is not None:
        # Rebuild with exact salient values stored unstructured: emulate via
        # outliers=None but effective dense = nm + w*salient_mask.  Consumers
        # use `dense_with_unstructured` below.
        res = dataclasses.replace(res, salient_mask=salient_mask)
    return res


def dense_effective_weight(w_dense: jax.Array, sl: SparsifiedLinear,
                           cfg: SparsifyConfig) -> jax.Array:
    """Dense materialization of the deployed weight (for eval / EBFT ref)."""
    w = sl.nm.to_dense()
    if sl.outliers is not None:
        w = jnp.where(sl.outliers.mask(), 0.0, w) + sl.outliers.to_dense()
    elif cfg.unstructured_outliers and cfg.outlier_pattern is not None:
        w = jnp.where(sl.salient_mask, w_dense, w)
    return w.astype(w_dense.dtype)


# --------------------------------------------------------------------------
# Pytree-level driver
# --------------------------------------------------------------------------

def default_prunable(path: str, leaf: jax.Array) -> bool:
    """Prune 2-D projection matrices; skip embeddings/norms/router/head."""
    if leaf.ndim < 2:
        return False
    skip = ("embed", "norm", "router", "lm_head", "scale", "bias", "pos")
    return not any(s in path.lower() for s in skip)


def _flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        name = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        out.append((name, leaf))
    return out, treedef


def sparsify_tree(params, stats_by_name: dict, cfg: SparsifyConfig,
                  prunable: Callable[[str, jax.Array], bool] = default_prunable):
    """Apply stages 1-3 across a parameter pytree.

    ``stats_by_name`` maps leaf path -> ActStats (arrays may carry a leading
    [L] dim matching stacked-layer leaves; missing/None entries fall back to
    activation-free scoring).  Stacked-layer leaves [L, out, in] are vmapped
    over L.  Returns (new_params_dense_effective, {path: SparsifiedLinear}).
    """
    leaves, treedef = _flatten_with_paths(params)

    new_leaves, records = [], {}
    for name, leaf in leaves:
        if not prunable(name, leaf):
            new_leaves.append(leaf)
            continue
        st = stats_by_name.get(name)
        layer_cfg = cfg
        if st is None and cfg.scorer != "magnitude":
            # No calibration stats for this leaf: fall back to magnitude
            # (uniform-activation limit of wanda/ria).
            layer_cfg = dataclasses.replace(cfg, scorer="magnitude",
                                            use_smoothquant=False)
        wp = parse_pattern(layer_cfg.weight_pattern)
        if leaf.shape[-1] % wp.m:
            new_leaves.append(leaf)       # in_dim below/misaligned to block
            continue
        if layer_cfg.outlier_pattern is not None:
            op = parse_pattern(layer_cfg.outlier_pattern)
            if leaf.shape[-1] % op.m:
                # too narrow for a 256-block: prune without outlier recovery
                layer_cfg = dataclasses.replace(layer_cfg, outlier_pattern=None)

        def one(w, s, _cfg=layer_cfg):
            sl = sparsify_linear(w, s, _cfg)
            return dense_effective_weight(w, sl, _cfg), sl

        if leaf.ndim == 3:  # stacked layers [L, out, in]
            if st is None:
                dense_eff, sl = jax.vmap(lambda w: one(w, None))(leaf)
            else:
                dense_eff, sl = jax.vmap(one)(leaf, st)
        elif leaf.ndim == 2:
            dense_eff, sl = one(leaf, st)
        else:
            new_leaves.append(leaf)
            continue
        records[name] = sl
        new_leaves.append(dense_eff)

    return jax.tree_util.tree_unflatten(treedef, [l for l in new_leaves]), records
