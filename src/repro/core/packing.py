"""Compressed storage for N:M-sparse weights + storage accounting.

The deployable layout (consumed by the Pallas kernels):

  values   : [out, in * N/M]       kept weight values, row-major by block
  indices  : [out, in/M, N] int32  position of each value inside its block
  packed   : [out, in/M]    int32  the same indices packed 4 bits each
                                   (valid for M <= 16, N <= 8 -> one word)

``bits_per_element`` accounting reproduces paper Table 1:
  2:4  -> 0.75   (ceil(log2 6)=3 bits / 4)
  4:8  -> 0.8125 (two blocks share a 13-bit code: ceil(2*log2 70)=13 / 16)
  8:16 -> 0.875  (ceil(log2 12870)=14 / 16)
  16:32-> 1.0    (word-aligned dense bitmap)
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from .patterns import Pattern, parse_pattern, block_topn_indices


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class PackedNM:
    """N:M compressed weight matrix (one linear layer, W[out, in])."""

    values: jax.Array    # [out, in//m * n]
    indices: jax.Array   # [out, in//m, n] int32 in [0, m)
    n: int = dataclasses.field(metadata=dict(static=True))
    m: int = dataclasses.field(metadata=dict(static=True))
    in_dim: int = dataclasses.field(metadata=dict(static=True))

    @property
    def out_dim(self) -> int:
        return self.values.shape[0]

    @property
    def n_blocks(self) -> int:
        return self.in_dim // self.m

    def to_dense(self) -> jax.Array:
        out = self.values.shape[0]
        vals = self.values.reshape(out, self.n_blocks, self.n)
        onehot = jax.nn.one_hot(self.indices, self.m, dtype=vals.dtype)
        return jnp.einsum("obn,obnm->obm", vals, onehot).reshape(out, self.in_dim)

    def packed_metadata(self) -> jax.Array:
        """4-bit-packed indices, one int32 word per block (m<=16, n<=8)."""
        if self.m > 16 or self.n > 8:
            raise ValueError(f"word packing supports m<=16,n<=8; got {self.n}:{self.m}")
        shifts = (4 * jnp.arange(self.n, dtype=jnp.int32))[None, None, :]
        return jnp.sum(self.indices << shifts, axis=-1).astype(jnp.int32)

    def storage_bytes(self, value_bytes: int = 2) -> int:
        """Deployed bytes: values + enumerative metadata (paper accounting)."""
        p = Pattern(self.n, self.m)
        meta_bits = p.bits_per_element(pack_blocks=2 if (self.n, self.m) == (4, 8) else 1)
        total_elems = self.values.shape[0] * self.in_dim
        return int(self.values.size * value_bytes + total_elems * meta_bits / 8)


def unpack_metadata(packed: jax.Array, n: int) -> jax.Array:
    """Inverse of PackedNM.packed_metadata: int32 word -> [.., n] indices."""
    shifts = (4 * jnp.arange(n, dtype=jnp.int32))
    return (packed[..., None] >> shifts) & 0xF


def pack_nm(w_pruned: jax.Array, mask: jax.Array, pattern) -> PackedNM:
    """Compress an already-pruned dense matrix given its N:M mask.

    Uses the mask (not the values) to locate kept positions so that exact
    zeros among kept weights survive round-tripping.
    """
    p = parse_pattern(pattern)
    out, in_dim = w_pruned.shape
    idx = block_topn_indices(mask.astype(jnp.float32), p.n, p.m)  # kept positions
    blocks = w_pruned.reshape(out, in_dim // p.m, p.m)
    values = jnp.take_along_axis(blocks, idx, axis=-1)
    return PackedNM(values=values.reshape(out, -1), indices=idx,
                    n=p.n, m=p.m, in_dim=in_dim)


def dense_bytes(out_dim: int, in_dim: int, value_bytes: int = 2) -> int:
    return out_dim * in_dim * value_bytes


def compression_report(out_dim: int, in_dim: int, weight_pattern,
                       outlier_pattern=None, value_bytes: int = 2) -> dict:
    """Static storage accounting for one linear layer (used by benchmarks)."""
    wp = parse_pattern(weight_pattern)
    total = out_dim * in_dim
    vals = total * wp.density * value_bytes
    meta = total * wp.bits_per_element(pack_blocks=2 if (wp.n, wp.m) == (4, 8) else 1) / 8
    rep = {"dense_bytes": dense_bytes(out_dim, in_dim, value_bytes),
           "nm_value_bytes": int(vals), "nm_meta_bytes": int(meta)}
    if outlier_pattern is not None:
        op = parse_pattern(outlier_pattern)
        o_vals = total * op.density * value_bytes
        o_meta = total * op.n / op.m  # 8-bit index per salient value (m=256)
        rep["outlier_value_bytes"] = int(o_vals)
        rep["outlier_meta_bytes"] = int(o_meta)
    rep["compressed_bytes"] = sum(v for k, v in rep.items() if k != "dense_bytes")
    rep["ratio"] = rep["compressed_bytes"] / rep["dense_bytes"]
    return rep
