"""Weight-importance metrics for pruning.

Implemented metrics (all return a score matrix shaped like ``W``; higher is
more important):

- ``magnitude``:  |W|                                  (classic baseline)
- ``wanda``:      |W| * ||x_j||_2                      (Sun et al., 2023)
- ``ria``:        (|W_ij|/sum_i|W_ij| + |W_ij|/sum_j|W_ij|) * ||x_j||_2^a
                                                        (Zhang et al., 2024)

Activation statistics come from a calibration pass: ``ActStats`` accumulates
the per-input-channel L2 norm and max-abs over calibration batches, exactly the
statistics RIA / Wanda / SmoothQuant need.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

EPS = 1e-8


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class ActStats:
    """Streaming per-channel activation statistics (input dim of a linear)."""

    sq_sum: jax.Array   # [in]  sum of x_j^2 over all calibration tokens
    max_abs: jax.Array  # [in]  max |x_j|
    count: jax.Array    # []    number of tokens seen

    @staticmethod
    def init(in_dim: int, dtype=jnp.float32) -> "ActStats":
        return ActStats(
            sq_sum=jnp.zeros((in_dim,), dtype),
            max_abs=jnp.zeros((in_dim,), dtype),
            count=jnp.zeros((), dtype),
        )

    def update(self, x: jax.Array) -> "ActStats":
        """x: [..., in] activation batch feeding this linear layer."""
        xf = x.reshape(-1, x.shape[-1]).astype(self.sq_sum.dtype)
        return ActStats(
            sq_sum=self.sq_sum + jnp.sum(xf * xf, axis=0),
            max_abs=jnp.maximum(self.max_abs, jnp.max(jnp.abs(xf), axis=0)),
            count=self.count + xf.shape[0],
        )

    @property
    def l2(self) -> jax.Array:
        """||x_j||_2 over the calibration set."""
        return jnp.sqrt(self.sq_sum + EPS)


def magnitude_score(w: jax.Array, stats: ActStats | None = None) -> jax.Array:
    return jnp.abs(w)


def wanda_score(w: jax.Array, stats: ActStats) -> jax.Array:
    """|W_ij| * ||x_j||_2 ; W is [out, in], stats over in."""
    return jnp.abs(w) * stats.l2[None, :]


@partial(jax.jit, static_argnames=("alpha",))
def ria_score(w: jax.Array, act_l2: jax.Array, alpha: float = 0.5) -> jax.Array:
    """Relative Importance and Activations (RIA).

    score_ij = (|W_ij| / sum_row_i + |W_ij| / sum_col_j) * (||x_j||_2)^alpha
    with sums of |W| along the row (input dim) and column (output dim).
    """
    a = jnp.abs(w.astype(jnp.float32))
    row_sum = a.sum(axis=1, keepdims=True)   # [out, 1] over inputs
    col_sum = a.sum(axis=0, keepdims=True)   # [1, in]  over outputs
    rel = a / (row_sum + EPS) + a / (col_sum + EPS)
    return rel * (act_l2[None, :] + EPS) ** alpha


SCORERS = ("magnitude", "wanda", "ria")


def score(method: str, w: jax.Array, stats: ActStats | None = None,
          alpha: float = 0.5) -> jax.Array:
    """Dispatch. ``stats`` required for wanda/ria."""
    if method == "magnitude":
        return magnitude_score(w)
    if method == "wanda":
        if stats is None:
            raise ValueError("wanda requires activation stats")
        return wanda_score(w, stats)
    if method == "ria":
        if stats is None:
            raise ValueError("ria requires activation stats")
        return ria_score(w, stats.l2, alpha)
    raise ValueError(f"unknown scorer {method!r}; options: {SCORERS}")
