"""Salient-weight ("outlier") extraction — structured and unstructured.

SSP-for-SW (paper contribution 2): the most important weights are *recovered*
from the N:M-pruned matrix and stored in a separate high-compression structured
pattern (4:256, 8:256, 16:256 — 1.56% / 3.13% / 6.25% density).  Compared to
SpQR's unstructured CSR this gives predictable memory access and O(1)
per-block metadata.

The unstructured baseline (global top-k at matched budget) is implemented for
the paper's Table 7 comparison.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from .patterns import Pattern, parse_pattern, topn_block_mask, block_topn_indices


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class StructuredOutliers:
    """N:256-pattern salient weights of one linear layer.

    values : [out, n_blocks, n]  — exact dense values of the salient weights
    indices: [out, n_blocks, n]  — int32 position of each value inside its
                                   256-wide input block (ascending)
    Block b of output row o covers input columns [b*m, (b+1)*m).
    """

    values: jax.Array
    indices: jax.Array
    n: int = dataclasses.field(metadata=dict(static=True))
    m: int = dataclasses.field(metadata=dict(static=True))

    @property
    def out_dim(self) -> int:
        return self.values.shape[0]

    @property
    def in_dim(self) -> int:
        return self.values.shape[1] * self.m

    def to_dense(self) -> jax.Array:
        """Scatter back to a dense [out, in] matrix (zeros elsewhere)."""
        out, nb, n = self.values.shape
        onehot = jax.nn.one_hot(self.indices, self.m, dtype=self.values.dtype)
        dense_blocks = jnp.einsum("obn,obnm->obm", self.values, onehot)
        return dense_blocks.reshape(out, nb * self.m)

    def mask(self) -> jax.Array:
        """Boolean [out, in] mask of salient positions."""
        onehot = jax.nn.one_hot(self.indices, self.m, dtype=jnp.int32)
        return (onehot.sum(axis=2) > 0).reshape(self.values.shape[0], -1)


def extract_structured_outliers(w: jax.Array, scores: jax.Array,
                                pattern) -> StructuredOutliers:
    """Keep the top-N scores per 256-block of each row as exact values."""
    p = parse_pattern(pattern)
    idx = block_topn_indices(scores, p.n, p.m)               # [out, nb, n]
    out, nb, n = idx.shape
    blocks = w.reshape(out, nb, p.m)
    values = jnp.take_along_axis(blocks, idx, axis=-1)
    return StructuredOutliers(values=values, indices=idx, n=p.n, m=p.m)


def unstructured_outlier_mask(scores: jax.Array, budget_fraction: float) -> jax.Array:
    """Global top-k mask at a matched parameter budget (Table 7 baseline)."""
    k = max(1, int(round(budget_fraction * scores.size)))
    flat = scores.reshape(-1)
    thresh = jax.lax.top_k(flat, k)[0][-1]
    return scores >= thresh


def structured_outlier_mask(scores: jax.Array, pattern) -> jax.Array:
    p = parse_pattern(pattern)
    return topn_block_mask(scores, p.n, p.m)
