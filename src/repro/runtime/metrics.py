"""Serving/runtime latency instrumentation.

``RequestMetrics`` records one request's lifecycle timestamps (all from the
engine's injected clock, so tests can drive virtual time) plus the chunked-
prefill trail: how many prefill chunks the request took to reach its first
token, and every inter-token gap its consumer observed.  ``summarize`` folds
a set of finished requests into the numbers the benchmark reports:
throughput (generated tok/s over the measured window), p50/p99 of
time-to-first-token, per-output-token latency, end-to-end latency, the
pooled inter-token-latency percentiles (the decode-tail stall metric
chunked prefill exists to shrink), and a prefill-chunk histogram.
"""
from __future__ import annotations

import collections
import dataclasses
import math

import numpy as np


@dataclasses.dataclass
class RequestMetrics:
    # model family that served the request ("" outside the engine) — keys
    # the per-family breakdown of mixed-family benchmark windows
    family: str = ""
    arrival: float = 0.0               # submitted to the queue
    admitted: float = 0.0              # scheduled into a slot (prefill start)
    first_token: float = 0.0           # first generated token emitted
    finished: float = 0.0              # final token emitted / evicted
    n_tokens: int = 0                  # generated tokens (prompt excluded)
    # chunked-prefill trail: prefill calls this request's prompt (plus any
    # re-prefilled history after a preemption) was split into
    prefill_chunks: int = 0
    # scheduler interventions: how many times this request was preempted
    # back to the queue, and why the LAST preemption/eviction happened
    # ("" = never preempted) — the paged pool's aggregate count can't
    # distinguish one thrashing request from many lightly-touched ones
    n_preemptions: int = 0
    last_preempt_reason: str = ""
    # speculative decoding trail: draft tokens proposed for this request
    # and how many the target accepted — the acceptance rate doubles as a
    # live Divergent-Token probe of how closely the draft tracks the
    # target (spec_accepted / spec_drafted)
    spec_drafted: int = 0
    spec_accepted: int = 0
    # every observed gap between consecutive generated tokens — includes
    # engine stalls (a long prefill sharing the step, preemption waits),
    # which is exactly what the decode-tail p99 must capture
    itl: list = dataclasses.field(default_factory=list)
    last_token_at: float = 0.0         # internal: previous emit timestamp

    @property
    def queue_wait(self) -> float:
        return self.admitted - self.arrival

    @property
    def ttft(self) -> float:
        """Time to first token, from arrival (includes queueing)."""
        return self.first_token - self.arrival

    @property
    def e2e(self) -> float:
        return self.finished - self.arrival

    @property
    def tpot(self) -> float:
        """Mean time per output token after the first."""
        if self.n_tokens <= 1:
            return 0.0
        return (self.finished - self.first_token) / (self.n_tokens - 1)


def percentiles(values, ps=(50, 99)) -> dict[str, float]:
    if not len(values):
        return {f"p{p}": float("nan") for p in ps}
    arr = np.asarray(values, np.float64)
    return {f"p{p}": float(np.percentile(arr, p)) for p in ps}


def histogram(values) -> dict[str, int]:
    """Exact counts keyed by value (chunk counts are small integers).
    Keys are sorted numerically so serialized histograms are diff-stable
    across runs regardless of first-occurrence order."""
    counts = collections.Counter(int(x) for x in values)
    return {str(v): counts[v] for v in sorted(counts)}


def histogram_str(values) -> dict[str, int]:
    """Exact counts for string-valued categories (preemption reasons),
    keys sorted lexically for diff stability."""
    counts = collections.Counter(values)
    return {k: counts[k] for k in sorted(counts)}


def summarize(metrics: list[RequestMetrics], wall_s: float) -> dict:
    """Aggregate finished-request metrics over a ``wall_s``-second window."""
    done = [m for m in metrics if m.n_tokens > 0]
    total_tokens = sum(m.n_tokens for m in done)
    gaps = [g for m in done for g in m.itl]
    chunks = [m.prefill_chunks for m in done]
    out = {
        "n_requests": len(done),
        "total_tokens": total_tokens,
        "wall_s": wall_s,
        "tok_per_s": total_tokens / wall_s if wall_s > 0 else float("nan"),
        "ttft": percentiles([m.ttft for m in done]),
        "tpot": percentiles([m.tpot for m in done if m.n_tokens > 1]),
        "itl": percentiles(gaps),
        "e2e": percentiles([m.e2e for m in done]),
        "queue_wait": percentiles([m.queue_wait for m in done]),
        "prefill_chunks": {
            "mean": float(np.mean(chunks)) if chunks else math.nan,
            "max": int(max(chunks, default=0)),
            "hist": histogram(chunks),
        },
        "preemptions": {
            "total": sum(m.n_preemptions for m in done),
            "n_requests_preempted": sum(
                1 for m in done if m.n_preemptions > 0),
            "max_per_request": max(
                (m.n_preemptions for m in done), default=0),
            "by_reason": histogram_str(
                m.last_preempt_reason for m in done
                if m.last_preempt_reason),
        },
    }
    drafted = sum(m.spec_drafted for m in done)
    if drafted:
        accepted = sum(m.spec_accepted for m in done)
        out["speculative"] = {
            "drafted": drafted,
            "accepted": accepted,
            "acceptance_rate": accepted / drafted,
        }
    families = sorted({m.family for m in done if m.family})
    if len(families) > 1 or (families and families != [""]):
        # mixed-family window: per-family throughput and latency tails,
        # over the SAME wall clock (the families share the step loop, so
        # each family's tok/s is its share of the window, not a solo run)
        out["families"] = {
            fam: {
                "n_requests": len(sub),
                "total_tokens": sum(m.n_tokens for m in sub),
                "tok_per_s": (sum(m.n_tokens for m in sub) / wall_s
                              if wall_s > 0 else float("nan")),
                "ttft": percentiles([m.ttft for m in sub]),
                "itl": percentiles([g for m in sub for g in m.itl]),
            }
            for fam in families
            for sub in [[m for m in done if m.family == fam]]
        }
    return out


def format_summary(name: str, s: dict) -> str:
    tps = s["tok_per_s"]
    line = (f"{name:>8}: {s['n_requests']} req, {s['total_tokens']} tok "
            f"in {s['wall_s']:.2f}s"
            + (f" = {tps:.1f} tok/s" if not math.isnan(tps) else ""))
    # ttft/tpot are NaN when no (multi-token) request finished in the
    # window — skip the segment rather than printing "nanms", same guard
    # itl has always had
    ttft = s.get("ttft", {})
    if ttft and not math.isnan(ttft.get("p99", math.nan)):
        line += (f" | ttft p50 {ttft['p50']*1e3:.0f}ms "
                 f"p99 {ttft['p99']*1e3:.0f}ms")
    tpot = s.get("tpot", {})
    if tpot and not math.isnan(tpot.get("p99", math.nan)):
        line += (f" | tpot p50 {tpot['p50']*1e3:.1f}ms "
                 f"p99 {tpot['p99']*1e3:.1f}ms")
    e2e = s.get("e2e", {})
    if e2e and not math.isnan(e2e.get("p99", math.nan)):
        line += (f" | e2e p50 {e2e['p50']*1e3:.0f}ms "
                 f"p99 {e2e['p99']*1e3:.0f}ms")
    itl = s.get("itl", {})
    if itl and not math.isnan(itl.get("p99", math.nan)):
        line += f" | itl p99 {itl['p99']*1e3:.1f}ms"
    ch = s.get("prefill_chunks", {})
    if ch.get("max", 0) > 1:
        line += f" | chunks max {ch['max']}"
    pre = s.get("preemptions", {})
    if pre.get("total", 0) > 0:
        line += f" | preempt {pre['total']}"
    sp = s.get("speculative")
    if sp:
        line += f" | spec accept {sp['acceptance_rate']:.2f}"
    return line
