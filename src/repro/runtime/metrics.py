"""Serving/runtime latency instrumentation.

``RequestMetrics`` records one request's lifecycle timestamps (all from the
engine's injected clock, so tests can drive virtual time); ``summarize``
folds a set of finished requests into the numbers the benchmark reports:
throughput (generated tok/s over the measured window) and p50/p99 of
time-to-first-token, per-output-token latency, and end-to-end latency.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class RequestMetrics:
    arrival: float = 0.0               # submitted to the queue
    admitted: float = 0.0              # scheduled into a slot (prefill start)
    first_token: float = 0.0           # first generated token emitted
    finished: float = 0.0              # final token emitted / evicted
    n_tokens: int = 0                  # generated tokens (prompt excluded)

    @property
    def queue_wait(self) -> float:
        return self.admitted - self.arrival

    @property
    def ttft(self) -> float:
        """Time to first token, from arrival (includes queueing)."""
        return self.first_token - self.arrival

    @property
    def e2e(self) -> float:
        return self.finished - self.arrival

    @property
    def tpot(self) -> float:
        """Mean time per output token after the first."""
        if self.n_tokens <= 1:
            return 0.0
        return (self.finished - self.first_token) / (self.n_tokens - 1)


def percentiles(values, ps=(50, 99)) -> dict[str, float]:
    if not len(values):
        return {f"p{p}": float("nan") for p in ps}
    arr = np.asarray(values, np.float64)
    return {f"p{p}": float(np.percentile(arr, p)) for p in ps}


def summarize(metrics: list[RequestMetrics], wall_s: float) -> dict:
    """Aggregate finished-request metrics over a ``wall_s``-second window."""
    done = [m for m in metrics if m.n_tokens > 0]
    total_tokens = sum(m.n_tokens for m in done)
    out = {
        "n_requests": len(done),
        "total_tokens": total_tokens,
        "wall_s": wall_s,
        "tok_per_s": total_tokens / wall_s if wall_s > 0 else float("nan"),
        "ttft": percentiles([m.ttft for m in done]),
        "tpot": percentiles([m.tpot for m in done if m.n_tokens > 1]),
        "e2e": percentiles([m.e2e for m in done]),
        "queue_wait": percentiles([m.queue_wait for m in done]),
    }
    return out


def format_summary(name: str, s: dict) -> str:
    return (f"{name:>8}: {s['n_requests']} req, {s['total_tokens']} tok "
            f"in {s['wall_s']:.2f}s = {s['tok_per_s']:.1f} tok/s | "
            f"ttft p50 {s['ttft']['p50']*1e3:.0f}ms p99 {s['ttft']['p99']*1e3:.0f}ms | "
            f"tpot p50 {s['tpot']['p50']*1e3:.1f}ms p99 {s['tpot']['p99']*1e3:.1f}ms | "
            f"e2e p50 {s['e2e']['p50']*1e3:.0f}ms p99 {s['e2e']['p99']*1e3:.0f}ms")
