"""Sharded checkpointing: atomic, async, elastic-restore.

Layout (one directory per step):
    ckpt_dir/
      step_000120.tmp/ ... -> atomically renamed to step_000120/
        manifest.json        # step, leaf paths/shapes/dtypes, config hash, mesh
        shard_00000.npz      # this host's leaves (addressed by logical name)

Design points for 1000+ node runs:
  - every host writes only its own addressable shards; the manifest stores
    the GLOBAL shapes, so a checkpoint saved on mesh A restores onto mesh B
    (elastic re-mesh) — restore reads the global array and re-shards.
  - commit is an atomic rename after all shards + manifest are fsync'd; a
    crashed save leaves only a .tmp dir that GC removes -> restart always
    finds a consistent step.
  - async save: the host-side np.copy happens on the caller thread (cheap),
    compression+IO in a background thread; ``wait()`` joins before the next
    save to bound in-flight state.
  - keep_last_k garbage collection.

On this single-process container every "host" is process 0; the pathing is
identical in multi-process runs (jax.process_index()).
"""
from __future__ import annotations

import hashlib
import json
import os
import pathlib
import shutil
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np

_RAW_VIEW = {1: np.uint8, 2: np.uint16, 4: np.uint32, 8: np.uint64}


def _leaf_names(tree) -> list[str]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    names = []
    for path, _leaf in flat:
        names.append("/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                              for p in path))
    return names


def config_hash(cfg) -> str:
    return hashlib.sha1(repr(cfg).encode()).hexdigest()[:12]


class CheckpointManager:
    def __init__(self, directory: str | os.PathLike, keep_last_k: int = 3):
        self.dir = pathlib.Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep_last_k
        self._thread: threading.Thread | None = None
        self.gc_stale_tmp()

    # ------------------------------------------------------------- save ---
    def save(self, step: int, tree, cfg=None, blocking: bool = True):
        """Serialize the pytree at ``step``. Host-local copy is synchronous;
        IO runs in the background when blocking=False."""
        self.wait()
        flat, treedef = jax.tree_util.tree_flatten(tree)
        names = _leaf_names(tree)
        # np.savez only handles builtin dtypes: store ml_dtypes (bfloat16,
        # fp8, ...) as raw same-width uint views; manifest keeps the truth.
        host = {}
        for n, l in zip(names, flat):
            a = np.asarray(l)
            if a.dtype.kind not in "biufc":
                a = a.view(_RAW_VIEW[a.dtype.itemsize])
            host[n] = a
        manifest = {
            "step": step,
            "time": time.time(),
            "config_hash": config_hash(cfg) if cfg is not None else None,
            "process_count": jax.process_count(),
            "leaves": {n: {"shape": list(np.shape(l)),
                           "dtype": str(np.asarray(l).dtype)}
                       for n, l in zip(names, flat)},
        }

        def _write():
            tmp = self.dir / f"step_{step:06d}.tmp"
            final = self.dir / f"step_{step:06d}"
            tmp.mkdir(parents=True, exist_ok=True)
            np.savez(tmp / f"shard_{jax.process_index():05d}.npz", **host)
            (tmp / "manifest.json").write_text(json.dumps(manifest))
            os.replace(tmp, final)          # atomic commit
            self._gc()

        if blocking:
            _write()
        else:
            self._thread = threading.Thread(target=_write, daemon=True)
            self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    # ---------------------------------------------------------- restore ---
    def latest_step(self) -> int | None:
        steps = sorted(int(p.name.split("_")[1]) for p in self.dir.glob("step_*")
                       if not p.name.endswith(".tmp"))
        return steps[-1] if steps else None

    def restore(self, like, step: int | None = None, shardings=None):
        """Rebuild the pytree. ``like`` provides structure (arrays or SDS).

        ``shardings`` (optional pytree) re-shards onto the CURRENT mesh —
        elastic restore across different mesh shapes."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {self.dir}")
        d = self.dir / f"step_{step:06d}"
        manifest = json.loads((d / "manifest.json").read_text())
        data = {}
        for shard in sorted(d.glob("shard_*.npz")):
            with np.load(shard) as z:
                data.update({k: z[k] for k in z.files})
        flat, treedef = jax.tree_util.tree_flatten(like)
        names = _leaf_names(like)
        out = []
        sh_flat = (jax.tree_util.tree_flatten(shardings)[0]
                   if shardings is not None else [None] * len(flat))
        import ml_dtypes
        for n, l, sh in zip(names, flat, sh_flat):
            arr = data[n]
            meta = manifest["leaves"][n]
            if arr.dtype.kind in "iu" and meta["dtype"] not in (str(arr.dtype),):
                # raw view of an ml_dtype (bfloat16, fp8, ...): view back
                arr = arr.view(np.dtype(getattr(ml_dtypes, meta["dtype"],
                                                meta["dtype"])))
            expect = tuple(meta["shape"])
            if tuple(arr.shape) != expect:
                raise ValueError(f"shape mismatch for {n}: {arr.shape} vs {expect}")
            out.append(jax.device_put(arr, sh) if sh is not None
                       else jnp.asarray(arr))
        return jax.tree_util.tree_unflatten(treedef, out), manifest

    # --------------------------------------------------------------- gc ---
    def _gc(self):
        steps = sorted((int(p.name.split("_")[1]), p) for p in self.dir.glob("step_*")
                       if not p.name.endswith(".tmp"))
        for _, p in steps[:-self.keep] if self.keep else []:
            shutil.rmtree(p, ignore_errors=True)

    def gc_stale_tmp(self):
        for p in self.dir.glob("*.tmp"):
            shutil.rmtree(p, ignore_errors=True)
