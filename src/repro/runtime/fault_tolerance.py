"""Fault tolerance for long multi-pod runs.

Components (all exercised by tests/test_fault_tolerance.py with simulated
failures — the container has one process, the logic is process-count
agnostic):

  HeartbeatRegistry   — per-host liveness; a host missing ``timeout`` seconds
                        of beats is declared dead -> run transitions to
                        RESTARTING and reloads the last committed checkpoint.
  StragglerDetector   — per-step host wall-times; EWMA + k*sigma flag.  At
                        scale the scheduler uses this to (a) exclude the host
                        at the next elastic re-mesh, or (b) enable backup
                        execution for input pipeline work.
  TrainSupervisor     — the restart loop: run steps, checkpoint every k,
                        on failure restore latest + rebuild the data iterator
                        at the right offset (deterministic resume), optionally
                        on a SMALLER mesh (elastic: checkpoint stores global
                        arrays; parallel/sharding re-shards).

PP note (DESIGN.md §4): at >=4 pods the `pod` axis would run a 1F1B pipeline;
the supervisor's step loop is already microbatch-structured so a ppermute
schedule slots into `steps.make_train_step` without touching this module.
"""
from __future__ import annotations

import dataclasses
import math
import time
from collections import defaultdict, deque
from typing import Callable


class HostFailure(RuntimeError):
    """Raised (or simulated) when a host dies mid-step."""


@dataclasses.dataclass
class HeartbeatRegistry:
    timeout_s: float = 60.0
    _last: dict = dataclasses.field(default_factory=dict)

    def beat(self, host: int, now: float | None = None):
        self._last[host] = now if now is not None else time.time()

    def dead_hosts(self, now: float | None = None) -> list[int]:
        now = now if now is not None else time.time()
        return [h for h, t in self._last.items() if now - t > self.timeout_s]

    def healthy(self, now: float | None = None) -> bool:
        return not self.dead_hosts(now)


class StragglerDetector:
    """Flags hosts whose step time exceeds mean + k*std of the fleet EWMA."""

    def __init__(self, alpha: float = 0.2, k_sigma: float = 3.0,
                 min_steps: int = 5):
        self.alpha = alpha
        self.k = k_sigma
        self.min_steps = min_steps
        self.ewma: dict[int, float] = {}
        self.count: dict[int, int] = defaultdict(int)

    def record(self, host: int, step_time_s: float):
        prev = self.ewma.get(host, step_time_s)
        self.ewma[host] = (1 - self.alpha) * prev + self.alpha * step_time_s
        self.count[host] += 1

    def stragglers(self) -> list[int]:
        ready = {h: v for h, v in self.ewma.items()
                 if self.count[h] >= self.min_steps}
        if len(ready) < 2:
            return []
        vals = list(ready.values())
        mean = sum(vals) / len(vals)
        var = sum((v - mean) ** 2 for v in vals) / len(vals)
        thr = mean + self.k * math.sqrt(var)
        return [h for h, v in ready.items() if v > thr]


@dataclasses.dataclass
class SupervisorReport:
    steps_run: int = 0
    restarts: int = 0
    restored_steps: list = dataclasses.field(default_factory=list)
    losses: list = dataclasses.field(default_factory=list)


class TrainSupervisor:
    """Restart-from-checkpoint loop around an arbitrary step function.

    step_fn(state, step_idx) -> (state, metrics) may raise HostFailure.
    ``make_state(restored_or_none)`` (re)builds device state from a restored
    host pytree (or fresh when None).
    """

    def __init__(self, ckpt_manager, save_every: int = 10,
                 max_restarts: int = 8):
        self.ckpt = ckpt_manager
        self.save_every = save_every
        self.max_restarts = max_restarts

    def run(self, make_state: Callable, step_fn: Callable, total_steps: int,
            cfg=None) -> SupervisorReport:
        rep = SupervisorReport()
        restarts = 0
        state = make_state(None)
        start = 0
        latest = self.ckpt.latest_step()
        if latest is not None:
            restored, _ = self.ckpt.restore(state)
            state = make_state(restored)
            start = latest
            rep.restored_steps.append(latest)

        step = start
        while step < total_steps:
            try:
                state, metrics = step_fn(state, step)
                rep.losses.append(float(metrics.get("loss", float("nan"))))
                step += 1
                rep.steps_run += 1
                if step % self.save_every == 0 or step == total_steps:
                    self.ckpt.save(step, state, cfg=cfg, blocking=False)
            except HostFailure:
                restarts += 1
                rep.restarts = restarts
                if restarts > self.max_restarts:
                    raise
                self.ckpt.wait()
                latest = self.ckpt.latest_step()
                if latest is None:          # nothing committed yet: cold start
                    state = make_state(None)
                    step = 0
                else:
                    restored, _ = self.ckpt.restore(state)
                    state = make_state(restored)
                    step = latest
                    rep.restored_steps.append(latest)
        self.ckpt.wait()
        return rep
