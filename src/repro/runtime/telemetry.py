"""Telemetry primitives: a counter/gauge registry and a Chrome/Perfetto
``trace_event`` buffer.

This module is the serving stack's measurement substrate, deliberately
generic — nothing in it knows about engines, requests, or KV pools.  The
serving-specific wiring (what spans mean, which counters exist, when they
are sampled) lives in ``serving/observe.py``.

``MetricsRegistry``
    Named counters (monotonic) and gauges (last-write) with optional
    labels, e.g. ``reg.counter("tokens_decoded_total").inc(5,
    family="dense")``.  ``prometheus_text()`` renders the whole registry
    in the Prometheus text exposition format; ``snapshot()`` returns the
    same data as plain nested dicts for JSON embedding.

``TraceBuffer``
    An append-only list of Chrome ``trace_event`` dicts — complete
    ("X") duration spans, instants ("i"), counter series ("C"), and
    process/thread metadata ("M") — exported as the JSON object format
    (``{"traceEvents": [...]}``) that ``ui.perfetto.dev`` and
    ``chrome://tracing`` load directly.  Timestamps are microseconds; the
    caller supplies them (the serving tracer uses the engine's injected
    clock so virtual-time tests produce exact traces).
"""
from __future__ import annotations

import json


def _label_key(labels: dict) -> tuple:
    return tuple(sorted(labels.items()))


def _label_text(key: tuple) -> str:
    if not key:
        return ""
    inner = ",".join(f'{k}="{v}"' for k, v in key)
    return "{" + inner + "}"


class _Metric:
    """One named metric family: a value per distinct label set."""

    kind = "untyped"

    def __init__(self, name: str, help: str = ""):
        self.name = name
        self.help = help
        self._values: dict[tuple, float] = {}

    def get(self, **labels) -> float:
        return self._values.get(_label_key(labels), 0.0)

    def series(self) -> dict[tuple, float]:
        return dict(self._values)


class Counter(_Metric):
    kind = "counter"

    def inc(self, amount: float = 1.0, **labels) -> None:
        if amount < 0:
            raise ValueError(f"counter {self.name} cannot decrease "
                             f"(inc by {amount})")
        key = _label_key(labels)
        self._values[key] = self._values.get(key, 0.0) + amount


class Gauge(_Metric):
    kind = "gauge"

    def set(self, value: float, **labels) -> None:
        self._values[_label_key(labels)] = float(value)


class MetricsRegistry:
    """Process-local named metrics.  ``counter``/``gauge`` create on first
    use and return the existing instance afterwards (re-registering with a
    different kind is an error — one name, one meaning)."""

    def __init__(self):
        self._metrics: dict[str, _Metric] = {}

    def _get(self, cls, name: str, help: str):
        m = self._metrics.get(name)
        if m is None:
            m = self._metrics[name] = cls(name, help)
        elif not isinstance(m, cls):
            raise ValueError(
                f"metric {name!r} already registered as {m.kind}")
        return m

    def counter(self, name: str, help: str = "") -> Counter:
        return self._get(Counter, name, help)

    def gauge(self, name: str, help: str = "") -> Gauge:
        return self._get(Gauge, name, help)

    def __iter__(self):
        return iter(self._metrics.values())

    def snapshot(self) -> dict:
        """{name: {label_text: value}} — JSON-embeddable."""
        return {m.name: {_label_text(k): v for k, v in m.series().items()}
                for m in self}

    def prometheus_text(self) -> str:
        """The Prometheus text exposition format (one HELP/TYPE header per
        metric family, one line per label set)."""
        lines = []
        for m in sorted(self, key=lambda m: m.name):
            if m.help:
                lines.append(f"# HELP {m.name} {m.help}")
            lines.append(f"# TYPE {m.name} {m.kind}")
            for key, value in sorted(m.series().items()):
                lines.append(f"{m.name}{_label_text(key)} {value:g}")
        return "\n".join(lines) + "\n"


class TraceBuffer:
    """Chrome ``trace_event`` accumulator (JSON object format).

    All timestamps (``ts``) and durations (``dur``) are in MICROSECONDS,
    per the trace_event spec.  Events carry a ``pid``/``tid`` pair that
    Perfetto renders as process/thread tracks; ``set_process_name`` /
    ``set_thread_name`` emit the metadata events that label them.
    """

    def __init__(self):
        self.events: list[dict] = []
        self._named_threads: set[tuple[int, int]] = set()
        self._named_processes: set[int] = set()

    def __len__(self) -> int:
        return len(self.events)

    # ------------------------------------------------------------ metadata
    def set_process_name(self, pid: int, name: str) -> None:
        if pid in self._named_processes:
            return
        self._named_processes.add(pid)
        self.events.append({"ph": "M", "name": "process_name", "pid": pid,
                            "tid": 0, "args": {"name": name}})

    def set_thread_name(self, pid: int, tid: int, name: str) -> None:
        if (pid, tid) in self._named_threads:
            return
        self._named_threads.add((pid, tid))
        self.events.append({"ph": "M", "name": "thread_name", "pid": pid,
                            "tid": tid, "args": {"name": name}})

    # -------------------------------------------------------------- events
    def complete(self, name: str, ts_us: float, dur_us: float, *,
                 pid: int = 0, tid: int = 0, cat: str = "",
                 args: dict | None = None) -> None:
        """A complete duration span ("X"): one event carrying ts + dur."""
        ev = {"ph": "X", "name": name, "ts": ts_us, "dur": max(dur_us, 0.0),
              "pid": pid, "tid": tid}
        if cat:
            ev["cat"] = cat
        if args:
            ev["args"] = args
        self.events.append(ev)

    def instant(self, name: str, ts_us: float, *, pid: int = 0, tid: int = 0,
                cat: str = "", scope: str = "t",
                args: dict | None = None) -> None:
        ev = {"ph": "i", "name": name, "ts": ts_us, "pid": pid, "tid": tid,
              "s": scope}
        if cat:
            ev["cat"] = cat
        if args:
            ev["args"] = args
        self.events.append(ev)

    def counter(self, name: str, ts_us: float, values: dict, *,
                pid: int = 0, tid: int = 0) -> None:
        """A counter sample ("C"): Perfetto plots each key as a series."""
        self.events.append({"ph": "C", "name": name, "ts": ts_us, "pid": pid,
                            "tid": tid, "args": values})

    # -------------------------------------------------------------- export
    def to_json(self) -> dict:
        return {"traceEvents": self.events, "displayTimeUnit": "ms"}

    def write(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.to_json(), f)


def validate_trace_events(obj) -> list[dict]:
    """Check ``obj`` is trace_event JSON (object format or bare array);
    returns the event list.  Raises ``ValueError`` on malformed input —
    used by CI to assert a written trace actually loads in Perfetto."""
    events = obj.get("traceEvents") if isinstance(obj, dict) else obj
    if not isinstance(events, list):
        raise ValueError("not trace_event JSON: no traceEvents array")
    for ev in events:
        if not isinstance(ev, dict) or "ph" not in ev:
            raise ValueError(f"malformed trace event: {ev!r}")
        if ev["ph"] in ("X", "i", "C", "b", "e") and "ts" not in ev:
            raise ValueError(f"event missing ts: {ev!r}")
        if ev["ph"] == "X" and "dur" not in ev:
            raise ValueError(f"complete event missing dur: {ev!r}")
    return events
