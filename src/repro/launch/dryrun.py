import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))
"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this driver:
  1. builds the production mesh (16x16 single-pod / 2x16x16 multi-pod),
  2. lowers the right step (train_step / prefill_step / decode_step) with
     ShapeDtypeStruct inputs + explicit NamedShardings,
  3. compiles, printing memory_analysis() (fits?) and cost_analysis()
     (FLOPs/bytes for §Roofline),
  4. parses collective bytes from the compiled HLO,
  5. (single-pod) runs depth-probe compiles at two reduced unrolled depths
     and extrapolates exact per-layer HLO costs (DESIGN.md §6 scan caveat),
  6. writes one JSON per cell to --out.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-8b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all --out results/dryrun
"""
import argparse
import dataclasses
import json
import pathlib
import time
import traceback

import jax
import jax.numpy as jnp

from ..configs import ASSIGNED, SHAPES, get, shape_applicable
from ..models import cache_specs, get_model, input_specs, param_specs
from ..optim import AdamWConfig, adamw_init
from ..parallel import (batch_shardings, cache_shardings, param_shardings,
                        replicated)
from ..parallel.policy import activation_sharding
from .hlo_analysis import collective_bytes, collective_counts, cost_summary
from .mesh import make_production_mesh
from .steps import TrainOptions, make_decode_step, make_prefill_step, make_train_step

PROBE_DEPTHS = {
    "dense": (2, 4), "moe": (2, 4), "vlm": (2, 4), "encdec": (2, 4),
    "ssm": (8, 16), "hybrid": (6, 12),
}


def _opt_cfg(cfg) -> AdamWConfig:
    big = cfg.param_count() > 1e11
    return AdamWConfig(state_dtype=jnp.bfloat16 if big else jnp.float32)


def _reduce_depth(cfg, L: int):
    kw = {"n_layers": L}
    if cfg.family == "encdec":
        kw["enc_layers"] = L
    return dataclasses.replace(cfg, **kw)


def build_lowered(cfg, shape, mesh, unroll: bool = False,
                  opts: TrainOptions | None = None, sparse: bool = False,
                  quant: bool = False):
    """Lower the cell's step with ShapeDtypeStructs; returns jax Lowered.

    ``sparse=True`` deploys the paper's compressed weights (8:16 + 16:256
    outliers) in the serving graph — inference shapes only.

    The whole body (incl. eval_shape) runs inside the activation-sharding
    policy: jax caches the trace at the first abstract evaluation, so the
    policy must be active for every trace of the step closure."""
    seq_shard = shape.global_batch == 1
    with activation_sharding(mesh, seq_shard):
        params_sds = param_specs(cfg)

        def _shardings(tree):
            sh = param_shardings(mesh, tree)
            if cfg.family == "ssm":
                # xlstm-350m: 1.4M params/chip — TP on block weights only
                # forces model-axis activation all-gathers (§Perf cell B).
                # Keep embed/lm_head vocab-sharded; replicate the rest over
                # `model` (pure DP+FSDP for block weights).
                from jax.sharding import NamedSharding, PartitionSpec as P

                def drop_model(path, ns):
                    name = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                                    for k in path)
                    if "embed" in name or "lm_head" in name:
                        return ns
                    spec = tuple(None if ax == "model" or
                                 (isinstance(ax, tuple) and "model" in ax)
                                 else ax for ax in ns.spec)
                    return NamedSharding(mesh, P(*spec))
                sh = jax.tree_util.tree_map_with_path(drop_model, sh)
            return sh
        if sparse:
            assert shape.kind != "train", "sparse deploy is a serving feature"
            from ..core import SparsifyConfig
            from ..models.sparse_serving import sparsify_for_serving
            scfg = SparsifyConfig(scorer="magnitude", use_smoothquant=False)
            params_sds = jax.eval_shape(
                lambda p: sparsify_for_serving(p, scfg, quantize=quant)[0],
                params_sds)
        p_shard = _shardings(params_sds)
        batch = input_specs(cfg, shape)
        b_shard = batch_shardings(mesh, batch, seq_shard=seq_shard)
        if opts is None:
            # >30B models: microbatched gradient accumulation (8x) bounds the
            # per-layer saved activations of the scan backward (DESIGN.md §7).
            mb = 8 if (shape.kind == "train" and cfg.param_count() > 30e9) else 1
            opts = TrainOptions(unroll=unroll, microbatches=mb)

        if shape.kind == "train":
            ocfg = _opt_cfg(cfg)
            opt_sds = jax.eval_shape(lambda p: adamw_init(p, ocfg), params_sds)
            o_shard = _shardings(opt_sds)
            step = make_train_step(cfg, ocfg, opts)
            out_sds = jax.eval_shape(step, params_sds, opt_sds, batch)
            out_shard = (p_shard, o_shard,
                         jax.tree.map(lambda _: replicated(mesh), out_sds[2]))
            jitted = jax.jit(step, in_shardings=(p_shard, o_shard, b_shard),
                             out_shardings=out_shard, donate_argnums=(0, 1))
            return jitted.lower(params_sds, opt_sds, batch)

        if shape.kind == "prefill":
            step = make_prefill_step(cfg, unroll=opts.unroll)
            out_sds = jax.eval_shape(step, params_sds, batch)
            logits_sh = cache_shardings(mesh, out_sds[0], seq_shard=False)
            caches_sh = cache_shardings(mesh, out_sds[1], seq_shard=seq_shard)
            jitted = jax.jit(step, in_shardings=(p_shard, b_shard),
                             out_shardings=(logits_sh, caches_sh))
            return jitted.lower(params_sds, batch)

        # decode
        step = make_decode_step(cfg, unroll=opts.unroll)
        caches = cache_specs(cfg, shape)
        c_shard = cache_shardings(mesh, caches, seq_shard=seq_shard)
        out_sds = jax.eval_shape(step, params_sds, caches, batch)
        logits_sh = cache_shardings(mesh, out_sds[0], seq_shard=False)
        jitted = jax.jit(step, in_shardings=(p_shard, c_shard, b_shard),
                         out_shardings=(logits_sh, c_shard), donate_argnums=(1,))
        return jitted.lower(params_sds, caches, batch)


def analyse(lowered) -> dict:
    t0 = time.time()
    compiled = lowered.compile()
    res = cost_summary(compiled)
    hlo = compiled.as_text()
    res["collective_bytes"] = collective_bytes(hlo)
    res["collective_counts"] = collective_counts(hlo)
    res["compile_s"] = round(time.time() - t0, 1)
    return res


def run_cell(arch: str, shape_name: str, multi_pod: bool, probe: bool = True,
             out_dir: pathlib.Path | None = None, verbose: bool = True,
             sparse: bool = False, quant: bool = False) -> dict:
    cfg = get(arch)
    shape = SHAPES[shape_name]
    mesh_tag = "pod2x16x16" if multi_pod else "pod16x16"
    if sparse:
        mesh_tag += "_sparse"
    if quant:
        mesh_tag += "q"
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_tag,
           "params": cfg.param_count(), "active_params": cfg.active_param_count()}

    if not shape_applicable(cfg, shape):
        rec["status"] = "skipped"
        rec["reason"] = "full-attention arch; long_500k requires sub-quadratic state (DESIGN.md §5)"
    else:
        mesh = make_production_mesh(multi_pod=multi_pod)
        try:
            lowered = build_lowered(cfg, shape, mesh, sparse=sparse,
                                    quant=quant)
            rec["full"] = analyse(lowered)
            rec["status"] = "ok"
            if verbose:
                mem = rec["full"]["memory"]
                print(f"  [{arch} x {shape_name} x {mesh_tag}] compile ok "
                      f"args={mem.get('argument_size_in_bytes', 0)/2**30:.2f}GiB/dev "
                      f"temp={mem.get('temp_size_in_bytes', 0)/2**30:.2f}GiB/dev "
                      f"flops={rec['full']['flops']:.3g} "
                      f"coll={rec['full']['collective_bytes'].get('total',0)/2**20:.1f}MiB")
        except Exception as e:  # noqa: BLE001 — record the failure, dryrun continues
            rec["status"] = "error"
            rec["error"] = f"{type(e).__name__}: {e}"
            rec["traceback"] = traceback.format_exc()[-2000:]
            print(f"  [{arch} x {shape_name} x {mesh_tag}] FAILED: {rec['error']}")

        if probe and not multi_pod and rec["status"] == "ok":
            try:
                rec["probe"] = depth_probe(cfg, shape, mesh, sparse=sparse,
                                           quant=quant)
            except Exception as e:  # noqa: BLE001
                rec["probe_error"] = f"{type(e).__name__}: {e}"

    if out_dir is not None:
        out_dir.mkdir(parents=True, exist_ok=True)
        fn = out_dir / f"{arch}__{shape_name}__{mesh_tag}.json"
        fn.write_text(json.dumps(rec, indent=1))
    return rec


def depth_probe(cfg, shape, mesh, sparse: bool = False,
                quant: bool = False) -> dict:
    """Compile at two reduced unrolled depths; linear-fit per-layer HLO cost."""
    L1, L2 = PROBE_DEPTHS[cfg.family]
    probes = {}
    for L in (L1, L2):
        lowered = build_lowered(_reduce_depth(cfg, L), shape, mesh,
                                unroll=True, sparse=sparse, quant=quant)
        probes[L] = analyse(lowered)

    def fit(get_val):
        c1, c2 = get_val(probes[L1]), get_val(probes[L2])
        b = (c2 - c1) / (L2 - L1)
        a = c1 - b * L1
        return a + b * cfg.n_layers

    extrap = {
        "flops": fit(lambda r: r["flops"]),
        "bytes_accessed": fit(lambda r: r["bytes_accessed"]),
        "collective_bytes": fit(lambda r: r["collective_bytes"].get("total", 0.0)),
        "depths": [L1, L2],
        "probe_full": probes,
    }
    return extrap


def iter_cells():
    for arch in ASSIGNED:
        for shape_name in SHAPES:
            yield arch, shape_name


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--no-probe", action="store_true")
    ap.add_argument("--sparse", action="store_true",
                    help="deploy compressed 8:16+outlier weights (serving cells)")
    ap.add_argument("--quant", action="store_true",
                    help="with --sparse: int8 N:M values (beyond-paper)")
    ap.add_argument("--out", default="results/dryrun")
    args = ap.parse_args()

    out = pathlib.Path(args.out)
    cells = list(iter_cells()) if args.all else [(args.arch, args.shape)]
    meshes = [False, True] if (args.both_meshes or args.all) else [args.multi_pod]
    t0 = time.time()
    for arch, shape_name in cells:
        for mp in meshes:
            run_cell(arch, shape_name, mp, probe=not args.no_probe,
                     out_dir=out, sparse=args.sparse, quant=args.quant)
    print(f"done in {time.time()-t0:.0f}s -> {out}")


if __name__ == "__main__":
    main()
