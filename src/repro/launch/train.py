"""Training driver: data pipeline -> sharded train_step -> checkpoint/restart.

Runs on anything from 1 CPU device (smoke) to the production mesh (the same
code path the dry-run lowers).  Fault tolerance comes from
runtime.TrainSupervisor: failures (including simulated ones via
--fail-at-step) restore the last committed checkpoint and continue.

Example (CPU):
  PYTHONPATH=src python -m repro.launch.train --arch llama-paper-smoke \
      --steps 50 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from ..configs import get, get_smoke
from ..data.pipeline import SyntheticLM
from ..models import get_model, param_specs
from ..optim import AdamWConfig, adamw_init
from ..parallel import batch_shardings, param_shardings
from ..parallel.policy import activation_sharding
from ..runtime.checkpoint import CheckpointManager
from ..runtime.fault_tolerance import HostFailure, TrainSupervisor
from .mesh import make_host_mesh, make_production_mesh
from .steps import TrainOptions, make_train_step


def build(cfg, mesh, opt_cfg, opts: TrainOptions):
    step_fn = make_train_step(cfg, opt_cfg, opts)
    params_sds = param_specs(cfg)
    p_sh = param_shardings(mesh, params_sds)
    opt_sds = jax.eval_shape(lambda p: adamw_init(p, opt_cfg), params_sds)
    o_sh = param_shardings(mesh, opt_sds)
    jitted = jax.jit(step_fn, in_shardings=(p_sh, o_sh, None),
                     donate_argnums=(0, 1))
    return jitted, p_sh, o_sh


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama-paper-smoke")
    ap.add_argument("--smoke-arch", action="store_true",
                    help="resolve --arch through the smoke registry")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--grad-compression", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--save-every", type=int, default=20)
    ap.add_argument("--fail-at-step", type=int, default=-1,
                    help="simulate a host failure once at this step")
    ap.add_argument("--production-mesh", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_smoke(args.arch) if args.smoke_arch else get(args.arch)
    mesh = (make_production_mesh() if args.production_mesh else make_host_mesh())
    opt_cfg = AdamWConfig(lr=args.lr)
    opts = TrainOptions(microbatches=args.microbatches,
                        grad_compression=args.grad_compression)
    data = SyntheticLM(vocab=cfg.vocab, seq_len=args.seq, batch=args.batch,
                       seed=args.seed)
    ckpt = CheckpointManager(args.ckpt_dir)
    supervisor = TrainSupervisor(ckpt, save_every=args.save_every)
    zoo = get_model(cfg)

    with activation_sharding(mesh):
        jitted, p_sh, o_sh = build(cfg, mesh, opt_cfg, opts)

        def make_state(restored):
            if restored is None:
                params = zoo.init(jax.random.PRNGKey(args.seed))
                return {"params": params,
                        "opt": adamw_init(params, opt_cfg)}
            return restored          # CheckpointManager returns device arrays

        failed = {"done": False}

        def step_fn(state, step):
            if step == args.fail_at_step and not failed["done"]:
                failed["done"] = True
                raise HostFailure(f"simulated failure at step {step}")
            batch = jax.tree.map(jnp.asarray, data.batch_at(step))
            t0 = time.time()
            params, opt, metrics = jitted(state["params"], state["opt"], batch)
            metrics = {k: float(v) for k, v in metrics.items()}
            metrics["step_s"] = time.time() - t0
            if step % 10 == 0 or step == args.steps - 1:
                print(f"step {step:5d} loss {metrics['loss']:.4f} "
                      f"gnorm {metrics['grad_norm']:.3f} "
                      f"({metrics['step_s']:.2f}s)")
            return {"params": params, "opt": opt}, metrics

        report = supervisor.run(make_state, step_fn, args.steps, cfg=cfg)
    print(f"finished: {report.steps_run} steps, {report.restarts} restarts, "
          f"final loss {report.losses[-1]:.4f}")
    return report


if __name__ == "__main__":
    main()
