"""Step functions the launcher / dry-run lower: train, prefill, decode.

All are pure (params, state, batch) -> (outputs) functions built per config,
jit-able with explicit in/out shardings.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp

from ..models import get_model
from ..optim import AdamWConfig, adamw_init, adamw_step
from ..optim import grad_compress as gc


@dataclasses.dataclass(frozen=True)
class TrainOptions:
    microbatches: int = 1          # gradient accumulation steps
    grad_compression: bool = False # int8 + error feedback between microbatches
    unroll: bool = False           # Python-unrolled layer loop (dry-run)


def make_train_step(cfg, opt_cfg: AdamWConfig, opts: TrainOptions = TrainOptions()):
    zoo = get_model(cfg)

    def loss_of(params, batch):
        loss, aux = zoo.loss(params, batch, unroll=opts.unroll)
        return loss

    def train_step(params, opt_state, batch):
        if opts.microbatches > 1:
            # the batch axis is axis 0 except for M-RoPE positions [3, B, S]
            bdim = max(x.shape[0] for x in jax.tree.leaves(batch)
                       if x.shape[0] != 3) if jax.tree.leaves(batch) else 0

            def split(x):
                mb = opts.microbatches
                ax = 0 if x.shape[0] == bdim else 1
                pre = x.shape[:ax]
                return jnp.moveaxis(
                    x.reshape(*pre, mb, x.shape[ax] // mb, *x.shape[ax + 1:]),
                    ax, 0)
            micro = jax.tree.map(split, batch)

            def acc_body(carry, mb_batch):
                gsum, lsum = carry
                l, g = jax.value_and_grad(loss_of)(params, mb_batch)
                if opts.grad_compression:
                    q, _ = gc.compress_with_feedback(g, jax.tree.map(
                        lambda p: jnp.zeros(p.shape, jnp.float32), g))
                    g = gc.decompress(q, g)
                gsum = jax.tree.map(jnp.add, gsum, jax.tree.map(
                    lambda x: x.astype(jnp.float32), g))
                return (gsum, lsum + l), None

            zero = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (gsum, lsum), _ = jax.lax.scan(acc_body, (zero, 0.0), micro)
            grads = jax.tree.map(lambda g: g / opts.microbatches, gsum)
            loss = lsum / opts.microbatches
        else:
            loss, grads = jax.value_and_grad(loss_of)(params, batch)
        new_params, new_opt, metrics = adamw_step(params, grads, opt_state, opt_cfg)
        return new_params, new_opt, {"loss": loss, **metrics}

    return train_step


def make_prefill_step(cfg, unroll: bool = False):
    zoo = get_model(cfg)

    def prefill_step(params, batch):
        return zoo.prefill(params, batch, unroll=unroll)

    return prefill_step


def make_decode_step(cfg, unroll: bool = False):
    zoo = get_model(cfg)

    def decode_step(params, caches, batch):
        return zoo.decode(params, caches, batch, unroll=unroll)

    return decode_step
