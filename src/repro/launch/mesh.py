"""Production meshes (assignment spec).

Defined as FUNCTIONS so importing this module never touches jax device
state.  The dry-run sets XLA_FLAGS=--xla_force_host_platform_device_count=512
before any jax import to get 512 placeholder CPU devices.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    # NOTE: no axis_types kwarg — jax.sharding.AxisType doesn't exist on the
    # pinned JAX, and Auto (what these meshes want) is the default where it
    # does, so the bare call is correct on every supported version.
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """1-device mesh for CPU smoke/bench runs (same axis names)."""
    return jax.make_mesh((1, 1), ("data", "model"))


# TPU v5e hardware constants (roofline):
PEAK_FLOPS_BF16 = 197e12          # per chip
HBM_BW = 819e9                    # bytes/s per chip
ICI_BW = 50e9                     # bytes/s per link
