"""Production meshes (assignment spec).

Defined as FUNCTIONS so importing this module never touches jax device
state.  The dry-run sets XLA_FLAGS=--xla_force_host_platform_device_count=512
before any jax import to get 512 placeholder CPU devices.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    # NOTE: no axis_types kwarg — jax.sharding.AxisType doesn't exist on the
    # pinned JAX, and Auto (what these meshes want) is the default where it
    # does, so the bare call is correct on every supported version.
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """1-device mesh for CPU smoke/bench runs (same axis names)."""
    return jax.make_mesh((1, 1), ("data", "model"))


def parse_mesh_spec(spec: str | None) -> tuple[int, int] | None:
    """CLI mesh spec -> (data, model) sizes.

    ``"1x8"`` -> (1, 8); a bare ``"8"`` means model-only TP, i.e. (1, 8);
    None/"" -> None (no mesh: the single-device serving path)."""
    if spec is None or spec == "" or spec.lower() == "none":
        return None
    parts = spec.lower().split("x")
    try:
        if len(parts) == 1:
            dm = 1, int(parts[0])
        elif len(parts) == 2:
            dm = int(parts[0]), int(parts[1])
        else:
            dm = None
    except ValueError:
        dm = None
    if dm is None or dm[0] < 1 or dm[1] < 1:
        raise ValueError(f"mesh spec must be positive sizes like '1x8' or "
                         f"'8', got {spec!r}")
    return dm


def make_serving_mesh(spec: str | None):
    """Build the serving ("data", "model") mesh named by a CLI spec over
    the locally visible devices; None when no mesh is requested."""
    dm = parse_mesh_spec(spec)
    if dm is None:
        return None
    d, m = dm
    n_dev = len(jax.devices())
    if d * m > n_dev:
        raise ValueError(
            f"mesh {d}x{m} needs {d * m} devices but only {n_dev} are "
            f"visible (CPU: set XLA_FLAGS=--xla_force_host_platform_"
            f"device_count={d * m} before the first jax import)")
    return jax.make_mesh((d, m), ("data", "model"))


def make_replica_meshes(spec: str | None, n_replicas: int) -> list:
    """Per-replica serving meshes over DISJOINT device slices — fleet
    scale-out: replica i's ``spec``-shaped mesh uses devices
    [i*d*m, (i+1)*d*m), so N engine replicas run side by side with no
    device shared (each replica's jitted steps dispatch to its own
    devices).  ``spec`` is the PER-REPLICA mesh; None -> [None]*N (every
    replica on the default single-device path, the CPU smoke case)."""
    import numpy as np
    from jax.sharding import Mesh

    if n_replicas < 1:
        raise ValueError(f"n_replicas must be >= 1, got {n_replicas}")
    dm = parse_mesh_spec(spec)
    if dm is None:
        return [None] * n_replicas
    d, m = dm
    per = d * m
    devices = jax.devices()
    if per * n_replicas > len(devices):
        raise ValueError(
            f"{n_replicas} replicas of a {d}x{m} mesh need "
            f"{per * n_replicas} devices but only {len(devices)} are "
            f"visible (CPU: set XLA_FLAGS=--xla_force_host_platform_"
            f"device_count={per * n_replicas} before the first jax import)")
    return [Mesh(np.asarray(devices[i * per:(i + 1) * per]).reshape(d, m),
                 ("data", "model"))
            for i in range(n_replicas)]


# TPU v5e hardware constants (roofline):
PEAK_FLOPS_BF16 = 197e12          # per chip
HBM_BW = 819e9                    # bytes/s per chip
ICI_BW = 50e9                     # bytes/s per link
