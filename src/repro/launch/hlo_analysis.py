"""Extract roofline inputs from a compiled SPMD module.

- flops / bytes: compiled.cost_analysis() (per-device in SPMD).
- collective bytes: parse the HLO text; for each all-gather / all-reduce /
  reduce-scatter / all-to-all / collective-permute instruction, sum the byte
  sizes of its *operands* (assignment spec).  Operand types are resolved from
  their defining instructions.

Known caveat (DESIGN.md §6): XLA cost analysis counts while-loop (lax.scan)
bodies ONCE.  The dry-run therefore (a) unrolls the layer loop where
feasible, and (b) uses the depth-probe extrapolation: compile the same step
at two reduced depths L1 < L2, fit flops = a + b*L, report a + b*L_full.
"""
from __future__ import annotations

import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8, "f16": 2, "bf16": 2, "f32": 4,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?(%?[\w.\-]+)\s*=\s*(\(?[^)=]*?\)?)\s*([\w\-]+)\(")
COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")


def _type_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Per-collective-kind operand bytes, plus 'total'."""
    # map instruction name -> result byte size
    sizes: dict[str, int] = {}
    lines = hlo_text.splitlines()
    for ln in lines:
        m = re.match(r"\s*(?:ROOT\s+)?(%?[\w.\-]+)\s*=\s*((?:\([^=]*?\))|(?:[\w\[\],{}\/#:\s]*?))\s[\w\-]+\(", ln)
        if m:
            sizes[m.group(1).lstrip("%")] = _type_bytes(m.group(2))

    out: dict[str, float] = defaultdict(float)
    for ln in lines:
        for kind in COLLECTIVES:
            # match e.g. "%ag = bf16[...] all-gather(%x)", avoid -start/-done fusions duplicates
            if re.search(rf"\s{kind}(?:-start)?\(", ln):
                ops = re.findall(r"\(([^)]*)\)", ln)
                if not ops:
                    continue
                args = ops[0]
                total = 0
                for arg in args.split(","):
                    arg = arg.strip().lstrip("%")
                    # operand may be printed with its own type: "bf16[8,16] %p.1"
                    if " " in arg:
                        ty, _, nm = arg.rpartition(" ")
                        b = _type_bytes(ty) or sizes.get(nm.lstrip("%"), 0)
                    else:
                        b = sizes.get(arg, 0)
                    total += b
                out[kind] += total
                break
    out["total"] = sum(v for k, v in out.items() if k != "total")
    return dict(out)


def collective_counts(hlo_text: str) -> dict:
    out = {}
    for kind in COLLECTIVES:
        out[kind] = len(re.findall(rf"\s{kind}(?:-start)?\(", hlo_text))
    return out


def cost_summary(compiled) -> dict:
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):       # older JAX: one dict per program
        ca = ca[0] if ca else {}
    ma = compiled.memory_analysis()
    mem = {}
    if ma is not None:
        for f in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "generated_code_size_in_bytes",
                  "alias_size_in_bytes"):
            mem[f] = getattr(ma, f, 0)
    return {
        "flops": float(ca.get("flops", 0.0)),
        "transcendentals": float(ca.get("transcendentals", 0.0)),
        "bytes_accessed": float(ca.get("bytes accessed", 0.0)),
        "memory": mem,
    }
