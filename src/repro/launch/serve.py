"""Serving driver: thin CLI over the continuous-batching engine.

The sparse path is the paper's deployment story: linear weights are replaced
by their 8:16 (+N:256 outlier) compressed form at load time
(models/sparse_serving.py); on TPU the fused Pallas kernel streams compressed
weights, on CPU the reference decompress path runs (same numerics).

Modes:
  default      continuous-batching engine (serving/) for every zoo family —
               dense/moe/ssm/hybrid/encdec ride their family adapters
               (serving/families.py); vlm keeps the one-shot loop.  The
               enc-dec family feeds each request's encoder features at
               submit time (here: random frontend embeddings).
  --legacy     DEPRECATED parity-check adapter: runs the one-shot lock-step
               loop, then (greedy, engine-supported family) replays the
               same prompts through the engine and verifies the token
               streams are identical.  Still the only path for vlm.
  --trace F    replay a JSON request trace (serving/trace.py) through the
               engine and report tok/s + latency percentiles.

``--kv-layout paged`` swaps the per-request max_len reservation for the
paged block pool (serving/paged/): block-granular allocation, prefix-cache
sharing of identical prompt prefixes, preempt-to-queue under KV pressure.
Token-identical to ``--kv-layout slot`` for the same requests and seeds.

``--token-budget N`` bounds the prefill tokens any engine step may spend:
prompts longer than N advance chunk-by-chunk across steps while everyone
else keeps decoding (chunked prefill — token-identical to the un-chunked
engine).  ``--max-prefill-per-step`` is the deprecated request-count
spelling of the same knob.

``--mesh 1x8`` serves mesh-native (serving/placement.py): compressed (and
dense) weights tensor-parallel over the "model" axis, KV arenas sharded by
head, explicit shardings on every jitted step.  Token-identical to the
single-device engine.  On CPU, force host devices first:
XLA_FLAGS=--xla_force_host_platform_device_count=8.

``--draft {ngram,sparse,self}`` turns on draft-verify speculative
decoding (serving/speculative.py): ``sparse`` is the paper's deployment
twist — the 8:16 + outlier compressed model drafts ``--spec-k`` tokens
per request per step for its dense counterpart, and the dense target
scores all k+1 positions in one fused verify call; ``ngram`` is the
model-free prompt-lookup proposer; ``self`` drafts with the target's own
params (an upper bound on acceptance, used by the parity tests).  Greedy
speculative streams are token-identical to non-speculative ones.

``--replicas N`` serves through N in-process engine replicas behind the
fleet router (serving/fleet/): every request is scored per replica on
prefix-cache hit potential, load, and session affinity
(``--routing prefix``; ``round_robin``/``least_loaded`` are the
baselines), with work-stealing rebalance between steps.  Each replica
gets its own ``--slots``/``--n-blocks`` pool; with ``--mesh`` the spec
is PER REPLICA and replicas take disjoint device slices
(``make_replica_meshes``).  Token streams are identical to a single
engine serving the same requests.

``--trace-out trace.json`` turns on the observability substrate
(serving/observe.py): a Chrome/Perfetto ``trace_event`` JSON of every
request lifecycle, engine step, jitted call and preemption (load the file
in ui.perfetto.dev), plus a Prometheus counter snapshot written next to
it.  Without the flag the engine runs with the no-op NULL_TRACER.

Example (CPU):
  PYTHONPATH=src python -m repro.launch.serve --arch llama-paper-smoke \
      --batch 4 --prompt-len 32 --gen 16 --sparse
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from ..configs import get, get_smoke
from ..models import get_model, grow_caches
from ..core import SparsifyConfig


def build_params(cfg, args):
    """Init the model (optionally deploying compressed sparse weights)."""
    zoo = get_model(cfg)
    key = jax.random.PRNGKey(args.seed)
    params = zoo.init(key)
    if args.sparse:
        from ..models.sparse_serving import sparsify_for_serving
        scfg = SparsifyConfig(weight_pattern=args.weight_pattern,
                              outlier_pattern=args.outlier_pattern,
                              scorer="magnitude", use_smoothquant=False)
        params, report = sparsify_for_serving(params, scfg)
        print(f"sparse deploy: {report['n_layers_sparsified']} matrices, "
              f"bytes {report['dense_bytes']/2**20:.1f}MiB -> "
              f"{report['compressed_bytes']/2**20:.1f}MiB "
              f"({report['ratio']:.3f}x)")
    return zoo, params, key


def run_oneshot(cfg, zoo, params, key, args):
    """Legacy lock-step loop: batched prefill, then decode the whole batch
    one token at a time.  Supports every model family."""
    prompt = jax.random.randint(key, (args.batch, args.prompt_len), 0, cfg.vocab)
    capacity = args.prompt_len + args.gen
    batch = {"tokens": prompt}
    if cfg.family in ("vlm", "encdec"):
        batch["embeds"] = jax.random.normal(key, (args.batch, args.prompt_len,
                                                  cfg.d_model), jnp.float32)
        if cfg.family == "vlm":
            pos = jnp.broadcast_to(jnp.arange(args.prompt_len)[None, None],
                                   (3, args.batch, args.prompt_len))
            batch["positions"] = pos
            del batch["tokens"]

    t0 = time.time()
    logits, caches = zoo.prefill(params, batch)
    # reserve decode headroom in every family's cache layout up front
    caches = grow_caches(caches, capacity)
    prefill_s = time.time() - t0

    tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
    out_tokens = [tok]
    t0 = time.time()
    for _ in range(args.gen - 1):
        logits, caches = zoo.decode(params, caches, {"tokens": tok})
        tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
        out_tokens.append(tok)
    gen = jnp.concatenate(out_tokens, axis=1)
    decode_s = time.time() - t0
    print(f"prefill {args.batch}x{args.prompt_len} in {prefill_s:.2f}s; "
          f"decoded {args.gen} tokens in {decode_s:.2f}s "
          f"({args.batch*(args.gen-1)/max(decode_s,1e-9):.1f} tok/s)")
    return gen


def _engine_kwargs(args) -> dict:
    return dict(n_slots=args.slots, max_queue=args.max_queue,
                token_budget=args.token_budget,
                max_prefill_per_step=args.max_prefill_per_step,
                kv_layout=args.kv_layout, kv_dtype=args.kv_dtype,
                block_size=args.block_size,
                n_blocks=args.n_blocks,
                prefix_caching=not args.no_prefix_cache)


def _make_draft(cfg, params, args):
    """A SpeculativeConfig from --draft/--spec-k, or None.

    ``sparse`` sparsifies a fresh dense init with the run's compression
    settings (the 8:16 model drafting for its dense counterpart); with
    --sparse the target IS that model, so the draft degenerates to
    self-drafting, which is still a valid (if pointless) configuration.
    """
    if args.draft in (None, "none"):
        return None
    from ..serving import SpeculativeConfig
    max_k = max(8, args.spec_k)
    if args.draft == "ngram":
        return SpeculativeConfig(k=args.spec_k, max_k=max_k, method="ngram")
    if args.draft == "self":
        dparams = params
    else:
        from ..models.sparse_serving import sparsify_for_serving
        dense = get_model(cfg).init(jax.random.PRNGKey(args.seed))
        scfg = SparsifyConfig(weight_pattern=args.weight_pattern,
                              outlier_pattern=args.outlier_pattern,
                              scorer="magnitude", use_smoothquant=False)
        dparams, _ = sparsify_for_serving(dense, scfg)
    return SpeculativeConfig(k=args.spec_k, max_k=max_k, method="model",
                             params=dparams, cfg=cfg)


def _print_spec_stats(engine) -> None:
    sp = engine.stats().get("speculative")
    if sp:
        print(f"  speculative[{sp['method']} k={sp['k']}]: "
              f"acceptance {sp['acceptance_rate']:.2f}, "
              f"{sp['accepted_per_step']:.2f} accepted tok/step "
              f"({sp['accepted']}/{sp['drafted']} over "
              f"{sp['n_spec_steps']} steps)")


def _make_tracer(args):
    """A ServingTracer when --trace-out was given, else None (the engine
    then runs with NULL_TRACER: zero observability cost)."""
    if not getattr(args, "trace_out", None):
        return None
    from ..serving import ServingTracer
    return ServingTracer()


def _make_fleet_tracers(args, n: int):
    """Per-replica ServingTracers sharing ONE buffer + registry (each
    replica gets its own pid track in the merged Perfetto file) plus the
    RouterTracer for routing-decision instants, when --trace-out was
    given; (None, None) otherwise."""
    if not getattr(args, "trace_out", None):
        return None, None
    from ..serving import RouterTracer, ServingTracer
    first = ServingTracer(name="r0")
    tracers = [first] + [
        ServingTracer(buffer=first.buffer, registry=first.registry,
                      name=f"r{i}") for i in range(1, n)]
    router = RouterTracer(buffer=first.buffer, registry=first.registry,
                          name="router")
    return tracers, router


def _build_target(cfg, params, args, *, max_len):
    """The serving target: one engine, or ``--replicas N`` of them behind
    the prefix-aware router.  ReplicaSet duck-types the engine surface
    (submit/step/run/has_work/finished/stats), so run/replay drive either.
    Returns (target, tracer-to-write)."""
    from .mesh import make_replica_meshes, make_serving_mesh
    draft = _make_draft(cfg, params, args)
    kw = _engine_kwargs(args)
    if args.replicas == 1:
        mesh = make_serving_mesh(args.mesh)
        if mesh is not None:
            print(f"serving mesh: {dict(mesh.shape)} "
                  f"({mesh.devices.size} devices, {jax.default_backend()})")
        from ..serving import ServingEngine
        tracer = _make_tracer(args)
        return ServingEngine(cfg, params, max_len=max_len, tracer=tracer,
                             draft=draft, mesh=mesh, **kw), tracer
    from ..serving import ReplicaSet
    meshes = make_replica_meshes(args.mesh, args.replicas)
    if meshes[0] is not None:
        print(f"fleet meshes: {args.replicas} x {dict(meshes[0].shape)} "
              f"(disjoint slices, {jax.default_backend()})")
    tracers, router_tracer = _make_fleet_tracers(args, args.replicas)
    fleet = ReplicaSet(cfg, params, n_replicas=args.replicas,
                       routing=args.routing, meshes=meshes, tracers=tracers,
                       router_tracer=router_tracer, max_len=max_len,
                       draft=draft, **kw)
    return fleet, (tracers[0] if tracers else None)


def _print_fleet_stats(target) -> None:
    st = target.stats()
    if "n_replicas" not in st:
        return
    pc = st["prefix_cache"]
    per = [f"r{i}: {p['n_finished']} done, {p['n_steps']} steps"
           for i, p in enumerate(st["replicas"])]
    print(f"  fleet[{st['routing']}]: {st['n_replicas']} replicas, "
          f"{st['n_steals']} steals, {st['n_drains']} drains, "
          f"prefix-hit {pc['hit_rate']:.2f} | " + "; ".join(per))


def _write_observability(tracer, args) -> None:
    """Write the Perfetto trace and the Prometheus counter snapshot next
    to it (<trace-out> and <trace-out>.counters.txt)."""
    if tracer is None:
        return
    tracer.write_trace(args.trace_out)
    counters = args.trace_out + ".counters.txt"
    with open(counters, "w") as f:
        f.write(tracer.counters_text())
    print(f"trace written to {args.trace_out} (load in ui.perfetto.dev); "
          f"counters in {counters}")


def run_engine(cfg, params, key, args, quiet: bool = False):
    """Continuous-batching engine (or --replicas N fleet) on a batch of
    random prompts."""
    from ..serving import SamplingParams
    engine, tracer = _build_target(cfg, params, args,
                                   max_len=args.prompt_len + args.gen)
    prompt = jax.random.randint(key, (args.batch, args.prompt_len), 0, cfg.vocab)
    # enc-dec requests carry their encoder features (same draw as the
    # one-shot loop, so --legacy parity compares like against like)
    embeds = jax.random.normal(key, (args.batch, args.prompt_len,
                                     cfg.d_model), jnp.float32) \
        if cfg.family == "encdec" else None
    sp = SamplingParams(max_new_tokens=args.gen,
                        temperature=args.temperature, top_k=args.top_k)
    t0 = time.time()
    reqs = [engine.submit(prompt[i], sp,
                          embeds=None if embeds is None else embeds[i])
            for i in range(args.batch)]
    engine.run()
    wall = time.time() - t0
    n_tok = sum(len(r.tokens) for r in reqs)
    if not quiet:
        print(f"engine[{args.kv_layout}]: {args.batch} requests, {n_tok} "
              f"tokens in {wall:.2f}s ({n_tok/max(wall,1e-9):.1f} tok/s, "
              f"{engine.stats()['n_steps']} steps, {args.slots} slots)")
        if args.kv_layout == "paged" and args.replicas == 1:
            print(f"  paged: {engine.stats()['pool']}")
        _print_fleet_stats(engine)
        _print_spec_stats(engine)
    _write_observability(tracer, args)
    return jnp.asarray([r.tokens for r in reqs], jnp.int32)


def run_trace(cfg, params, args):
    """Replay a recorded request trace through the engine (or fleet)."""
    from ..runtime.metrics import format_summary, summarize
    from ..serving import load_trace, replay
    engine, tracer = _build_target(cfg, params, args, max_len=args.max_len)
    trace = load_trace(args.trace)
    res = replay(engine, trace, time_scale=args.time_scale)
    summary = summarize([r.metrics for r in res["finished"]], res["wall_s"])
    print(format_summary("trace", summary))
    _print_fleet_stats(engine)
    _print_spec_stats(engine)
    if res["rejected"]:
        print(f"rejected by admission control: {res['rejected']}")
    _write_observability(tracer, args)
    return res


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama-paper-smoke")
    ap.add_argument("--smoke-arch", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--sparse", action="store_true",
                    help="deploy 8:16 + 16:256-outlier compressed weights")
    ap.add_argument("--weight-pattern", default="8:16")
    ap.add_argument("--outlier-pattern", default="16:256")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--legacy", action="store_true",
                    help="one-shot lock-step loop instead of the engine")
    ap.add_argument("--slots", type=int, default=8,
                    help="engine KV-pool slots (concurrent requests); "
                         "per replica with --replicas")
    ap.add_argument("--replicas", type=int, default=1,
                    help="serve with N in-process engine replicas behind "
                         "the fleet router (serving/fleet/); each replica "
                         "gets its own --slots/--n-blocks pool and, with "
                         "--mesh, its own disjoint device slice")
    ap.add_argument("--routing", default="prefix",
                    choices=("prefix", "round_robin", "least_loaded"),
                    help="fleet routing policy: 'prefix' scores cached-"
                         "prompt fraction minus load plus session "
                         "affinity; baselines cycle or pick the emptiest")
    ap.add_argument("--kv-layout", default="slot", choices=("slot", "paged"),
                    help="contiguous per-slot KV vs paged block pool")
    ap.add_argument("--kv-dtype", default="bf16", choices=("bf16", "int8"),
                    help="KV arena storage dtype; int8 stores per-position "
                         "per-KV-head scales and dequantizes inside "
                         "attention (~1.9x more context per HBM byte)")
    ap.add_argument("--mesh", default=None,
                    help="serving mesh 'DATAxMODEL' (e.g. '1x8'; bare '8' = "
                         "model-only TP) — tensor-parallel compressed "
                         "forward + sharded KV arenas; default: no mesh "
                         "(single device)")
    ap.add_argument("--block-size", type=int, default=16,
                    help="tokens per KV block (paged layout)")
    ap.add_argument("--n-blocks", type=int, default=None,
                    help="paged arena size in blocks (default: the same "
                         "HBM as the slot layout would reserve)")
    ap.add_argument("--no-prefix-cache", action="store_true",
                    help="disable prefix-cache block sharing (paged)")
    ap.add_argument("--token-budget", type=int, default=None,
                    help="prefill tokens any engine step may spend; prompts "
                         "longer than this advance chunk-by-chunk across "
                         "steps beside the decode batch (default: 2x the "
                         "KV capacity, i.e. effectively un-chunked)")
    ap.add_argument("--max-prefill-per-step", type=int, default=None,
                    help="DEPRECATED: request-count interleave bound; "
                         "aliased to --token-budget N*capacity")
    ap.add_argument("--draft", default="none",
                    choices=("none", "ngram", "sparse", "self"),
                    help="speculative-decoding proposer: 'sparse' drafts "
                         "with the 8:16+outlier compressed model, 'ngram' "
                         "with prompt-lookup, 'self' with the target's own "
                         "params (parity/upper-bound runs)")
    ap.add_argument("--spec-k", type=int, default=4,
                    help="initial draft tokens per request per step (each "
                         "request's k then adapts to its own observed "
                         "acceptance)")
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--top-k", type=int, default=0)
    ap.add_argument("--trace", default=None,
                    help="JSON request trace to replay through the engine")
    ap.add_argument("--max-len", type=int, default=256,
                    help="per-slot KV capacity (trace mode)")
    ap.add_argument("--max-queue", type=int, default=64)
    ap.add_argument("--time-scale", type=float, default=1.0,
                    help="compress (<1) / stretch (>1) trace arrival gaps")
    ap.add_argument("--trace-out", default=None,
                    help="write a Chrome/Perfetto trace_event JSON of the "
                         "run here (load in ui.perfetto.dev); a Prometheus "
                         "counter snapshot lands next to it")
    args = ap.parse_args(argv)

    from ..serving import SUPPORTED_FAMILIES
    cfg = get_smoke(args.arch) if args.smoke_arch else get(args.arch)
    if args.trace is not None and args.legacy:
        ap.error("--trace replays through the engine; drop --legacy")
    if args.replicas < 1:
        ap.error("--replicas must be >= 1")
    if args.replicas > 1 and args.legacy:
        ap.error("--replicas needs the engine path; drop --legacy")
    if args.trace is not None and cfg.family not in SUPPORTED_FAMILIES:
        ap.error(f"--trace replays through the engine, which serves "
                 f"{SUPPORTED_FAMILIES} families; {args.arch!r} is "
                 f"{cfg.family!r}")
    if args.trace is not None and cfg.family == "encdec":
        ap.error("--trace carries token prompts only; the enc-dec family "
                 "needs per-request encoder features")

    zoo, params, key = build_params(cfg, args)

    if args.trace is not None:
        return run_trace(cfg, params, args)

    if args.legacy or cfg.family not in SUPPORTED_FAMILIES:
        if args.legacy:
            print("--legacy is DEPRECATED: the engine serves every zoo "
                  "family except vlm; running the one-shot loop as a "
                  "parity check")
        else:
            print(f"family {cfg.family!r} is not engine-served; "
                  f"using the one-shot loop")
        gen = run_oneshot(cfg, zoo, params, key, args)
        if (args.legacy and cfg.family in SUPPORTED_FAMILIES
                and args.temperature == 0):
            import numpy as np
            eng = run_engine(cfg, params, key, args, quiet=True)
            if np.array_equal(np.asarray(gen), np.asarray(eng)):
                print("legacy parity: engine token streams identical")
            elif cfg.dtype == jnp.float32:
                raise SystemExit("legacy parity FAILED: engine and one-shot "
                                 "token streams differ")
            else:
                # sub-f32 dtypes: XLA rounds fused low-precision chains at
                # shape-dependent fusion boundaries, so the jitted engine
                # step and the eager one-shot loop can disagree by one ulp
                # — enough to flip greedy argmax on a near-tie.  Bit-exact
                # parity is asserted at f32 (tests/test_family_engines.py).
                n_bad = int((np.asarray(gen) != np.asarray(eng))
                            .any(axis=1).sum())
                print(f"legacy parity: {n_bad}/{args.batch} streams diverge "
                      f"(greedy near-ties under {np.dtype(cfg.dtype).name} "
                      f"fusion rounding; rerun an f32 config for the "
                      f"bit-exact check)")
    else:
        gen = run_engine(cfg, params, key, args)
    print("sample:", gen[0, :12].tolist())
    return gen


if __name__ == "__main__":
    main()
