"""Serving driver: batched prefill + decode loop, dense or SPARSE weights.

The sparse path is the paper's deployment story: linear weights are replaced
by their 8:16 (+N:256 outlier) compressed form at load time
(models/sparse_serving.py); on TPU the fused Pallas kernel streams compressed
weights, on CPU the reference decompress path runs (same numerics).

Example (CPU):
  PYTHONPATH=src python -m repro.launch.serve --arch llama-paper-smoke \
      --batch 4 --prompt-len 32 --gen 16 --sparse
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from ..configs import get, get_smoke
from ..models import get_model
from ..core import SparsifyConfig


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama-paper-smoke")
    ap.add_argument("--smoke-arch", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--sparse", action="store_true",
                    help="deploy 8:16 + 16:256-outlier compressed weights")
    ap.add_argument("--weight-pattern", default="8:16")
    ap.add_argument("--outlier-pattern", default="16:256")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_smoke(args.arch) if args.smoke_arch else get(args.arch)
    zoo = get_model(cfg)
    key = jax.random.PRNGKey(args.seed)
    params = zoo.init(key)

    if args.sparse:
        from ..models.sparse_serving import sparsify_for_serving
        scfg = SparsifyConfig(weight_pattern=args.weight_pattern,
                              outlier_pattern=args.outlier_pattern,
                              scorer="magnitude", use_smoothquant=False)
        params, report = sparsify_for_serving(params, scfg)
        print(f"sparse deploy: {report['n_layers_sparsified']} matrices, "
              f"bytes {report['dense_bytes']/2**20:.1f}MiB -> "
              f"{report['compressed_bytes']/2**20:.1f}MiB "
              f"({report['ratio']:.3f}x)")

    prompt = jax.random.randint(key, (args.batch, args.prompt_len), 0, cfg.vocab)
    pad = args.prompt_len + args.gen
    batch = {"tokens": jnp.pad(prompt, ((0, 0), (0, 0)))}
    if cfg.family in ("vlm", "encdec"):
        batch["embeds"] = jax.random.normal(key, (args.batch, args.prompt_len,
                                                  cfg.d_model), jnp.float32)
        if cfg.family == "vlm":
            pos = jnp.broadcast_to(jnp.arange(args.prompt_len)[None, None],
                                   (3, args.batch, args.prompt_len))
            batch["positions"] = pos
            del batch["tokens"]

    t0 = time.time()
    logits, caches = zoo.prefill(params, batch)
    # pad caches to prompt+gen when the family uses dense KV buffers
    if isinstance(caches, dict) and "k" in caches:
        grow = pad - caches["k"].shape[2]
        widths = [(0, 0), (0, 0), (0, grow), (0, 0), (0, 0)]
        caches = {**caches,
                  "k": jnp.pad(caches["k"], widths),
                  "v": jnp.pad(caches["v"], widths)}
    prefill_s = time.time() - t0

    tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
    out_tokens = [tok]
    t0 = time.time()
    for _ in range(args.gen - 1):
        logits, caches = zoo.decode(params, caches, {"tokens": tok})
        tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
        out_tokens.append(tok)
    gen = jnp.concatenate(out_tokens, axis=1)
    decode_s = time.time() - t0
    print(f"prefill {args.batch}x{args.prompt_len} in {prefill_s:.2f}s; "
          f"decoded {args.gen} tokens in {decode_s:.2f}s "
          f"({args.batch*(args.gen-1)/max(decode_s,1e-9):.1f} tok/s)")
    print("sample:", gen[0, :12].tolist())
    return gen


if __name__ == "__main__":
    main()
