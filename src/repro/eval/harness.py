"""Evaluation harness: small-LM training, calibration-stats collection,
model-level sparsification, and perplexity — the machinery behind the
paper-table benchmarks and the system tests.

Works on the dense/llama family (what the paper evaluates).  Stats collection
runs an instrumented unrolled forward that accumulates per-projection input
statistics (L2 norm + max-abs per channel), exactly what RIA / Wanda /
SmoothQuant consume.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from ..core import (ActStats, SparsifyConfig, sparsify_tree)
from ..models import get_model
from ..models import transformer as tfm
from ..models.layers import linear, rms_norm, activation
from ..optim import AdamWConfig, adamw_init, adamw_step


# --------------------------------------------------------------------------
# small-LM training
# --------------------------------------------------------------------------

def train_small_lm(cfg, data, steps: int = 200, lr: float = 3e-3,
                   seed: int = 0, log_every: int = 0):
    zoo = get_model(cfg)
    params = zoo.init(jax.random.PRNGKey(seed))
    ocfg = AdamWConfig(lr=lr, weight_decay=0.01)
    opt = adamw_init(params, ocfg)

    @jax.jit
    def step(params, opt, batch):
        (loss, _), grads = jax.value_and_grad(
            lambda p: zoo.loss(p, batch), has_aux=True)(params)
        params, opt, _ = adamw_step(params, grads, opt, ocfg)
        return params, opt, loss

    losses = []
    for s in range(steps):
        batch = jax.tree.map(jnp.asarray, data.batch_at(s))
        params, opt, loss = step(params, opt, batch)
        losses.append(float(loss))
        if log_every and s % log_every == 0:
            print(f"  step {s:4d} loss {losses[-1]:.4f}")
    return params, losses


# --------------------------------------------------------------------------
# calibration statistics (instrumented dense-transformer forward)
# --------------------------------------------------------------------------

def _init_stats(cfg):
    L = cfg.n_layers
    d, ff = cfg.d_model, cfg.d_ff
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd

    def mk(in_dim):
        return ActStats(sq_sum=jnp.zeros((L, in_dim)),
                        max_abs=jnp.zeros((L, in_dim)),
                        count=jnp.zeros((L,)))
    stats = {"layers/wq": mk(d), "layers/wk": mk(d), "layers/wv": mk(d),
             "layers/wo": mk(H * hd), "layers/w_up": mk(d),
             "layers/w_down": mk(ff)}
    if cfg.glu:
        stats["layers/w_gate"] = mk(d)
    return stats


def _upd(stats, key, i, x):
    xf = x.reshape(-1, x.shape[-1]).astype(jnp.float32)
    s = stats[key]
    stats[key] = ActStats(
        sq_sum=s.sq_sum.at[i].add(jnp.sum(xf * xf, axis=0)),
        max_abs=s.max_abs.at[i].max(jnp.max(jnp.abs(xf), axis=0)),
        count=s.count.at[i].add(xf.shape[0]))
    return stats


@partial(jax.jit, static_argnames=("cfg",))
def _stats_forward(params, tokens, stats, cfg):
    x = jnp.take(params["embed"], tokens, axis=0)
    B, S = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    for i in range(cfg.n_layers):
        lp = jax.tree.map(lambda p: p[i], params["layers"])
        h = rms_norm(x, lp["attn_norm"], cfg.norm_eps)
        for k in ("wq", "wk", "wv"):
            stats = _upd(stats, f"layers/{k}", i, h)
        q, k_, v = tfm._project_qkv(lp, h, cfg, positions)
        from ..models.layers import sdpa
        attn = sdpa(q, k_, v, causal=True, window=cfg.window)
        attn2 = attn.reshape(*attn.shape[:2], -1)
        stats = _upd(stats, "layers/wo", i, attn2)
        x = x + linear(lp["wo"], attn2)
        h = rms_norm(x, lp["mlp_norm"], cfg.norm_eps)
        stats = _upd(stats, "layers/w_up", i, h)
        if cfg.glu:
            stats = _upd(stats, "layers/w_gate", i, h)
            hidden = activation(cfg.act, linear(lp["w_gate"], h)) \
                * linear(lp["w_up"], h)
        else:
            hidden = activation(cfg.act, linear(lp["w_up"], h))
        stats = _upd(stats, "layers/w_down", i, hidden)
        x = x + linear(lp["w_down"], hidden)
    return stats


def collect_activation_stats(cfg, params, calib_batches) -> dict:
    """Returns {leaf path -> ActStats with leading [L] dim} for sparsify_tree."""
    stats = _init_stats(cfg)
    for batch in calib_batches:
        stats = _stats_forward(params, jnp.asarray(batch["tokens"]), stats, cfg)
    return stats


# --------------------------------------------------------------------------
# model-level sparsification + PPL
# --------------------------------------------------------------------------

def sparsify_model(cfg, params, stats, scfg: SparsifyConfig):
    """Apply the pipeline to every projection; returns dense-effective params."""
    new_params, _records = sparsify_tree(params, stats or {}, scfg)
    return new_params


def eval_ppl(cfg, params, data, n_batches: int = 8, start_step: int = 50_000):
    zoo = get_model(cfg)

    @jax.jit
    def nll(params, batch):
        loss, _ = zoo.loss(params, batch)
        return loss

    total = 0.0
    for i in range(n_batches):
        batch = jax.tree.map(jnp.asarray, data.batch_at(start_step + i))
        total += float(nll(params, batch))
    return float(np.exp(total / n_batches))
