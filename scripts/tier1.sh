#!/usr/bin/env bash
# Canonical tier-1 verification (ROADMAP.md): the full test suite, fail-fast.
# Usage: scripts/tier1.sh [extra pytest args]
set -euo pipefail
cd "$(dirname "$0")/.."
PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" exec python -m pytest -x -q "$@"
