"""Mesh-native serving: placement + tensor-parallel engine parity.

The load-bearing property (ISSUE 3 acceptance): on a 1x8 model-axis mesh
the engine produces token streams IDENTICAL to the single-device engine
for the same requests and seeds — dense and 8:16+outlier compressed
weights, slot and paged KV layouts, including prefix-cache hits and
preemption/resume — while every SparseWeight leaf and both KV arenas
carry a non-replicated NamedSharding.

The multi-device tests need forced host devices and skip otherwise; run

  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
      PYTHONPATH=src python -m pytest tests/test_mesh_serving.py

(CI runs exactly this in its multi-device step.)  The placement-unit
tests at the bottom run on any device count.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import NamedSharding

from repro import configs
from repro.core import SparsifyConfig
from repro.models import get_model
from repro.models.sparse_serving import SparseWeight
from repro.serving import (SamplingParams, ServingEngine, ServingPlacement,
                           Status)

# 8 KV heads so the KV arenas and attention projections divide the 8-wide
# model axis (the GQA-narrower-than-mesh regime replicates by design)
CFG = dataclasses.replace(configs.get_smoke("llama-paper"),
                          name="mesh-serving-test", n_layers=2, d_model=128,
                          n_heads=8, n_kv_heads=8, head_dim=16, d_ff=256,
                          vocab=512, remat=False)
GEN = 5
BS = 8

needs8 = pytest.mark.skipif(
    len(jax.devices()) < 8,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=8")


@pytest.fixture(scope="module")
def mesh():
    return jax.make_mesh((1, 8), ("data", "model"))


@pytest.fixture(scope="module")
def dense_params():
    return get_model(CFG).init(jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def sparse_params(dense_params):
    from repro.models.sparse_serving import sparsify_for_serving
    scfg = SparsifyConfig(weight_pattern="8:16", outlier_pattern="16:256",
                          scorer="magnitude", use_smoothquant=False)
    sp, report = sparsify_for_serving(dense_params, scfg)
    assert report["n_layers_sparsified"] > 0
    return sp


def _prompts(n, length, seed=1):
    key = jax.random.PRNGKey(seed)
    return [t.tolist() for t in
            jax.random.randint(key, (n, length), 0, CFG.vocab)]


def _run(params, prompts, gen, *, samplings=None, mesh=None, **kw):
    engine = ServingEngine(CFG, params, mesh=mesh, **kw)
    samplings = samplings or [SamplingParams(max_new_tokens=gen)] * len(prompts)
    reqs = [engine.submit(p, s) for p, s in zip(prompts, samplings)]
    engine.run()
    assert all(r.status is Status.FINISHED for r in reqs)
    return engine, [r.tokens for r in reqs]


def _solo(params, prompt, gen):
    _, (toks,) = _run(params, [prompt], gen, n_slots=1, max_len=64)
    return toks


# --------------------------------------------------------------------------
# parity: sharded == single-device, all weight/KV combinations
# --------------------------------------------------------------------------

@needs8
@pytest.mark.parametrize("kv_layout", ["slot", "paged"])
@pytest.mark.parametrize("which", ["dense", "sparse"])
def test_mesh_engine_token_identical(which, kv_layout, mesh, dense_params,
                                     sparse_params):
    """Greedy AND seeded-stochastic streams survive sharding bit-for-bit."""
    params = dense_params if which == "dense" else sparse_params
    prompts = _prompts(3, 12)
    samplings = [SamplingParams(max_new_tokens=GEN),
                 SamplingParams(max_new_tokens=GEN),
                 SamplingParams(max_new_tokens=GEN, temperature=1.0,
                                top_k=8, seed=5)]
    kw = dict(n_slots=4, max_len=32, kv_layout=kv_layout, block_size=BS,
              samplings=samplings)
    _, ref = _run(params, prompts, GEN, **kw)
    engine, out = _run(params, prompts, GEN, mesh=mesh, **kw)
    assert out == ref
    assert engine.placement.active
    assert engine.stats()["placement"]["devices"] == 8


@needs8
def test_mesh_prefix_cache_hits_token_identical(mesh, dense_params):
    """Prefix-cache suffix prefill through the sharded gather path."""
    sys_prompt = _prompts(1, 3 * BS, seed=5)[0]
    tails = _prompts(3, 6, seed=6)
    engine = ServingEngine(CFG, dense_params, n_slots=4, max_len=64,
                           kv_layout="paged", block_size=BS, mesh=mesh)
    reqs = []
    for tail in tails:                    # sequential so the cache is warm
        reqs.append(engine.submit(sys_prompt + tail,
                                  SamplingParams(max_new_tokens=GEN)))
        engine.run()
    assert engine.pool.prefix_cache.stats()["hit_tokens"] >= 2 * 3 * BS
    for tail, r in zip(tails, reqs):
        assert r.tokens == _solo(dense_params, sys_prompt + tail, GEN)


@needs8
def test_mesh_preemption_resumes_identically(mesh, dense_params):
    """Preempt-to-queue + re-prefill resume on the sharded arena."""
    prompts = _prompts(4, 16, seed=9)
    kw = dict(n_slots=4, max_len=40, kv_layout="paged", block_size=BS,
              n_blocks=10, prefix_caching=False)
    engine, out = _run(dense_params, prompts, 12, mesh=mesh, **kw)
    assert engine.n_preemptions > 0
    for p, toks in zip(prompts, out):
        assert toks == _solo(dense_params, p, 12)


# --------------------------------------------------------------------------
# placement assertions: what actually lives where
# --------------------------------------------------------------------------

@needs8
def test_sparse_leaves_carry_nonreplicated_shardings(mesh, sparse_params):
    engine = ServingEngine(CFG, sparse_params, n_slots=2, max_len=32,
                           mesh=mesh)
    n_sw = 0
    for leaf in jax.tree.leaves(
            engine.params,
            is_leaf=lambda x: isinstance(x, SparseWeight)):
        if not isinstance(leaf, SparseWeight):
            continue
        n_sw += 1
        for arr in jax.tree.leaves(leaf):       # nm/o values+meta (+scale)
            assert isinstance(arr.sharding, NamedSharding)
            assert not arr.sharding.is_fully_replicated, arr.shape
            assert "model" in jax.tree.leaves(tuple(arr.sharding.spec))
    assert n_sw > 0


@needs8
@pytest.mark.parametrize("kv_layout", ["slot", "paged"])
def test_kv_arenas_sharded_tables_host_side(kv_layout, mesh, dense_params):
    engine = ServingEngine(CFG, dense_params, n_slots=2, max_len=32,
                           kv_layout=kv_layout, block_size=BS, mesh=mesh)
    for arena in (engine.pool.k, engine.pool.v):
        assert isinstance(arena.sharding, NamedSharding)
        assert not arena.sharding.is_fully_replicated
        assert arena.sharding.spec[3] == "model"      # KV-head dim
    if kv_layout == "paged":
        # scheduling state stays host-side numpy, layout-agnostic
        assert isinstance(engine.pool._bt_np, np.ndarray)
        assert isinstance(engine.pool._pos_np, np.ndarray)
        assert isinstance(engine.pool.blocks.ref, np.ndarray)
        assert engine.pool.prefix_cache is not None


@needs8
def test_param_shardings_sparse_alignment_on_mesh(dense_params, sparse_params):
    """In-dim (fsdp) sharding of compressed leaves only on block-aligned
    boundaries — checked at the NamedSharding level on a 2x4 mesh."""
    from repro.parallel.sharding import sparse_weight_shardings
    mesh24 = jax.make_mesh((2, 4), ("data", "model"))
    sw = next(l for l in jax.tree.leaves(
        sparse_params, is_leaf=lambda x: isinstance(x, SparseWeight))
        if isinstance(l, SparseWeight))
    sh = sparse_weight_shardings(mesh24, sw)
    vals, meta = sh.nm_values, sh.nm_meta
    assert isinstance(vals, NamedSharding) and not vals.is_fully_replicated
    # values and metadata co-shard
    assert tuple(vals.spec) == tuple(meta.spec)
    # serving policy: out-dim only, contraction dims replicated
    ssh = sparse_weight_shardings(mesh24, sw, serving=True)
    assert tuple(ssh.nm_values.spec)[-1] is None


# --------------------------------------------------------------------------
# placement units (any device count — covered by plain tier-1 too)
# --------------------------------------------------------------------------

def test_inactive_placement_is_identity():
    pl = ServingPlacement()
    assert not pl.active
    assert pl.replicated is None and pl.kv is None
    x = jnp.ones((3,))
    assert pl.place_kv(x) is x and pl.place_replicated(x) is x
    tree = {"a": x}
    assert pl.place_params(tree) is tree
    assert pl.param_shardings(tree) is None
    assert pl.describe() == {"devices": 1, "mesh": None}


def test_placement_validates_mesh_and_cfg():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    with pytest.raises(ValueError, match="cfg"):
        ServingPlacement(mesh)                    # mesh without cfg
    bad = jax.make_mesh((1,), ("replica",))
    with pytest.raises(ValueError, match="model"):
        ServingPlacement(bad, CFG)


@needs8
def test_placement_rejects_data_parallel_mesh(dense_params):
    """Only model-axis TP is placed today; a data>1 mesh would run fully
    redundant replicas and skew per-device throughput accounting."""
    mesh24 = jax.make_mesh((2, 4), ("data", "model"))
    with pytest.raises(ValueError, match="size 1"):
        ServingEngine(CFG, dense_params, n_slots=2, max_len=32, mesh=mesh24)


def test_engine_without_mesh_unchanged(dense_params):
    """mesh=None is the exact pre-placement engine (default path)."""
    engine, out = _run(dense_params, _prompts(2, 10), 3,
                       n_slots=2, max_len=32)
    assert not engine.placement.active
    assert engine.stats()["placement"] == {"devices": 1, "mesh": None}
    assert all(len(t) == 3 for t in out)


def test_parse_mesh_spec():
    from repro.launch.mesh import make_serving_mesh, parse_mesh_spec
    assert parse_mesh_spec(None) is None and parse_mesh_spec("") is None
    assert parse_mesh_spec("1x8") == (1, 8)
    assert parse_mesh_spec("8") == (1, 8)
    assert parse_mesh_spec("2x4") == (2, 4)
    with pytest.raises(ValueError):
        parse_mesh_spec("2x3x4")
    with pytest.raises(ValueError):
        parse_mesh_spec("banana")
    with pytest.raises(ValueError):
        parse_mesh_spec("0x8")
    with pytest.raises(ValueError):
        parse_mesh_spec("-1x8")
    assert make_serving_mesh(None) is None
    with pytest.raises(ValueError, match="devices"):
        make_serving_mesh(f"{4096}")              # more than any host has
