"""Unit + property tests for N:M patterns and masks."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:                    # deterministic seeded sweep fallback
    from hypothesis_fallback import given, settings, st

from repro.core import (Pattern, nm_mask, topn_block_mask, validate_nm_mask,
                        block_topn_indices, mask_sparsity, WEIGHT_PATTERNS,
                        OUTLIER_PATTERNS)


@pytest.mark.parametrize("n,m", list(WEIGHT_PATTERNS) + list(OUTLIER_PATTERNS))
def test_mask_invariant(n, m):
    w = jax.random.normal(jax.random.PRNGKey(0), (8, 4 * m))
    mask = nm_mask(jnp.abs(w), (n, m))
    assert bool(validate_nm_mask(mask, (n, m)))
    assert float(mask_sparsity(mask)) == pytest.approx(1 - n / m)


def test_paper_table1_metadata():
    """Reproduces paper Table 1 exactly: configurations and bits/element."""
    expected = {(2, 4): (6, 0.75), (4, 8): (70, 0.8125),
                (8, 16): (12870, 0.875), (16, 32): (601080390, 1.0)}
    for (n, m), (cfgs, bits) in expected.items():
        p = Pattern(n, m)
        assert p.configurations == cfgs
        assert p.paper_bits_per_element() == pytest.approx(bits)


def test_mask_keeps_topn():
    scores = jnp.array([[5.0, 1.0, 4.0, 2.0, 9.0, 8.0, 7.0, 6.0]])
    mask = topn_block_mask(scores, 2, 4)
    np.testing.assert_array_equal(
        np.asarray(mask), [[True, False, True, False, True, True, False, False]])


def test_block_topn_indices_sorted_and_valid():
    scores = jax.random.normal(jax.random.PRNGKey(1), (4, 64))
    idx = block_topn_indices(scores, 8, 16)
    assert idx.shape == (4, 4, 8)
    assert (np.diff(np.asarray(idx), axis=-1) > 0).all()   # strictly ascending
    assert (np.asarray(idx) >= 0).all() and (np.asarray(idx) < 16).all()


@settings(max_examples=25, deadline=None)
@given(st.integers(1, 4).map(lambda k: 2 ** k),
       st.integers(0, 10_000), st.integers(1, 6))
def test_property_exact_n_per_block(logm, seed, rows):
    m = logm * 2
    n = m // 2
    w = jax.random.normal(jax.random.PRNGKey(seed), (rows, 4 * m))
    mask = nm_mask(jnp.abs(w), (n, m))
    blocks = np.asarray(mask).reshape(rows, -1, m)
    assert (blocks.sum(-1) == n).all()


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 10_000))
def test_property_mask_selects_larger_scores(seed):
    """Every kept element within a block scores >= every dropped element."""
    s = np.asarray(jax.random.normal(jax.random.PRNGKey(seed), (3, 64)))
    mask = np.asarray(nm_mask(jnp.asarray(s), (8, 16)))
    for r in range(3):
        for b in range(4):
            blk_s = s[r, b * 16:(b + 1) * 16]
            blk_m = mask[r, b * 16:(b + 1) * 16]
            assert blk_s[blk_m].min() >= blk_s[~blk_m].max() - 1e-7
