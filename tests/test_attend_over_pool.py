"""The unified attend-over-pool primitive (ISSUE 5 acceptance).

Load-bearing properties:
  1. ``transformer.unified_step`` is ONE attention path for every serving
     shape: a long prompt prefilled one-shot (S = P, cursor = 0), in
     chunks (S = chunk), or extended token-by-token through the decode
     shape (S = 1) produces the same logits and the same token streams —
     slot and paged views, dense and 8:16+outlier compressed weights, and
     on a 1x8 mesh.
  2. Chunked prefill attends IN PLACE: per-step prefix HBM traffic is
     independent of the written-prefix length (the compiled step's cost
     does not change with the cursor — the O(P^2/budget) re-gather of the
     old ``gather_prefix`` path is structurally impossible), asserted
     through ``launch/hlo_analysis.cost_summary``.
  3. The three legacy attention entry points and the prefix gathers are
     gone.
  4. ``token_budget`` is validated at engine construction (satellite):
     budgets that could never schedule a chunk raise a clear ValueError.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.core import SparsifyConfig
from repro.launch.hlo_analysis import cost_summary
from repro.models import get_model
from repro.models import transformer as tfm
from repro.serving import (PagedPoolView, SamplingParams, ServingEngine,
                           SlotPoolView, Status, validate_token_budget)

# float32 so the logits comparisons below resolve real divergence, not
# bf16 rounding between differently-shaped (but equivalent) reductions
CFG = dataclasses.replace(configs.get_smoke("llama-paper"),
                          name="attend-pool-test", n_layers=2, d_model=128,
                          n_heads=4, n_kv_heads=2, head_dim=32, d_ff=256,
                          vocab=512, remat=False, dtype=jnp.float32)
BS = 8                                     # paged block size
P = 48                                     # long-prompt length
T = 64                                     # arena tokens per row


@pytest.fixture(scope="module")
def dense_params():
    return get_model(CFG).init(jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def sparse_params(dense_params):
    from repro.models.sparse_serving import sparsify_for_serving
    scfg = SparsifyConfig(weight_pattern="8:16", outlier_pattern="16:256",
                          scorer="magnitude", use_smoothquant=False)
    sp, report = sparsify_for_serving(dense_params, scfg)
    assert report["n_layers_sparsified"] > 0
    return sp


def _prompts(n, length, seed=1):
    key = jax.random.PRNGKey(seed)
    return [t.tolist() for t in
            jax.random.randint(key, (n, length), 0, CFG.vocab)]


# --------------------------------------------------------------------------
# primitive-level parity walk: chunked == one-shot == decode-extended
# --------------------------------------------------------------------------

def _slot_walk(params, toks, chunks):
    """Feed ``toks`` [1, P] through unified_step in the given chunk sizes
    against one slot-arena row; returns per-position logits [P, V]."""
    L, KV, hd = CFG.n_layers, CFG.n_kv_heads, CFG.hd
    k = jnp.zeros((L, 1, T, KV, hd), CFG.dtype)
    v = jnp.zeros((L, 1, T, KV, hd), CFG.dtype)
    outs, cursor = [], 0
    for ln in chunks:
        view = SlotPoolView(k=k, v=v, rows=jnp.asarray([0], jnp.int32),
                            cursor=jnp.asarray([cursor], jnp.int32),
                            n_new=jnp.asarray([ln], jnp.int32))
        logits, (k, v) = tfm.unified_step(
            params, view, {"tokens": toks[:, cursor:cursor + ln]}, CFG)
        outs.append(logits[0])
        cursor += ln
    return jnp.concatenate(outs, axis=0)


def _paged_walk(params, toks, chunks):
    """Same walk over a paged view: identity block table (block 0 = trash)."""
    L, KV, hd = CFG.n_layers, CFG.n_kv_heads, CFG.hd
    nb = T // BS
    k = jnp.zeros((L, nb + 1, BS, KV, hd), CFG.dtype)
    v = jnp.zeros((L, nb + 1, BS, KV, hd), CFG.dtype)
    bt = jnp.asarray([[1 + i for i in range(nb)]], jnp.int32)
    outs, cursor = [], 0
    for ln in chunks:
        view = PagedPoolView(k=k, v=v, block_tables=bt,
                             cursor=jnp.asarray([cursor], jnp.int32),
                             n_new=jnp.asarray([ln], jnp.int32), trash=0)
        logits, (k, v) = tfm.unified_step(
            params, view, {"tokens": toks[:, cursor:cursor + ln]}, CFG)
        outs.append(logits[0])
        cursor += ln
    return jnp.concatenate(outs, axis=0)


@pytest.mark.parametrize("layout", ["slot", "paged"])
@pytest.mark.parametrize("which", ["dense", "sparse"])
def test_long_prompt_parity_walk(which, layout, dense_params, sparse_params):
    """One primitive, three shapes: S=P one-shot, S=chunk, S=1 decode —
    argmax-identical logits at every prompt position, and numerically the
    legacy full-sequence forward."""
    params = dense_params if which == "dense" else sparse_params
    toks = jnp.asarray(_prompts(1, P), jnp.int32)
    walk = _slot_walk if layout == "slot" else _paged_walk
    oneshot = walk(params, toks, [P])
    chunked = walk(params, toks, [8] * (P // 8))
    stepped = walk(params, toks, [1] * P)
    np.testing.assert_allclose(np.asarray(chunked), np.asarray(oneshot),
                               atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(stepped), np.asarray(oneshot),
                               atol=1e-4, rtol=1e-4)
    assert (jnp.argmax(chunked, -1) == jnp.argmax(oneshot, -1)).all()
    assert (jnp.argmax(stepped, -1) == jnp.argmax(oneshot, -1)).all()
    # ... and the pre-pool full-sequence forward agrees
    ref, _ = tfm.forward(params, {"tokens": toks}, CFG)
    np.testing.assert_allclose(np.asarray(oneshot), np.asarray(ref[0]),
                               atol=1e-4, rtol=1e-4)


def test_slot_and_paged_views_agree(dense_params):
    toks = jnp.asarray(_prompts(1, P, seed=3), jnp.int32)
    a = _slot_walk(dense_params, toks, [16] * (P // 16))
    b = _paged_walk(dense_params, toks, [16] * (P // 16))
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               atol=1e-4, rtol=1e-4)


# --------------------------------------------------------------------------
# engine-level: long-prompt streams, chunked == one-shot, all combinations
# --------------------------------------------------------------------------

@pytest.mark.parametrize("kv_layout", ["slot", "paged"])
@pytest.mark.parametrize("which", ["dense", "sparse"])
def test_engine_long_prompt_chunked_identical(which, kv_layout, dense_params,
                                              sparse_params):
    params = dense_params if which == "dense" else sparse_params
    prompts = _prompts(3, P, seed=5)

    def run(budget):
        engine = ServingEngine(CFG, params, n_slots=4, max_len=T,
                               kv_layout=kv_layout, block_size=BS,
                               token_budget=budget)
        reqs = [engine.submit(p, SamplingParams(max_new_tokens=6))
                for p in prompts]
        engine.run()
        assert all(r.status is Status.FINISHED for r in reqs)
        return [r.tokens for r in reqs], reqs

    ref, _ = run(4 * T)                       # one-shot
    out, reqs = run(16)                       # 3 chunks per prompt minimum
    assert out == ref
    assert all(r.metrics.prefill_chunks >= 3 for r in reqs)


@pytest.mark.parametrize("kv_layout", ["slot", "paged"])
def test_sliding_window_chunked_identical(kv_layout):
    """MoE + sliding-window + GQA (mixtral smoke): the windowed in-place
    mask is chunk-size invariant on both layouts."""
    cfg = configs.get_smoke("mixtral-8x7b")
    params = get_model(cfg).init(jax.random.PRNGKey(0))
    prompts = [t.tolist() for t in
               jax.random.randint(jax.random.PRNGKey(1), (2, 24), 0,
                                  cfg.vocab)]

    def run(budget):
        engine = ServingEngine(cfg, params, n_slots=2, max_len=48,
                               kv_layout=kv_layout, block_size=BS,
                               token_budget=budget)
        reqs = [engine.submit(p, SamplingParams(max_new_tokens=6))
                for p in prompts]
        engine.run()
        assert all(r.status is Status.FINISHED for r in reqs)
        return [r.tokens for r in reqs]

    assert run(8) == run(2 * 48)


# --------------------------------------------------------------------------
# HBM regression: per-step prefix traffic independent of the cursor
# --------------------------------------------------------------------------

@pytest.mark.parametrize("kv_layout", ["slot", "paged"])
def test_chunk_step_cost_independent_of_cursor(kv_layout, dense_params):
    """The compiled chunk step reads the arena through the pool view, so
    its cost is a function of (batch, bucket) ONLY — lowering the same
    shapes at cursor 0 and at a deep cursor yields identical
    bytes-accessed (the old gather path shipped a [L, B, cursor, KV, hd]
    prefix operand whose bytes grew linearly with the cursor)."""
    engine = ServingEngine(CFG, dense_params, n_slots=4, max_len=128,
                           kv_layout=kv_layout, block_size=BS,
                           token_budget=16)
    B, S = 4, 16
    tokens = jnp.zeros((B, S), jnp.int32)
    n_new = jnp.full((B,), S, jnp.int32)
    if kv_layout == "paged":
        lanes = jnp.asarray(engine.pool.lane_tables([0, 1, 2, 3], B))
    else:
        lanes = jnp.asarray(engine.pool.lane_rows([0, 1, 2, 3], B))

    def cost(cursor_val):
        cur = jnp.full((B,), cursor_val, jnp.int32)
        lowered = engine._step_fn.lower(
            engine.params, engine.pool.k, engine.pool.v, lanes, cur,
            n_new, tokens)
        c = cost_summary(lowered.compile())
        # no operand of the compiled step may scale with the cursor: the
        # only prefix-sized buffers are the arenas themselves
        for aval in jax.tree.leaves(lowered.in_avals):
            assert cursor_val not in aval.shape or cursor_val in (0, S)
        return c

    c0, c1 = cost(0), cost(96)
    assert c0["bytes_accessed"] == c1["bytes_accessed"]
    assert c0["flops"] == c1["flops"]


def test_legacy_attention_entry_points_gone():
    """ISSUE 5 acceptance: gather_prefix and the three divergent entry
    points no longer exist — attend_over_pool is the only path."""
    from repro.serving import PagedKVPool, SlotKVPool
    for name in ("forward_with_prefix", "decode_step", "decode_step_paged"):
        assert not hasattr(tfm, name), name
    for pool_cls in (SlotKVPool, PagedKVPool):
        assert not hasattr(pool_cls, "gather_prefix")
        assert not hasattr(pool_cls, "write_prefill")
        assert not hasattr(pool_cls, "write_prefill_group")
    assert callable(tfm.attend_over_pool)
    assert callable(tfm.unified_step)


# --------------------------------------------------------------------------
# satellite: token_budget validated at engine construction
# --------------------------------------------------------------------------

def test_token_budget_validated_at_construction(dense_params):
    assert validate_token_budget(8, max_len=64) == 8
    assert validate_token_budget(4, max_len=4) == 4     # tiny-pool engines
    with pytest.raises(ValueError, match="chunk quantum"):
        validate_token_budget(4, max_len=64)
    with pytest.raises(ValueError, match="max_len"):
        validate_token_budget(8, max_len=0)
    # the engine surfaces the same clear error at construction, instead
    # of a stalled plan_chunks loop deep inside step()
    with pytest.raises(ValueError, match="chunk quantum"):
        ServingEngine(CFG, dense_params, n_slots=2, max_len=64,
                      token_budget=4)
    # deprecated alias resolves, then validates, through the same path
    engine = ServingEngine(CFG, dense_params, n_slots=2, max_len=16,
                           token_budget=16)
    assert engine.token_budget == 16


# --------------------------------------------------------------------------
# mesh: the unified path is token-identical under tensor parallelism
# --------------------------------------------------------------------------

needs8 = pytest.mark.skipif(
    len(jax.devices()) < 8,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=8")

MESH_CFG = dataclasses.replace(CFG, name="attend-pool-mesh-test", n_heads=8,
                               n_kv_heads=8, head_dim=16)


@needs8
@pytest.mark.parametrize("kv_layout", ["slot", "paged"])
def test_mesh_long_prompt_unified_identical(kv_layout):
    params = get_model(MESH_CFG).init(jax.random.PRNGKey(0))
    prompts = [t.tolist() for t in
               jax.random.randint(jax.random.PRNGKey(2), (3, P), 0,
                                  MESH_CFG.vocab)]

    def run(mesh, budget):
        engine = ServingEngine(MESH_CFG, params, n_slots=4, max_len=T,
                               kv_layout=kv_layout, block_size=BS,
                               token_budget=budget, mesh=mesh)
        reqs = [engine.submit(p, SamplingParams(max_new_tokens=5))
                for p in prompts]
        engine.run()
        assert all(r.status is Status.FINISHED for r in reqs)
        return [r.tokens for r in reqs]

    mesh = jax.make_mesh((1, 8), ("data", "model"))
    ref = run(None, 4 * T)                  # single-device, one-shot
    assert run(mesh, 16) == ref             # sharded, chunked
