"""Every model family rides the unified serving engine: token-identity
parity against each family's ``decode_lockstep`` reference, preemption
swap/resume exactness, scheduler behavior for O(1)-state families, the
``RecurrentStatePool`` lifecycle invariants, and the recurrent-state
shardings on a forced 8-device mesh.

All parity configs pin ``dtype=float32``: XLA rounds fused sub-f32
elementwise chains at shape-dependent fusion boundaries, so a bf16 engine
step (one program shape) and the bf16 one-shot loop (another) can disagree
by one ulp — enough to flip greedy argmax on a near-tie without either
side being wrong.  At f32 every elementwise op rounds identically whether
fused or not, so token streams must match bit-for-bit and any mismatch is
a real scheduling/state bug.  (serve.py's ``--legacy`` cross-check
documents the same caveat for sub-f32 runs.)

The mesh tests need forced host devices and skip otherwise; CI's
multi-device job runs

  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
      PYTHONPATH=src python -m pytest tests/test_family_engines.py
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import NamedSharding

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                                     # pragma: no cover
    from hypothesis_fallback import given, settings, st

from repro import configs
from repro.core import SparsifyConfig
from repro.models import get_model, grow_caches
from repro.serving import (DoubleFree, RecurrentStatePool, SamplingParams,
                           ServingEngine, Status)

GEN = 6
P_LEN = 16
ARCHS = {"ssm": "xlstm-350m", "hybrid": "zamba2-2.7b",
         "encdec": "whisper-medium"}


def _cfg(family):
    cfg = configs.get_smoke(ARCHS[family])
    return dataclasses.replace(cfg, name=f"family-test-{family}",
                               dtype=jnp.float32, remat=False)


CFGS = {fam: _cfg(fam) for fam in ARCHS}


@pytest.fixture(scope="module", params=list(ARCHS))
def family(request):
    return request.param


@pytest.fixture(scope="module")
def dense_params():
    return {fam: get_model(cfg).init(jax.random.PRNGKey(0))
            for fam, cfg in CFGS.items()}


@pytest.fixture(scope="module")
def sparse_params(dense_params):
    from repro.models.sparse_serving import sparsify_for_serving
    scfg = SparsifyConfig(weight_pattern="8:16", outlier_pattern="16:256",
                          scorer="magnitude", use_smoothquant=False)
    out = {}
    for fam, params in dense_params.items():
        out[fam], report = sparsify_for_serving(params, scfg)
        assert report["n_layers_sparsified"] > 0, fam
    return out


def _prompts(cfg, n, length, seed=1):
    key = jax.random.PRNGKey(seed)
    return np.asarray(jax.random.randint(key, (n, length), 0, cfg.vocab))


def _embeds(cfg, n, length, seed=2):
    return np.asarray(jax.random.normal(jax.random.PRNGKey(seed),
                                        (n, length, cfg.d_model),
                                        jnp.float32))


def _lockstep(cfg, params, prompts, gen, embeds=None):
    """The legacy one-shot loop: batched prefill + ``decode_lockstep``
    greedy decode — each family's reference float operation order."""
    zoo = get_model(cfg)
    toks = jnp.asarray(prompts, jnp.int32)
    batch = {"tokens": toks}
    if embeds is not None:
        batch["embeds"] = jnp.asarray(embeds)
    logits, caches = zoo.prefill(params, batch)
    caches = grow_caches(caches, toks.shape[1] + gen)
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    outs = [tok]
    for _ in range(gen - 1):
        logits, caches = zoo.decode(params, caches, {"tokens": tok})
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        outs.append(tok)
    return np.asarray(jnp.concatenate(outs, 1))


def _submit_all(engine, cfg, prompts, gen, embeds=None):
    return [engine.submit(p, SamplingParams(max_new_tokens=gen),
                          embeds=None if embeds is None else embeds[i])
            for i, p in enumerate(prompts)]


def _ref(cfg, params, prompts, gen):
    embeds = _embeds(cfg, len(prompts), 7) if cfg.family == "encdec" else None
    return _lockstep(cfg, params, prompts, gen, embeds=embeds), embeds


# ---------------------------------------------------------------- parity ---

@pytest.mark.parametrize("which", ["dense", "sparse"])
def test_engine_matches_lockstep(family, which, dense_params, sparse_params):
    """Chunked, continuously-batched engine == one-shot lock-step loop,
    token for token, for dense and 8:16-compressed weights alike."""
    cfg = CFGS[family]
    params = (dense_params if which == "dense" else sparse_params)[family]
    prompts = _prompts(cfg, 3, P_LEN)
    ref, embeds = _ref(cfg, params, prompts, GEN)
    engine = ServingEngine(cfg, params, n_slots=4, max_len=P_LEN + GEN,
                           token_budget=8, max_ctx=7)
    reqs = _submit_all(engine, cfg, prompts, GEN, embeds)
    engine.run()
    for i, r in enumerate(reqs):
        assert r.status is Status.FINISHED
        assert r.tokens == ref[i].tolist(), f"{family}/{which} req {i}"
    assert engine.stats()["family"] == family


def test_hybrid_paged_matches_lockstep(dense_params):
    """The hybrid family mixes paged shared-attention KV with slot-indexed
    SSM state inside one step; block-granular allocation must not change a
    single token."""
    cfg = CFGS["hybrid"]
    params = dense_params["hybrid"]
    prompts = _prompts(cfg, 3, P_LEN)
    ref, _ = _ref(cfg, params, prompts, GEN)
    engine = ServingEngine(cfg, params, n_slots=4, max_len=P_LEN + GEN,
                           token_budget=8, kv_layout="paged", block_size=4)
    reqs = _submit_all(engine, cfg, prompts, GEN)
    engine.run()
    for i, r in enumerate(reqs):
        assert r.tokens == ref[i].tolist(), f"paged hybrid req {i}"
    # prefix caching is structurally off: cached KV blocks cannot
    # reconstruct the SSM state that absorbed those tokens
    assert engine.pool.prefix_cache is None


def test_preempt_resume_exact(family, dense_params):
    """Preempting a stateful request mid-generation and resuming it must
    reproduce the uninterrupted stream exactly: the adapters swap the
    recurrent state / decoder KV / encoder context out and back instead of
    recomputing (recompute would change float summation order)."""
    cfg = CFGS[family]
    params = dense_params[family]
    prompts = _prompts(cfg, 3, P_LEN, seed=4)
    ref, embeds = _ref(cfg, params, prompts, GEN)
    engine = ServingEngine(cfg, params, n_slots=4, max_len=P_LEN + GEN,
                           token_budget=8, max_ctx=7)
    reqs = _submit_all(engine, cfg, prompts, GEN, embeds)
    # advance until at least one request is decoding, then force a
    # preemption (slot layouts never hit memory pressure on their own)
    for _ in range(32):
        engine.step()
        if any(r.tokens for r in engine.running.values()):
            break
    engine._preempt_one({"preempted": 0})
    engine.run()
    assert engine.n_preemptions == 1
    assert any(r.n_preempted == 1 for r in reqs)
    for i, r in enumerate(reqs):
        assert r.status is Status.FINISHED
        assert r.tokens == ref[i].tolist(), f"{family} resumed req {i}"


def test_ssm_chunk_boundaries_are_invisible(dense_params):
    """An O(1)-state family has no block math: any token budget is legal
    (the quantum floor is waived) and odd chunk splits cannot change the
    stream."""
    cfg = CFGS["ssm"]
    params = dense_params["ssm"]
    prompts = _prompts(cfg, 2, P_LEN, seed=5)
    ref, _ = _ref(cfg, params, prompts, GEN)
    engine = ServingEngine(cfg, params, n_slots=4, max_len=P_LEN + GEN,
                           token_budget=5)        # < CHUNK_QUANTUM: ssm-only
    assert engine.token_budget == 5
    assert engine.chunk_quantum == 5              # widened to the budget
    reqs = _submit_all(engine, cfg, prompts, GEN)
    engine.run()
    for i, r in enumerate(reqs):
        assert r.tokens == ref[i].tolist()
    # the same sub-quantum budget is a construction-time error for a
    # paged-KV family, whose chunks must cover the block quantum
    dense_cfg = dataclasses.replace(configs.get_smoke("llama-paper"),
                                    n_layers=1, remat=False)
    with pytest.raises(ValueError, match="quantum|budget"):
        ServingEngine(dense_cfg, None, token_budget=5)


# ------------------------------------------------- family admission rules ---

def test_ssm_coerces_layout_and_rejects_embeds(dense_params):
    cfg = CFGS["ssm"]
    engine = ServingEngine(cfg, dense_params["ssm"], n_slots=2, max_len=32,
                           kv_layout="paged")     # nothing to page
    assert engine.kv_layout == "slot"
    with pytest.raises(ValueError, match="embeds"):
        engine.submit([1, 2, 3], SamplingParams(max_new_tokens=2),
                      embeds=np.zeros((4, cfg.d_model), np.float32))


def test_encdec_requires_embeds_and_bounds_ctx(dense_params):
    cfg = CFGS["encdec"]
    engine = ServingEngine(cfg, dense_params["encdec"], n_slots=2,
                           max_len=32, max_ctx=8)
    with pytest.raises(ValueError, match="embeds"):
        engine.submit([1, 2, 3], SamplingParams(max_new_tokens=2))
    with pytest.raises(ValueError, match="max_ctx"):
        engine.submit([1, 2, 3], SamplingParams(max_new_tokens=2),
                      embeds=np.zeros((9, cfg.d_model), np.float32))
    with pytest.raises(ValueError, match="paged"):
        ServingEngine(cfg, dense_params["encdec"], n_slots=2, max_len=32,
                      kv_layout="paged")


def test_hybrid_requires_shared_attention(dense_params):
    cfg = dataclasses.replace(CFGS["hybrid"], attn_every=0)
    with pytest.raises(ValueError, match="attn_every"):
        ServingEngine(cfg, dense_params["hybrid"], n_slots=2, max_len=32)


# ------------------------------------- RecurrentStatePool lifecycle walk ---

def _tiny_pool(n_slots=4):
    init = lambda _cfg, n: [(jnp.zeros((n, 2, 3)),
                             jnp.full((n, 3), -1.0))]
    return RecurrentStatePool(None, n_slots, max_len=32, init_states=init)


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 2 ** 32 - 1))
def test_recurrent_pool_invariant_walk(seed):
    """Random alloc/release/save/restore/adopt/advance walk: the free list
    and the arenas never desynchronize, slot ids are stable, double frees
    raise, and a save/restore round-trip is bitwise."""
    import random
    rng = random.Random(seed)
    pool = _tiny_pool()
    held, saved = [], {}
    for step in range(30):
        op = rng.choice(["alloc", "release", "save", "restore", "adopt",
                         "advance"])
        if op == "alloc":
            slot = pool.alloc()
            if len(held) == pool.n_slots:
                assert slot is None
            else:
                assert slot is not None and slot not in held
                held.append(slot)
            assert pool.n_free == pool.n_slots - len(held)
        elif op == "release" and held:
            slot = held.pop(rng.randrange(len(held)))
            pool.release(slot)
            saved.pop(slot, None)
            with pytest.raises(DoubleFree):
                pool.release(slot)
        elif op == "save" and held:
            slot = rng.choice(held)
            saved[slot] = (pool.save_slot(slot),
                           [np.asarray(l[slot])
                            for l in jax.tree.leaves(pool.states)])
        elif op == "restore" and saved:
            slot = rng.choice(list(saved))
            blob, want = saved[slot]
            pool.restore_slot(slot, blob)
            got = [np.asarray(l[slot]) for l in jax.tree.leaves(pool.states)]
            for g, w in zip(got, want):
                assert np.array_equal(g, w)       # swap round-trip: bitwise
        elif op == "adopt":
            # a jitted step hands back mutated arenas; ownership moves but
            # the tree structure and shapes must be preserved
            before = jax.tree.structure(pool.states)
            pool.adopt(jax.tree.map(lambda a: a + 1.0, pool.states))
            assert jax.tree.structure(pool.states) == before
        elif op == "advance" and held:
            pool.advance_prefill(held, [rng.randrange(32) for _ in held])
            mask = np.zeros((pool.n_slots,), bool)
            mask[held] = True
            pos = np.asarray(pool.pos).copy()
            pool.advance_decode(mask)
            assert np.array_equal(np.asarray(pool.pos), pos + mask)
    assert sorted(held + pool._free) == list(range(pool.n_slots))


# -------------------------------------------------- mesh-native shardings ---

needs8 = pytest.mark.skipif(
    len(jax.devices()) < 8,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=8")


@pytest.fixture(scope="module")
def mesh():
    if len(jax.devices()) < 8:
        return None
    return jax.make_mesh((1, 8), ("data", "model"))


@needs8
def test_mesh_recurrent_state_shardings(mesh, dense_params):
    """On a 1x8 model-axis mesh the recurrent-state arenas must actually
    distribute: every matrix-memory leaf (ndim >= 4) carries a
    non-replicated NamedSharding (heads when they divide, else the
    never-contracted value dim), and contraction dims stay whole."""
    for fam in ("ssm", "hybrid"):
        engine = ServingEngine(CFGS[fam], dense_params[fam], n_slots=4,
                               max_len=32, mesh=mesh)
        pool = engine.pool if fam == "ssm" else engine.pool.state
        n_sharded = 0
        for leaf in jax.tree.leaves(pool.states):
            assert isinstance(leaf.sharding, NamedSharding), fam
            if leaf.ndim >= 4:
                assert not leaf.sharding.is_fully_replicated, \
                    f"{fam} leaf {leaf.shape} replicated on 1x8"
                n_sharded += 1
        assert n_sharded > 0, fam
        assert engine.stats()["placement"]["devices"] == 8


@needs8
def test_mesh_family_engine_token_identical(mesh, dense_params):
    """Mesh-native recurrent serving produces exactly the single-device
    streams (the state shardings never split a contraction)."""
    cfg = CFGS["ssm"]
    params = dense_params["ssm"]
    prompts = _prompts(cfg, 2, P_LEN, seed=6)
    ref, _ = _ref(cfg, params, prompts, GEN)
    engine = ServingEngine(cfg, params, n_slots=4, max_len=P_LEN + GEN,
                           token_budget=8, mesh=mesh)
    reqs = _submit_all(engine, cfg, prompts, GEN)
    engine.run()
    for i, r in enumerate(reqs):
        assert r.tokens == ref[i].tolist(), f"mesh ssm req {i}"
