"""Per-architecture smoke tests: reduced config, one forward/train step on
CPU, asserting output shapes + finiteness (assignment deliverable f)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import get_model

B, S = 2, 32


def _batch(cfg, key):
    batch = {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab),
             "labels": jax.random.randint(key, (B, S), 0, cfg.vocab)}
    if cfg.family == "vlm":
        batch["embeds"] = jax.random.normal(key, (B, S, cfg.d_model), jnp.float32)
        batch["positions"] = jnp.broadcast_to(jnp.arange(S)[None, None], (3, B, S))
        del batch["tokens"]
    if cfg.family == "encdec":
        batch["embeds"] = jax.random.normal(key, (B, S, cfg.d_model), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", configs.ASSIGNED)
def test_arch_smoke(arch):
    cfg = configs.get_smoke(arch)
    zoo = get_model(cfg)
    key = jax.random.PRNGKey(0)
    params = zoo.init(key)
    batch = _batch(cfg, key)

    # one train step: loss + grads finite
    (loss, _aux), grads = jax.value_and_grad(
        lambda p: zoo.loss(p, batch), has_aux=True)(params)
    assert np.isfinite(float(loss))
    assert float(loss) < 2 * np.log(cfg.vocab)        # near ln(V) at init
    gn = jax.tree_util.tree_reduce(
        lambda a, g: a + float(jnp.sum(jnp.abs(g.astype(jnp.float32)))), grads, 0.0)
    assert np.isfinite(gn) and gn > 0

    # prefill: logits shape [B, vocab]
    pf = {k: v for k, v in batch.items() if k != "labels"}
    logits, caches = zoo.prefill(params, pf)
    assert logits.shape == (B, cfg.vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all()

    # decode one token
    step = {"tokens": jnp.zeros((B, 1), jnp.int32)}
    logits2, caches2 = zoo.decode(params, caches, step)
    assert logits2.shape == (B, cfg.vocab)
    assert np.isfinite(np.asarray(logits2, np.float32)).all()


@pytest.mark.parametrize("arch", configs.ASSIGNED)
def test_full_config_registered_exact(arch):
    """The full (non-smoke) config matches the assigned hyper-parameters."""
    expected = {
        "qwen2-vl-72b": (80, 8192, 64, 8, 29568, 152064),
        "xlstm-350m": (24, 1024, 4, 4, 0, 50304),
        "gemma-7b": (28, 3072, 16, 16, 24576, 256000),
        "qwen3-8b": (36, 4096, 32, 8, 12288, 151936),
        "internlm2-1.8b": (24, 2048, 16, 8, 8192, 92544),
        "nemotron-4-340b": (96, 18432, 96, 8, 73728, 256000),
        "mixtral-8x7b": (32, 4096, 32, 8, 14336, 32000),
        "llama4-maverick-400b-a17b": (48, 5120, 40, 8, 8192, 202048),
        "whisper-medium": (24, 1024, 16, 16, 4096, 51865),
        "zamba2-2.7b": (54, 2560, 32, 32, 10240, 32000),
    }
    cfg = configs.get(arch)
    L, d, H, KV, ff, V = expected[arch]
    assert (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
            cfg.d_ff, cfg.vocab) == (L, d, H, KV, ff, V)


def test_decode_matches_prefill_logits():
    """Autoregressive consistency: decoding token t with a cache built from
    tokens [0..t) must reproduce the teacher-forced logits."""
    cfg = configs.get_smoke("internlm2-1.8b")
    zoo = get_model(cfg)
    params = zoo.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab)

    from repro.models import transformer as tfm
    logits_full, _ = tfm.forward(params, {"tokens": toks}, cfg)

    last, caches = zoo.prefill(params, {"tokens": toks[:, :-1]})
    # pad cache by 1 slot for the decode write
    caches = {**caches,
              "k": jnp.pad(caches["k"], [(0, 0), (0, 0), (0, 1), (0, 0), (0, 0)]),
              "v": jnp.pad(caches["v"], [(0, 0), (0, 0), (0, 1), (0, 0), (0, 0)])}
    dec_logits, _ = zoo.decode(params, caches, {"tokens": toks[:, -1:]})
    np.testing.assert_allclose(np.asarray(dec_logits, np.float32),
                               np.asarray(logits_full[:, -1], np.float32),
                               rtol=2e-2, atol=2e-2)


def test_mlstm_chunked_matches_decode_recurrence():
    """xLSTM chunkwise prefill state == step-by-step decode state."""
    from repro.models import xlstm as xls
    cfg = configs.get_smoke("xlstm-350m")
    zoo = get_model(cfg)
    params = zoo.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, 16), 0, cfg.vocab)

    _, caches = zoo.prefill(params, {"tokens": toks})
    # replay token-by-token through decode
    caches2 = {"states": xls.init_state(cfg, 1), "pos": jnp.zeros((), jnp.int32)}
    for t in range(16):
        logits2, caches2 = zoo.decode(params, caches2, {"tokens": toks[:, t:t+1]})
    for s1, s2 in zip(caches["states"], caches2["states"]):
        for a, b in zip(s1, s2):
            if a is None:
                continue
            # chunked vs stepwise differ by f32 summation order; errors of
            # this size sit below the mLSTM normalizer floor max(|q.n|, 1)
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=3e-2, atol=2e-2)


def test_moe_local_routing_exact():
    """Local MoE path: manual per-token expert compute equals moe_apply."""
    from repro.models import moe as moe_lib
    cfg = configs.get_smoke("mixtral-8x7b")
    key = jax.random.PRNGKey(0)
    p = moe_lib.init_moe(key, cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (8, cfg.d_model))
    y = moe_lib.moe_apply(p, x, cfg)

    # manual reference
    probs = jax.nn.softmax(x @ p["router"].T, axis=-1)
    top_w, top_e = jax.lax.top_k(probs, cfg.moe.top_k)
    top_w = top_w / top_w.sum(-1, keepdims=True)
    y_ref = np.zeros_like(np.asarray(x))
    for t in range(8):
        for j in range(cfg.moe.top_k):
            e = int(top_e[t, j])
            g = jax.nn.silu(x[t] @ p["we_gate"][e]) * (x[t] @ p["we_up"][e])
            y_ref[t] += float(top_w[t, j]) * np.asarray(g @ p["we_down"][e])
    np.testing.assert_allclose(np.asarray(y), y_ref, rtol=1e-3, atol=1e-3)
