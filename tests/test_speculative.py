"""Speculative decoding: draft-verify over the serving engine's pools.

The load-bearing property (ISSUE 8 acceptance): GREEDY speculative decode
is token-identical to non-speculative greedy decode — for dense and
8:16+outlier targets, in both KV layouts, with every proposer (self-draft,
a genuinely different draft model forcing rejection rollbacks, n-gram
prompt lookup), through preemption mid-speculation, and on a 1x8 mesh.
Speculation may only change WHEN tokens arrive, never WHICH tokens.

Also pinned here: the leave-one-in ``verify_draft`` unit semantics, the
token-budget verify reserve, acceptance-driven per-request k adaptation,
the speculative counters/phases of the PR-7 observability substrate, and
the jit-variant growth cap (S = k+1 shapes ride the ``_bucket`` ladder —
compiled variants stay logarithmic in k, not linear).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.core import SparsifyConfig
from repro.models import get_model
from repro.serving import (Request, SamplingParams, ServingEngine,
                           ServingTracer, SpeculativeConfig, Status,
                           spec_verify_reserve)
from repro.serving.sampling import verify_draft
from repro.serving.speculative import NGramProposer

CFG = dataclasses.replace(configs.get_smoke("llama-paper"),
                          name="spec-test", n_layers=2, d_model=128,
                          n_heads=4, n_kv_heads=4, head_dim=32, d_ff=256,
                          vocab=512, remat=False)
GEN = 10


@pytest.fixture(scope="module")
def dense_params():
    return get_model(CFG).init(jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def other_params():
    """A second, unrelated init: a draft model that genuinely disagrees
    with the target, so verification exercises rejection + rollback."""
    return get_model(CFG).init(jax.random.PRNGKey(7))


@pytest.fixture(scope="module")
def sparse_params(dense_params):
    from repro.models.sparse_serving import sparsify_for_serving
    scfg = SparsifyConfig(weight_pattern="8:16", outlier_pattern="16:256",
                          scorer="magnitude", use_smoothquant=False)
    sp, report = sparsify_for_serving(dense_params, scfg)
    assert report["n_layers_sparsified"] > 0
    return sp


def _prompts(n, length, seed=1):
    key = jax.random.PRNGKey(seed)
    return [t.tolist() for t in
            jax.random.randint(key, (n, length), 0, CFG.vocab)]


def _run(params, prompts, gen=GEN, *, draft=None, samplings=None, **kw):
    kw.setdefault("n_slots", 4)
    kw.setdefault("max_len", 48)
    engine = ServingEngine(CFG, params, draft=draft, **kw)
    samplings = samplings or [SamplingParams(max_new_tokens=gen)] * len(prompts)
    reqs = [engine.submit(p, s) for p, s in zip(prompts, samplings)]
    engine.run()
    return engine, reqs


# ---------------------------------------------------------------------------
# the tentpole property: greedy speculation is exact
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("layout", ["slot", "paged"])
@pytest.mark.parametrize("proposer", ["self", "other", "ngram"])
@pytest.mark.parametrize("target", ["dense", "sparse"])
def test_greedy_token_identical(target, proposer, layout, dense_params,
                                other_params, sparse_params):
    params = dense_params if target == "dense" else sparse_params
    prompts = _prompts(3, 16)
    _, base = _run(params, prompts)
    if proposer == "ngram":
        draft = SpeculativeConfig(k=3, method="ngram")
    else:
        dp = params if proposer == "self" else other_params
        draft = SpeculativeConfig(k=3, method="model", params=dp, cfg=CFG)
    engine, reqs = _run(params, prompts, draft=draft, kv_layout=layout)
    for b, r in zip(base, reqs):
        assert r.status is Status.FINISHED
        assert r.tokens == b.tokens, \
            f"{target}/{proposer}/{layout} diverged"
        assert len(r.logprobs) == len(r.tokens)
        assert all(lp <= 1e-6 for lp in r.logprobs)
    st = engine.stats()["speculative"]
    if proposer == "self":
        # a self-draft agrees with the target everywhere: everything
        # proposed is accepted, and the request finishes in far fewer
        # engine steps than one-token-per-step decode
        assert st["acceptance_rate"] == 1.0
        assert st["accepted_per_step"] > 1.0
    if proposer == "other":
        # an unrelated init must disagree sometimes — otherwise this
        # matrix never exercises rejection rollback
        assert st["drafted"] > 0
        assert st["accepted"] < st["drafted"]


def test_sparse_drafts_its_densified_counterpart(sparse_params):
    """The ISSUE headline pair: the 8:16+outlier compressed model drafts
    for its dense counterpart (here the exact densification, standing in
    for a trained above-threshold pair) — near-total acceptance while the
    draft runs the sparse kernels and the target the dense matmuls."""
    from repro.models.sparse_serving import densify_params
    target = densify_params(sparse_params)
    prompts = _prompts(3, 16)
    _, base = _run(target, prompts)
    draft = SpeculativeConfig(k=3, method="model", params=sparse_params,
                              cfg=CFG)
    engine, reqs = _run(target, prompts, draft=draft, kv_layout="paged")
    for b, r in zip(base, reqs):
        assert r.tokens == b.tokens
    st = engine.stats()["speculative"]
    assert st["acceptance_rate"] > 0.9
    assert st["accepted_per_step"] > 1.0


def test_speculation_takes_fewer_steps(dense_params):
    prompts = _prompts(3, 16)
    base_engine, _ = _run(dense_params, prompts)
    spec_engine, _ = _run(
        dense_params, prompts,
        draft=SpeculativeConfig(k=3, method="model", params=dense_params,
                                cfg=CFG))
    assert spec_engine.n_steps < base_engine.n_steps


def test_preemption_mid_speculation(dense_params, other_params):
    """A paged arena too small for every request's speculative burst: the
    k+1-token prepare_decode preempts the youngest mid-speculation, and
    the preempted request resumes (draft cursor reset via on_admit) with
    its stream intact."""
    prompts = _prompts(4, 16)
    _, base = _run(dense_params, prompts)          # roomy baseline
    draft = SpeculativeConfig(k=4, method="model", params=other_params,
                              cfg=CFG)
    # 4 requests need 28 blocks at full length; 24 forces preempt-to-queue
    # while speculative bursts are in flight
    engine, reqs = _run(dense_params, prompts, draft=draft,
                        kv_layout="paged", max_len=48, block_size=4,
                        n_blocks=24, prefix_caching=False)
    assert engine.n_preemptions > 0, \
        "arena sized to force preemption mid-speculation"
    for b, r in zip(base, reqs):
        assert r.status is Status.FINISHED
        assert r.tokens == b.tokens


def test_spec_budget_charges_verify_tokens(dense_params, other_params):
    """With speculation on, each step reserves k+1 verify tokens per
    decoding request out of the prefill budget — a late-arriving prompt
    chunks through the remainder and every stream still matches the
    non-speculative engine's."""
    prompts = _prompts(2, 16) + _prompts(1, 24, seed=9)
    kw = dict(n_slots=4, max_len=48, token_budget=16)
    _, base = _run(dense_params, prompts, **kw)
    draft = SpeculativeConfig(k=3, method="model", params=other_params,
                              cfg=CFG)
    _, reqs = _run(dense_params, prompts, draft=draft, **kw)
    for b, r in zip(base, reqs):
        assert r.status is Status.FINISHED
        assert r.tokens == b.tokens


needs8 = pytest.mark.skipif(
    len(jax.devices()) < 8,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=8")

MESH_CFG = dataclasses.replace(CFG, name="spec-mesh-test", n_heads=8,
                               n_kv_heads=8, head_dim=16)


@needs8
def test_mesh_parity():
    """1x8 model-axis mesh: speculative token streams identical to the
    single-device speculative engine AND to the unmeshed non-speculative
    engine — draft params co-resident under the same placement."""
    params = get_model(MESH_CFG).init(jax.random.PRNGKey(0))
    other = get_model(MESH_CFG).init(jax.random.PRNGKey(7))
    mesh = jax.make_mesh((1, 8), ("data", "model"))
    prompts = _prompts(3, 16)
    draft = SpeculativeConfig(k=3, method="model", params=other,
                              cfg=MESH_CFG)

    def run(mesh_, draft_):
        engine = ServingEngine(MESH_CFG, params, n_slots=4, max_len=48,
                               mesh=mesh_, draft=draft_)
        reqs = [engine.submit(p, SamplingParams(max_new_tokens=GEN))
                for p in prompts]
        engine.run()
        assert all(r.status is Status.FINISHED for r in reqs)
        return [r.tokens for r in reqs]

    base = run(None, None)
    assert run(None, draft) == base
    assert run(mesh, draft) == base


# ---------------------------------------------------------------------------
# satellite: jit-variant growth stays bucketed
# ---------------------------------------------------------------------------

def test_jit_variant_growth_is_bucketed(dense_params):
    """Adaptive k walks 1..max_k over a long generation; the verify step's
    S = k+1 axis must ride the power-of-two ``_bucket`` ladder, so the
    number of compiled step variants (compile + retrace instants in the
    trace) stays logarithmic in k — NOT one variant per k.  A self-draft
    accepts everything, so k actually climbs 1 -> max_k (a disagreeing
    draft would pin k at min_k and never exercise the ladder)."""
    tracer = ServingTracer()
    draft = SpeculativeConfig(k=1, min_k=1, max_k=8, method="model",
                              params=dense_params, cfg=CFG)
    prompts = _prompts(3, 16)
    engine, reqs = _run(dense_params, prompts, gen=24, max_len=64,
                        draft=draft, tracer=tracer)
    assert all(r.status is Status.FINISHED for r in reqs)
    ks = {r.draft_k for r in reqs}
    assert ks - {1}, "adaptive k never moved; the ladder was not exercised"
    variants = {}
    for ev in tracer.buffer.events:
        if ev["name"] in ("compile", "retrace"):
            fn = ev["args"]["fn"]
            variants[fn] = variants.get(fn, 0) + 1
    # target verify/prefill chunks ("step"): S in {bucketed prompt} union
    # {2, 4, 8, 16} for k+1 — a per-k retrace would give ~max_k variants
    assert variants["step"] <= 6, variants
    # drafter catch-up + decode variants are bucketed the same way
    assert variants.get("draft_step", 0) <= 6, variants
    assert variants.get("draft_decode", 0) <= 2, variants


def test_spec_counters_and_phases(dense_params):
    tracer = ServingTracer()
    draft = SpeculativeConfig(k=3, method="model", params=dense_params,
                              cfg=CFG)
    _run(dense_params, _prompts(2, 16), draft=draft, tracer=tracer)
    text = tracer.counters_text()
    assert "serving_spec_tokens_drafted_total" in text
    assert "serving_spec_tokens_accepted_total" in text
    assert "serving_spec_tokens_emitted_total" in text
    assert "serving_spec_acceptance_rate" in text
    names = {ev["name"] for ev in tracer.buffer.events}
    assert {"draft", "verify", "emit"} <= names


# ---------------------------------------------------------------------------
# unit semantics
# ---------------------------------------------------------------------------

def _one_hot_logits(seq, vocab=16, scale=8.0):
    """[S, V] logits whose argmax at position j is seq[j]."""
    return scale * jax.nn.one_hot(jnp.asarray(seq), vocab,
                                  dtype=jnp.float32)[None]


def test_verify_draft_greedy_accepts_matching_prefix():
    zeros = jnp.zeros((1,), jnp.int32)
    greedy = jnp.zeros((1,), jnp.float32)
    # target argmaxes [5, 6, 7, 9]; draft proposes [5, 6, 8]
    logits = _one_hot_logits([5, 6, 7, 9])
    draft = jnp.asarray([[5, 6, 8, 0]], jnp.int32)
    n_acc, toks, lps = verify_draft(logits, draft, jnp.asarray([3]),
                                    greedy, zeros, zeros, zeros)
    assert int(n_acc[0]) == 2                 # d1, d2 accepted; d3 rejected
    # emitted burst = accepted drafts + the correction token, each the
    # position's argmax — exactly the sequential greedy stream
    assert toks[0, :3].tolist() == [5, 6, 7]
    assert np.all(np.asarray(lps[0, :3]) <= 0)


def test_verify_draft_greedy_full_acceptance_gets_bonus():
    zeros = jnp.zeros((1,), jnp.int32)
    greedy = jnp.zeros((1,), jnp.float32)
    logits = _one_hot_logits([5, 6, 7, 9])
    draft = jnp.asarray([[5, 6, 7, 0]], jnp.int32)
    n_acc, toks, _ = verify_draft(logits, draft, jnp.asarray([3]),
                                  greedy, zeros, zeros, zeros)
    assert int(n_acc[0]) == 3
    assert toks[0, :4].tolist() == [5, 6, 7, 9]   # 3 drafts + bonus


def test_verify_draft_stochastic_is_valid_and_deterministic():
    key = jax.random.PRNGKey(3)
    logits = jax.random.normal(key, (4, 5, 16), jnp.float32)
    draft = jax.random.randint(key, (4, 5), 0, 16)
    n_draft = jnp.asarray([4, 2, 0, 3])
    temps = jnp.full((4,), 0.8, jnp.float32)
    zeros = jnp.zeros((4,), jnp.int32)
    seeds = jnp.asarray([1, 2, 3, 4])
    steps = jnp.asarray([0, 5, 9, 2])
    a1, t1, l1 = verify_draft(logits, draft, n_draft, temps, zeros,
                              seeds, steps)
    a2, t2, l2 = verify_draft(logits, draft, n_draft, temps, zeros,
                              seeds, steps)
    np.testing.assert_array_equal(np.asarray(a1), np.asarray(a2))
    np.testing.assert_array_equal(np.asarray(t1), np.asarray(t2))
    for i in range(4):
        assert 0 <= int(a1[i]) <= int(n_draft[i])
    assert np.all((np.asarray(t1) >= 0) & (np.asarray(t1) < 16))
    assert np.all(np.asarray(l1) <= 1e-6)


def test_ngram_proposer():
    p = NGramProposer(2)
    #           0  1  2  3  4  5  6
    seq = [3, 4, 9, 8, 7, 3, 4]
    # suffix [3, 4] matched at position 0 -> continuation [9, 8, 7]
    assert p.propose(seq, 3) == [9, 8, 7]
    assert p.propose(seq, 2) == [9, 8]
    assert p.propose([1, 2, 3], 3) == []          # no earlier occurrence
    assert p.propose([1], 3) == []                # shorter than the suffix
    assert p.propose(seq, 0) == []


def test_spec_verify_reserve_counts_running_only():
    def req(i, status, draft_k=0):
        r = Request(request_id=i, prompt=[1, 2],
                    sampling=SamplingParams(max_new_tokens=4))
        r.status = status
        r.draft_k = draft_k
        return r

    running = {0: req(0, Status.RUNNING, 4),      # 4 + 1
               1: req(1, Status.RUNNING),         # default_k 3 + 1
               2: req(2, Status.PREFILLING, 8)}   # not decoding: no charge
    assert spec_verify_reserve(running, 3) == 9
    assert spec_verify_reserve({}, 3) == 0


def test_adaptive_k_walks_with_acceptance(dense_params, other_params):
    prompts = _prompts(3, 16)
    up = SpeculativeConfig(k=2, min_k=1, max_k=8, method="model",
                           params=dense_params, cfg=CFG)
    _, reqs = _run(dense_params, prompts, gen=16, max_len=64, draft=up)
    assert all(r.draft_k > 2 for r in reqs), \
        "full acceptance must grow draft_k"
    down = SpeculativeConfig(k=4, min_k=1, max_k=8, method="model",
                             params=other_params, cfg=CFG)
    _, reqs = _run(dense_params, prompts, gen=16, max_len=64, draft=down)
    assert any(r.draft_k < 4 for r in reqs), \
        "majority rejection must shrink draft_k"


def test_draft_validation_errors(dense_params):
    with pytest.raises(ValueError, match="method"):
        SpeculativeConfig(method="oracle")
    with pytest.raises(ValueError, match="params"):
        SpeculativeConfig(method="model")
    with pytest.raises(ValueError, match="min_k"):
        SpeculativeConfig(k=9, method="ngram")
    bad_vocab = dataclasses.replace(CFG, vocab=CFG.vocab * 2)
    with pytest.raises(ValueError, match="vocab"):
        ServingEngine(CFG, dense_params, n_slots=2, max_len=32,
                      draft=SpeculativeConfig(
                          method="model", params=dense_params,
                          cfg=bad_vocab))


def test_verify_bucket_ladder_anchors_at_configured_k():
    from repro.serving import verify_bucket
    # draft-free steps (ngram found nothing) keep the decode shape
    assert verify_bucket(1, 4) == 1
    # any drafted step in [1, k0] shares ONE compiled shape...
    assert [verify_bucket(q, 4) for q in (2, 3, 4, 5)] == [8, 8, 8, 8]
    # ...and adaptive excursions above k0 add log2(max_k/k0) rungs
    assert verify_bucket(9, 4) == 16
    # k0=1 (the adaptive self-draft test's config) keeps the old ladder
    assert [verify_bucket(q, 1) for q in (1, 2, 3, 5, 9)] == [1, 2, 4, 8, 16]


def test_ngram_variable_draft_len_variants_bucketed(dense_params):
    """N-gram proposals run 0..k tokens per lane per step — the exact
    workload that retraced the verify step once per draft-length bucket
    (9 ``step`` variants in the serving bench) before the ladder was
    anchored at the configured k.  Periodic prompts make the proposer
    actually fire at varying match lengths; the compiled step variants
    must stay within the same bound as the adaptive-k model-draft test."""
    tracer = ServingTracer()
    draft = SpeculativeConfig(k=4, min_k=1, max_k=8, method="ngram")
    # period-4 token loops with varying phase: suffix lookup hits with
    # continuation lengths all over [0, k]
    prompts = [([5, 6, 7, 8] * 6)[:16 + i] for i in range(3)]
    engine, reqs = _run(dense_params, prompts, gen=24, max_len=64,
                        draft=draft, tracer=tracer)
    assert all(r.status is Status.FINISHED for r in reqs)
    assert engine.n_drafted > 0, "ngram proposer never fired"
    variants = {}
    for ev in tracer.buffer.events:
        if ev["name"] in ("compile", "retrace"):
            fn = ev["args"]["fn"]
            variants[fn] = variants.get(fn, 0) + 1
    assert variants["step"] <= 6, variants
