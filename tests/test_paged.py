"""Paged KV-cache subsystem tests.

Load-bearing properties:
  1. The paged engine is token-identical to the slot engine (dense AND
     8:16+outlier compressed weights) — paging is a memory layout, never
     a numerics change.
  2. Prefix-cache hits, copy-on-write, and preempt-to-queue never change
     a request's token stream either.
  3. The Pallas paged-attention kernel (interpret mode here) matches the
     jnp gather reference, which matches contiguous decode attention.
  4. Block accounting (refcounts, double free, exhaustion, LRU eviction)
     raises real exceptions and never leaks or aliases blocks.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.core import SparsifyConfig
from repro.models import get_model
from repro.models.layers import decode_attention
from repro.serving import SamplingParams, ServingEngine, Status
from repro.serving.paged import (BlockPool, BlockPoolError, BlockTable,
                                 OutOfBlocks, PagedKVPool, PrefixCache,
                                 blocks_needed, paged_attention_pallas,
                                 paged_attention_ref)

CFG = dataclasses.replace(configs.get_smoke("llama-paper"),
                          name="paged-test", n_layers=2, d_model=128,
                          n_heads=4, n_kv_heads=4, head_dim=32, d_ff=256,
                          vocab=512, remat=False)
GEN = 6
BS = 8                                     # block size for engine tests


@pytest.fixture(scope="module")
def dense_params():
    return get_model(CFG).init(jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def sparse_params(dense_params):
    from repro.models.sparse_serving import sparsify_for_serving
    scfg = SparsifyConfig(weight_pattern="8:16", outlier_pattern="16:256",
                          scorer="magnitude", use_smoothquant=False)
    sp, report = sparsify_for_serving(dense_params, scfg)
    assert report["n_layers_sparsified"] > 0
    return sp


def _prompts(n, length, seed=1):
    key = jax.random.PRNGKey(seed)
    return [t.tolist() for t in
            jax.random.randint(key, (n, length), 0, CFG.vocab)]


def _run(params, prompts, gen, **kw):
    engine = ServingEngine(CFG, params, **kw)
    reqs = [engine.submit(p, SamplingParams(max_new_tokens=gen))
            for p in prompts]
    engine.run()
    return engine, reqs


def _solo(params, prompt, gen):
    _, (r,) = _run(params, [prompt], gen, n_slots=1, max_len=64)
    return r.tokens


# --------------------------------------------------------------------------
# allocator / table / prefix-cache units
# --------------------------------------------------------------------------

def test_block_pool_refcounts_and_exhaustion():
    pool = BlockPool(CFG, n_blocks=3, block_size=4)
    a, b, c = pool.alloc(), pool.alloc(), pool.alloc()
    assert pool.n_free == 0 and sorted((a, b, c)) == [0, 1, 2]
    with pytest.raises(OutOfBlocks):
        pool.alloc()
    pool.incref(a)
    assert not pool.decref(a)              # shared: not yet freed
    assert pool.decref(a) and pool.n_free == 1
    with pytest.raises(BlockPoolError):    # double free
        pool.decref(a)
    with pytest.raises(BlockPoolError):    # incref of a free block
        pool.incref(a)
    pool.decref(b), pool.decref(c)
    assert pool.n_free == 3


def test_copy_on_write_preserves_content_and_refs():
    pool = BlockPool(CFG, n_blocks=2, block_size=4)
    src = pool.alloc()
    pool.k = pool.k.at[:, src].set(7.0)
    pool.incref(src)                       # two owners
    dst = pool.copy_on_write(src)
    assert dst != src
    assert pool.ref[src] == 1 and pool.ref[dst] == 1
    np.testing.assert_array_equal(np.asarray(pool.k[:, dst]),
                                  np.asarray(pool.k[:, src]))


def test_block_table_mapping():
    t = BlockTable(4, [9, 2, 5])
    assert t.capacity == 12 and t.n_blocks == 3
    assert t.physical_block(0) == 9 and t.physical_block(7) == 2
    assert t.slot(6) == 2 * 4 + 2 and t.slot(11) == 5 * 4 + 3
    assert blocks_needed(0, 4) == 0 and blocks_needed(9, 4) == 3


def test_prefix_cache_match_insert_evict():
    pool = BlockPool(CFG, n_blocks=3, block_size=4)
    cache = PrefixCache(pool)
    toks = list(range(11))                       # 2 full blocks + tail of 3
    blocks = [pool.alloc() for _ in range(3)]
    cache.insert(toks, blocks)
    assert len(cache) == 2                       # only full blocks cached
    assert pool.ref[blocks[0]] == 2 and pool.ref[blocks[2]] == 1

    m = cache.match(toks)
    assert m == blocks[:2]                       # chain hit, increfed for us
    assert pool.ref[blocks[0]] == 3
    assert cache.match(list(range(100, 111))) == []   # different prefix
    # chain property: same second block tokens under a different first
    # block must NOT match
    assert cache.match([99] * 4 + toks[4:]) == []

    for b in m:
        pool.decref(b)
    for b in blocks:                             # request releases its table
        pool.decref(b)
    assert cache.n_evictable == 2
    assert cache.evict_one() and pool.n_free == 2   # child evicted first
    assert cache.evict_one() and not cache.evict_one()
    assert len(cache) == 0


def test_prefix_cache_match_length_probe_is_side_effect_free():
    pool = BlockPool(CFG, n_blocks=4, block_size=4)
    cache = PrefixCache(pool)
    toks = list(range(11))                       # 2 full blocks + tail of 3
    blocks = [pool.alloc() for _ in range(3)]
    cache.insert(toks, blocks)
    order_after_insert = list(cache._entries)

    # exact multiples, partial tails, and the chain property
    assert cache.match_length(toks) == 8         # tail never matches
    assert cache.match_length(toks[:8]) == 8
    assert cache.match_length(toks[:7]) == 4     # partial second block
    assert cache.match_length(toks[:4]) == 4
    assert cache.match_length(toks[:3]) == 0
    assert cache.match_length(toks + [99] * 8) == 8
    assert cache.match_length([99] * 4 + toks[4:]) == 0
    assert cache.match_length([]) == 0

    # the router probes every replica per request: NO refcounts taken,
    # NO LRU touch, NO hit/lookup accounting — probes counted apart
    assert [int(pool.ref[b]) for b in blocks] == [2, 2, 1]
    assert list(cache._entries) == order_after_insert
    st = cache.stats()
    assert st["probes"] == 8
    assert st["lookups"] == 0 and st["hits"] == 0 and st["hit_tokens"] == 0
    m = cache.match(toks)                        # admission lookup DOES count
    assert cache.stats()["lookups"] == 1 and cache.stats()["hits"] == 1
    for b in m:
        pool.decref(b)


def test_pool_prefix_match_length_passthrough():
    pool = PagedKVPool(CFG, n_rows=4, max_len=32, block_size=4)
    p = list(range(10))
    assert pool.prefix_match_length(p) == 0      # cold cache
    row, _ = pool.admit(p)
    pool.register_prefix(row, p)
    assert pool.prefix_match_length(p) == 8      # 2 full blocks cached
    assert pool.prefix_match_length(p[:5]) == 4
    assert pool.prefix_match_length([7] + p) == 0


def test_pool_admit_shares_and_releases():
    pool = PagedKVPool(CFG, n_rows=4, max_len=32, block_size=4)
    p = list(range(10))                          # 3 blocks
    row, n_cached = pool.admit(p)
    assert n_cached == 0
    assert pool.tables[row].n_blocks == 3
    pool.register_prefix(row, p)
    row2, n_cached2 = pool.admit(p)
    assert n_cached2 == 8                        # 2 full blocks shared
    assert pool.tables[row2].blocks[:2] == pool.tables[row].blocks[:2]
    assert pool.tables[row2].blocks[2] != pool.tables[row].blocks[2]
    free_before = pool.blocks.n_free
    pool.release(row2)
    assert pool.blocks.n_free == free_before + 1  # shared blocks survive
    from repro.serving import DoubleFree
    with pytest.raises(DoubleFree):
        pool.release(row2)


# --------------------------------------------------------------------------
# paged attention numerics
# --------------------------------------------------------------------------

def _attn_case(seed=0, B=3, S=1, H=4, KV=2, hd=16, bs=8, n_blocks=10, nb=4):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (B, S, H, hd), jnp.float32)
    ka = jax.random.normal(ks[1], (n_blocks, bs, KV, hd), jnp.float32)
    va = jax.random.normal(ks[2], (n_blocks, bs, KV, hd), jnp.float32)
    bt = jnp.asarray(np.array([[3, 1, 7, 0], [2, 4, 5, 9], [8, 6, 0, 0]],
                              np.int32))
    # cursor = tokens visible before this step's S fresh ones; keep every
    # row's last visible position inside its real blocks
    cursor = jnp.asarray([27 - S, 12 - S, 9 - S], jnp.int32)
    return q, ka, va, bt, cursor


def test_paged_attention_ref_matches_contiguous():
    q, ka, va, bt, cursor = _attn_case()
    ref = paged_attention_ref(q, ka, va, bt, cursor)
    # contiguous view assembled by the same table; decode masks < len
    B, nb = bt.shape
    bs = ka.shape[1]
    kc = ka[bt].reshape(B, nb * bs, *ka.shape[2:])
    vc = va[bt].reshape(B, nb * bs, *va.shape[2:])
    ctg = decode_attention(q, kc, vc, cursor + 1)
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(ctg))


def test_paged_attention_ref_chunk_matches_masked_contiguous():
    """S>1 queries (a prefill chunk): query i of row b sees gathered
    positions <= cursor[b] + i — identical to length-masked attention
    over the contiguous view assembled by the same tables."""
    from repro.models.layers import attend_length_masked
    q, ka, va, bt, cursor = _attn_case(S=5)
    ref = paged_attention_ref(q, ka, va, bt, cursor)
    B, nb = bt.shape
    bs = ka.shape[1]
    kc = ka[bt].reshape(B, nb * bs, *ka.shape[2:])
    vc = va[bt].reshape(B, nb * bs, *va.shape[2:])
    ctg = attend_length_masked(q, kc, vc, cursor)
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(ctg))


@pytest.mark.parametrize("S", [1, 5])
@pytest.mark.parametrize("window", [None, 10])
def test_paged_attention_pallas_matches_ref(window, S):
    q, ka, va, bt, cursor = _attn_case(S=S)
    ref = paged_attention_ref(q, ka, va, bt, cursor, window=window)
    pal = paged_attention_pallas(q, ka, va, bt, cursor, window=window,
                                 interpret=True)
    np.testing.assert_allclose(np.asarray(pal), np.asarray(ref),
                               atol=1e-5, rtol=1e-5)


@pytest.mark.parametrize("S", [1, 4])
def test_paged_attention_pallas_head_tiled_matches_ref(S):
    """The large-H*hd variant (grid over KV-head tiles) is numerically
    the untiled kernel; forced here via head_tile regardless of the
    auto-select threshold."""
    q, ka, va, bt, cursor = _attn_case(S=S, H=8, KV=4)
    ref = paged_attention_ref(q, ka, va, bt, cursor)
    for tile in (1, 2):
        pal = paged_attention_pallas(q, ka, va, bt, cursor, interpret=True,
                                     head_tile=tile)
        np.testing.assert_allclose(np.asarray(pal), np.asarray(ref),
                                   atol=1e-5, rtol=1e-5)
    with pytest.raises(ValueError, match="head_tile"):
        paged_attention_pallas(q, ka, va, bt, cursor, interpret=True,
                               head_tile=3)


def test_head_tile_autoselect_threshold(monkeypatch):
    """Dispatch picks the head-tiled kernel above the H*hd threshold and
    honors the REPRO_PAGED_HEAD_TILE override."""
    import importlib
    pa = importlib.import_module("repro.serving.paged.paged_attention")
    monkeypatch.delenv("REPRO_PAGED_HEAD_TILE", raising=False)
    assert pa._head_tile(4, 2, 16) is None              # tiny: untiled
    big = pa._head_tile(64, 8, 128)                     # 8192 lanes: tiled
    assert big is not None and 8 % big == 0 and big < 8
    monkeypatch.setenv("REPRO_PAGED_HEAD_TILE", "0")
    assert pa._head_tile(64, 8, 128) is None            # forced off
    monkeypatch.setenv("REPRO_PAGED_HEAD_TILE", "2")
    assert pa._head_tile(8, 4, 16) == 2                 # forced on
    # an override that cannot tile this model's KV heads falls back to
    # the untiled kernel instead of crashing the serving path
    assert pa._head_tile(4, 2, 16) is None              # t >= KV
    monkeypatch.setenv("REPRO_PAGED_HEAD_TILE", "3")
    assert pa._head_tile(8, 4, 16) is None              # KV % t != 0


# --------------------------------------------------------------------------
# engine: paged == slot, prefix sharing, CoW, preemption
# --------------------------------------------------------------------------

@pytest.mark.parametrize("which", ["dense", "sparse"])
def test_paged_engine_token_identical_to_slot(which, dense_params,
                                              sparse_params):
    params = dense_params if which == "dense" else sparse_params
    prompts = _prompts(4, 16)
    _, slot_reqs = _run(params, prompts, GEN, n_slots=4, max_len=32)
    _, paged_reqs = _run(params, prompts, GEN, n_slots=4, max_len=32,
                         kv_layout="paged", block_size=BS)
    for i, (s, p) in enumerate(zip(slot_reqs, paged_reqs)):
        assert p.status is Status.FINISHED
        assert p.tokens == s.tokens, f"request {i} diverged"


def test_prefix_cache_hits_are_token_identical(dense_params):
    """Requests sharing a system prompt: later ones hit the prefix cache
    (suffix-only prefill) yet produce exactly their solo tokens."""
    sys_prompt = _prompts(1, 3 * BS, seed=5)[0]       # 3 full blocks
    tails = _prompts(3, 6, seed=6)
    engine = ServingEngine(CFG, dense_params, n_slots=4, max_len=64,
                           kv_layout="paged", block_size=BS)
    reqs = []
    for tail in tails:                    # sequential so the cache is warm
        reqs.append(engine.submit(sys_prompt + tail,
                                  SamplingParams(max_new_tokens=GEN)))
        engine.run()
    stats = engine.pool.prefix_cache.stats()
    assert stats["hit_tokens"] >= 2 * 3 * BS          # reqs 2,3 hit 3 blocks
    for tail, r in zip(tails, reqs):
        assert r.tokens == _solo(dense_params, sys_prompt + tail, GEN)


def test_fully_cached_prompt_copy_on_write(dense_params):
    """An identical prompt of exactly full blocks: the repeat admission
    matches every block, CoWs the last one to recompute its tail, and
    still emits identical tokens — the shared original stays intact."""
    prompt = _prompts(1, 3 * BS, seed=7)[0]
    engine = ServingEngine(CFG, dense_params, n_slots=4, max_len=64,
                           kv_layout="paged", block_size=BS)
    r1 = engine.submit(prompt, SamplingParams(max_new_tokens=GEN))
    engine.run()
    r2 = engine.submit(prompt, SamplingParams(max_new_tokens=GEN))
    engine.run()
    r3 = engine.submit(prompt + _prompts(1, 4, seed=8)[0],
                       SamplingParams(max_new_tokens=GEN))
    engine.run()                         # r3 shares the SAME cached blocks
    assert r1.tokens == r2.tokens == _solo(dense_params, prompt, GEN)
    assert r3.tokens == _solo(dense_params, r3.prompt, GEN)


def test_preemption_resumes_identically(dense_params):
    """A starved arena forces preempt-to-queue mid-decode; every request
    still finishes with exactly its solo token stream."""
    prompts = _prompts(4, 16, seed=9)
    engine, reqs = _run(dense_params, prompts, 12, n_slots=4, max_len=40,
                        kv_layout="paged", block_size=BS, n_blocks=10,
                        prefix_caching=False)
    assert engine.n_preemptions > 0
    assert all(r.status is Status.FINISHED for r in reqs)
    assert any(r.n_preempted > 0 for r in reqs)
    for p, r in zip(prompts, reqs):
        assert r.tokens == _solo(dense_params, p, 12)


def test_block_exhaustion_defers_admission(dense_params):
    """More burst than blocks: admission stays block-aware (no OutOfBlocks
    escapes), deferred requests run as memory frees, order preserved."""
    prompts = _prompts(6, 16, seed=10)
    engine, reqs = _run(dense_params, prompts, GEN, n_slots=6, max_len=32,
                        kv_layout="paged", block_size=BS, n_blocks=9)
    assert all(r.status is Status.FINISHED for r in reqs)
    for p, r in zip(prompts, reqs):
        assert r.tokens == _solo(dense_params, p, GEN)


def test_paged_capacity_validation(dense_params):
    engine = ServingEngine(CFG, dense_params, n_slots=2, max_len=64,
                           kv_layout="paged", block_size=BS, n_blocks=4)
    # 4 blocks * 8 = 32 tokens is the real capacity, not max_len
    assert engine.pool.max_request_tokens == 32
    with pytest.raises(ValueError):
        engine.submit(_prompts(1, 30, seed=11)[0],
                      SamplingParams(max_new_tokens=8))


def test_full_capacity_request_admits_despite_lookahead(dense_params):
    """A request whose prompt+generation fills the whole arena is legal
    (submit bounds it by capacity); the lookahead margin must not defer
    it forever (regression: admission livelock in engine.run())."""
    engine = ServingEngine(CFG, dense_params, n_slots=1, max_len=32,
                           kv_layout="paged", block_size=BS)  # 4 blocks
    req = engine.submit(_prompts(1, 28, seed=14)[0],
                        SamplingParams(max_new_tokens=4))
    engine.run(max_steps=50)
    assert req.status is Status.FINISHED and len(req.tokens) == 4


def test_preempted_requests_exempt_from_queue_timeout():
    """Timeout eviction bounds the wait for FIRST service only: a request
    preempted back to the queue with generated tokens must not be dropped
    (that would silently discard completed work)."""
    from repro.serving import RequestQueue
    from repro.serving.request import Request
    q = RequestQueue(max_size=4, queue_timeout_s=5.0)
    fresh_stale = Request(0, [1, 2])
    fresh_stale.metrics.arrival = 0.0
    preempted = Request(1, [3, 4])
    preempted.metrics.arrival = 0.0
    preempted.tokens = [7]
    preempted.n_preempted = 1
    q.try_push(fresh_stale)
    q.push_front(preempted)
    evicted = q.evict_expired(now=100.0)
    assert evicted == [fresh_stale]
    assert q.pop() is preempted


def test_paged_moe_sliding_window_identical():
    """MoE + sliding-window + GQA (mixtral smoke) through the paged path:
    the windowed mask over gathered blocks matches the slot layout."""
    cfg = configs.get_smoke("mixtral-8x7b")
    params = get_model(cfg).init(jax.random.PRNGKey(0))
    prompts = [t.tolist() for t in
               jax.random.randint(jax.random.PRNGKey(1), (3, 24), 0,
                                  cfg.vocab)]
    outs = []
    for layout in ("slot", "paged"):
        engine = ServingEngine(cfg, params, n_slots=3, max_len=48,
                               kv_layout=layout, block_size=BS)
        reqs = [engine.submit(p, SamplingParams(max_new_tokens=8))
                for p in prompts]
        engine.run()
        assert all(r.status is Status.FINISHED for r in reqs)
        outs.append([r.tokens for r in reqs])
    assert outs[0] == outs[1]


def test_mixed_arrivals_paged(dense_params):
    """Requests joining a running paged batch mid-decode match their solo
    runs (same property the slot engine guarantees)."""
    early = _prompts(2, 16, seed=12)
    late = _prompts(2, 11, seed=13)
    engine = ServingEngine(CFG, dense_params, n_slots=4, max_len=64,
                           kv_layout="paged", block_size=BS)
    reqs = [engine.submit(p, SamplingParams(max_new_tokens=12))
            for p in early]
    for _ in range(3):
        engine.step()
    reqs += [engine.submit(p, SamplingParams(max_new_tokens=4))
             for p in late]
    engine.run()
    assert [len(r.tokens) for r in reqs] == [12, 12, 4, 4]
    for r, prompt, gen in [(reqs[0], early[0], 12), (reqs[2], late[0], 4),
                           (reqs[3], late[1], 4)]:
        assert r.tokens == _solo(dense_params, prompt, gen)
