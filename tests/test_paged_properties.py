"""Property tests: PrefixCache + BlockPool accounting invariants.

A seeded random walk over the paged pool's public lifecycle (admit with
prefix matching, release, LRU evict, decode-step block growth — single
and multi-token, the speculative verify write — and copy-on-write row
forks) checks after EVERY operation that

  * refcounts are never negative and exactly equal the ground truth
    (one ref per block-table entry + one per prefix-cache entry + the
    permanent trash ref),
  * the free list and live references partition the arena (no block is
    simultaneously free and referenced, no duplicate free entries),
  * LRU eviction never frees a block a live request still references,
  * the O(1) evictability counter matches a full rescan,
  * copy-on-write hands back a private block with identical contents.

Uses ``hypothesis`` when installed, else the deterministic fallback sweep
(tests/hypothesis_fallback.py) — same property, seeded draws.
"""
import dataclasses
import random

import jax
import jax.numpy as jnp
import numpy as np

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                               # pragma: no cover
    from hypothesis_fallback import given, settings, st

from repro import configs
from repro.serving.cache_pool import CapacityError
from repro.serving.paged import BlockPool, OutOfBlocks, PagedKVPool

CFG = dataclasses.replace(configs.get_smoke("llama-paper"),
                          name="paged-prop-test", n_layers=1, d_model=32,
                          n_heads=2, n_kv_heads=2, head_dim=16, d_ff=64,
                          vocab=64, remat=False)
BS = 4                                            # tiny blocks -> pressure


def _check_invariants(pool: PagedKVPool) -> None:
    bp = pool.blocks
    expected = np.zeros((bp.n_blocks,), np.int64)
    expected[pool._trash] += 1                    # permanent trash ref
    for t in pool.tables:
        if t is not None:
            for b in t.blocks:
                expected[b] += 1
    cache = pool.prefix_cache
    if cache is not None:
        for b in cache._entries.values():
            expected[b] += 1
    assert (bp.ref >= 0).all(), "negative refcount"
    np.testing.assert_array_equal(np.asarray(bp.ref, np.int64), expected)
    free = bp._free
    assert len(free) == len(set(free)), "duplicate free-list entry"
    assert all(bp.ref[b] == 0 for b in free), "free block still referenced"
    live = {b for b in range(bp.n_blocks) if bp.ref[b] > 0}
    assert live.isdisjoint(free)
    assert len(free) + len(live) == bp.n_blocks   # partition, nothing leaked
    if cache is not None:
        rescan = sum(1 for b in cache._entries.values() if bp.ref[b] == 1)
        assert cache.n_evictable == rescan, "stale O(1) evictability counter"


def _lifecycle_walk(seed, kv_dtype="bf16"):
    rng = random.Random(seed)
    pool = PagedKVPool(CFG, n_rows=4, max_len=6 * BS, block_size=BS,
                       n_blocks=8, kv_dtype=kv_dtype)
    active: dict[int, list[int]] = {}             # row -> full token seq

    for _ in range(40):
        op = rng.choice(("admit", "admit", "release", "evict", "decode",
                         "decode", "fork"))
        if op == "admit":
            # tiny alphabet so identical prefixes (cache hits) are common
            toks = [rng.randint(0, 2) for _ in
                    range(rng.randint(1, pool.max_request_tokens))]
            if pool.can_admit(len(toks)):
                try:
                    row, n_cached = pool.admit(toks)
                except OutOfBlocks:
                    pass
                else:
                    assert 0 <= n_cached < len(toks)
                    # what write_prefill would record for the full seq
                    pool._pos_np[row] = len(toks)
                    pool.register_prefix(row, toks)
                    active[row] = toks
        elif op == "release" and active:
            row = rng.choice(sorted(active))
            pool.release(row)
            del active[row]
        elif op == "evict" and pool.prefix_cache is not None:
            live = {b for t in pool.tables if t is not None
                    for b in t.blocks}
            before = set(pool.blocks._free)
            if pool.prefix_cache.evict_one():
                freed = set(pool.blocks._free) - before
                assert len(freed) == 1
                assert freed.isdisjoint(live), \
                    "LRU evicted a block a live request references"
        elif op == "decode" and active:
            row = rng.choice(sorted(active))
            # n > 1 is the speculative verify write: k drafts + 1 bonus
            # land through one prepare_decode across [pos, pos + n)
            n = rng.choice((1, 1, rng.randint(2, BS + 1)))
            if int(pool._pos_np[row]) + n <= pool.max_request_tokens:
                try:
                    pool.prepare_decode([row], [n])
                except OutOfBlocks:
                    pass
                else:
                    pool._pos_np[row] += n
                    # the write range must be private to this row now
                    t = pool.tables[row]
                    pos = int(pool._pos_np[row])
                    for bi in range((pos - n) // BS, (pos - 1) // BS + 1):
                        assert pool.blocks.ref[t.blocks[bi]] == 1, \
                            "decode wrote into a shared block"
        elif op == "fork" and active:
            row = rng.choice(sorted(active))
            try:
                new = pool.fork(row)
            except (CapacityError, OutOfBlocks):
                pass                              # row/block pressure, not a bug
            else:
                assert pool.tables[new].blocks == pool.tables[row].blocks
                active[new] = list(active[row])
        _check_invariants(pool)

    for row in sorted(active):                    # drain; nothing may leak
        pool.release(row)
        _check_invariants(pool)
    cache = pool.prefix_cache
    while cache is not None and cache.evict_one():
        _check_invariants(pool)
    if cache is not None:
        assert pool.blocks.n_free == pool.n_blocks   # all but trash free


@settings(max_examples=12, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_pool_lifecycle_invariants_hold(seed):
    _lifecycle_walk(seed)


@settings(max_examples=6, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_pool_lifecycle_invariants_hold_int8(seed):
    """The same walk over a quantized pool: scale arenas change no
    refcount/free-list/prefix-cache bookkeeping (scales are addressed
    through the block tables, never tracked separately)."""
    _lifecycle_walk(seed, kv_dtype="int8")


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_copy_on_write_preserves_contents(seed):
    rng = random.Random(seed)
    pool = BlockPool(CFG, n_blocks=4, block_size=BS)
    src = pool.alloc()
    kval, vval = rng.uniform(-8, 8), rng.uniform(-8, 8)
    pool.k = pool.k.at[:, src].set(kval)
    pool.v = pool.v.at[:, src].set(vval)
    pool.incref(src)                              # shared: CoW must copy
    dst = pool.copy_on_write(src)
    assert dst != src
    assert pool.ref[src] == 1 and pool.ref[dst] == 1
    np.testing.assert_array_equal(np.asarray(pool.k[:, dst]),
                                  np.full_like(np.asarray(pool.k[:, dst]),
                                               kval))
    np.testing.assert_array_equal(np.asarray(pool.v[:, dst]),
                                  np.asarray(pool.v[:, src]))


@settings(max_examples=8, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_fork_diverges_copy_on_write(seed):
    """A forked row shares every parent block by reference; the first
    decode write either side makes inside a shared block must go through
    copy-on-write — the writer gets a private copy carrying the shared
    bytes, the other side's view stays byte-identical."""
    rng = random.Random(seed)
    pool = PagedKVPool(CFG, n_rows=4, max_len=6 * BS, block_size=BS,
                       n_blocks=12)
    n_tok = rng.randint(2 * BS + 1, 3 * BS - 1)   # 3 blocks, last partial
    toks = [rng.randint(0, 63) for _ in range(n_tok)]
    parent, _ = pool.admit(toks)
    pool._pos_np[parent] = n_tok
    shared = pool.tables[parent].blocks[-1]       # the partial tail block
    kval = rng.uniform(-8, 8)
    pool.blocks.k = pool.blocks.k.at[:, shared].set(kval)

    child = pool.fork(parent)
    _check_invariants(pool)
    assert pool.tables[child].blocks == pool.tables[parent].blocks
    assert int(pool._pos_np[child]) == n_tok
    assert pool.blocks.ref[shared] == 2

    # child writes its next token inside the shared tail block
    pool.prepare_decode([child], [1])
    pool._pos_np[child] += 1
    _check_invariants(pool)
    priv = pool.tables[child].blocks[-1]
    assert priv != shared, "child wrote into a block the parent references"
    assert pool.tables[parent].blocks[-1] == shared
    assert pool.blocks.ref[shared] == 1 and pool.blocks.ref[priv] == 1
    np.testing.assert_array_equal(                # CoW carried the bytes
        np.asarray(pool.blocks.k[:, priv]),
        np.asarray(pool.blocks.k[:, shared]))

    # parent's tail is private again: its own write must NOT copy
    pool.prepare_decode([parent], [1])
    pool._pos_np[parent] += 1
    _check_invariants(pool)
    assert pool.tables[parent].blocks[-1] == shared

    # full-block growth past the fork point stays disjoint
    pool.prepare_decode([child], [BS])
    pool._pos_np[child] += BS
    _check_invariants(pool)
    assert set(pool.tables[child].blocks[3:]).isdisjoint(
        pool.tables[parent].blocks)

    pool.release(child)
    _check_invariants(pool)
    pool.release(parent)
    _check_invariants(pool)


@settings(max_examples=8, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_copy_on_write_carries_scales(seed):
    """int8 pools: copy-on-write must copy the per-position scale block
    alongside the value block — a CoW'd block with stale scales would
    dequantize the right int8 bytes with the wrong multipliers."""
    rng = random.Random(seed)
    pool = BlockPool(CFG, n_blocks=4, block_size=BS, kv_dtype="int8")
    assert pool.k.dtype == jnp.int8 and pool.k_scale is not None
    src = pool.alloc()
    kq, vq = rng.randint(-127, 127), rng.randint(-127, 127)
    ks, vs = rng.uniform(0.01, 2.0), rng.uniform(0.01, 2.0)
    pool.k = pool.k.at[:, src].set(kq)
    pool.v = pool.v.at[:, src].set(vq)
    pool.k_scale = pool.k_scale.at[:, src].set(ks)
    pool.v_scale = pool.v_scale.at[:, src].set(vs)
    pool.incref(src)                              # shared: CoW must copy
    dst = pool.copy_on_write(src)
    assert dst != src
    np.testing.assert_array_equal(np.asarray(pool.k[:, dst]),
                                  np.asarray(pool.k[:, src]))
    np.testing.assert_array_equal(np.asarray(pool.v[:, dst]),
                                  np.asarray(pool.v[:, src]))
    np.testing.assert_allclose(np.asarray(pool.k_scale[:, dst]),
                               np.full_like(
                                   np.asarray(pool.k_scale[:, dst]), ks))
    np.testing.assert_allclose(np.asarray(pool.v_scale[:, dst]),
                               np.asarray(pool.v_scale[:, src]))


@settings(max_examples=6, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_fork_scale_bookkeeping(seed):
    """Fork + first decode write on an int8 pool: the CoW triggered by
    prepare_decode carries the shared block's scales to the private copy
    and leaves the parent's block (values AND scales) untouched."""
    rng = random.Random(seed)
    pool = PagedKVPool(CFG, n_rows=4, max_len=6 * BS, block_size=BS,
                       n_blocks=12, kv_dtype="int8")
    n_tok = rng.randint(2 * BS + 1, 3 * BS - 1)   # 3 blocks, last partial
    toks = [rng.randint(0, 63) for _ in range(n_tok)]
    parent, _ = pool.admit(toks)
    pool._pos_np[parent] = n_tok
    shared = pool.tables[parent].blocks[-1]       # the partial tail block
    kq, ksc = rng.randint(-127, 127), rng.uniform(0.01, 2.0)
    pool.blocks.k = pool.blocks.k.at[:, shared].set(kq)
    pool.blocks.k_scale = pool.blocks.k_scale.at[:, shared].set(ksc)

    child = pool.fork(parent)
    _check_invariants(pool)
    pool.prepare_decode([child], [1])             # child writes -> CoW
    pool._pos_np[child] += 1
    _check_invariants(pool)
    priv = pool.tables[child].blocks[-1]
    assert priv != shared
    np.testing.assert_array_equal(
        np.asarray(pool.blocks.k[:, priv]),
        np.asarray(pool.blocks.k[:, shared]))
    np.testing.assert_allclose(
        np.asarray(pool.blocks.k_scale[:, priv]),
        np.asarray(pool.blocks.k_scale[:, shared]))
    # parent view untouched: still the original quantized bytes + scales
    np.testing.assert_array_equal(
        np.asarray(pool.blocks.k[:, shared]),
        np.full_like(np.asarray(pool.blocks.k[:, shared]), kq))
    np.testing.assert_allclose(
        np.asarray(pool.blocks.k_scale[:, shared]),
        np.full_like(np.asarray(pool.blocks.k_scale[:, shared]), ksc))
    pool.release(child)
    pool.release(parent)
    _check_invariants(pool)


@settings(max_examples=12, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_match_length_probe_agrees_with_admission(seed):
    """The fleet router's side-effect-free probe must predict admission
    truth: for ANY prompt against ANY cache state, ``admit`` reuses
    exactly ``min(match_length(p), (len(p)-1)//bs*bs)`` cached tokens
    (the cap keeps the last prompt token recomputed for first-token
    logits), and probing — once or many times — never changes what a
    subsequent admission sees.  A disagreement would mean prefix-aware
    routing sends requests to replicas that then can't deliver the
    predicted reuse."""
    rng = random.Random(seed)
    pool = PagedKVPool(CFG, n_rows=4, max_len=6 * BS, block_size=BS,
                       n_blocks=32)
    seen: list[list[int]] = []
    rows: list[int] = []
    for _ in range(16):
        if seen and rng.random() < 0.6:
            # extend / truncate a previously admitted prompt: shared
            # prefixes of every alignment, the case routing cares about
            base = rng.choice(seen)
            cut = rng.randint(0, len(base))
            toks = base[:cut] + [rng.randint(0, 2)
                                 for _ in range(rng.randint(1, 6))]
        else:
            toks = [rng.randint(0, 2)
                    for _ in range(rng.randint(1, pool.max_request_tokens))]
        toks = toks[:pool.max_request_tokens]

        ml = pool.prefix_match_length(toks)
        assert ml % BS == 0
        assert 0 <= ml <= len(toks) - len(toks) % BS
        assert pool.prefix_match_length(toks) == ml    # probe idempotent

        if len(rows) == pool.n_rows:                   # make room
            pool.release(rows.pop(0))
        try:
            row, n_cached = pool.admit(toks)
        except OutOfBlocks:
            _check_invariants(pool)
            continue
        expected = min(ml, (len(toks) - 1) // BS * BS) if ml else 0
        assert n_cached == expected, \
            f"probe said {ml}, admit reused {n_cached} of {len(toks)}"
        pool._pos_np[row] = len(toks)
        pool.register_prefix(row, toks)
        rows.append(row)
        seen.append(toks)
        # the probe itself must appear in stats as a probe, not a lookup
        _check_invariants(pool)
    st_ = pool.prefix_cache.stats()
    assert st_["probes"] >= 2 * len(seen)
