"""Chunked prefill through the token-budgeted step pipeline.

Load-bearing properties (ISSUE 4 acceptance):
  1. The chunked engine (token_budget smaller than the longest prompt) is
     TOKEN-IDENTICAL to the one-shot engine and to the legacy lock-step
     loop — dense and 8:16+outlier compressed weights, slot and paged KV
     layouts, prefix-cache hits and preemption/resume included, and on a
     1x8 mesh.  Chunking is a scheduling change, never a numerics change.
  2. The token budget is a hard per-step bound: no step's prefill work
     exceeds it, in-flight cursors advance before new admissions, and the
     FIFO queue head is never skipped (no starvation of long prompts).
  3. Preempted requests resume from the last fully-written block (their
     blocks are published to the prefix cache at preemption), not by
     recomputing prompt + generated from scratch.

Uses ``hypothesis`` when installed, else the deterministic fallback sweep
(tests/hypothesis_fallback.py) for the scheduler property walk.
"""
import dataclasses
import random

import jax
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                               # pragma: no cover
    from hypothesis_fallback import given, settings, st

from repro import configs
from repro.core import SparsifyConfig
from repro.models import get_model
from repro.serving import SamplingParams, ServingEngine, Status
from repro.serving.scheduler import (CHUNK_QUANTUM, plan_chunks,
                                     resolve_token_budget)

CFG = dataclasses.replace(configs.get_smoke("llama-paper"),
                          name="chunked-test", n_layers=2, d_model=128,
                          n_heads=4, n_kv_heads=4, head_dim=32, d_ff=256,
                          vocab=512, remat=False)
GEN = 6
BS = 8                                     # paged block size


@pytest.fixture(scope="module")
def dense_params():
    return get_model(CFG).init(jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def sparse_params(dense_params):
    from repro.models.sparse_serving import sparsify_for_serving
    scfg = SparsifyConfig(weight_pattern="8:16", outlier_pattern="16:256",
                          scorer="magnitude", use_smoothquant=False)
    sp, report = sparsify_for_serving(dense_params, scfg)
    assert report["n_layers_sparsified"] > 0
    return sp


def _prompts(n, length, seed=1):
    key = jax.random.PRNGKey(seed)
    return [t.tolist() for t in
            jax.random.randint(key, (n, length), 0, CFG.vocab)]


def _run_budgeted(params, prompts, gen, **kw):
    """Run an engine to drain, asserting the per-step budget bound."""
    engine = ServingEngine(CFG, params, **kw)
    reqs = [engine.submit(p, SamplingParams(max_new_tokens=gen))
            for p in prompts]
    while engine.has_work:
        stats = engine.step()
        assert stats["prefill_tokens"] <= engine.token_budget
    assert all(r.status is Status.FINISHED for r in reqs)
    return engine, reqs


def _solo(params, prompt, gen):
    _, (r,) = _run_budgeted(params, [prompt], gen, n_slots=1, max_len=64)
    return r.tokens


# --------------------------------------------------------------------------
# token identity: chunked == one-shot, all weight/layout combinations
# --------------------------------------------------------------------------

@pytest.mark.parametrize("kv_layout", ["slot", "paged"])
@pytest.mark.parametrize("which", ["dense", "sparse"])
def test_chunked_token_identical_to_oneshot(which, kv_layout, dense_params,
                                            sparse_params):
    params = dense_params if which == "dense" else sparse_params
    prompts = _prompts(4, 24)
    # one-shot: budget covers any prompt whole
    _, ref = _run_budgeted(params, prompts, GEN, n_slots=4, max_len=40,
                           kv_layout=kv_layout, block_size=BS,
                           token_budget=4 * 40)
    # chunked: a 24-token prompt takes 3 chunks of 8
    engine, reqs = _run_budgeted(params, prompts, GEN, n_slots=4, max_len=40,
                                 kv_layout=kv_layout, block_size=BS,
                                 token_budget=8)
    for i, (a, b) in enumerate(zip(reqs, ref)):
        assert a.tokens == b.tokens, f"request {i} diverged under chunking"
    assert all(r.metrics.prefill_chunks >= 3 for r in reqs)
    assert all(r.metrics.ttft >= 0 for r in reqs)


def test_chunked_mixed_arrivals_decode_keeps_flowing(dense_params):
    """A long prompt lands while short requests decode: the prompt takes
    several steps (budget-bounded) and the short requests emit a token on
    every one of those steps — the anti-stall property chunking buys."""
    shorts = _prompts(2, 8, seed=3)
    long_p = _prompts(1, 32, seed=4)[0]
    engine = ServingEngine(CFG, dense_params, n_slots=4, max_len=48,
                           token_budget=16)
    short_reqs = [engine.submit(p, SamplingParams(max_new_tokens=12))
                  for p in shorts]
    engine.step()                          # both shorts (8+8) + first tokens
    assert all(r.status is Status.RUNNING for r in short_reqs)
    long_req = engine.submit(long_p, SamplingParams(max_new_tokens=4))
    emitted_during_long_prefill = []
    while long_req.status in (Status.QUEUED, Status.PREFILLING):
        before = [len(r.tokens) for r in short_reqs]
        stats = engine.step()
        assert stats["prefill_tokens"] <= 16
        emitted_during_long_prefill.append(
            [len(r.tokens) - b for r, b in zip(short_reqs, before)])
    # the 32-token prompt needed 2 budgeted steps, and every one of them
    # also advanced the decoding shorts (no monopolized step)
    assert long_req.metrics.prefill_chunks == 2
    assert all(all(d == 1 for d in step_d)
               for step_d in emitted_during_long_prefill)
    engine.run()
    assert long_req.tokens == _solo(dense_params, long_p, 4)
    for p, r in zip(shorts, short_reqs):
        assert r.tokens == _solo(dense_params, p, 12)


def test_chunked_prefix_cache_hits_token_identical(dense_params):
    """Chunked prefill composes with prefix-cache hits: the cursor starts
    at the cached block boundary and chunks cover only the remainder."""
    sys_prompt = _prompts(1, 3 * BS, seed=5)[0]
    tails = _prompts(3, 6, seed=6)
    engine = ServingEngine(CFG, dense_params, n_slots=4, max_len=64,
                           kv_layout="paged", block_size=BS, token_budget=8)
    reqs = []
    for tail in tails:                    # sequential so the cache is warm
        reqs.append(engine.submit(sys_prompt + tail,
                                  SamplingParams(max_new_tokens=GEN)))
        engine.run()
    stats = engine.pool.prefix_cache.stats()
    assert stats["hit_tokens"] >= 2 * 2 * BS
    # cache-hit requests prefilled fewer chunks than the cold one
    assert reqs[1].metrics.prefill_chunks < reqs[0].metrics.prefill_chunks
    for tail, r in zip(tails, reqs):
        assert r.tokens == _solo(dense_params, sys_prompt + tail, GEN)


# --------------------------------------------------------------------------
# preemption: cursor resume from the last fully-written block
# --------------------------------------------------------------------------

def test_preemption_resumes_from_cached_blocks(dense_params):
    """Regression (ISSUE 4 satellite): preempted requests used to
    re-prefill prompt + generated from scratch.  Now their fully-written
    blocks are published to the prefix cache at preemption and the resume
    matches them — distinct prompts mean any cache hit can only come from
    a resume.  Token streams are preserved exactly."""
    prompts = _prompts(4, 16, seed=9)
    engine, reqs = _run_budgeted(dense_params, prompts, 12, n_slots=4,
                                 max_len=40, kv_layout="paged",
                                 block_size=BS, n_blocks=10, token_budget=16)
    assert engine.n_preemptions > 0
    assert any(r.n_preempted > 0 for r in reqs)
    assert engine.pool.prefix_cache.stats()["hit_tokens"] > 0, \
        "resume did not reuse the preempted request's written blocks"
    for p, r in zip(prompts, reqs):
        assert r.tokens == _solo(dense_params, p, 12)


def test_preemption_without_cache_still_identical(dense_params):
    """With prefix caching off the resume recomputes through the chunked
    path — slower, but the streams must still match exactly."""
    prompts = _prompts(4, 16, seed=9)
    engine, reqs = _run_budgeted(dense_params, prompts, 12, n_slots=4,
                                 max_len=40, kv_layout="paged",
                                 block_size=BS, n_blocks=10,
                                 prefix_caching=False, token_budget=16)
    assert engine.n_preemptions > 0
    for p, r in zip(prompts, reqs):
        assert r.tokens == _solo(dense_params, p, 12)


def test_preemption_of_validated_chunk_same_step(dense_params):
    """Regression: with two mid-prefill prompts and nothing decoding, the
    younger one's block-capacity loop preempts the older AFTER it was
    already validated into this step's chunk plan — the stale entry (slot
    None, cursor reset) must be dropped, not run (it used to crash the
    step loop with a TypeError in the paged write path)."""
    engine = ServingEngine(CFG, dense_params, n_slots=4, max_len=80,
                           kv_layout="paged", block_size=BS, n_blocks=9,
                           token_budget=24, prefix_caching=False)
    short = engine.submit([1, 2, 3], SamplingParams(max_new_tokens=5))
    engine.step()
    longs = _prompts(2, 64, seed=11)
    reqs = [engine.submit(p, SamplingParams(max_new_tokens=4))
            for p in longs]
    engine.run(max_steps=300)
    assert engine.n_preemptions > 0
    assert short.status is Status.FINISHED
    assert all(r.status is Status.FINISHED for r in reqs)
    for p, r in zip(longs, reqs):
        solo = ServingEngine(CFG, dense_params, n_slots=1, max_len=80,
                             kv_layout="paged", block_size=BS)
        s = solo.submit(p, SamplingParams(max_new_tokens=4))
        solo.run()
        assert r.tokens == s.tokens


# --------------------------------------------------------------------------
# scheduler policy: budget accounting, FIFO, no starvation
# --------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_plan_chunks_invariants(seed):
    """Random walk over the token-budget planner: per-call bounds, and a
    multi-round simulation in which every request — long prompts included
    — finishes its prefill (starvation-freedom)."""
    rng = random.Random(seed)
    Q = CHUNK_QUANTUM
    budget = rng.choice([Q, 2 * Q, 3 * Q, 8 * Q])
    n_rows = rng.randint(1, 4)
    queued = [(i, rng.randint(1, 12 * Q)) for i in range(rng.randint(1, 10))]
    in_flight: list[list] = []             # [key, remaining], admission order
    admitted_order: list[int] = []
    rounds = 0
    while queued or in_flight:
        rounds += 1
        assert rounds < 400, "scheduler starved a request"

        def try_admit(key, chunk):
            if len(in_flight) >= n_rows:          # no free row
                return None
            assert queued and queued[0][0] == key, "queue head skipped"
            _, n = queued.pop(0)
            in_flight.append([key, n])
            admitted_order.append(key)
            return n

        plan = plan_chunks([(k, rem) for k, rem in in_flight], list(queued),
                           budget, Q, try_admit)
        assert sum(t for _, t in plan) <= budget, "budget exceeded"
        seen = [k for k, _ in plan]
        assert len(seen) == len(set(seen)), "request chunked twice in a step"
        for key, take in plan:
            entry = next(e for e in in_flight if e[0] == key)
            assert 0 < take <= entry[1]
            if take < entry[1]:
                assert take % Q == 0, "mid-sequence chunk not quantized"
            entry[1] -= take
        done = [e for e in in_flight if e[1] == 0]
        # completed prefills leave their rows (decode is out of scope here)
        in_flight = [e for e in in_flight if e[1] > 0]
        if not plan and not done and len(in_flight) >= n_rows:
            # every row is mid-prefill but the budget is below the quantum
            # head-of-line requirement — impossible: budget >= Q always
            # lets the oldest in-flight advance
            raise AssertionError("no progress")
    assert admitted_order == sorted(admitted_order), "admission broke FIFO"


def test_resolve_token_budget_alias_and_floor():
    import repro.serving.scheduler as sched
    assert resolve_token_budget(64, None, 256) == 64
    assert resolve_token_budget(None, None, 256) == 512
    sched._budget_alias_warned = False
    with pytest.warns(DeprecationWarning):
        assert resolve_token_budget(None, 3, 100) == 300
    # one-time warning: a second resolution stays silent
    import warnings as w
    with w.catch_warnings():
        w.simplefilter("error")
        assert resolve_token_budget(None, 2, 100) == 200
    with pytest.raises(ValueError, match="token_budget"):
        resolve_token_budget(CHUNK_QUANTUM - 1, None, 256)


def test_deprecated_max_prefill_per_step_engine_alias(dense_params):
    import repro.serving.scheduler as sched
    sched._budget_alias_warned = False
    with pytest.warns(DeprecationWarning, match="max_prefill_per_step"):
        engine = ServingEngine(CFG, dense_params, n_slots=2, max_len=32,
                               max_prefill_per_step=2)
    assert engine.token_budget == 2 * 32
    r = engine.submit(_prompts(1, 8)[0], SamplingParams(max_new_tokens=3))
    engine.run()
    assert r.tokens == _solo(dense_params, list(r.prompt), 3)


# --------------------------------------------------------------------------
# mesh parity: chunked 1x8 == one-shot single-device
# --------------------------------------------------------------------------

needs8 = pytest.mark.skipif(
    len(jax.devices()) < 8,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=8")

# 8 KV heads so arenas/projections divide the 8-wide model axis
MESH_CFG = dataclasses.replace(CFG, name="chunked-mesh-test", n_heads=8,
                               n_kv_heads=8, head_dim=16)


@needs8
@pytest.mark.parametrize("kv_layout", ["slot", "paged"])
@pytest.mark.parametrize("which", ["dense", "sparse"])
def test_mesh_chunked_token_identical(which, kv_layout):
    params = get_model(MESH_CFG).init(jax.random.PRNGKey(0))
    if which == "sparse":
        from repro.models.sparse_serving import sparsify_for_serving
        scfg = SparsifyConfig(weight_pattern="8:16", outlier_pattern="16:256",
                              scorer="magnitude", use_smoothquant=False)
        params, _ = sparsify_for_serving(params, scfg)
    prompts = [t.tolist() for t in
               jax.random.randint(jax.random.PRNGKey(2), (3, 24), 0,
                                  MESH_CFG.vocab)]

    def run(mesh, token_budget):
        engine = ServingEngine(MESH_CFG, params, n_slots=4, max_len=40,
                               kv_layout=kv_layout, block_size=BS,
                               token_budget=token_budget, mesh=mesh)
        reqs = [engine.submit(p, SamplingParams(max_new_tokens=GEN))
                for p in prompts]
        engine.run()
        assert all(r.status is Status.FINISHED for r in reqs)
        return [r.tokens for r in reqs]

    mesh = jax.make_mesh((1, 8), ("data", "model"))
    ref = run(None, 4 * 40)                 # single-device, one-shot
    assert run(mesh, 8) == ref              # sharded, 3 chunks per prompt
