"""Quantized int8 KV arenas + int8 N:M kernel path (ISSUE 9).

Load-bearing properties:
  1. ``kv_dtype="int8"`` engines (slot and paged, dense and 8:16+outlier
     compressed weights) generate greedy streams whose divergence from
     the bf16 reference is bounded — quantization is a numerics knob,
     never a correctness break (requests finish, streams are full
     length).
  2. Quantized arenas are EXACT under every lifecycle path that re-reads
     written KV: prefix-cache hits, preemption/resume, speculative
     verify-rollback, and a 1x8 tensor-parallel mesh each reproduce the
     cold int8 engine's streams token for token (the stored int8 bytes +
     scales are the sequence's KV; re-reading them cannot drift).
  3. The compiled int8 step accesses FEWER bytes than the bf16 step at
     identical shapes: the online-softmax dequant fuses into attention,
     so no bf16 copy of the arena ever materializes in HBM (tentpole
     cost pin, same method as the cursor-independence test of ISSUE 5).
  4. Pool stats bill the arena honestly: values + scales, dtype
     labelled, on SlotKVPool.stats / BlockPool.occupancy /
     engine.stats()["pool"] (satellite).
  5. The fused int8 weight kernels (nm_spmm / fused_sparse_linear with a
     scale operand) match the portable dequantizing reference, and the
     int8 pallas path accesses fewer bytes than the bf16 one — the
     pre-kernel densify is structurally gone.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.core import SparsifyConfig
from repro.launch.hlo_analysis import cost_summary
from repro.models import get_model
from repro.serving import (SamplingParams, ServingEngine, SpeculativeConfig,
                           Status)
from repro.serving.cache_pool import SlotKVPool, quantize_kv
from repro.serving.paged import BlockPool, PagedKVPool

CFG = dataclasses.replace(configs.get_smoke("llama-paper"),
                          name="kv-quant-test", n_layers=2, d_model=128,
                          n_heads=4, n_kv_heads=4, head_dim=32, d_ff=256,
                          vocab=512, remat=False)
GEN = 8
BS = 8                                     # paged block size


@pytest.fixture(scope="module")
def dense_params():
    return get_model(CFG).init(jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def sparse_params(dense_params):
    from repro.models.sparse_serving import sparsify_for_serving
    scfg = SparsifyConfig(weight_pattern="8:16", outlier_pattern="16:256",
                          scorer="magnitude", use_smoothquant=False)
    sp, report = sparsify_for_serving(dense_params, scfg)
    assert report["n_layers_sparsified"] > 0
    return sp


def _prompts(n, length, seed=1):
    key = jax.random.PRNGKey(seed)
    return [t.tolist() for t in
            jax.random.randint(key, (n, length), 0, CFG.vocab)]


def _run(params, prompts, gen=GEN, **kw):
    engine = ServingEngine(CFG, params, **kw)
    reqs = [engine.submit(p, SamplingParams(max_new_tokens=gen))
            for p in prompts]
    engine.run()
    assert all(r.status is Status.FINISHED for r in reqs)
    return engine, [r.tokens for r in reqs]


def _agreement(ref, got):
    matched = sum(sum(a == b for a, b in zip(r, g))
                  for r, g in zip(ref, got))
    total = sum(len(r) for r in ref)
    return matched / total


# --------------------------------------------------------------------------
# quantize_kv unit properties
# --------------------------------------------------------------------------

def test_quantize_kv_roundtrip():
    x = jax.random.normal(jax.random.PRNGKey(7), (3, 5, 4, 32),
                          jnp.float32) * 3.0
    q, s = quantize_kv(x)
    assert q.dtype == jnp.int8 and s.shape == x.shape[:-1]
    deq = q.astype(jnp.float32) * s[..., None]
    # absmax symmetric quant: error <= scale/2 = absmax/254 per element
    bound = jnp.max(jnp.abs(x), axis=-1, keepdims=True) / 254.0 + 1e-7
    assert bool(jnp.all(jnp.abs(deq - x) <= bound))


def test_quantize_kv_zero_rows_safe():
    x = jnp.zeros((2, 3, 2, 16), jnp.float32)
    q, s = quantize_kv(x)
    assert bool(jnp.all(q == 0)) and bool(jnp.all(s == 1.0))
    assert bool(jnp.all(jnp.isfinite(s)))


# --------------------------------------------------------------------------
# 1. bounded greedy divergence, dense/sparse x slot/paged
# --------------------------------------------------------------------------

@pytest.mark.parametrize("kv_layout", ["slot", "paged"])
@pytest.mark.parametrize("which", ["dense", "sparse"])
def test_int8_greedy_divergence_bounded(which, kv_layout, dense_params,
                                        sparse_params):
    params = dense_params if which == "dense" else sparse_params
    prompts = _prompts(4, 12)
    kw = dict(n_slots=4, max_len=48, kv_layout=kv_layout, block_size=BS)
    _, ref = _run(params, prompts, **kw, kv_dtype="bf16")
    _, got = _run(params, prompts, **kw, kv_dtype="int8")
    assert all(len(g) == len(r) for g, r in zip(got, ref))
    agree = _agreement(ref, got)
    assert agree >= 0.6, \
        f"int8 KV diverged too far from bf16: agreement {agree:.2f}"


# --------------------------------------------------------------------------
# 2. exactness under KV-re-reading lifecycle paths
# --------------------------------------------------------------------------

def test_int8_prefix_cache_hits_token_identical(dense_params):
    """A prefix-cache hit reuses the stored int8 blocks + scales instead
    of re-prefilling; since the stored bytes ARE the sequence's KV, the
    hit path must match the cold int8 path exactly."""
    sys_prompt = _prompts(1, 3 * BS, seed=5)[0]
    tails = _prompts(3, 6, seed=6)
    engine = ServingEngine(CFG, dense_params, n_slots=4, max_len=64,
                           kv_layout="paged", block_size=BS,
                           kv_dtype="int8")
    reqs = []
    for tail in tails:                    # sequential so the cache is warm
        reqs.append(engine.submit(sys_prompt + tail,
                                  SamplingParams(max_new_tokens=GEN)))
        engine.run()
    assert engine.pool.prefix_cache.stats()["hit_tokens"] >= 2 * 2 * BS
    for tail, r in zip(tails, reqs):
        _, (solo,) = _run(dense_params, [sys_prompt + tail], n_slots=1,
                          max_len=64, kv_layout="paged", block_size=BS,
                          kv_dtype="int8")
        assert r.tokens == solo, "prefix hit diverged under int8 KV"


def test_int8_preemption_resume_token_identical(dense_params):
    """Preempt/resume re-prefills from the prefix cache + deterministic
    requantization of the same fresh KV — identical int8 bytes, identical
    streams."""
    prompts = _prompts(4, 16, seed=9)
    engine = ServingEngine(CFG, dense_params, n_slots=4, max_len=40,
                           kv_layout="paged", block_size=BS, n_blocks=10,
                           token_budget=16, kv_dtype="int8")
    reqs = [engine.submit(p, SamplingParams(max_new_tokens=12))
            for p in prompts]
    engine.run()
    assert all(r.status is Status.FINISHED for r in reqs)
    assert engine.n_preemptions > 0, "scenario must actually preempt"
    for p, r in zip(prompts, reqs):
        _, (solo,) = _run(dense_params, [p], gen=12, n_slots=1, max_len=40,
                          kv_layout="paged", block_size=BS,
                          kv_dtype="int8")
        assert r.tokens == solo, "preempt/resume diverged under int8 KV"


def test_int8_speculative_rollback_token_identical(dense_params,
                                                   sparse_params):
    """Verify-rollback under int8: rejected draft positions are hidden by
    the cursor and overwritten by the next deterministic requantized
    write, so the speculative engine (draft arena int8 too) is
    token-identical to the non-speculative int8 engine."""
    prompts = _prompts(4, 12, seed=11)
    kw = dict(n_slots=4, max_len=48, kv_layout="paged", block_size=BS,
              kv_dtype="int8")
    _, ref = _run(dense_params, prompts, gen=10, **kw)
    draft = SpeculativeConfig(k=3, method="model", params=sparse_params,
                              cfg=CFG)
    engine, got = _run(dense_params, prompts, gen=10, **kw, draft=draft)
    assert engine.spec.drafter.adapter.pool.kv_dtype == "int8"
    assert engine.n_drafted > 0
    assert got == ref, "speculative int8 engine diverged from baseline"


# --------------------------------------------------------------------------
# 3. tentpole cost pin: no bf16 arena materialization
# --------------------------------------------------------------------------

def test_int8_step_accesses_fewer_bytes_than_bf16(dense_params):
    """Arena-dominant shapes: the compiled int8 chunk step must touch
    FEWER HBM bytes than the bf16 step — the dequant fuses into the
    attention upcast.  A materialized bf16 copy of the arena would make
    the int8 step's bytes a superset of bf16's and fail this
    directionally."""
    costs = {}
    B, S, ML = 4, 16, 512                 # arena >> activations
    tokens = jnp.zeros((B, S), jnp.int32)
    n_new = jnp.full((B,), S, jnp.int32)
    cur = jnp.zeros((B,), jnp.int32)
    for dtype in ("bf16", "int8"):
        engine = ServingEngine(CFG, dense_params, n_slots=4, max_len=ML,
                               kv_dtype=dtype, token_budget=16)
        lanes = jnp.asarray(engine.pool.lane_rows([0, 1, 2, 3], B))
        p = engine.pool
        arenas = ((p.k, p.v) if dtype == "bf16"
                  else (p.k, p.v, p.k_scale, p.v_scale))
        lowered = engine._step_fn.lower(engine.params, *arenas, lanes, cur,
                                        n_new, tokens)
        costs[dtype] = cost_summary(lowered.compile())["bytes_accessed"]
    assert costs["int8"] < costs["bf16"], (
        f"int8 step accessed {costs['int8']} bytes >= bf16's "
        f"{costs['bf16']}: a dense arena copy is materializing")


# --------------------------------------------------------------------------
# 4. satellite: stats bill values + scales with dtype labels
# --------------------------------------------------------------------------

def test_slot_pool_stats_bytes():
    L, KV, hd = CFG.n_layers, CFG.n_kv_heads, CFG.head_dim
    ns, ml = 4, 64
    val_elems = L * ns * ml * KV * hd
    sc_elems = L * ns * ml * KV
    bf = SlotKVPool(CFG, n_slots=ns, max_len=ml)
    q = SlotKVPool(CFG, n_slots=ns, max_len=ml, kv_dtype="int8")
    sb, sq = bf.stats(), q.stats()
    assert sb["kv_dtype"] == "bf16" and sq["kv_dtype"] == "int8"
    assert sb["scale_bytes"] == 0
    assert sb["arena_bytes"] == 2 * val_elems * 2          # k+v, bf16
    assert sq["scale_bytes"] == 2 * sc_elems * 4           # k+v, f32
    assert sq["arena_bytes"] == 2 * val_elems + sq["scale_bytes"]
    assert sq["arena_bytes"] < sb["arena_bytes"]


def test_block_pool_occupancy_bytes():
    L, KV, hd = CFG.n_layers, CFG.n_kv_heads, CFG.head_dim
    nb = 8
    val_elems = L * nb * BS * KV * hd
    sc_elems = L * nb * BS * KV
    bf = BlockPool(CFG, n_blocks=nb, block_size=BS)
    q = BlockPool(CFG, n_blocks=nb, block_size=BS, kv_dtype="int8")
    ob, oq = bf.occupancy(), q.occupancy()
    assert ob["kv_dtype"] == "bf16" and oq["kv_dtype"] == "int8"
    assert ob["scale_bytes"] == 0
    assert ob["arena_bytes"] == 2 * val_elems * 2
    assert oq["scale_bytes"] == 2 * sc_elems * 4
    assert oq["arena_bytes"] == 2 * val_elems + oq["scale_bytes"]


@pytest.mark.parametrize("kv_layout", ["slot", "paged"])
def test_engine_stats_surface_arena_bytes(kv_layout, dense_params):
    engine = ServingEngine(CFG, dense_params, n_slots=2, max_len=32,
                           kv_layout=kv_layout, block_size=BS,
                           kv_dtype="int8")
    st = engine.stats()
    assert st["kv_dtype"] == "int8"
    pool = st["pool"]
    assert pool["kv_dtype"] == "int8"
    assert pool["arena_bytes"] > 0
    assert pool["scale_bytes"] > 0
    assert pool["scale_bytes"] < pool["arena_bytes"]


# --------------------------------------------------------------------------
# 5. int8 weight kernels: parity with the portable path, no densify
# --------------------------------------------------------------------------

@pytest.mark.parametrize("outliers", [None, "16:256"])
def test_int8_kernel_matches_portable(outliers):
    from repro.models.sparse_serving import (_to_sparse_weight,
                                             sparse_apply,
                                             sparse_apply_pallas)
    w = jax.random.normal(jax.random.PRNGKey(0), (128, 512)) * 0.05
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 512), jnp.float32)
    cfg = SparsifyConfig(scorer="magnitude", use_smoothquant=False,
                         outlier_pattern=outliers)
    sw = _to_sparse_weight(w, cfg, quantize=True)
    assert sw.nm_values.dtype == jnp.int8 and sw.v_scale is not None
    assert (sw.o_values is None) == (outliers is None)
    y_ref = sparse_apply(sw, x)           # portable: dequant then matmul
    y_pal = sparse_apply_pallas(sw, x)    # fused: dequant in-register
    np.testing.assert_allclose(np.asarray(y_pal, np.float32),
                               np.asarray(y_ref, np.float32),
                               rtol=2e-5, atol=2e-4)


def test_int8_kernel_accesses_fewer_bytes_than_bf16():
    """The acceptance pin for deleting the pre-kernel densify: the
    compiled int8 apply must read FEWER bytes than the bf16 apply (int8
    values are half the bytes; a pre-kernel dequantize-to-bf16 would
    read at least as many)."""
    from repro.models.sparse_serving import (_to_sparse_weight,
                                             sparse_apply_pallas)
    w = jax.random.normal(jax.random.PRNGKey(2), (256, 512)) * 0.05
    x = jax.random.normal(jax.random.PRNGKey(3), (8, 512), jnp.bfloat16)
    cfg = SparsifyConfig(scorer="magnitude", use_smoothquant=False)
    costs = {}
    for quant in (False, True):
        sw = _to_sparse_weight(w, cfg, quantize=quant)
        compiled = jax.jit(
            lambda xx, sw=sw: sparse_apply_pallas(sw, xx)).lower(x).compile()
        costs[quant] = cost_summary(compiled)["bytes_accessed"]
    assert costs[True] < costs[False], (
        f"int8 apply accessed {costs[True]} bytes >= bf16's "
        f"{costs[False]}: values are being densified before the kernel")


# --------------------------------------------------------------------------
# 6. mesh: int8 arenas + co-sharded scales under tensor parallelism
# --------------------------------------------------------------------------

needs8 = pytest.mark.skipif(
    len(jax.devices()) < 8,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=8")

MESH_CFG = dataclasses.replace(CFG, name="kv-quant-mesh-test", n_heads=8,
                               n_kv_heads=8, head_dim=16)


@needs8
@pytest.mark.parametrize("kv_layout", ["slot", "paged"])
def test_mesh_int8_token_identical(kv_layout):
    """Sharded int8 engine == single-device int8 engine, token for token:
    the scale arenas co-shard with the KV-head dim so the dequant is
    local to each shard."""
    params = get_model(MESH_CFG).init(jax.random.PRNGKey(0))
    prompts = [t.tolist() for t in
               jax.random.randint(jax.random.PRNGKey(2), (3, 12), 0,
                                  MESH_CFG.vocab)]

    def run(mesh):
        engine = ServingEngine(MESH_CFG, params, n_slots=4, max_len=48,
                               kv_layout=kv_layout, block_size=BS,
                               kv_dtype="int8", mesh=mesh)
        reqs = [engine.submit(p, SamplingParams(max_new_tokens=5))
                for p in prompts]
        engine.run()
        assert all(r.status is Status.FINISHED for r in reqs)
        return [r.tokens for r in reqs]

    mesh = jax.make_mesh((1, 8), ("data", "model"))
    assert run(mesh) == run(None)
