"""Pallas kernel validation: shape/dtype sweeps vs the pure-jnp oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (ActStats, SparsifyConfig, sparsify_linear,
                        dense_effective_weight, pack_nm, nm_mask)
from repro.kernels import ref
from repro.kernels.nm_spmm import nm_spmm
from repro.kernels.outlier_spmm import (outlier_spmm, pack_outlier_meta,
                                        unpack_outlier_meta)
from repro.kernels.fused_sparse_linear import fused_sparse_linear
from repro.kernels import ops


def _packed(key, out, kdim, n, m, dtype):
    w = jax.random.normal(key, (out, kdim), jnp.float32).astype(dtype)
    mask = nm_mask(jnp.abs(w.astype(jnp.float32)), (n, m))
    return pack_nm(jnp.where(mask, w, 0), mask, (n, m))


TOL = {jnp.float32: dict(rtol=2e-5, atol=2e-4),
       jnp.bfloat16: dict(rtol=2e-2, atol=2e-1)}


class TestNmSpmm:
    @pytest.mark.parametrize("n,m", [(2, 4), (4, 8), (8, 16)])
    @pytest.mark.parametrize("b,out,kdim", [(8, 64, 256), (32, 128, 512),
                                            (128, 256, 1024)])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_vs_ref(self, n, m, b, out, kdim, dtype):
        pk = _packed(jax.random.PRNGKey(0), out, kdim, n, m, dtype)
        x = jax.random.normal(jax.random.PRNGKey(1), (b, kdim)).astype(dtype)
        y_ref = ref.nm_spmm_ref(x, pk.values, pk.indices, m)
        y = nm_spmm(x, pk.values, pk.packed_metadata(), n=n, m=m,
                    block_b=64, block_o=64, block_k=256)
        np.testing.assert_allclose(np.asarray(y, np.float32),
                                   np.asarray(y_ref, np.float32), **TOL[dtype])

    def test_vs_dense_matmul(self):
        """Compressed matmul == dense matmul with the pruned matrix."""
        pk = _packed(jax.random.PRNGKey(2), 64, 512, 8, 16, jnp.float32)
        x = jax.random.normal(jax.random.PRNGKey(3), (16, 512))
        y_dense = x @ pk.to_dense().T
        y = nm_spmm(x, pk.values, pk.packed_metadata(), n=8, m=16,
                    block_b=16, block_o=64, block_k=256)
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_dense),
                                   rtol=2e-5, atol=2e-4)


class TestOutlierSpmm:
    @pytest.mark.parametrize("o_n", [4, 8, 16])
    @pytest.mark.parametrize("b,out,kdim", [(8, 64, 256), (16, 128, 512)])
    def test_vs_ref(self, o_n, b, out, kdim):
        w = jax.random.normal(jax.random.PRNGKey(0), (out, kdim))
        x = jax.random.normal(jax.random.PRNGKey(1), (b, kdim))
        st = ActStats.init(kdim).update(x)
        cfg = SparsifyConfig(weight_pattern="8:16", outlier_pattern=f"{o_n}:256")
        sl = sparsify_linear(w, st, cfg)
        y_ref = ref.outlier_spmm_ref(x, sl.outliers.values, sl.outliers.indices)
        y = outlier_spmm(x, sl.outliers.values,
                         pack_outlier_meta(sl.outliers.indices), n=o_n,
                         block_b=8, block_o=64, block_k=256)
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                                   rtol=2e-5, atol=2e-4)

    def test_meta_roundtrip(self):
        idx = jax.random.randint(jax.random.PRNGKey(0), (4, 2, 16), 0, 256)
        idx = jnp.sort(idx, axis=-1)
        packed = pack_outlier_meta(idx)
        assert packed.shape == (4, 2, 4)
        np.testing.assert_array_equal(np.asarray(unpack_outlier_meta(packed, 16)),
                                      np.asarray(idx))


class TestFused:
    @pytest.mark.parametrize("n,m,o_n", [(2, 4, 4), (8, 16, 16), (4, 8, 8)])
    def test_fused_equals_dense_effective(self, n, m, o_n):
        """Fused kernel output == x @ (deployed dense-effective weight)^T."""
        w = jax.random.normal(jax.random.PRNGKey(0), (128, 512))
        x = jax.random.normal(jax.random.PRNGKey(1), (16, 512))
        st = ActStats.init(512).update(x)
        cfg = SparsifyConfig(weight_pattern=f"{n}:{m}",
                             outlier_pattern=f"{o_n}:256")
        sl = sparsify_linear(w, st, cfg)
        eff = dense_effective_weight(w, sl, cfg)
        y_dense = x @ eff.T
        y = fused_sparse_linear(x, sl.nm.values, sl.nm.packed_metadata(),
                                sl.outliers.values,
                                pack_outlier_meta(sl.outliers.indices),
                                n=n, m=m, o_n=o_n,
                                block_b=16, block_o=64, block_k=256)
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_dense),
                                   rtol=2e-5, atol=5e-4)

    def test_ops_backends_agree(self):
        w = jax.random.normal(jax.random.PRNGKey(0), (64, 512))
        x = jax.random.normal(jax.random.PRNGKey(1), (8, 512))
        st = ActStats.init(512).update(x)
        sl = sparsify_linear(w, st, SparsifyConfig())
        y_ref = ops.sparse_linear_apply(x, sl.nm, sl.outliers, backend="reference")
        y_pl = ops.sparse_linear_apply(x, sl.nm, sl.outliers, backend="pallas")
        np.testing.assert_allclose(np.asarray(y_pl), np.asarray(y_ref),
                                   rtol=2e-5, atol=5e-4)


class TestSparseServing:
    def test_sparse_weight_matches_dense_effective(self):
        from repro.models.sparse_serving import (_to_sparse_weight,
                                                 sparse_apply,
                                                 sparse_apply_pallas)
        w = jax.random.normal(jax.random.PRNGKey(0), (128, 512))
        x = jax.random.normal(jax.random.PRNGKey(1), (4, 512))
        cfg = SparsifyConfig(scorer="magnitude", use_smoothquant=False)
        sw = _to_sparse_weight(w, cfg)
        sl = sparsify_linear(w, None, cfg)
        eff = dense_effective_weight(w, sl, cfg)
        y_dense = x @ eff.T
        np.testing.assert_allclose(np.asarray(sparse_apply(sw, x)),
                                   np.asarray(y_dense), rtol=2e-5, atol=5e-4)
        np.testing.assert_allclose(np.asarray(sparse_apply_pallas(sw, x)),
                                   np.asarray(y_dense), rtol=2e-5, atol=5e-4)

    def test_deployed_bytes_ratio(self):
        from repro.models.sparse_serving import _to_sparse_weight
        w = jax.random.normal(jax.random.PRNGKey(0), (512, 1024), jnp.bfloat16)
        cfg = SparsifyConfig(scorer="magnitude", use_smoothquant=False)
        sw = _to_sparse_weight(w, cfg)
        ratio = sw.deployed_bytes() / (w.size * 2)
        # 8:16 values (1.0 B/e) + 4-bit packed idx (0.25) + 16:256 outliers
        # (0.125 + 0.0625) = 1.4375 B/e vs dense 2 B/e  => 0.719
        # (the paper's 0.875 BITS/e figure assumes enumerative silicon
        #  decoding; the software TPU layout spends 2 bits/e on 4-bit idx)
        assert ratio == pytest.approx(0.71875, abs=1e-3)


class TestQuantizedSparse:
    """Beyond-paper: int8 N:M values + exact bf16 outliers."""

    def test_int8_accuracy_and_bytes(self):
        from repro.models.sparse_serving import _to_sparse_weight, sparse_apply
        w = jax.random.normal(jax.random.PRNGKey(0), (128, 512)) * 0.05
        x = jax.random.normal(jax.random.PRNGKey(1), (4, 512))
        cfg = SparsifyConfig(scorer="magnitude", use_smoothquant=False)
        sl = sparsify_linear(w, None, cfg)
        y_ref = x @ dense_effective_weight(w, sl, cfg).T
        sw_bf = _to_sparse_weight(w, cfg)
        sw_q = _to_sparse_weight(w, cfg, quantize=True)
        y_q = sparse_apply(sw_q, x)
        # int8 error stays below 1% of output RMS
        rms = float(jnp.sqrt(jnp.mean(y_ref ** 2)))
        assert float(jnp.abs(y_q - y_ref).max()) < 0.05 * rms
        assert sw_q.deployed_bytes() < 0.45 * sw_bf.deployed_bytes()

    def test_outliers_stay_exact_under_quant(self):
        from repro.models.sparse_serving import _to_sparse_weight
        w = jax.random.normal(jax.random.PRNGKey(2), (64, 512))
        cfg = SparsifyConfig(scorer="magnitude", use_smoothquant=False)
        sw = _to_sparse_weight(w, cfg, quantize=True)
        assert sw.nm_values.dtype == jnp.int8
        assert sw.o_values.dtype == w.dtype          # outliers uncompressed

    def test_deployed_bytes_counts_v_scale(self):
        """Regression: int8 mode must bill the per-row f32 scales too, or
        benchmark compression ratios overstate the int8 savings."""
        from repro.models.sparse_serving import _to_sparse_weight
        w = jax.random.normal(jax.random.PRNGKey(3), (64, 512))
        cfg = SparsifyConfig(scorer="magnitude", use_smoothquant=False)
        sw = _to_sparse_weight(w, cfg, quantize=True)
        without_scale = sum(
            v.size * v.dtype.itemsize
            for v in (sw.nm_values, sw.nm_meta, sw.o_values, sw.o_meta))
        assert sw.v_scale is not None
        assert sw.deployed_bytes() == without_scale + sw.v_scale.size * 4
