"""Deterministic stand-in for the tiny ``hypothesis`` subset these tests use.

When ``hypothesis`` is installed the test modules import it directly; when it
is missing they fall back to this module, which replays each property test as
a seeded deterministic parameter sweep (``max_examples`` draws from
``random.Random(0)``).  Only what the suite needs is implemented:

  @settings(max_examples=N, deadline=None)
  @given(st.integers(a, b), ...)      # strategies support .map(f)
"""
from __future__ import annotations

import functools
import inspect
import random
import types

_DEFAULT_EXAMPLES = 10


class _Strategy:
    def __init__(self, draw):
        self._draw = draw              # callable(rng) -> value

    def map(self, f):
        return _Strategy(lambda rng: f(self._draw(rng)))


def _integers(lo: int, hi: int) -> _Strategy:
    return _Strategy(lambda rng: rng.randint(lo, hi))


st = types.SimpleNamespace(integers=_integers)
strategies = st


def settings(max_examples: int = _DEFAULT_EXAMPLES, deadline=None, **_kw):
    def deco(fn):
        fn._fallback_max_examples = max_examples
        return fn
    return deco


def given(*strats: _Strategy):
    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            n = getattr(wrapper, "_fallback_max_examples", _DEFAULT_EXAMPLES)
            rng = random.Random(0)
            for _ in range(n):
                drawn = [s._draw(rng) for s in strats]
                fn(*args, *drawn, **kwargs)

        # hide the strategy-filled parameters from pytest's fixture
        # resolution (it introspects the signature; ``seed`` etc. would
        # otherwise be looked up as fixtures)
        del wrapper.__wrapped__
        sig = inspect.signature(fn)
        params = list(sig.parameters.values())[:-len(strats)]
        wrapper.__signature__ = sig.replace(parameters=params)
        return wrapper
    return deco
