"""EBFT blockwise fine-tuning: mask preservation + reconstruction recovery."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (ActStats, EBFTConfig, SparsifyConfig, ebft_block,
                        sparsify_linear, dense_effective_weight)
from repro.core.ebft import make_block_masks


def _mini_block(params, x):
    """A tiny transformer-ish block: norm -> linear -> gelu -> linear."""
    h = x * (1 + params["norm"])
    h = jax.nn.gelu(h @ params["w1"].T)
    return x + h @ params["w2"].T


def test_ebft_recovers_pruned_block():
    key = jax.random.PRNGKey(0)
    d, ff, n = 64, 128, 256
    dense = {"norm": jnp.zeros((d,)),
             "w1": jax.random.normal(key, (ff, d)) / np.sqrt(d),
             "w2": jax.random.normal(jax.random.PRNGKey(1), (d, ff)) / np.sqrt(ff)}
    x = jax.random.normal(jax.random.PRNGKey(2), (n, d))

    cfg = SparsifyConfig(weight_pattern="2:4", outlier_pattern=None,
                         scorer="magnitude", use_smoothquant=False)
    masks_by_path = {}
    sparse = dict(dense)
    for k in ("w1", "w2"):
        sl = sparsify_linear(dense[k], None, cfg)
        sparse[k] = dense_effective_weight(dense[k], sl, cfg)
        masks_by_path[k] = sl.nonsalient_kept_mask

    y_dense = _mini_block(dense, x)
    err_before = float(jnp.mean((_mini_block(sparse, x) - y_dense) ** 2))

    masks = make_block_masks(sparse, masks_by_path)
    tuned, losses = ebft_block(_mini_block, sparse, dense, masks, x,
                               EBFTConfig(steps=60, lr=3e-3, batch_size=64))
    err_after = float(jnp.mean((_mini_block(tuned, x) - y_dense) ** 2))

    # reconstruction improves substantially...
    assert err_after < 0.5 * err_before
    assert losses[-1] < losses[0]
    # ...and the sparsity structure is EXACTLY preserved
    for k in ("w1", "w2"):
        off_mask = ~np.asarray(masks_by_path[k])
        assert (np.asarray(tuned[k])[off_mask] == 0).all()


def test_norms_trainable_weights_frozen_without_mask():
    d = 8
    params = {"norm": jnp.zeros((d,)), "w1": jnp.ones((d, d)),
              "w2": jnp.ones((d, d))}
    masks = make_block_masks(params, {})   # no weight masks
    flat = jax.tree_util.tree_leaves_with_path(masks)
    by_name = {"/".join(str(getattr(p, "key", p)) for p in path): v
               for path, v in flat}
    assert by_name["norm"] is True
    assert by_name["w1"] is False and by_name["w2"] is False
