"""End-to-end behaviour tests for the paper's system.

The centerpiece: train a small LM on structured synthetic data, run the
paper's full sparsification pipeline, and assert the paper's QUALITATIVE
claims (method orderings) hold — the absolute numbers live in benchmarks/.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.core import SparsifyConfig
from repro.data.pipeline import SyntheticLM
from repro.models import get_model
from repro.eval.harness import (collect_activation_stats, eval_ppl,
                                sparsify_model, train_small_lm)


@pytest.fixture(scope="module")
def trained():
    """A tiny llama trained enough to be structurally meaningful (~60s CPU)."""
    cfg = dataclasses.replace(configs.get_smoke("llama-paper"),
                              n_layers=2, d_model=128, d_ff=256, vocab=256)
    data = SyntheticLM(vocab=cfg.vocab, seq_len=64, batch=16, seed=0)
    params, losses = train_small_lm(cfg, data, steps=120, lr=1e-2)
    assert losses[-1] < 0.8 * losses[0], "toy LM failed to learn"
    return cfg, params, data


def test_pruning_method_ordering(trained):
    """Paper Table 4 ordering: magnitude >= RIA >= RIA(+SQ) PPL; VC helps."""
    cfg, params, data = trained
    stats = collect_activation_stats(cfg, params, data.calibration(4))
    dense_ppl = eval_ppl(cfg, params, data, n_batches=4)

    def run(**kw):
        scfg = SparsifyConfig(weight_pattern="2:4", outlier_pattern=None, **kw)
        sp = sparsify_model(cfg, params, stats, scfg)
        return eval_ppl(cfg, sp, data, n_batches=4)

    ppl_mag = run(scorer="magnitude", use_smoothquant=False,
                  use_variance_correction=False)
    ppl_ria = run(scorer="ria", use_smoothquant=False,
                  use_variance_correction=False)
    ppl_ria_sq_vc = run(scorer="ria", use_smoothquant=True,
                        use_variance_correction=True)

    assert dense_ppl < ppl_ria_sq_vc
    assert ppl_ria <= ppl_mag * 1.05          # RIA no worse than magnitude
    assert ppl_ria_sq_vc <= ppl_ria * 1.10    # SQ+VC do not hurt


def test_pattern_flexibility_ordering(trained):
    """Paper Table 1 ordering: PPL(2:4) >= PPL(4:8) >= PPL(8:16)."""
    cfg, params, data = trained
    stats = collect_activation_stats(cfg, params, data.calibration(4))
    ppls = {}
    for pat in ("2:4", "4:8", "8:16", "16:32"):
        scfg = SparsifyConfig(weight_pattern=pat, outlier_pattern=None,
                              scorer="ria")
        sp = sparsify_model(cfg, params, stats, scfg)
        ppls[pat] = eval_ppl(cfg, sp, data, n_batches=4)
    assert ppls["8:16"] <= ppls["2:4"] * 1.02
    assert ppls["16:32"] <= ppls["4:8"] * 1.02


def test_outlier_recovery_helps(trained):
    """Paper Tables 5/6: structured outlier recovery improves PPL, more
    outliers help more."""
    cfg, params, data = trained
    stats = collect_activation_stats(cfg, params, data.calibration(4))
    ppls = {}
    for op in (None, "4:256", "16:256"):
        scfg = SparsifyConfig(weight_pattern="2:4", outlier_pattern=op,
                              scorer="ria")
        sp = sparsify_model(cfg, params, stats, scfg)
        ppls[op] = eval_ppl(cfg, sp, data, n_batches=4)
    assert ppls["4:256"] <= ppls[None] * 1.02
    assert ppls["16:256"] <= ppls["4:256"] * 1.02


def test_sparse_serving_matches_dense_effective(trained):
    """Deploying compressed weights (serve path) changes nothing numerically:
    sparse-serving logits == dense-effective logits."""
    cfg, params, data = trained
    from repro.models.sparse_serving import sparsify_for_serving
    scfg = SparsifyConfig(scorer="magnitude", use_smoothquant=False)
    sp_serve, report = sparsify_for_serving(params, scfg)
    sp_dense = sparsify_model(cfg, params, None, scfg)

    batch = data.batch_at(0)
    toks = {"tokens": jnp.asarray(batch["tokens"][:2, :32])}
    from repro.models import transformer as tfm
    l1, _ = tfm.forward(sp_serve, toks, cfg)
    l2, _ = tfm.forward(sp_dense, toks, cfg)
    np.testing.assert_allclose(np.asarray(l1, np.float32),
                               np.asarray(l2, np.float32), rtol=5e-3, atol=5e-2)
    assert report["ratio"] < 0.70


def test_train_driver_with_failure_recovers(tmp_path):
    """launch.train end-to-end with a simulated host failure mid-run."""
    from repro.launch.train import main
    report = main(["--arch", "llama-paper", "--smoke-arch",
                   "--steps", "12", "--batch", "4", "--seq", "32",
                   "--save-every", "4", "--fail-at-step", "6",
                   "--ckpt-dir", str(tmp_path)])
    assert report.restarts == 1
    assert report.restored_steps == [4]
    assert np.isfinite(report.losses[-1])


def test_serve_driver_sparse(capsys):
    from repro.launch.serve import main
    gen = main(["--arch", "llama-paper", "--smoke-arch", "--batch", "2",
                "--prompt-len", "16", "--gen", "4", "--sparse"])
    assert gen.shape == (2, 4)
    out = capsys.readouterr().out
    assert "sparse deploy" in out
