"""Continuous-batching serving engine tests.

The load-bearing property: a request's generated tokens are IDENTICAL
whether it runs through the engine (slot-indexed caches, strangers in the
batch, staggered arrival) or through the legacy one-shot lock-step loop —
for dense and compressed (SparseWeight) params alike.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.core import SparsifyConfig
from repro.models import get_model, grow_caches
from repro.serving import (QueueFull, SamplingParams, ServingEngine, Status,
                           poisson_trace)

CFG = dataclasses.replace(configs.get_smoke("llama-paper"),
                          name="serving-test", n_layers=2, d_model=128,
                          n_heads=4, n_kv_heads=4, head_dim=32, d_ff=256,
                          vocab=512, remat=False)
GEN = 6


@pytest.fixture(scope="module")
def dense_params():
    return get_model(CFG).init(jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def sparse_params(dense_params):
    from repro.models.sparse_serving import sparsify_for_serving
    scfg = SparsifyConfig(weight_pattern="8:16", outlier_pattern="16:256",
                          scorer="magnitude", use_smoothquant=False)
    sp, report = sparsify_for_serving(dense_params, scfg)
    assert report["n_layers_sparsified"] > 0
    return sp


def _prompts(n, length, seed=1):
    key = jax.random.PRNGKey(seed)
    return [t.tolist() for t in
            jax.random.randint(key, (n, length), 0, CFG.vocab)]


def _oneshot(params, prompts, gen):
    """The legacy lock-step loop: batch prefill + scalar-pos greedy decode."""
    zoo = get_model(CFG)
    toks = jnp.asarray(prompts, jnp.int32)
    logits, caches = zoo.prefill(params, {"tokens": toks})
    caches = grow_caches(caches, toks.shape[1] + gen)
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    outs = [tok]
    for _ in range(gen - 1):
        logits, caches = zoo.decode(params, caches, {"tokens": tok})
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        outs.append(tok)
    return np.asarray(jnp.concatenate(outs, 1))


def _engine_run(params, prompts, gen, **kw):
    engine = ServingEngine(CFG, params, **kw)
    reqs = [engine.submit(p, SamplingParams(max_new_tokens=gen))
            for p in prompts]
    engine.run()
    return engine, reqs


@pytest.mark.parametrize("which", ["dense", "sparse"])
def test_engine_token_identical_to_oneshot(which, dense_params, sparse_params):
    params = dense_params if which == "dense" else sparse_params
    prompts = _prompts(4, 16)
    ref = _oneshot(params, prompts, GEN)
    _, reqs = _engine_run(params, prompts, GEN, n_slots=4, max_len=32)
    for i, r in enumerate(reqs):
        assert r.status is Status.FINISHED
        assert r.tokens == ref[i].tolist(), f"request {i} diverged"


def test_slot_reuse_after_completion(dense_params):
    """More requests than slots: finished slots are recycled and late
    requests still match their solo-run output exactly."""
    prompts = _prompts(5, 16)
    engine, reqs = _engine_run(dense_params, prompts, GEN,
                               n_slots=2, max_len=32)
    assert all(r.status is Status.FINISHED for r in reqs)
    used = [r.slot for r in reqs]
    assert set(used) == {0, 1} and len(used) > len(set(used))
    # a recycled-slot request matches its own solo run
    solo = _oneshot(dense_params, [prompts[4]], GEN)
    assert reqs[4].tokens == solo[0].tolist()


def test_mixed_arrivals_join_running_batch(dense_params):
    """Requests submitted mid-decode (different prompt lengths) produce the
    same tokens as running alone: slot-indexed decode isolates rows."""
    early = _prompts(2, 16, seed=2)
    late = _prompts(2, 11, seed=3)           # odd length -> padded bucket
    engine = ServingEngine(CFG, dense_params, n_slots=4, max_len=64,
                           token_budget=2 * 64)
    reqs = [engine.submit(p, SamplingParams(max_new_tokens=12)) for p in early]
    for _ in range(3):                        # decode a few tokens first
        engine.step()
    reqs += [engine.submit(p, SamplingParams(max_new_tokens=4)) for p in late]
    engine.run()
    assert all(r.status is Status.FINISHED for r in reqs)
    assert [len(r.tokens) for r in reqs] == [12, 12, 4, 4]
    for r, prompt, gen in [(reqs[0], early[0], 12), (reqs[2], late[0], 4),
                           (reqs[3], late[1], 4)]:
        _, solo = _engine_run(dense_params, [prompt], gen,
                              n_slots=4, max_len=64)
        assert r.tokens == solo[0].tokens


def test_streaming_callbacks_and_metrics(dense_params):
    seen = []
    engine = ServingEngine(CFG, dense_params, n_slots=2, max_len=32)
    req = engine.submit(_prompts(1, 8)[0],
                        SamplingParams(max_new_tokens=GEN),
                        on_token=lambda r, t: seen.append(t),
                        on_finish=lambda r: seen.append("done"))
    engine.run()
    assert seen == req.tokens + ["done"]
    m = req.metrics
    assert m.arrival <= m.admitted <= m.first_token <= m.finished
    assert m.n_tokens == GEN and m.ttft >= 0 and m.e2e >= m.ttft


def test_admission_control_and_eviction(dense_params):
    # fake clock so queue timeout is deterministic
    t = [0.0]
    engine = ServingEngine(CFG, dense_params, n_slots=1, max_len=32,
                           max_queue=2, queue_timeout_s=10.0,
                           clock=lambda: t[0])
    with pytest.raises(ValueError):          # can never fit a slot
        engine.submit(list(range(30)), SamplingParams(max_new_tokens=8))
    p = _prompts(3, 8)
    engine.submit(p[0], SamplingParams(max_new_tokens=2))
    engine.submit(p[1], SamplingParams(max_new_tokens=2))
    with pytest.raises(QueueFull):           # queue capacity reached
        engine.submit(p[2], SamplingParams(max_new_tokens=2))
    t[0] = 100.0                             # everything queued times out
    engine.step()
    evicted = [r for r in engine.finished if r.status is Status.EVICTED]
    assert len(evicted) >= 1                  # the slotless one was dropped
    engine.run()
    done = [r for r in engine.finished if r.status is Status.FINISHED]
    assert all(len(r.tokens) == 2 for r in done)


def test_sampling_temperature_and_seed(dense_params):
    """Stochastic sampling is reproducible per seed and differs across
    seeds; greedy stays deterministic."""
    prompt = _prompts(1, 8)[0]

    def run(seed, temp):
        engine = ServingEngine(CFG, dense_params, n_slots=1, max_len=32)
        r = engine.submit(prompt, SamplingParams(max_new_tokens=8,
                                                 temperature=temp, seed=seed))
        engine.run()
        return r.tokens

    assert run(0, 0.0) == run(7, 0.0)                 # greedy ignores seed
    assert run(3, 1.0) == run(3, 1.0)                 # same seed reproduces
    assert run(3, 1.0) != run(4, 1.0)                 # seeds decorrelate


@pytest.mark.parametrize("kv_layout", ["slot", "paged"])
class TestSchedulerFailurePaths:
    """Dedicated coverage for the admission/eviction failure modes, on
    both KV layouts: queue-timeout eviction, QueueFull, and
    prompt-exceeds-capacity rejection."""

    def _engine(self, params, kv_layout, **kw):
        kw.setdefault("n_slots", 1)
        kw.setdefault("max_len", 32)
        return ServingEngine(CFG, params, kv_layout=kv_layout,
                             block_size=8, **kw)

    def test_prompt_exceeding_capacity_rejected(self, dense_params,
                                                kv_layout):
        engine = self._engine(dense_params, kv_layout)
        with pytest.raises(ValueError, match="exceeds KV capacity"):
            engine.submit(list(range(30)), SamplingParams(max_new_tokens=8))
        with pytest.raises(ValueError, match="empty prompt"):
            engine.submit([], SamplingParams(max_new_tokens=2))
        with pytest.raises(ValueError, match="max_new_tokens"):
            engine.submit([1, 2], SamplingParams(max_new_tokens=0))
        assert len(engine.queue) == 0          # nothing leaked into the queue

    def test_queue_full_rejects_not_drops(self, dense_params, kv_layout):
        engine = self._engine(dense_params, kv_layout, max_queue=2)
        p = _prompts(3, 8)
        engine.submit(p[0], SamplingParams(max_new_tokens=2))
        engine.submit(p[1], SamplingParams(max_new_tokens=2))
        with pytest.raises(QueueFull):
            engine.submit(p[2], SamplingParams(max_new_tokens=2))
        engine.run()                           # accepted requests still run
        assert sum(r.status is Status.FINISHED for r in engine.finished) == 2

    def test_queue_timeout_evicts_with_callback(self, dense_params,
                                                kv_layout):
        t = [0.0]
        seen = []
        engine = self._engine(dense_params, kv_layout, max_queue=4,
                              queue_timeout_s=5.0, clock=lambda: t[0])
        live = engine.submit(_prompts(1, 8)[0],
                             SamplingParams(max_new_tokens=2))
        stale = engine.submit(_prompts(1, 8, seed=4)[0],
                              SamplingParams(max_new_tokens=2),
                              on_finish=lambda r: seen.append(r.status))
        engine.step()                          # admits 'live' (1 slot/row)
        t[0] = 100.0
        stats = engine.step()
        assert stats["evicted"] == 1
        assert stale.status is Status.EVICTED and stale.tokens == []
        assert seen == [Status.EVICTED]        # on_finish fired on eviction
        engine.run()
        assert live.status is Status.FINISHED and len(live.tokens) == 2


def test_slot_pool_double_free_raises(dense_params):
    """Pool invariants are real exceptions (assert vanishes under -O)."""
    from repro.serving import DoubleFree, SlotKVPool
    pool = SlotKVPool(CFG, n_slots=2, max_len=16)
    slot = pool.alloc()
    pool.release(slot)
    with pytest.raises(DoubleFree):
        pool.release(slot)


def test_poisson_trace_deterministic():
    a = poisson_trace(n_requests=5, rate_per_s=2.0, vocab=128, seed=9)
    b = poisson_trace(n_requests=5, rate_per_s=2.0, vocab=128, seed=9)
    assert a == b
    assert all(x.arrival_s < y.arrival_s for x, y in zip(a, a[1:]))
    assert all(0 <= tok < 128 for t in a for tok in t.prompt)


def test_legacy_serve_driver_hybrid_family():
    """The one-shot path must stay correct for non-engine families: zamba's
    per-application KV caches previously never grew past the prompt."""
    from repro.launch.serve import main
    gen = main(["--arch", "zamba2-2.7b", "--smoke-arch", "--batch", "2",
                "--prompt-len", "8", "--gen", "3", "--legacy"])
    assert gen.shape == (2, 3)
    assert np.isfinite(np.asarray(gen)).all()
