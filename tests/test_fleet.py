"""Multi-replica fleet serving tests.

The load-bearing property mirrors test_serving's: WHERE a request runs
never changes WHAT it generates.  A fleet of N replicas (any routing
policy, even with a mid-trace work-steal) must emit per-request token
streams identical to one engine holding the fleet's total KV.  Around
that: router scoring unit tests on fake replicas, fleet_trace
determinism, drain/re-admit, replica meshes, stats schema, and the
RouterTracer's shared-buffer observability.
"""
import dataclasses

import jax
import pytest

from repro import configs
from repro.launch.mesh import make_replica_meshes
from repro.models import get_model
from repro.serving import (QueueFull, ReplicaSet, Router, RouterTracer,
                           SamplingParams, ServingEngine, ServingTracer,
                           fleet_trace, replay)

CFG = dataclasses.replace(configs.get_smoke("llama-paper"),
                          name="fleet-test", n_layers=2, d_model=128,
                          n_heads=4, n_kv_heads=4, head_dim=32, d_ff=256,
                          vocab=512, remat=False)

# small but real fleet workload: 8:16-style tenant mix, heavy tails,
# bursts — shared across the identity tests so compiles amortize
TRACE_KW = dict(n_requests=16, n_tenants=4, vocab=CFG.vocab, sys_len=16,
                rate_per_s=200.0, burst_mean=3.0, prompt_median=6,
                prompt_sigma=0.5, prompt_max=16, gen_median=5,
                gen_sigma=0.8, gen_max=12, seed=11)
ENGINE_KW = dict(kv_layout="paged", block_size=4, max_len=48,
                 prefix_caching=True, max_queue=64, token_budget=32)


@pytest.fixture(scope="module")
def dense_params():
    return get_model(CFG).init(jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def single_streams(dense_params):
    """Reference: the same trace through ONE engine with the fleet's
    total KV (16 blocks x 2 replicas), cold caches."""
    eng = ServingEngine(CFG, dense_params, n_slots=4, n_blocks=32,
                        **ENGINE_KW)
    res = replay(eng, fleet_trace(**TRACE_KW), time_scale=0.001)
    assert res["rejected"] == 0
    return {r.request_id: list(r.tokens) for r in res["finished"]}


# --------------------------------------------------------------------------
# router scoring (fake replicas: the router only reads queue/pool/cache)
# --------------------------------------------------------------------------

class _FakeQueue(list):
    def __init__(self, n, max_size=8):
        super().__init__(range(n))
        self.max_size = max_size


class _FakePool:
    def __init__(self, n_slots):
        self.n_slots = n_slots


class _Fake:
    def __init__(self, *, slots=4, running=0, queued=0, max_queue=8,
                 cached=0):
        self.pool = _FakePool(slots)
        self.running = list(range(running))
        self.queue = _FakeQueue(queued, max_queue)
        self._cached = cached

    def prefix_match_length(self, prompt):
        return min(self._cached, len(prompt))


def test_router_validates_policy_and_replicas():
    with pytest.raises(ValueError, match="unknown routing policy"):
        Router([_Fake()], "random")
    with pytest.raises(ValueError, match="at least one replica"):
        Router([], "prefix")


def test_round_robin_cycles_and_skips_full():
    reps = [_Fake(), _Fake(queued=8, max_queue=8), _Fake()]
    r = Router(reps, "round_robin")
    picks = [r.route([1, 2, 3]).replica for _ in range(4)]
    assert picks == [0, 2, 0, 2]            # replica 1's queue is full
    assert r.n_decisions == 4
    assert r.decisions_by == {"round_robin": 4}


def test_least_loaded_prefers_empty_replica():
    reps = [_Fake(running=4, queued=2), _Fake(running=1), _Fake(running=2)]
    r = Router(reps, "least_loaded")
    d = r.route(list(range(8)))
    assert d.replica == 1 and d.picked_by == "load"
    assert d.loads == (1.5, 0.25, 0.5)


def test_prefix_score_wins_on_cached_prompt():
    reps = [_Fake(), _Fake(cached=16), _Fake()]
    r = Router(reps, "prefix")
    d = r.route(list(range(16)))
    assert d.replica == 1 and d.picked_by == "prefix"
    assert d.prefix_tokens == 16 and d.prefix_frac == 1.0


def test_prefix_score_load_counterweight():
    # a fully-cached prompt on a replica with a full batch QUEUED behind
    # its running batch must lose to an idle cache-cold replica:
    # 2.0 * 1.0 - 1.0 * 2.0 = 0.0 <= idle's 0.0, tie broken by load
    reps = [_Fake(), _Fake(cached=16, running=4, queued=4)]
    r = Router(reps, "prefix")
    d = r.route(list(range(16)))
    assert d.replica == 0 and d.picked_by == "load"


def test_prefix_score_session_affinity_breaks_ties():
    reps = [_Fake(), _Fake(), _Fake()]
    r = Router(reps, "prefix")
    first = r.route(list(range(8)), session=7).replica
    d = r.route(list(range(8)), session=7)
    assert d.replica == first and d.picked_by == "affinity"
    # a different session has no home yet: cold-cache tie goes to the
    # least-loaded, lowest-indexed replica
    assert r.route(list(range(8)), session=8).picked_by == "load"


def test_router_queue_full_when_no_candidates():
    reps = [_Fake(queued=2, max_queue=2), _Fake(queued=2, max_queue=2)]
    for policy in ("prefix", "round_robin", "least_loaded"):
        with pytest.raises(QueueFull):
            Router(reps, policy).route([1, 2])


def test_router_stats_and_reset():
    r = Router([_Fake(cached=4), _Fake()], "prefix")
    r.route(list(range(4)), session=1)
    st = r.stats()
    assert st["n_decisions"] == 1 and st["prefix_tokens_routed"] == 4
    assert st["decisions_by"] == {"prefix": 1} and st["sessions"] == 1
    r.reset_stats()
    st = r.stats()
    assert st["n_decisions"] == 0 and st["decisions_by"] == {}
    assert st["sessions"] == 1               # routing state persists


# --------------------------------------------------------------------------
# fleet_trace: deterministic, tenant-structured workload
# --------------------------------------------------------------------------

def test_fleet_trace_deterministic_and_tenant_shaped():
    a = fleet_trace(**TRACE_KW)
    b = fleet_trace(**TRACE_KW)
    assert [(t.arrival_s, t.prompt, t.max_new_tokens, t.session)
            for t in a] == \
           [(t.arrival_s, t.prompt, t.max_new_tokens, t.session)
            for t in b]
    c = fleet_trace(**{**TRACE_KW, "seed": TRACE_KW["seed"] + 1})
    assert [t.prompt for t in a] != [t.prompt for t in c]

    sys_len = TRACE_KW["sys_len"]
    sys_prompts = {}
    # arrivals are near-sorted (bursts carry tiny intra-burst jitter that
    # can overtake the next epoch at high rates; replay sorts regardless)
    assert all(t.arrival_s > 0 for t in a)
    for t in a:
        assert 0 <= t.session < TRACE_KW["n_tenants"]
        assert len(t.prompt) <= sys_len + TRACE_KW["prompt_max"]
        assert 1 <= t.max_new_tokens <= TRACE_KW["gen_max"]
        assert all(0 <= tok < CFG.vocab for tok in t.prompt)
        # every request of a tenant opens with the SAME system prompt —
        # the sharing opportunity prefix routing exploits
        head = tuple(t.prompt[:sys_len])
        assert sys_prompts.setdefault(t.session, head) == head
    assert len(sys_prompts) > 1              # multiple tenants actually hit


# --------------------------------------------------------------------------
# token identity: 1 engine vs N replicas, cold caches
# --------------------------------------------------------------------------

def _fleet_streams(params, *, routing, n_replicas=2, steal_threshold=4,
                   **overrides):
    rs = ReplicaSet(CFG, params, n_replicas=n_replicas, routing=routing,
                    steal_threshold=steal_threshold, n_slots=4,
                    n_blocks=32 // n_replicas, **ENGINE_KW, **overrides)
    res = replay(rs, fleet_trace(**TRACE_KW), time_scale=0.001)
    assert res["rejected"] == 0
    return rs, {r.request_id: list(r.tokens) for r in res["finished"]}


@pytest.mark.parametrize("routing", ["prefix", "round_robin"])
def test_fleet_token_identical_to_single_engine(routing, dense_params,
                                                single_streams):
    rs, streams = _fleet_streams(dense_params, routing=routing)
    assert set(streams) == set(single_streams)
    for rid, toks in single_streams.items():
        assert streams[rid] == toks, f"request {rid} diverged under {routing}"
    # both replicas actually served work (routing didn't degenerate)
    served = [e.stats()["n_finished"] for e in rs.replicas]
    assert all(n > 0 for n in served)


def test_fleet_token_identical_with_forced_steal(dense_params,
                                                 single_streams):
    # steal_threshold=1 + prefix affinity piling one tenant's burst onto
    # its home replica forces mid-trace work-stealing; migrated requests
    # must still generate the exact same tokens
    rs, streams = _fleet_streams(dense_params, routing="prefix",
                                 steal_threshold=1)
    assert rs.n_steals > 0
    assert streams == single_streams


# --------------------------------------------------------------------------
# rebalance mechanics
# --------------------------------------------------------------------------

def test_drain_readmits_stuck_preempted_request(dense_params):
    rs = ReplicaSet(CFG, dense_params, n_replicas=2, routing="least_loaded",
                    n_slots=1, n_blocks=8, kv_layout="paged", block_size=4,
                    max_len=32, prefix_caching=True, max_queue=8)
    # occupy replica 0's only slot...
    rs.replicas[0].submit(list(range(8)), SamplingParams(max_new_tokens=16))
    rs.replicas[0].step()
    assert rs.replicas[0].pool.n_free == 0
    # ...and park a once-preempted request at the head of its queue:
    # it cannot re-admit here until its victim's slot frees, but
    # replica 1 could run it right now
    stuck = rs.replicas[0].submit(list(range(8)),
                                  SamplingParams(max_new_tokens=4))
    stuck.n_preempted = 1                    # simulate a prior eviction
    moved = rs._rebalance()
    assert moved == 1 and rs.n_drains == 1 and rs.n_steals == 0
    assert stuck not in rs.replicas[0].queue
    assert stuck in rs.replicas[1].queue
    assert rs.home[stuck.request_id] == 1


def test_replica_set_validates_shapes(dense_params):
    with pytest.raises(ValueError, match="n_replicas"):
        ReplicaSet(CFG, dense_params, n_replicas=0)
    with pytest.raises(ValueError, match="meshes"):
        ReplicaSet(CFG, dense_params, n_replicas=2, meshes=[None],
                   n_slots=1, max_len=16)


# --------------------------------------------------------------------------
# replica meshes
# --------------------------------------------------------------------------

def test_make_replica_meshes_default_and_bounds():
    assert make_replica_meshes(None, 3) == [None, None, None]
    with pytest.raises(ValueError, match="n_replicas"):
        make_replica_meshes(None, 0)
    with pytest.raises(ValueError, match="devices"):
        make_replica_meshes("1x1", len(jax.devices()) + 1)


@pytest.mark.skipif(len(jax.devices()) < 2,
                    reason="needs >= 2 devices for disjoint slices")
def test_make_replica_meshes_disjoint_slices():
    meshes = make_replica_meshes("1x1", 2)
    d0 = set(meshes[0].devices.flat)
    d1 = set(meshes[1].devices.flat)
    assert d0 and d1 and not (d0 & d1)


# --------------------------------------------------------------------------
# stats schema + observability
# --------------------------------------------------------------------------

def test_fleet_stats_schema_and_reset(dense_params):
    rs, _ = _fleet_streams(dense_params, routing="prefix")
    st = rs.stats()
    assert st["n_replicas"] == 2 and st["routing"] == "prefix"
    assert len(st["busy_s"]) == 2 and len(st["replicas"]) == 2
    assert st["critical_path_s"] == \
        pytest.approx(max(st["busy_s"]) + st["router_busy_s"])
    assert st["prefix_cache"]["lookups"] > 0
    assert st["router"]["n_decisions"] == TRACE_KW["n_requests"]
    assert sum(p["n_finished"] for p in st["replicas"]) \
        == TRACE_KW["n_requests"]
    rs.reset_stats()
    st = rs.stats()
    assert st["busy_s"] == [0.0, 0.0] and st["router"]["n_decisions"] == 0
    assert st["n_steals"] == 0 and st["n_drains"] == 0


def test_router_tracer_shares_buffer_with_replica_tracers(dense_params):
    t0 = ServingTracer(name="r0")
    t1 = ServingTracer(buffer=t0.buffer, registry=t0.registry, name="r1")
    rt = RouterTracer(buffer=t0.buffer, registry=t0.registry)
    rs = ReplicaSet(CFG, dense_params, n_replicas=2, routing="prefix",
                    tracers=[t0, t1], router_tracer=rt, n_slots=4,
                    n_blocks=16, **ENGINE_KW)
    replay(rs, fleet_trace(**{**TRACE_KW, "n_requests": 8}),
           time_scale=0.001)
    events = t0.buffer.events
    routes = [e for e in events if e.get("name") == "route"]
    assert len(routes) == 8
    assert {e["args"]["replica"] for e in routes} <= {0, 1}
    # one buffer, three processes: two replicas + the router
    names = {e["args"]["name"] for e in events
             if e.get("name") == "process_name"}
    assert {"engine r0", "engine r1", "fleet router"} <= names
    text = t0.registry.prometheus_text()
    assert "fleet_routing_decisions_total" in text
    assert "fleet_queue_imbalance" in text
