"""Sharding-rule unit tests (no 512-device env needed: 4-device host mesh)."""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.parallel.sharding import (param_spec, batch_spec, cache_spec,
                                     fsdp_axes, sparse_weight_specs)


@pytest.fixture(scope="module")
def mesh():
    # a virtual (4, 4) mesh: spec resolution only needs axis SIZES
    devs = np.array(jax.devices() * 16)[:16].reshape(4, 4)
    return jax.sharding.Mesh(devs, ("data", "model"))


class TestParamSpecs:
    def test_column_parallel(self, mesh):
        assert param_spec(mesh, "layers/wq", (24, 2048, 4096)) == \
            P(None, "model", ("data",))

    def test_row_parallel(self, mesh):
        assert param_spec(mesh, "layers/wo", (24, 4096, 2048)) == \
            P(None, ("data",), "model")

    def test_norms_replicated(self, mesh):
        assert param_spec(mesh, "layers/attn_norm", (24, 4096)) == P(None, None)

    def test_embed(self, mesh):
        assert param_spec(mesh, "embed", (32000, 4096)) == P("model", ("data",))

    def test_divisibility_fallback(self, mesh):
        # whisper vocab 51865 is not divisible by 4 -> vocab dim replicated
        sp = param_spec(mesh, "embed", (51865, 1024))
        assert sp == P(None, ("data",))

    def test_moe_expert_ep_layout(self, mesh):
        # 128 experts divisible by fsdp=4 -> experts over data, ff over model
        assert param_spec(mesh, "layers/moe/we_gate", (48, 128, 5120, 8192)) == \
            P(None, ("data",), None, "model")
        # 6 experts not divisible -> fallback TP-only
        sp = param_spec(mesh, "layers/moe/we_gate", (48, 6, 5120, 8192))
        assert sp == P(None, None, ("data",), "model")

    def test_router_replicated(self, mesh):
        assert param_spec(mesh, "layers/moe/router", (48, 128, 5120)) == \
            P(None, None, None)


def _sw(out, in_dim, m=16, n=8, o_n=0, quantized=False, L=None):
    """SparseWeight of ShapeDtypeStructs (specs only need shapes+statics)."""
    from repro.models.sparse_serving import SparseWeight
    lead = () if L is None else (L,)
    sds = jax.ShapeDtypeStruct
    vdt = jnp.int8 if quantized else jnp.bfloat16
    return SparseWeight(
        nm_values=sds((*lead, out, in_dim * n // m), vdt),
        nm_meta=sds((*lead, out, in_dim // m), jnp.int32),
        o_values=None if o_n == 0 else
        sds((*lead, out, in_dim // 256, o_n), jnp.bfloat16),
        o_meta=None if o_n == 0 else
        sds((*lead, out, in_dim // 256, o_n // 4), jnp.int32),
        v_scale=None if not quantized else sds((*lead, out), jnp.float32),
        n=n, m=m, o_n=o_n, in_dim=in_dim)


class TestSparseWeightSpecs:
    """Mesh-aware placement of compressed containers: out-dim (row)
    sharding is always safe; in-dim sharding must land on N:M-block and
    256-wide outlier-group boundaries or fall back to replication."""

    def test_aligned_in_dim_shards_over_fsdp(self, mesh):
        # fsdp=4, m=16: in_dim 256 % (4*16) == 0 -> values+meta in over data
        sp = sparse_weight_specs(mesh, _sw(64, 256))
        assert sp.nm_values == P("model", ("data",))
        assert sp.nm_meta == P("model", ("data",))

    def test_in_dim_splitting_nm_block_replicates(self, mesh):
        # in_dim 48 is 16-aligned but 48 % (4*16) != 0: a data-shard
        # boundary would land inside an N:M block.  The raw compressed dim
        # (48*8/16 = 24) DOES divide 4 — divisibility alone must not win.
        sp = sparse_weight_specs(mesh, _sw(64, 48))
        assert sp.nm_values[-1] is None and sp.nm_meta[-1] is None
        # out dim may absorb fsdp instead (64 % (4*4) == 0)
        assert sp.nm_values[0] == ("model", "data")

    def test_in_dim_splitting_outlier_group_replicates(self, mesh):
        # 512 % (4*16) == 0 but 512 % (4*256) != 0: fine without outliers,
        # rejected with them (a shard edge would cut a 256-wide group)
        no_outliers = sparse_weight_specs(mesh, _sw(4, 512))
        assert no_outliers.nm_values[-1] == ("data",)
        with_outliers = sparse_weight_specs(mesh, _sw(4, 512, o_n=16))
        assert with_outliers.nm_values[-1] is None
        assert with_outliers.o_values == P("model", None, None)

    def test_replication_fallback_when_nothing_divides(self, mesh):
        # out 4 % model(4) == 0 but 4 % (model*fsdp)=16 != 0: no fsdp fold
        sp = sparse_weight_specs(mesh, _sw(4, 48))
        assert sp.nm_values == P("model", None)

    def test_metadata_and_scales_coshard_with_values(self, mesh):
        sp = sparse_weight_specs(mesh, _sw(64, 1024, o_n=16, quantized=True,
                                           L=2))
        assert sp.nm_meta == sp.nm_values == P(None, "model", ("data",))
        assert sp.o_values == sp.o_meta == P(None, "model", ("data",), None)
        assert sp.v_scale == P(None, "model")     # same out axes

    def test_serving_policy_never_shards_contractions(self, mesh):
        # serving placement: out-dim TP only (token-stream parity)
        sp = sparse_weight_specs(mesh, _sw(64, 256, o_n=16), serving=True)
        assert sp.nm_values == P("model", None)
        assert sp.o_values == P("model", None, None)


class TestBatchCacheSpecs:
    def test_tokens(self, mesh):
        assert batch_spec(mesh, (256, 4096)) == P(("data",), None)

    def test_mrope_positions(self, mesh):
        assert batch_spec(mesh, (3, 256, 4096)) == P(None, ("data",), None)

    def test_seq_shard_for_batch1(self, mesh):
        assert batch_spec(mesh, (1, 524288), seq_shard=True) == \
            P(None, ("data",))

    def test_kv_cache_head_sharded_when_divisible(self, mesh):
        assert cache_spec(mesh, "k", (24, 128, 32768, 16, 64)) == \
            P(None, ("data",), None, "model", None)

    def test_kv_cache_seq_sharded_for_gqa(self, mesh):
        # kv=2 < model axis 4 -> flash-decoding layout (seq over model)
        assert cache_spec(mesh, "k", (24, 128, 32768, 2, 64)) == \
            P(None, ("data",), "model", None, None)

    def test_pos_scalar(self, mesh):
        assert cache_spec(mesh, "pos", ()) == P()


@pytest.mark.slow
def test_dryrun_cell_subprocess():
    """End-to-end dry-run of one small cell in a fresh 512-device process."""
    code = (
        "import sys; sys.path.insert(0, 'src')\n"
        "from repro.launch.dryrun import run_cell\n"
        "rec = run_cell('internlm2-1.8b', 'decode_32k', multi_pod=False,"
        " probe=False, out_dir=None, verbose=False)\n"
        "assert rec['status'] == 'ok', rec\n"
        "assert rec['full']['collective_bytes']['total'] > 0\n"
        "print('CELL_OK')\n"
    )
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, cwd=os.path.dirname(os.path.dirname(__file__)),
                         env=env, timeout=900)
    assert "CELL_OK" in out.stdout, out.stdout + out.stderr
