"""Sharding-rule unit tests (no 512-device env needed: 4-device host mesh)."""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.parallel.sharding import (param_spec, batch_spec, cache_spec,
                                     fsdp_axes)


@pytest.fixture(scope="module")
def mesh():
    # a virtual (4, 4) mesh: spec resolution only needs axis SIZES
    devs = np.array(jax.devices() * 16)[:16].reshape(4, 4)
    return jax.sharding.Mesh(devs, ("data", "model"))


class TestParamSpecs:
    def test_column_parallel(self, mesh):
        assert param_spec(mesh, "layers/wq", (24, 2048, 4096)) == \
            P(None, "model", ("data",))

    def test_row_parallel(self, mesh):
        assert param_spec(mesh, "layers/wo", (24, 4096, 2048)) == \
            P(None, ("data",), "model")

    def test_norms_replicated(self, mesh):
        assert param_spec(mesh, "layers/attn_norm", (24, 4096)) == P(None, None)

    def test_embed(self, mesh):
        assert param_spec(mesh, "embed", (32000, 4096)) == P("model", ("data",))

    def test_divisibility_fallback(self, mesh):
        # whisper vocab 51865 is not divisible by 4 -> vocab dim replicated
        sp = param_spec(mesh, "embed", (51865, 1024))
        assert sp == P(None, ("data",))

    def test_moe_expert_ep_layout(self, mesh):
        # 128 experts divisible by fsdp=4 -> experts over data, ff over model
        assert param_spec(mesh, "layers/moe/we_gate", (48, 128, 5120, 8192)) == \
            P(None, ("data",), None, "model")
        # 6 experts not divisible -> fallback TP-only
        sp = param_spec(mesh, "layers/moe/we_gate", (48, 6, 5120, 8192))
        assert sp == P(None, None, ("data",), "model")

    def test_router_replicated(self, mesh):
        assert param_spec(mesh, "layers/moe/router", (48, 128, 5120)) == \
            P(None, None, None)


class TestBatchCacheSpecs:
    def test_tokens(self, mesh):
        assert batch_spec(mesh, (256, 4096)) == P(("data",), None)

    def test_mrope_positions(self, mesh):
        assert batch_spec(mesh, (3, 256, 4096)) == P(None, ("data",), None)

    def test_seq_shard_for_batch1(self, mesh):
        assert batch_spec(mesh, (1, 524288), seq_shard=True) == \
            P(None, ("data",))

    def test_kv_cache_head_sharded_when_divisible(self, mesh):
        assert cache_spec(mesh, "k", (24, 128, 32768, 16, 64)) == \
            P(None, ("data",), None, "model", None)

    def test_kv_cache_seq_sharded_for_gqa(self, mesh):
        # kv=2 < model axis 4 -> flash-decoding layout (seq over model)
        assert cache_spec(mesh, "k", (24, 128, 32768, 2, 64)) == \
            P(None, ("data",), "model", None, None)

    def test_pos_scalar(self, mesh):
        assert cache_spec(mesh, "pos", ()) == P()


@pytest.mark.slow
def test_dryrun_cell_subprocess():
    """End-to-end dry-run of one small cell in a fresh 512-device process."""
    code = (
        "import sys; sys.path.insert(0, 'src')\n"
        "from repro.launch.dryrun import run_cell\n"
        "rec = run_cell('internlm2-1.8b', 'decode_32k', multi_pod=False,"
        " probe=False, out_dir=None, verbose=False)\n"
        "assert rec['status'] == 'ok', rec\n"
        "assert rec['full']['collective_bytes']['total'] > 0\n"
        "print('CELL_OK')\n"
    )
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, cwd=os.path.dirname(os.path.dirname(__file__)),
                         env=env, timeout=900)
    assert "CELL_OK" in out.stdout, out.stdout + out.stderr
