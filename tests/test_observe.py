"""Observability substrate tests.

Two load-bearing properties:

1. Disabled is free AND invisible: the default engine holds the
   NULL_TRACER singleton, whose hooks return shared objects (no per-step
   allocation), and a traced run emits exactly the same tokens as an
   untraced one — for slot+paged layouts, dense+8:16-sparse params.
2. The trace IS the metrics: every request's "request_summary" event in
   the written Perfetto trace restates its ``RequestMetrics`` exactly —
   including preemption/resume and prefix-cache-hit lifecycles.
"""
import dataclasses
import json
import math

import jax
import pytest

from repro import configs
from repro.core import SparsifyConfig
from repro.models import get_model
from repro.runtime.metrics import (RequestMetrics, format_summary, histogram,
                                   histogram_str, percentiles, summarize)
from repro.runtime.telemetry import (Counter, MetricsRegistry, TraceBuffer,
                                     validate_trace_events)
from repro.serving import (NULL_TRACER, NullTracer, SamplingParams,
                           ServingEngine, ServingTracer, Status)
from repro.serving.observe import NULL_SPAN

CFG = dataclasses.replace(configs.get_smoke("llama-paper"),
                          name="observe-test", n_layers=2, d_model=128,
                          n_heads=4, n_kv_heads=4, head_dim=32, d_ff=256,
                          vocab=512, remat=False)
GEN = 6


@pytest.fixture(scope="module")
def dense_params():
    return get_model(CFG).init(jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def sparse_params(dense_params):
    from repro.models.sparse_serving import sparsify_for_serving
    scfg = SparsifyConfig(weight_pattern="8:16", outlier_pattern="16:256",
                          scorer="magnitude", use_smoothquant=False)
    sp, report = sparsify_for_serving(dense_params, scfg)
    assert report["n_layers_sparsified"] > 0
    return sp


def _prompts(n, length, seed=1):
    key = jax.random.PRNGKey(seed)
    return [t.tolist() for t in
            jax.random.randint(key, (n, length), 0, CFG.vocab)]


def _run(params, prompts, gen, tracer=None, **kw):
    engine = ServingEngine(CFG, params, tracer=tracer, **kw)
    reqs = [engine.submit(p, SamplingParams(max_new_tokens=gen))
            for p in prompts]
    engine.run()
    return engine, reqs


def _summaries(tracer):
    """{request_id: args} of every request_summary event in the buffer."""
    return {e["args"]["id"]: e["args"] for e in tracer.buffer.events
            if e.get("name") == "request_summary"}


def _check_lifecycle_agreement(tracer, reqs):
    """The acceptance property: trace events reconstruct each request's
    lifecycle in exact agreement with its RequestMetrics."""
    summaries = _summaries(tracer)
    for r in reqs:
        m = r.metrics
        s = summaries[r.request_id]
        assert s["status"] == r.status.value
        assert s["admitted"] == m.admitted
        assert s["first_token"] == m.first_token
        assert s["finished"] == m.finished
        assert s["n_tokens"] == m.n_tokens == len(r.tokens)
        assert s["prefill_chunks"] == m.prefill_chunks
        assert s["n_preemptions"] == m.n_preemptions
        assert s["last_preempt_reason"] == m.last_preempt_reason


# --------------------------------------------------------------------------
# disabled tracing: free, and invisible in the token stream
# --------------------------------------------------------------------------

def test_null_tracer_is_default_and_allocation_free(dense_params):
    engine = ServingEngine(CFG, dense_params, n_slots=2, max_len=32)
    assert engine.tracer is NULL_TRACER
    assert engine.adapter.tracer is NULL_TRACER
    # every hook returns a shared singleton or None — nothing per call
    t = NullTracer()
    assert t.enabled is False
    assert t.begin_step(3, 0.0) is NULL_SPAN
    assert t.begin_phase("plan", tokens=7) is NULL_SPAN
    assert t.attach(engine) is t
    for hook in (t.end_step, t.end_phase, t.instant, t.on_submit,
                 t.on_admit, t.on_chunk, t.on_prefill_complete,
                 t.on_preempt, t.on_finish, t.on_evict):
        pass  # existence; no-arg-shape enforcement below via real run
    assert t.end_phase() is None
    assert t.instant("x", a=1) is None
    # jit_call is a bare passthrough
    assert t.jit_call("step", lambda a, b: a + b, (2, 3)) == 5
    with NULL_SPAN:
        pass  # usable as an inert context manager


@pytest.mark.parametrize("which", ["dense", "sparse"])
@pytest.mark.parametrize("layout", ["slot", "paged"])
def test_traced_tokens_identical_and_trace_agrees(which, layout, tmp_path,
                                                  dense_params,
                                                  sparse_params):
    params = dense_params if which == "dense" else sparse_params
    prompts = _prompts(3, 16)
    kw = dict(n_slots=3, max_len=32, kv_layout=layout)
    _, ref = _run(params, prompts, GEN, tracer=None, **kw)
    tracer = ServingTracer()
    engine, reqs = _run(params, prompts, GEN, tracer=tracer, **kw)
    assert engine.tracer is tracer
    for rr, r in zip(ref, reqs):
        assert r.status is Status.FINISHED
        assert r.tokens == rr.tokens, "tracing changed the token stream"
    _check_lifecycle_agreement(tracer, reqs)
    # the written file is valid trace_event JSON with the span inventory
    path = tmp_path / "trace.json"
    tracer.write_trace(str(path))
    events = validate_trace_events(json.loads(path.read_text()))
    names = {e["name"] for e in events}
    assert {"step", "plan", "decode", "emit", "queued", "prefill",
            "request_summary"} <= names
    # per-step spans live in the engine process, lifecycles in requests
    assert any(e["pid"] == tracer._pid_engine for e in events)
    assert any(e["pid"] == tracer._pid_requests for e in events)


def test_preemption_lifecycle_in_trace(dense_params):
    """A starved paged arena forces preempt-to-queue; the trace carries the
    preemption instants and the summaries agree with RequestMetrics."""
    prompts = _prompts(4, 16, seed=9)
    tracer = ServingTracer()
    engine, reqs = _run(dense_params, prompts, 12, tracer=tracer,
                        n_slots=4, max_len=40, kv_layout="paged",
                        block_size=8, n_blocks=10, prefix_caching=False)
    assert engine.n_preemptions > 0
    assert all(r.status is Status.FINISHED for r in reqs)
    _check_lifecycle_agreement(tracer, reqs)
    summaries = _summaries(tracer)
    preempted = [r for r in reqs if r.metrics.n_preemptions > 0]
    assert preempted
    for r in preempted:
        assert summaries[r.request_id]["last_preempt_reason"] != ""
    # victim instants on both the engine track and the request track
    ev_names = [(e["name"], e.get("cat")) for e in tracer.buffer.events]
    assert ("preempt", "engine") in ev_names
    assert ("preempted", "request") in ev_names
    # counter: every engine-counted preemption is attributed to a reason
    reg = tracer.registry.snapshot()
    total = sum(reg["serving_preemptions_total"].values())
    assert total == engine.n_preemptions


def test_prefix_cache_hit_lifecycle(dense_params):
    """Second submission of the same prompt hits the prefix cache; the
    trace records the lookup, the matched depth, and the summary's
    cached_tokens."""
    prompt = _prompts(1, 16, seed=3)[0]
    tracer = ServingTracer()
    engine = ServingEngine(CFG, dense_params, n_slots=2, max_len=40,
                           kv_layout="paged", block_size=8, n_blocks=16,
                           prefix_caching=True, tracer=tracer)
    r1 = engine.submit(prompt, SamplingParams(max_new_tokens=GEN))
    engine.run()
    r2 = engine.submit(prompt, SamplingParams(max_new_tokens=GEN))
    engine.run()
    assert r1.tokens == r2.tokens
    _check_lifecycle_agreement(tracer, [r1, r2])
    summaries = _summaries(tracer)
    assert summaries[r1.request_id]["cached_tokens"] == 0
    assert summaries[r2.request_id]["cached_tokens"] > 0
    reg = tracer.registry
    assert reg.counter("serving_prefix_cache_lookups_total").get(
        engine=tracer.name, family="dense") == 2
    assert reg.counter("serving_prefix_cache_hits_total").get(
        engine=tracer.name, family="dense") == 1
    assert reg.counter("serving_prefix_cache_hit_tokens_total").get(
        engine=tracer.name, family="dense") == \
        summaries[r2.request_id]["cached_tokens"]
    hits = [e for e in tracer.buffer.events
            if e.get("name") == "prefix_cache" and e["args"]["hit"]]
    assert len(hits) == 1


def test_counters_and_attribution(dense_params):
    prompts = _prompts(3, 16)
    tracer = ServingTracer()
    engine, reqs = _run(dense_params, prompts, GEN, tracer=tracer,
                        n_slots=3, max_len=32)
    lb = dict(engine=tracer.name, family="dense")
    reg = tracer.registry
    assert reg.counter("serving_tokens_decoded_total").get(**lb) == \
        sum(len(r.tokens) for r in reqs)
    assert reg.counter("serving_tokens_prefilled_total").get(**lb) == \
        sum(len(p) for p in prompts)
    assert reg.counter("serving_requests_finished_total").get(
        status="finished", **lb) == len(reqs)
    assert reg.counter("serving_steps_total").get(**lb) == engine.n_steps
    # jit attribution: at least the prefill-step and decode variants, each
    # wall-clocked; compiles counted once per variant
    attr = tracer.attribution()
    kinds = {v["kind"] for v in attr.values()}
    assert {"step", "decode"} <= kinds
    for v in attr.values():
        assert v["calls"] > 0 and v["total_s"] > 0
        assert "flops" in v and "bytes_accessed" in v
    n_variants = len(attr)
    compiles = sum(reg.counter("serving_jit_compiles_total")
                   .series().values())
    retraces = sum(reg.counter("serving_jit_retraces_total")
                   .series().values())
    assert compiles + retraces == n_variants
    # prometheus text renders every family with HELP/TYPE headers
    text = tracer.counters_text()
    assert "# TYPE serving_tokens_decoded_total counter" in text
    assert f'engine="{tracer.name}"' in text


def test_shared_buffer_multi_engine(dense_params):
    """Two engines share one buffer+registry: disjoint pid pairs, distinct
    engine labels (dense and sparse engines share family=dense)."""
    buf, reg = TraceBuffer(), MetricsRegistry()
    prompts = _prompts(2, 8, seed=5)
    t1 = ServingTracer(buffer=buf, registry=reg, name="a/slot")
    t2 = ServingTracer(buffer=buf, registry=reg, name="b/paged")
    _run(dense_params, prompts, 4, tracer=t1, n_slots=2, max_len=16)
    _run(dense_params, prompts, 4, tracer=t2, n_slots=2, max_len=16,
         kv_layout="paged", block_size=8, n_blocks=8)
    assert {t1._pid_engine, t1._pid_requests}.isdisjoint(
        {t2._pid_engine, t2._pid_requests})
    decoded = reg.counter("serving_tokens_decoded_total")
    assert decoded.get(engine="a/slot", family="dense") == 8
    assert decoded.get(engine="b/paged", family="dense") == 8
    events = validate_trace_events(buf.to_json())
    assert {e["pid"] for e in events} >= {t1._pid_engine, t2._pid_engine}


# --------------------------------------------------------------------------
# telemetry primitives
# --------------------------------------------------------------------------

def test_counter_monotonic_and_labels():
    c = Counter("c_total")
    c.inc()
    c.inc(2, family="x")
    assert c.get() == 1 and c.get(family="x") == 2
    with pytest.raises(ValueError):
        c.inc(-1)


def test_registry_kind_collision_and_reuse():
    reg = MetricsRegistry()
    c = reg.counter("n_total", "help")
    assert reg.counter("n_total") is c
    with pytest.raises(ValueError):
        reg.gauge("n_total")
    g = reg.gauge("depth")
    g.set(3, q="a")
    g.set(1, q="a")
    assert g.get(q="a") == 1           # gauges are last-write
    snap = reg.snapshot()
    assert snap["n_total"] == {"": 0.0} or snap["n_total"] == {}
    text = reg.prometheus_text()
    assert "# TYPE n_total counter" in text
    assert 'depth{q="a"} 1' in text


def test_trace_buffer_dedup_and_clamp():
    buf = TraceBuffer()
    buf.set_process_name(1, "p")
    buf.set_process_name(1, "p again")    # deduped
    buf.set_thread_name(1, 2, "t")
    buf.set_thread_name(1, 2, "t again")  # deduped
    buf.complete("span", 10.0, -5.0)      # negative dur clamps to 0
    assert len(buf) == 3
    assert buf.events[-1]["dur"] == 0.0
    assert buf.to_json()["displayTimeUnit"] == "ms"


def test_validate_trace_events_accepts_and_rejects():
    ok = {"traceEvents": [
        {"ph": "X", "name": "s", "ts": 0, "dur": 1, "pid": 1, "tid": 0},
        {"ph": "i", "name": "e", "ts": 2, "pid": 1, "tid": 0, "s": "t"},
        {"ph": "M", "name": "process_name", "pid": 1, "tid": 0,
         "args": {"name": "p"}}]}
    assert len(validate_trace_events(ok)) == 3
    assert len(validate_trace_events(ok["traceEvents"])) == 3  # bare array
    with pytest.raises(ValueError):
        validate_trace_events({"notTrace": 1})
    with pytest.raises(ValueError):
        validate_trace_events([{"name": "no-ph"}])
    with pytest.raises(ValueError):
        validate_trace_events([{"ph": "X", "name": "s", "ts": 0}])  # no dur
    with pytest.raises(ValueError):
        validate_trace_events([{"ph": "i", "name": "s"}])           # no ts


# --------------------------------------------------------------------------
# runtime/metrics edge cases
# --------------------------------------------------------------------------

def _req(n_tokens, family="", n_pre=0, reason="", chunks=1):
    m = RequestMetrics(family=family, arrival=0.0, admitted=0.1,
                       first_token=0.2, finished=1.0, n_tokens=n_tokens,
                       prefill_chunks=chunks, n_preemptions=n_pre,
                       last_preempt_reason=reason)
    m.itl = [0.01] * max(n_tokens - 1, 0)
    return m


def test_summarize_empty_window():
    s = summarize([], wall_s=0.0)
    assert s["n_requests"] == 0 and s["total_tokens"] == 0
    assert math.isnan(s["tok_per_s"])
    assert math.isnan(s["ttft"]["p50"]) and math.isnan(s["itl"]["p99"])
    assert s["prefill_chunks"]["hist"] == {}
    assert math.isnan(s["prefill_chunks"]["mean"])
    assert s["preemptions"] == {"total": 0, "n_requests_preempted": 0,
                                "max_per_request": 0, "by_reason": {}}
    line = format_summary("empty", s)
    assert "nan" not in line   # no "nanms" segments for an empty window


def test_summarize_all_single_token():
    """Single-token requests have no tpot; the summary line must not print
    nanms for it (the original bug this PR's satellite fixes)."""
    s = summarize([_req(1), _req(1)], wall_s=2.0)
    assert s["n_requests"] == 2
    assert math.isnan(s["tpot"]["p99"])
    assert not math.isnan(s["ttft"]["p99"])   # ttft exists from token one
    line = format_summary("single", s)
    assert "nan" not in line
    assert "ttft" in line and "tpot" not in line


def test_summarize_mixed_family_keys():
    s = summarize([_req(4, family="dense"), _req(4, family="ssm")],
                  wall_s=1.0)
    assert set(s["families"]) == {"dense", "ssm"}
    for fam in ("dense", "ssm"):
        assert s["families"][fam]["n_requests"] == 1
        assert s["families"][fam]["total_tokens"] == 4
    # requests served outside an engine (family unset) get no breakdown
    assert "families" not in summarize([_req(4)], 1.0)
    # a single NAMED family still gets its breakdown (the benchmark keys
    # per-family lines off it even when one family dominates a window)
    assert set(summarize([_req(4, family="dense")], 1.0)["families"]) == \
        {"dense"}


def test_summarize_preemption_block():
    s = summarize([_req(4, n_pre=2, reason="decode_pressure"),
                   _req(4, n_pre=1, reason="prefill_pressure"),
                   _req(4)], wall_s=1.0)
    assert s["preemptions"]["total"] == 3
    assert s["preemptions"]["n_requests_preempted"] == 2
    assert s["preemptions"]["max_per_request"] == 2
    assert s["preemptions"]["by_reason"] == {"decode_pressure": 1,
                                             "prefill_pressure": 1}
    assert "| preempt 3" in format_summary("pre", s)


def test_histogram_numeric_sort():
    h = histogram([10, 2, 10, 1, 2, 10])
    assert list(h) == ["1", "2", "10"]          # numeric, not lexical
    assert h == {"1": 1, "2": 2, "10": 3}
    assert histogram_str(["b", "a", "b"]) == {"a": 1, "b": 2}
    assert list(histogram_str(["b", "a"])) == ["a", "b"]


def test_percentiles_nan_paths():
    p = percentiles([])
    assert math.isnan(p["p50"]) and math.isnan(p["p99"])
    p = percentiles([1.0])
    assert p["p50"] == 1.0 and p["p99"] == 1.0
    # tpot property guards the 0/1-token cases
    assert _req(1).tpot == 0.0
    assert _req(0).tpot == 0.0
    assert _req(5).tpot == pytest.approx((1.0 - 0.2) / 4)
