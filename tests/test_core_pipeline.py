"""Tests for scoring, equalization, variance correction, outliers, packing,
and the 4-stage pipeline."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:                    # deterministic seeded sweep fallback
    from hypothesis_fallback import given, settings, st

from repro.core import (ActStats, score, ria_score, smoothquant_scales,
                        equalize_weights, equalized_view_for_scoring,
                        variance_correction_factor, apply_variance_correction,
                        extract_structured_outliers, unstructured_outlier_mask,
                        SparsifyConfig, sparsify_linear, dense_effective_weight,
                        pack_nm, nm_mask, unpack_metadata, compression_report)
from repro.core.equalize import check_equivalence


@pytest.fixture(scope="module")
def wx():
    key = jax.random.PRNGKey(0)
    w = jax.random.normal(key, (64, 512), jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (128, 512))
    # inject activation outliers in a few channels (the paper's setting)
    x = x.at[:, :8].mul(25.0)
    return w, x


def _stats(x):
    return ActStats.init(x.shape[-1]).update(x)


class TestScoring:
    def test_shapes_and_nonneg(self, wx):
        w, x = wx
        st_ = _stats(x)
        for m in ("magnitude", "wanda", "ria"):
            s = score(m, w, st_)
            assert s.shape == w.shape
            assert (np.asarray(s) >= 0).all()

    def test_ria_prefers_activation_outlier_channels(self, wx):
        w, x = wx
        st_ = _stats(x)
        s = ria_score(w, st_.l2)
        # average score on boosted channels must exceed the rest
        assert float(s[:, :8].mean()) > float(s[:, 8:].mean())

    def test_wanda_scales_with_activation(self, wx):
        w, x = wx
        st_ = _stats(x)
        s = score("wanda", w, st_)
        ratio = float(s[:, :8].mean() / s[:, 8:].mean())
        assert ratio > 5.0


class TestEqualize:
    def test_math_equivalence(self, wx):
        """(W*s)(x/s) == W x — Eq. 1."""
        w, x = wx
        scales = smoothquant_scales(w, _stats(x).max_abs)
        lhs, rhs = check_equivalence(w, x, scales)
        np.testing.assert_allclose(np.asarray(lhs), np.asarray(rhs),
                                   rtol=1e-4, atol=1e-3)

    def test_weights_unchanged_by_pipeline(self, wx):
        """Equalization only affects the scoring view (paper impl. note)."""
        w, x = wx
        view = equalized_view_for_scoring(w, _stats(x).max_abs)
        assert not np.allclose(np.asarray(view), np.asarray(w))
        # original w untouched (functional), and effective weight values come
        # from w not view:
        cfg = SparsifyConfig(outlier_pattern=None)
        sl = sparsify_linear(w, _stats(x), cfg)
        eff = np.asarray(dense_effective_weight(w, sl, cfg))
        kept = eff != 0
        # non-VC entries are exactly original values under use_vc=False
        cfg2 = dataclasses.replace(cfg, use_variance_correction=False)
        sl2 = sparsify_linear(w, _stats(x), cfg2)
        eff2 = np.asarray(dense_effective_weight(w, sl2, cfg2))
        w_np = np.asarray(w)
        assert np.array_equal(eff2[eff2 != 0], w_np[eff2 != 0])


class TestVarianceCorrection:
    def test_restores_variance(self, wx):
        w, _ = wx
        mask = np.asarray(nm_mask(jnp.abs(w), (2, 4)))
        corrected = np.asarray(apply_variance_correction(w, jnp.asarray(mask)))
        kept = corrected[mask]
        assert kept.var() == pytest.approx(float(jnp.var(w)), rel=1e-3)

    def test_zero_off_mask(self, wx):
        w, _ = wx
        mask = nm_mask(jnp.abs(w), (8, 16))
        corrected = np.asarray(apply_variance_correction(w, mask))
        assert (corrected[~np.asarray(mask)] == 0).all()

    @settings(max_examples=20, deadline=None)
    @given(st.integers(0, 9999))
    def test_property_factor_ge_one_for_magnitude_pruning(self, seed):
        """Magnitude pruning keeps large entries -> variance of kept exceeds
        dense -> factor < 1; random masks -> factor ~ 1. Both stay finite."""
        w = jax.random.normal(jax.random.PRNGKey(seed), (32, 64))
        mask = nm_mask(jnp.abs(w), (2, 4))
        f = float(variance_correction_factor(w, mask))
        assert np.isfinite(f) and 0.1 < f < 10.0


class TestOutliers:
    def test_structured_roundtrip(self, wx):
        w, x = wx
        s = score("ria", w, _stats(x))
        o = extract_structured_outliers(w, s, (16, 256))
        dense = np.asarray(o.to_dense())
        mask = np.asarray(o.mask())
        assert mask.sum() == w.shape[0] * (w.shape[1] // 256) * 16
        np.testing.assert_array_equal(dense[mask], np.asarray(w)[mask])
        assert (dense[~mask] == 0).all()

    def test_unstructured_budget(self, wx):
        w, x = wx
        s = score("ria", w, _stats(x))
        m = unstructured_outlier_mask(s, 16 / 256)
        frac = float(jnp.mean(m.astype(jnp.float32)))
        assert frac == pytest.approx(16 / 256, rel=0.05)


class TestPacking:
    @pytest.mark.parametrize("n,m", [(2, 4), (4, 8), (8, 16)])
    def test_roundtrip(self, wx, n, m):
        w, _ = wx
        mask = nm_mask(jnp.abs(w), (n, m))
        pruned = jnp.where(mask, w, 0)
        pk = pack_nm(pruned, mask, (n, m))
        np.testing.assert_array_equal(np.asarray(pk.to_dense()),
                                      np.asarray(pruned))
        meta = pk.packed_metadata()
        np.testing.assert_array_equal(np.asarray(unpack_metadata(meta, n)),
                                      np.asarray(pk.indices))

    def test_compression_report(self):
        rep = compression_report(4096, 4096, "8:16", "16:256")
        assert rep["ratio"] < 0.66                     # beats dense by >1.5x
        assert rep["nm_meta_bytes"] == 4096 * 4096 * 0.875 / 8


class TestPipeline:
    def test_density_and_structure(self, wx):
        w, x = wx
        cfg = SparsifyConfig(weight_pattern="8:16", outlier_pattern="16:256")
        sl = sparsify_linear(w, _stats(x), cfg)
        from repro.core import validate_nm_mask
        assert bool(validate_nm_mask(sl.nm_mask, (8, 16)))
        eff = dense_effective_weight(w, sl, cfg)
        density = float(jnp.mean((eff != 0).astype(jnp.float32)))
        assert 0.45 <= density <= 0.57

    def test_salient_values_exact(self, wx):
        """Outliers must survive pruning bit-exact (incl. under VC)."""
        w, x = wx
        cfg = SparsifyConfig(weight_pattern="2:4", outlier_pattern="16:256")
        sl = sparsify_linear(w, _stats(x), cfg)
        eff = np.asarray(dense_effective_weight(w, sl, cfg))
        sm = np.asarray(sl.salient_mask)
        np.testing.assert_array_equal(eff[sm], np.asarray(w)[sm])

    def test_reconstruction_better_with_outliers(self, wx):
        """Recovering outliers reduces layer output error (paper Table 5)."""
        w, x = wx
        st_ = _stats(x)
        y_ref = np.asarray(x @ w.T)
        errs = {}
        for op in (None, "4:256", "16:256"):
            cfg = SparsifyConfig(weight_pattern="2:4", outlier_pattern=op,
                                 scorer="ria")
            sl = sparsify_linear(w, st_, cfg)
            eff = dense_effective_weight(w, sl, cfg)
            errs[op] = float(np.square(np.asarray(x @ eff.T) - y_ref).mean())
        assert errs["4:256"] < errs[None]
        assert errs["16:256"] < errs["4:256"]
