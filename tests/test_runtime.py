"""Checkpoint/restore, fault tolerance, data determinism, optimizer,
gradient compression."""
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data.pipeline import SyntheticLM
from repro.optim import (AdamWConfig, adamw_init, adamw_step, grad_compress)
from repro.runtime.checkpoint import CheckpointManager
from repro.runtime.fault_tolerance import (HeartbeatRegistry, HostFailure,
                                           StragglerDetector, TrainSupervisor)


class TestCheckpoint:
    def test_roundtrip(self, tmp_path):
        cm = CheckpointManager(tmp_path)
        tree = {"a": jnp.arange(12.0).reshape(3, 4),
                "b": {"c": jnp.ones((2,), jnp.int32)}}
        cm.save(5, tree)
        restored, manifest = cm.restore(tree)
        assert manifest["step"] == 5
        np.testing.assert_array_equal(np.asarray(restored["a"]),
                                      np.asarray(tree["a"]))

    def test_async_and_gc(self, tmp_path):
        cm = CheckpointManager(tmp_path, keep_last_k=2)
        tree = {"w": jnp.ones((8, 8))}
        for s in (1, 2, 3, 4):
            cm.save(s, tree, blocking=False)
        cm.wait()
        cm._gc()
        steps = sorted(int(p.name.split("_")[1]) for p in tmp_path.glob("step_*"))
        assert steps == [3, 4]
        assert cm.latest_step() == 4

    def test_atomic_commit_no_partial(self, tmp_path):
        cm = CheckpointManager(tmp_path)
        (tmp_path / "step_000009.tmp").mkdir()     # simulated crash leftovers
        cm2 = CheckpointManager(tmp_path)          # new run GCs stale tmp
        assert not (tmp_path / "step_000009.tmp").exists()
        assert cm2.latest_step() is None


class TestFaultTolerance:
    def test_heartbeat(self):
        hb = HeartbeatRegistry(timeout_s=10)
        hb.beat(0, now=100.0)
        hb.beat(1, now=100.0)
        assert hb.healthy(now=105.0)
        hb.beat(0, now=112.0)
        assert hb.dead_hosts(now=115.0) == [1]

    def test_straggler_detection(self):
        sd = StragglerDetector(min_steps=3, k_sigma=2.0)
        for step in range(6):
            for h in range(8):
                sd.record(h, 1.0 + (3.0 if h == 5 else 0.0))
        assert sd.stragglers() == [5]

    def test_supervisor_restart_resumes_from_checkpoint(self, tmp_path):
        cm = CheckpointManager(tmp_path)
        sup = TrainSupervisor(cm, save_every=5)
        fail_once = {"armed": True}

        def make_state(restored):
            return restored if restored is not None else {"x": jnp.zeros(())}

        def step_fn(state, step):
            if step == 12 and fail_once["armed"]:
                fail_once["armed"] = False
                raise HostFailure("boom")
            return {"x": state["x"] + 1}, {"loss": float(state["x"])}

        rep = sup.run(make_state, step_fn, total_steps=20)
        assert rep.restarts == 1
        assert rep.restored_steps == [10]          # resumed at last commit
        assert float(rep.losses[-1]) == 19.0       # state monotone, no gap


class TestData:
    def test_deterministic_resume(self):
        d1 = SyntheticLM(vocab=128, seq_len=16, batch=4, seed=3)
        d2 = SyntheticLM(vocab=128, seq_len=16, batch=4, seed=3)
        b1 = d1.batch_at(17)
        b2 = d2.batch_at(17)
        np.testing.assert_array_equal(b1["tokens"], b2["tokens"])

    def test_hosts_get_disjoint_streams(self):
        d = SyntheticLM(vocab=128, seq_len=16, batch=4, seed=3)
        assert not np.array_equal(d.batch_at(0, host=0)["tokens"],
                                  d.batch_at(0, host=1)["tokens"])

    def test_labels_shifted(self):
        d = SyntheticLM(vocab=128, seq_len=16, batch=4)
        b = d.batch_at(0)
        np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])

    def test_learnable_structure(self):
        """Bigram stream must be far from uniform (so PPL orderings mean
        something): next-token conditional entropy << log2(V)."""
        d = SyntheticLM(vocab=64, seq_len=256, batch=8, seed=0)
        b = d.batch_at(0)
        toks = b["tokens"]
        # empirical conditional entropy via bigram counts
        counts = np.zeros((64, 64))
        for row in toks:
            for a, c in zip(row[:-1], row[1:]):
                counts[a, c] += 1
        p = counts / np.maximum(counts.sum(1, keepdims=True), 1)
        with np.errstate(divide="ignore", invalid="ignore"):
            h = -np.nansum(p * np.log2(np.where(p > 0, p, np.nan)), axis=1)
        assert np.nanmean(h) < 0.7 * np.log2(64)


class TestOptim:
    def test_adamw_reduces_loss(self):
        cfg = AdamWConfig(lr=0.1, weight_decay=0.0)
        w = {"w": jnp.array([5.0, -3.0])}
        opt = adamw_init(w, cfg)
        for _ in range(50):
            g = jax.grad(lambda p: jnp.sum(p["w"] ** 2))(w)
            w, opt, _ = adamw_step(w, g, opt, cfg)
        assert float(jnp.abs(w["w"]).max()) < 1.0

    def test_masked_update_keeps_sparsity(self):
        cfg = AdamWConfig(lr=0.1, weight_decay=0.0)
        w = {"w": jnp.array([1.0, 0.0, 2.0, 0.0])}
        mask = {"w": jnp.array([True, False, True, False])}
        opt = adamw_init(w, cfg)
        for _ in range(5):
            g = jax.grad(lambda p: jnp.sum((p["w"] - 3.0) ** 2))(w)
            w, opt, _ = adamw_step(w, g, opt, cfg, mask=mask)
        assert float(w["w"][1]) == 0.0 and float(w["w"][3]) == 0.0
        assert float(w["w"][0]) != 1.0

    def test_grad_clip(self):
        cfg = AdamWConfig(lr=1.0, grad_clip=1.0, weight_decay=0.0)
        w = {"w": jnp.zeros(3)}
        opt = adamw_init(w, cfg)
        g = {"w": jnp.array([100.0, 0.0, 0.0])}
        _, _, metrics = adamw_step(w, g, opt, cfg)
        assert float(metrics["grad_norm"]) == pytest.approx(100.0)


class TestGradCompression:
    def test_error_feedback_converges(self):
        """Quantization error is carried: the sum of dequantized grads tracks
        the sum of true grads to within one quantization step."""
        g = {"w": jnp.linspace(-1, 1, 512)}
        err = grad_compress.init_error(g)
        total_q = jnp.zeros(512)
        for _ in range(20):
            q, err = grad_compress.compress_with_feedback(g, err)
            total_q += grad_compress.decompress(q, g)["w"]
        np.testing.assert_allclose(np.asarray(total_q),
                                   np.asarray(20 * g["w"]), atol=2e-2)

    def test_int8_payload(self):
        g = {"w": jnp.ones((64, 64))}
        q, _ = grad_compress.compress_with_feedback(g, grad_compress.init_error(g))
        payload, scale = jax.tree.leaves(q["w"])[0], jax.tree.leaves(q["w"])[1]
        qd = q["w"][0]
        assert qd.dtype == jnp.int8
